//! Minimal, offline, API-compatible subset of the `anyhow` crate covering
//! exactly the surface `swapless` uses: [`Error`], [`Result`], the
//! [`anyhow!`] macro, the [`Context`] extension trait, and
//! [`Error::msg`]. The error is a plain message string — no backtraces,
//! no downcasting — which is all the coordinator/runtime layers need.

use std::fmt;

/// A string-backed error value. Like the real `anyhow::Error`, it does
/// NOT implement `std::error::Error` itself so that the blanket
/// `From<E: std::error::Error>` conversion (what makes `?` work on
/// `io::Result` etc.) does not collide with `impl From<T> for T`.
pub struct Error {
    msg: String,
}

impl Error {
    /// Create an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            msg: message.to_string(),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(&e)
    }
}

/// `anyhow::Result<T>` — with the same defaulted error parameter as the
/// real crate, so `Result<T, String>` written against this alias works.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to an error, replicating `anyhow::Context`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(&$err)
    };
}

/// `bail!(...)` — early-return an error (provided for completeness).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn macro_forms() {
        let plain = anyhow!("plain message");
        assert_eq!(plain.to_string(), "plain message");
        let x = 7;
        let inline = anyhow!("value {x} here");
        assert_eq!(inline.to_string(), "value 7 here");
        let formatted = anyhow!("a {} b {:?}", 1, "q");
        assert_eq!(formatted.to_string(), "a 1 b \"q\"");
        let from_string = anyhow!(String::from("already a string"));
        assert_eq!(from_string.to_string(), "already a string");
    }

    #[test]
    fn question_mark_on_std_errors() {
        fn io_fail() -> Result<()> {
            std::fs::read("/definitely/not/a/real/path/xyz")?;
            Ok(())
        }
        assert!(io_fail().is_err());
    }

    #[test]
    fn context_wraps() {
        let r: std::result::Result<(), String> = Err("inner".into());
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
        let r: std::result::Result<(), String> = Err("inner".into());
        let e = r.with_context(|| format!("outer{}", 2)).unwrap_err();
        assert_eq!(e.to_string(), "outer2: inner");
    }

    #[test]
    fn msg_from_display() {
        let e = Error::msg(42);
        assert_eq!(e.to_string(), "42");
    }
}
