//! Offline stub of the `xla` (xla-rs) PJRT API surface used by the
//! swapless runtime layer (`runtime::Engine`).
//!
//! The build environment carries no XLA C++ distribution, so this crate
//! provides the same types and signatures with every entry point
//! returning a descriptive error at runtime. The analytic model,
//! allocator, simulator, and coordinator logic never touch PJRT, so all
//! tier-1 tests run unaffected; the integration tests that do need real
//! execution skip themselves when no artifacts are present.
//!
//! To run against real AOT artifacts, replace this path dependency with
//! the actual `xla` crate (same API) in `rust/Cargo.toml`.

use std::fmt;
use std::rc::Rc;

const UNAVAILABLE: &str =
    "XLA/PJRT backend unavailable: swapless was built against the offline xla stub \
     (rust/vendor/xla); swap in the real xla crate to execute artifacts";

/// Stub error type; `Display` is all the caller formatting needs.
pub struct Error(String);

impl Error {
    fn unavailable() -> Error {
        Error(UNAVAILABLE.to_string())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// PJRT client handle. Like the real client it is deliberately `!Send`
/// (`Rc`-based) so the `ExecService` single-executor-thread discipline
/// is still enforced by the compiler against the stub.
pub struct PjRtClient {
    _not_send: Rc<()>,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(Error::unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(Error::unavailable())
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(Error::unavailable())
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Host literal (stub).
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Err(Error::unavailable())
    }

    pub fn to_tuple1(&self) -> Result<Literal, Error> {
        Err(Error::unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(Error::unavailable())
    }
}

/// Device buffer (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(Error::unavailable())
    }
}

/// Compiled executable (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error::unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_errors_are_descriptive() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("offline xla stub"));
        assert!(HloModuleProto::from_text_file("x").is_err());
        assert!(Literal::vec1(&[1.0]).reshape(&[1]).is_err());
        assert!(PjRtBuffer.to_literal_sync().is_err());
        assert!(PjRtLoadedExecutable.execute::<Literal>(&[]).is_err());
    }
}
