//! DES raw-speed bench: ≥1M-request workloads through the simulator on
//! both event-queue implementations, reporting wall-clock events/sec and
//! simulated requests per wall-minute. CI runs this as the throughput
//! guard: the calendar queue must sustain at least
//! [`TARGET_REQ_PER_MIN`] simulated requests per minute on the
//! single-device workload, or the bench exits non-zero.

use std::time::Instant;

use swapless::analytic::{Config, Tenant};
use swapless::config::HardwareSpec;
use swapless::fleet::{place, run_fleet, Fleet};
use swapless::model::synthetic_model;
use swapless::sim::{QueueKind, SimOptions, Simulator};
use swapless::tpu::{CostModel, SramCache};
use swapless::util::bench::{bench, black_box, print_header, print_row};
use swapless::util::rng::Rng;
use swapless::workload::{generate_arrivals, RateSchedule};

/// The CI floor: simulated requests per wall-clock minute the calendar
/// queue must sustain on the 1M-request single-device workload.
const TARGET_REQ_PER_MIN: f64 = 10_000_000.0;

struct RunStats {
    completed: u64,
    events: u64,
    wall_s: f64,
}

impl RunStats {
    fn events_per_sec(&self) -> f64 {
        self.events as f64 / self.wall_s
    }

    fn req_per_min(&self) -> f64 {
        self.completed as f64 * 60.0 / self.wall_s
    }
}

/// 1M-request single-tenant workload (full-TPU config, ρ ≈ 0.7),
/// arrivals pre-generated outside the timed region.
fn single_device(kind: QueueKind) -> RunStats {
    let cost = CostModel::new(HardwareSpec::default());
    let model = synthetic_model("m", 6, 1_000_000, 500_000_000);
    let service = cost.tpu_service(&model, 6);
    let rate = 0.6 / service;
    let horizon = 1_000_000.0 / rate;
    let tenants = vec![Tenant { model, rate }];
    let cfg = Config::all_tpu(&tenants);
    let schedules = vec![RateSchedule::constant(rate)];
    let mut rng = Rng::new(7);
    let arrivals = generate_arrivals(&schedules, horizon, &mut rng);

    let opts = SimOptions {
        horizon,
        warmup: 0.0,
        seed: 7,
        queue: kind,
        ..SimOptions::default()
    };
    let t0 = Instant::now();
    let mut sim = Simulator::new(&cost, &tenants, cfg, opts);
    let res = sim.run(&arrivals, None);
    let wall_s = t0.elapsed().as_secs_f64();
    RunStats {
        completed: res.per_model.iter().map(|m| m.completed).sum(),
        events: res.events,
        wall_s,
    }
}

/// ~1M requests across a 4-device fleet (8 tenants, two-level placement),
/// replayed through the multi-device DES.
fn fleet_scale(kind: QueueKind) -> RunStats {
    let hw = HardwareSpec::default();
    let cost = CostModel::new(hw.clone());
    let tenants: Vec<Tenant> = (0..8)
        .map(|i| {
            let model = synthetic_model(&format!("m{i}"), 6, 1_000_000, 500_000_000);
            let service = cost.tpu_service(&model, 6);
            // Two tenants per device at ρ ≈ 0.7 once placed.
            Tenant {
                model,
                rate: 0.35 / service,
            }
        })
        .collect();
    let total_rate: f64 = tenants.iter().map(|t| t.rate).sum();
    let horizon = 1_000_000.0 / total_rate;
    let fleet = Fleet::uniform(4, &hw);
    let plan = place(&fleet, &tenants);
    let schedules: Vec<RateSchedule> = tenants
        .iter()
        .map(|t| RateSchedule::constant(t.rate))
        .collect();
    let mut rng = Rng::new(11);
    let arrivals = generate_arrivals(&schedules, horizon, &mut rng);

    let opts = SimOptions {
        horizon,
        warmup: 0.0,
        seed: 11,
        queue: kind,
        ..SimOptions::default()
    };
    let t0 = Instant::now();
    let res = run_fleet(&fleet, &tenants, &plan, &arrivals, &opts);
    let wall_s = t0.elapsed().as_secs_f64();
    RunStats {
        completed: res.completed,
        events: res.per_device.iter().map(|d| d.result.events).sum(),
        wall_s,
    }
}

fn print_run(label: &str, kind: QueueKind, s: &RunStats) {
    println!(
        "  {label} [{kind:<8}]  {:>9} req in {:>6.2} s | {:>12.0} events/s | {:>12.0} sim-req/min",
        s.completed,
        s.wall_s,
        s.events_per_sec(),
        s.req_per_min()
    );
}

fn main() {
    println!("== DES raw speed (1M-request workloads) ==");
    let mut calendar_rpm = 0.0;
    for kind in QueueKind::ALL {
        let s = single_device(kind);
        print_run("single-device", kind, &s);
        if kind == QueueKind::Calendar {
            calendar_rpm = s.req_per_min();
        }
    }
    for kind in QueueKind::ALL {
        let s = fleet_scale(kind);
        print_run("4-device fleet", kind, &s);
    }

    // Carried over from the old bench_sim: the small-mix steady-state
    // run (virtual-seconds per wall-second) and the cache microbenches.
    let cost = CostModel::new(HardwareSpec::default());
    let tenants: Vec<Tenant> = (0..3)
        .map(|i| Tenant {
            model: synthetic_model(&format!("m{i}"), 8, 3_000_000, 900_000_000),
            rate: 4.0,
        })
        .collect();
    let cfg = Config {
        partitions: vec![4, 4, 4],
        cores: vec![2, 1, 1],
    };
    print_header("discrete-event simulator (small mix)");
    let opts = SimOptions {
        horizon: 300.0,
        warmup: 10.0,
        seed: 3,
        ..SimOptions::default()
    };
    let s = bench("simulate 300s x3 models (~18k events)", 5, 1500, || {
        swapless::sim::simulate(&cost, &tenants, &cfg, opts.clone())
    });
    print_row(&s);
    let virt_per_wall = 300.0 / (s.mean_ns / 1e9);
    println!("  -> {virt_per_wall:.0} virtual-seconds per wall-second");

    let s = bench("sram_cache access (hit)", 1000, 200, || {
        let mut c = SramCache::new(8 * 1024 * 1024);
        c.access(1, 4_000_000);
        for _ in 0..100 {
            black_box(c.access(1, 4_000_000));
        }
        c
    });
    print_row(&s);

    let s = bench("sram_cache interleave (miss+evict)", 1000, 200, || {
        let mut c = SramCache::new(8 * 1024 * 1024);
        for i in 0..100 {
            black_box(c.access(i % 2, 6_000_000));
        }
        c
    });
    print_row(&s);

    assert!(
        calendar_rpm >= TARGET_REQ_PER_MIN,
        "throughput regression: calendar queue sustained {calendar_rpm:.0} \
         sim-req/min on the single-device workload (floor {TARGET_REQ_PER_MIN:.0})"
    );
    println!(
        "\nthroughput guard: calendar {calendar_rpm:.0} sim-req/min >= \
         {TARGET_REQ_PER_MIN:.0} floor"
    );
}
