//! Wire-protocol and serving-edge performance guards (EXPERIMENTS.md
//! §Wire):
//!
//! 1. Frame codec round-trip (header encode+decode plus a 512-f32
//!    payload encode+decode) stays under 1 µs.
//! 2. The framing hot path performs **zero heap allocations** after
//!    warmup — proven with the counting allocator, not asserted in a
//!    comment: the codec loop, the `FrameReader` streaming loop, and a
//!    live closed-loop client thread over a real loopback socket.
//! 3. A loopback closed-loop sweep against an emulated single-device
//!    server sustains ≥ 50k req/s.

use std::io::Read;
use std::net::TcpStream;
use std::sync::Arc;

use swapless::config::HardwareSpec;
use swapless::coordinator::{AttachOptions, ServerBuilder};
use swapless::model::{synthetic_model, Manifest};
use swapless::net::loadgen::{self, LoadgenMode, LoadgenOptions, TenantSpec};
use swapless::net::proto::{
    decode_payload, encode_payload, write_frame, FrameHeader, FrameKind, FrameReader, HEADER_BYTES,
};
use swapless::net::{NetListener, NetOptions};
use swapless::runtime::service::ExecBackend;
use swapless::sched::SloClass;
use swapless::tpu::CostModel;
use swapless::util::bench::{bench, black_box, print_header, print_row};
use swapless::util::count_alloc::{thread_allocs, CountingAlloc};
use swapless::workload::RateSchedule;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

const INPUT_LEN: usize = 512; // synthetic models: [1, 8, 8, 8]

/// Codec round-trip: < 1 µs and allocation-free after warmup.
fn frame_codec() {
    let values = [0.5f32; INPUT_LEN];
    let mut payload: Vec<u8> = Vec::with_capacity(INPUT_LEN * 4);
    let mut decoded: Vec<f32> = Vec::with_capacity(INPUT_LEN);
    let mut buf = [0u8; HEADER_BYTES];

    let mut round_trip = || {
        encode_payload(&values, &mut payload);
        let h = FrameHeader::submit(7, 42, Some(SloClass::Interactive), 50, payload.len() as u32);
        h.encode(&mut buf);
        let back = FrameHeader::decode(&buf).expect("own header decodes");
        decode_payload(&payload, &mut decoded).expect("own payload decodes");
        (back.seq, decoded.len())
    };

    let s = bench("frame round-trip (header + 2 KiB payload)", 1000, 300, &mut round_trip);
    print_row(&s);
    assert!(
        s.mean_ns < 1_000.0,
        "frame round-trip {:.0} ns exceeds the 1 µs guard",
        s.mean_ns
    );

    for _ in 0..1_000 {
        black_box(round_trip());
    }
    let before = thread_allocs();
    for _ in 0..10_000 {
        black_box(round_trip());
    }
    let allocs = thread_allocs() - before;
    println!("  codec allocations over 10k round-trips: {allocs}");
    assert_eq!(allocs, 0, "frame codec allocated on the hot path");
}

/// Endless in-memory byte stream of whole frames (wraps at the frame
/// boundary), so the reader loop can run without a socket.
struct FrameTape {
    data: Vec<u8>,
    pos: usize,
}

impl Read for FrameTape {
    fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
        if self.pos == self.data.len() {
            self.pos = 0;
        }
        let n = out.len().min(self.data.len() - self.pos);
        out[..n].copy_from_slice(&self.data[self.pos..self.pos + n]);
        self.pos += n;
        Ok(n)
    }
}

/// The streaming parse loop: zero allocations once the ring has grown.
fn reader_loop() {
    let values = [0.25f32; INPUT_LEN];
    let mut payload = Vec::new();
    encode_payload(&values, &mut payload);
    let mut tape = FrameTape {
        data: Vec::new(),
        pos: 0,
    };
    for seq in 0..16u64 {
        let h = FrameHeader::submit(1, seq, None, 0, payload.len() as u32);
        write_frame(&mut tape.data, &h, &payload).expect("write to vec");
    }

    let mut reader = FrameReader::new();
    let mut step = |reader: &mut FrameReader, tape: &mut FrameTape| {
        let (h, p) = reader
            .next_frame(tape)
            .expect("tape frames parse")
            .expect("tape never ends");
        assert_eq!(h.kind, FrameKind::Submit);
        p.len()
    };

    for _ in 0..1_000 {
        black_box(step(&mut reader, &mut tape));
    }
    let before = thread_allocs();
    for _ in 0..10_000 {
        black_box(step(&mut reader, &mut tape));
    }
    let allocs = thread_allocs() - before;
    println!("  FrameReader allocations over 10k frames: {allocs}");
    assert_eq!(allocs, 0, "FrameReader allocated in steady state");
}

fn tiny_manifest() -> Manifest {
    Manifest {
        kernel_path: "pallas".to_string(),
        models: vec![synthetic_model("wirebench", 1, 500_000, 50_000_000)],
        base_dir: "synthetic".to_string(),
    }
}

/// Live edge: client-thread zero-alloc steady state, then the 50k req/s
/// closed-loop sweep.
fn loopback() {
    let server = Arc::new(
        ServerBuilder::new(&tiny_manifest(), CostModel::new(HardwareSpec::default()))
            .backend(ExecBackend::Emulated)
            .adaptive(false)
            .time_scale(0.0)
            .build()
            .expect("build server"),
    );
    let h = server
        .attach(
            "wirebench",
            AttachOptions {
                rate_hint: 50.0,
                class: SloClass::Standard,
            },
        )
        .expect("attach");
    let listener =
        NetListener::bind(server.clone(), "127.0.0.1:0", NetOptions::default()).expect("bind");
    let addr = listener.local_addr().to_string();

    // Steady-state connection loop, window 1: write a frame, block for
    // the response, repeat. Everything reused; 0 allocations on this
    // thread after warmup (the server side allocates the per-request
    // input tensor by contract — that is the backend's, not the wire's).
    {
        let mut stream = TcpStream::connect(&addr).expect("connect");
        stream.set_nodelay(true).expect("nodelay");
        let mut reader = FrameReader::new();
        let mut payload = Vec::new();
        encode_payload(&[0.5f32; INPUT_LEN], &mut payload);
        let mut seq = 0u64;
        let mut step = |stream: &mut TcpStream, reader: &mut FrameReader, seq: &mut u64| {
            *seq += 1;
            let header = FrameHeader::submit(h.0, *seq, None, 0, payload.len() as u32);
            write_frame(stream, &header, &payload).expect("submit frame");
            loop {
                match reader.next_frame(stream) {
                    Ok(Some((resp, _))) => {
                        assert_eq!(resp.kind, FrameKind::Response, "code {}", resp.code);
                        assert_eq!(resp.seq, *seq);
                        return;
                    }
                    Ok(None) => panic!("server closed mid-run"),
                    Err(e) => panic!("client parse error: {e}"),
                }
            }
        };
        for _ in 0..200 {
            step(&mut stream, &mut reader, &mut seq);
        }
        let before = thread_allocs();
        for _ in 0..1_000 {
            step(&mut stream, &mut reader, &mut seq);
        }
        let allocs = thread_allocs() - before;
        println!("  client-loop allocations over 1k round-trips: {allocs}");
        assert_eq!(allocs, 0, "wire client loop allocated in steady state");
    }

    // Throughput probe: closed loop, 4 connections, deep windows.
    let report = loadgen::run(&LoadgenOptions {
        addr,
        connections: 4,
        duration_s: 2.0,
        mode: LoadgenMode::Closed,
        tenants: vec![TenantSpec {
            handle: h.0,
            schedule: RateSchedule::constant(0.0), // closed loop ignores rates
            class: None,
            deadline_ms: 0,
        }],
        window: 64,
        seed: 42,
    })
    .expect("loadgen");
    println!("  {}", report.line());
    assert_eq!(report.errors, 0, "typed errors under closed-loop load");
    assert!(
        report.rate() >= 50_000.0,
        "loopback closed-loop rate {:.0} req/s below the 50k guard",
        report.rate()
    );

    let net = listener.shutdown();
    println!("  {}", net.line());
    assert_eq!(
        net.frames_in,
        net.responses_ok + net.responses_err,
        "listener accounting must close out"
    );
}

fn main() {
    print_header("network edge (proto + listener + loadgen)");
    frame_codec();
    reader_loop();
    loopback();
}
