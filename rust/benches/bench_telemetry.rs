//! Telemetry overhead bench + CI guards. Two claims back "observability
//! that doesn't tax the data plane" (README §Observability):
//!
//! 1. sampling is cheap at serve granularity: a closed-loop run on the
//!    emulated backend with 1-in-16 span sampling stays within 5% of
//!    the same logged run with sampling off;
//! 2. the span path proper — sampling decision, trace bookkeeping, and
//!    the completion burst into the log channel and the collector —
//!    performs zero heap allocations at steady state, proven by a
//!    counting allocator rather than asserted in a comment.

#[global_allocator]
static ALLOC: swapless::util::count_alloc::CountingAlloc =
    swapless::util::count_alloc::CountingAlloc;

use std::time::Instant;

use swapless::config::HardwareSpec;
use swapless::coordinator::{AttachOptions, ServerBuilder};
use swapless::eventlog::EventLog;
use swapless::model::Manifest;
use swapless::runtime::service::ExecBackend;
use swapless::sched::SloClass;
use swapless::telemetry::{emit_burst, SpanCollector, SpanSampler};
use swapless::tpu::CostModel;
use swapless::util::bench::{bench, print_header, print_row};
use swapless::util::count_alloc::thread_allocs;

const REQS: usize = 2_000;
const ROUNDS: usize = 5;
/// Steady-state sampled bursts the zero-allocation proof covers.
const PROOF_BURSTS: usize = 4_096;

fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("swapless-bench-{name}-{}.log", std::process::id()))
}

/// Drive `n` admissions through the full span path at 1-in-16 sampling:
/// sampling decision, trace field fills, and the burst into both sinks.
fn drive(n: usize, sampler: &SpanSampler, log: &EventLog, collector: &SpanCollector) {
    for i in 0..n {
        let now = i as f64 * 1e-3;
        if let Some(mut tr) = sampler.try_begin(3, now) {
            tr.queued = 0.4e-3;
            tr.swap = if i % 7 == 0 { 1.2e-3 } else { 0.0 };
            tr.tpu = 2.0e-3;
            tr.tpu_end = now + 3.6e-3;
            emit_burst(
                Some(log),
                0,
                (i % 4) as u64,
                SloClass::Standard,
                &tr,
                0.8e-3,
                now + 4.4e-3,
                5,
                Some(collector),
            );
        }
    }
}

/// One closed-loop serve round at the given span cadence; returns req/s.
fn serve_round(log: &EventLog, sample: usize) -> f64 {
    let server = ServerBuilder::new(
        &Manifest::synthetic(),
        CostModel::new(HardwareSpec::default()),
    )
    .backend(ExecBackend::Emulated)
    .adaptive(false)
    .span_sample(sample)
    .log(log.clone())
    .build()
    .unwrap();
    let h = server.attach("mobilenetv2", AttachOptions::default()).unwrap();
    let n: usize = server.model_meta(h).unwrap().input_shape.iter().product();
    let input = vec![0.5f32; n];
    for _ in 0..50 {
        server.submit(h, input.clone()).wait().unwrap();
    }
    let t0 = Instant::now();
    for _ in 0..REQS {
        server.submit(h, input.clone()).wait().unwrap();
    }
    REQS as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    // Zero-allocation proof first, before any bench machinery muddies
    // the thread's counter: warm the path, then assert a steady-state
    // run of sampled bursts allocates nothing on the calling thread.
    print_header("span path allocations (steady state)");
    let path = tmp("telemetry-alloc");
    let log = EventLog::create(&path).unwrap();
    let sampler = SpanSampler::new(16);
    let collector = SpanCollector::new();
    drive(64 * 16, &sampler, &log, &collector);
    let before = thread_allocs();
    drive(PROOF_BURSTS * 16, &sampler, &log, &collector);
    let allocs = thread_allocs() - before;
    println!(
        "span path: {allocs} allocations over {PROOF_BURSTS} sampled bursts \
         ({} spans folded)",
        sampler.sampled()
    );
    assert_eq!(
        allocs, 0,
        "span hot path allocated {allocs} time(s) at steady state"
    );

    // Per-burst cost on the caller's thread (the producer-side price of
    // one sampled completion: up to 4 records + 4 collector folds).
    let mut tr = sampler.try_begin(3, 0.0).expect("counter is at a sample point");
    tr.queued = 0.4e-3;
    tr.swap = 1.2e-3;
    tr.tpu = 2.0e-3;
    tr.tpu_end = 3.6e-3;
    let s = bench("span burst (4 records + folds)", 20, 400, || {
        emit_burst(
            Some(&log),
            0,
            1,
            SloClass::Standard,
            &tr,
            0.8e-3,
            4.4e-3,
            5,
            Some(&collector),
        );
    });
    print_row(&s);
    log.close();
    let _ = std::fs::remove_file(&path);
    assert!(
        s.mean_ns < 4_000.0,
        "span burst regressed: {:.0} ns (4 records should stay under 4 us)",
        s.mean_ns
    );

    // Serve-path guard: best-of-N alternating sampled/unsampled rounds,
    // both logged, so the delta isolates the sampling cost.
    print_header("1-in-16 sampled vs unsampled closed-loop serve (emulated, logged)");
    let path = tmp("telemetry-serve");
    let (mut best_plain, mut best_sampled) = (0f64, 0f64);
    for round in 0..ROUNDS {
        let log = EventLog::create(&path).unwrap();
        let plain = serve_round(&log, 0);
        log.close();
        let log = EventLog::create(&path).unwrap();
        let sampled = serve_round(&log, 16);
        println!(
            "round {round}: unsampled {plain:.0} req/s, sampled {sampled:.0} req/s \
             ({} records)",
            log.appended()
        );
        log.close();
        best_plain = best_plain.max(plain);
        best_sampled = best_sampled.max(sampled);
    }
    let _ = std::fs::remove_file(&path);
    println!(
        "best: unsampled {best_plain:.0} req/s, sampled {best_sampled:.0} req/s ({:+.1}%)",
        (best_sampled / best_plain - 1.0) * 100.0
    );
    assert!(
        best_sampled >= best_plain / 1.05,
        "span sampling costs more than 5% serve throughput: {best_sampled:.0} vs \
         {best_plain:.0} req/s"
    );
}
