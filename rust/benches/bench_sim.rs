//! DES throughput bench: virtual-seconds simulated per wall-second and
//! event-processing cost — the hot path behind every figure regeneration.

use swapless::analytic::{Config, Tenant};
use swapless::config::HardwareSpec;
use swapless::model::synthetic_model;
use swapless::sim::{simulate, SimOptions};
use swapless::tpu::{CostModel, SramCache};
use swapless::util::bench::{bench, black_box, print_header, print_row};

fn main() {
    let cost = CostModel::new(HardwareSpec::default());
    let tenants: Vec<Tenant> = (0..3)
        .map(|i| Tenant {
            model: synthetic_model(&format!("m{i}"), 8, 3_000_000, 900_000_000),
            rate: 4.0,
        })
        .collect();
    let cfg = Config {
        partitions: vec![4, 4, 4],
        cores: vec![2, 1, 1],
    };

    print_header("discrete-event simulator");
    let opts = SimOptions {
        horizon: 300.0,
        warmup: 10.0,
        seed: 3,
        ..SimOptions::default()
    };
    // ~12 rps * 300 s = ~3600 requests, ~5 events each.
    let s = bench("simulate 300s x3 models (~18k events)", 5, 1500, || {
        simulate(&cost, &tenants, &cfg, opts.clone())
    });
    print_row(&s);
    let virt_per_wall = 300.0 / (s.mean_ns / 1e9);
    println!("  -> {virt_per_wall:.0} virtual-seconds per wall-second");

    let s = bench("sram_cache access (hit)", 1000, 200, || {
        let mut c = SramCache::new(8 * 1024 * 1024);
        c.access(1, 4_000_000);
        for _ in 0..100 {
            black_box(c.access(1, 4_000_000));
        }
        c
    });
    print_row(&s);

    let s = bench("sram_cache interleave (miss+evict)", 1000, 200, || {
        let mut c = SramCache::new(8 * 1024 * 1024);
        for i in 0..100 {
            black_box(c.access(i % 2, 6_000_000));
        }
        c
    });
    print_row(&s);
}
