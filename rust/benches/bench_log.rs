//! Event-log overhead bench + CI guards. Two claims back the "off the
//! hot path" design (README §Event log & audit):
//!
//! 1. appending is cheap: the emit side sustains >= 1M records/s
//!    end-to-end (encode + bounded channel + writer thread + fsync on
//!    close), i.e. well above any serve rate the coordinator reaches;
//! 2. logging is free at serve granularity: a logged closed-loop run
//!    on the emulated backend stays within 5% of an unlogged one.

use std::time::Instant;

use swapless::config::HardwareSpec;
use swapless::coordinator::{AttachOptions, ServerBuilder};
use swapless::eventlog::{Event, EventKind, EventLog};
use swapless::model::Manifest;
use swapless::runtime::service::ExecBackend;
use swapless::sched::SloClass;
use swapless::tpu::CostModel;
use swapless::util::bench::{bench, print_header, print_row};

const BURST: u64 = 1_000_000;
const REQS: usize = 2_000;
const ROUNDS: usize = 5;

fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("swapless-bench-{name}-{}.log", std::process::id()))
}

/// One closed-loop serve round; returns requests/second.
fn serve_round(log: Option<&EventLog>) -> f64 {
    let mut b = ServerBuilder::new(
        &Manifest::synthetic(),
        CostModel::new(HardwareSpec::default()),
    )
    .backend(ExecBackend::Emulated)
    .adaptive(false);
    if let Some(l) = log {
        b = b.log(l.clone());
    }
    let server = b.build().unwrap();
    let h = server.attach("mobilenetv2", AttachOptions::default()).unwrap();
    let n: usize = server.model_meta(h).unwrap().input_shape.iter().product();
    let input = vec![0.5f32; n];
    for _ in 0..50 {
        server.submit(h, input.clone()).wait().unwrap();
    }
    let t0 = Instant::now();
    for _ in 0..REQS {
        server.submit(h, input.clone()).wait().unwrap();
    }
    REQS as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    print_header("event log append path");

    // Per-record emit cost on the caller's thread (the hot-path side).
    let path = tmp("emit");
    let log = EventLog::create(&path).unwrap();
    let mut i = 0u64;
    let s = bench("emit (encode + channel send)", 20, 400, || {
        i += 1;
        let ev = Event::new(EventKind::Complete, i as f64 * 1e-6, 0, i % 8, SloClass::Standard);
        log.emit(ev);
    });
    print_row(&s);
    log.close();
    let _ = std::fs::remove_file(&path);
    assert!(
        s.mean_ns < 1_000.0,
        "emit hot-path regressed: {:.0} ns/record (need < 1 us for 1M/s)",
        s.mean_ns
    );

    // End-to-end burst: emit BURST records, close (drain + fsync).
    let path = tmp("burst");
    let log = EventLog::create(&path).unwrap();
    let t0 = Instant::now();
    for i in 0..BURST {
        let mut ev = Event::new(
            EventKind::Admit,
            i as f64 * 1e-6,
            (i % 4) as usize,
            i % 16,
            SloClass::Interactive,
        );
        ev.entry = true;
        log.emit(ev);
    }
    log.close();
    let dt = t0.elapsed().as_secs_f64();
    let rate = BURST as f64 / dt;
    println!(
        "burst: {BURST} records in {:.3} s = {:.2} M records/s (appended {}, dropped {})",
        dt,
        rate / 1e6,
        log.appended(),
        log.dropped()
    );
    let _ = std::fs::remove_file(&path);
    assert!(
        rate >= 1e6,
        "append throughput regressed: {:.2} M records/s < 1 M records/s",
        rate / 1e6
    );

    // Serve-path guard: best-of-N alternating logged/unlogged rounds.
    print_header("logged vs unlogged closed-loop serve (emulated)");
    let path = tmp("serve");
    let (mut best_plain, mut best_logged) = (0f64, 0f64);
    for round in 0..ROUNDS {
        let plain = serve_round(None);
        let log = EventLog::create(&path).unwrap();
        let logged = serve_round(Some(&log));
        println!(
            "round {round}: unlogged {:.0} req/s, logged {:.0} req/s ({} records)",
            plain,
            logged,
            log.appended()
        );
        best_plain = best_plain.max(plain);
        best_logged = best_logged.max(logged);
    }
    let _ = std::fs::remove_file(&path);
    println!(
        "best: unlogged {:.0} req/s, logged {:.0} req/s ({:+.1}%)",
        best_plain,
        best_logged,
        (best_logged / best_plain - 1.0) * 100.0
    );
    assert!(
        best_logged >= best_plain / 1.05,
        "logging costs more than 5% serve throughput: {:.0} vs {:.0} req/s",
        best_logged,
        best_plain
    );
}
