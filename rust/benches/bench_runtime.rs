//! PJRT runtime bench: HLO-artifact execution latency per segment — the
//! real-compute hot path of the serving examples. Requires `make artifacts`.

use swapless::model::Manifest;
use swapless::runtime::Engine;
use swapless::util::bench::{bench, print_header, print_row};

fn main() {
    let Ok(manifest) = Manifest::load("artifacts") else {
        eprintln!("bench_runtime: artifacts/ not built (run `make artifacts`); skipping");
        return;
    };
    let mut engine = Engine::new().expect("pjrt client");
    let model = manifest.get("squeezenet").unwrap().clone();
    engine.load_model(&manifest, &model).expect("load");

    print_header("PJRT segment execution (squeezenet)");
    for seg in &model.segments {
        let n_in: usize = seg.in_shape.iter().product();
        let input = vec![0.5f32; n_in];
        let s = bench(
            &format!("seg{} {:?}->{:?}", seg.index, seg.in_shape, seg.out_shape),
            5,
            1000,
            || engine.execute_segment("squeezenet", seg.index, &input).unwrap(),
        );
        print_row(&s);
    }

    let n_in: usize = model.segments[0].in_shape.iter().product();
    let input = vec![0.5f32; n_in];
    let s = bench("full pipeline (all segments)", 5, 1500, || {
        engine
            .execute_range("squeezenet", 0, model.partition_points, &input)
            .unwrap()
    });
    print_row(&s);
}
