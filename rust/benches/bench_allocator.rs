//! Allocator decision-overhead bench — the paper claims the hill-climbing
//! allocation runs in < 2 ms per invocation on an embedded CPU; verify we
//! are far under that on every workload size, and measure the exhaustive
//! NLIP reference for the ablation (why the heuristic is needed).

use swapless::alloc;
use swapless::analytic::{AnalyticModel, Tenant};
use swapless::config::HardwareSpec;
use swapless::model::synthetic_model;
use swapless::tpu::CostModel;
use swapless::util::bench::{bench, print_header, print_row};

fn tenants(n: usize) -> Vec<Tenant> {
    (0..n)
        .map(|i| Tenant {
            model: synthetic_model(&format!("m{i}"), 8 + (i % 4), 3_000_000, 900_000_000),
            rate: 1.0 + i as f64,
        })
        .collect()
}

fn main() {
    let am = AnalyticModel::new(CostModel::new(HardwareSpec::default()));
    print_header("allocator decision overhead (paper: < 2 ms)");

    for n in [1, 2, 3, 4, 6, 9] {
        let ts = tenants(n);
        let s = bench(&format!("hill_climb n={n}"), 50, 300, || {
            alloc::hill_climb(&am, &ts, 4)
        });
        print_row(&s);
        assert!(
            s.mean_ns < 2_000_000.0,
            "hill climb exceeded the paper's 2 ms budget"
        );
    }

    for n in [1, 2] {
        let ts = tenants(n);
        let s = bench(&format!("exhaustive_nlip n={n}"), 5, 500, || {
            alloc::exhaustive_best(&am, &ts, 4)
        });
        print_row(&s);
    }

    let ts = tenants(4);
    let s = bench("prop_alloc n=4", 100, 200, || {
        alloc::prop_alloc(&am.cost, &ts, &[2, 3, 1, 0], 4)
    });
    print_row(&s);

    let s = bench("objective_eval n=4", 100, 200, || {
        let cfg = swapless::analytic::Config {
            partitions: vec![4, 4, 4, 4],
            cores: vec![1, 1, 1, 1],
        };
        am.objective(&ts, &cfg)
    });
    print_row(&s);
}
