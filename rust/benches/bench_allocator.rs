//! Allocator decision-overhead bench — the paper claims the hill-climbing
//! allocation runs in < 2 ms per invocation on an embedded CPU; verify we
//! are far under that on every workload size, and measure the exhaustive
//! NLIP reference for the ablation (why the heuristic is needed).
//!
//! Also the EXPERIMENTS.md §Perf before/after measurement: every size is
//! benched through the pre-engine naive evaluation (`hill_climb_naive`)
//! AND the prefix-table + delta-evaluation engine, both as a one-shot
//! call (table build included) and as the coordinator's steady-state
//! decision path (tables prebuilt). The multi-tenant decision path must
//! come out ≥ 5× faster than the naive baseline.

use swapless::alloc;
use swapless::analytic::{AnalyticModel, Tenant};
use swapless::config::HardwareSpec;
use swapless::model::synthetic_model;
use swapless::tpu::{CostModel, PrefixTables};
use swapless::util::bench::{bench, fmt_ns, print_header, print_row};

fn tenants(n: usize) -> Vec<Tenant> {
    (0..n)
        .map(|i| Tenant {
            model: synthetic_model(&format!("m{i}"), 8 + (i % 4), 3_000_000, 900_000_000),
            // Scaled so the aggregate load stays serveable as n grows —
            // an instantly-unstable mix collapses the climb to one scan
            // and would bench a pathological decision, not a real one.
            rate: (1.0 + i as f64) * 3.0 / (n as f64 + 2.0),
        })
        .collect()
}

fn main() {
    let am = AnalyticModel::new(CostModel::new(HardwareSpec::default()));
    print_header("allocator decision overhead (paper: < 2 ms)");

    let mut speedups: Vec<(usize, f64)> = Vec::new();
    for n in [1, 2, 3, 4, 6, 9] {
        let ts = tenants(n);
        let tables = PrefixTables::for_tenants(&am.cost, &ts);

        // Pre-engine baseline: every candidate re-runs the naive O(n·L)
        // objective.
        let naive = bench(&format!("hill_climb_naive n={n}"), 50, 300, || {
            alloc::hill_climb_naive(&am, &ts, 4)
        });
        print_row(&naive);

        // One-shot engine call (prefix-table build included).
        let oneshot = bench(&format!("hill_climb n={n} (incl. table build)"), 50, 300, || {
            alloc::hill_climb(&am, &ts, 4)
        });
        print_row(&oneshot);

        // Steady-state decision path: the coordinator/reconfig policy
        // holds the tables across decisions, so re-planning pays only the
        // delta evaluation.
        let decision = bench(&format!("hill_climb n={n} (tables amortized)"), 50, 300, || {
            alloc::hill_climb_with_tables(&am, &ts, &tables, 4)
        });
        print_row(&decision);

        // Both the one-shot call (what plan/baseline call sites pay,
        // table build included) and the amortized decision path must stay
        // inside the paper's 2 ms budget.
        assert!(
            oneshot.mean_ns < 2_000_000.0,
            "one-shot hill climb exceeded the paper's 2 ms budget"
        );
        assert!(
            decision.mean_ns < 2_000_000.0,
            "hill climb exceeded the paper's 2 ms budget"
        );
        let speedup = naive.mean_ns / decision.mean_ns;
        println!(
            "{:<44} {:>10} {:>12} {:>12} {:>12}",
            format!("  -> decision-path speedup n={n}"),
            "",
            format!("{speedup:.1}x"),
            fmt_ns(naive.mean_ns),
            fmt_ns(decision.mean_ns),
        );
        speedups.push((n, speedup));
    }

    // EXPERIMENTS.md §Perf acceptance: ≥5× on the multi-tenant (n ≥ 4)
    // decision path vs the pre-engine naive evaluation.
    for (n, s) in &speedups {
        if *n >= 4 {
            assert!(
                *s >= 5.0,
                "multi-tenant decision path speedup regressed: n={n} only {s:.1}x"
            );
        }
    }

    for n in [1, 2] {
        let ts = tenants(n);
        let s = bench(&format!("exhaustive_nlip n={n}"), 5, 500, || {
            alloc::exhaustive_best(&am, &ts, 4).expect("feasible configuration")
        });
        print_row(&s);
    }

    let ts = tenants(4);
    let tables = PrefixTables::for_tenants(&am.cost, &ts);
    let s = bench("prop_alloc n=4 (naive)", 100, 200, || {
        alloc::prop_alloc(&am.cost, &ts, &[2, 3, 1, 0], 4)
    });
    print_row(&s);
    let s = bench("prop_alloc n=4 (tables)", 100, 200, || {
        alloc::prop_alloc_tables(&tables, &ts, &[2, 3, 1, 0], 4)
    });
    print_row(&s);

    let cfg = swapless::analytic::Config {
        partitions: vec![4, 4, 4, 4],
        cores: vec![1, 1, 1, 1],
    };
    let s = bench("objective_eval n=4 (naive)", 100, 200, || {
        am.objective(&ts, &cfg)
    });
    print_row(&s);
    let s = bench("objective_eval n=4 (tables)", 100, 200, || {
        swapless::analytic::objective_with_tables(&am, &ts, &tables, &cfg)
    });
    print_row(&s);
}
