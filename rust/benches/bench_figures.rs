//! End-to-end figure-regeneration bench: one entry per paper table/figure,
//! timing the full pipeline (workload gen → DES → statistics) behind each.
//! Requires `make artifacts`.

use swapless::config::HardwareSpec;
use swapless::experiments as exp;
use swapless::util::bench::{bench, print_header, print_row};

fn main() {
    let Ok(mut ctx) = exp::Ctx::load("artifacts", HardwareSpec::default()) else {
        eprintln!("bench_figures: artifacts/ not built (run `make artifacts`); skipping");
        return;
    };
    // Shorter horizon for benching — the figure CLIs use 2000 s.
    ctx.horizon = 400.0;

    print_header("figure/table regeneration (horizon 400 s)");
    let s = bench("table2", 3, 200, || exp::table2::run(&ctx));
    print_row(&s);
    let s = bench("fig1 intra-model swap", 3, 2000, || {
        exp::fig1::run(&ctx).unwrap()
    });
    print_row(&s);
    let s = bench("fig2 inter-model swap", 3, 2000, || {
        exp::fig2::run(&ctx).unwrap()
    });
    print_row(&s);
    let s = bench("fig3 segment profile", 3, 500, || {
        exp::fig3::run(&ctx, "inceptionv4").unwrap()
    });
    print_row(&s);
    let s = bench("fig5 single-tenant validation", 2, 3000, || {
        exp::fig5::run(&ctx, "inceptionv4", 0.2, &[1.0, 3.0, 5.0]).unwrap()
    });
    print_row(&s);
    let s = bench("fig6 multi-tenant validation", 2, 3000, || {
        exp::fig6::run(&ctx, 0.4, &[1.0, 2.0]).unwrap()
    });
    print_row(&s);
    let s = bench("fig7 baseline comparison", 2, 5000, || {
        exp::fig7::run(&ctx, &[0.2, 0.5]).unwrap()
    });
    print_row(&s);
    let s = bench("fig8 dynamic adaptation", 2, 3000, || {
        exp::fig8::run(&ctx).unwrap()
    });
    print_row(&s);
}
