//! Micro-benches of the analytic queueing model and the substrates it sits
//! on (JSON, RNG, histograms) — the building blocks of the decision path.

use swapless::analytic::{AnalyticModel, Config, Tenant};
use swapless::config::HardwareSpec;
use swapless::metrics::LatencyHistogram;
use swapless::model::synthetic_model;
use swapless::tpu::CostModel;
use swapless::util::bench::{bench, black_box, print_header, print_row};
use swapless::util::json;
use swapless::util::rng::Rng;

fn main() {
    let am = AnalyticModel::new(CostModel::new(HardwareSpec::default()));
    let tenants: Vec<Tenant> = (0..3)
        .map(|i| Tenant {
            model: synthetic_model(&format!("m{i}"), 8, 3_000_000, 900_000_000),
            rate: 2.0,
        })
        .collect();
    let cfg = Config {
        partitions: vec![4, 6, 2],
        cores: vec![2, 0, 2],
    };

    print_header("analytic model & substrates");
    let s = bench("e2e_latency (Eq. 4)", 200, 200, || {
        am.e2e_latency(&tenants, &cfg, 0)
    });
    print_row(&s);
    let s = bench("tpu_wait P-K (Eq. 1-2)", 200, 200, || {
        am.tpu_wait(&tenants, &cfg)
    });
    print_row(&s);
    let s = bench("alpha (Eq. 10)", 200, 200, || {
        am.alpha(&tenants, &cfg, 1)
    });
    print_row(&s);

    let manifest_like = r#"{"models": [{"name": "m", "segments": [{"index": 0, "in_shape": [1,64,64,3], "flops": 123456789, "util": 0.25}]}], "version": 1}"#;
    let s = bench("json parse (manifest-like)", 200, 200, || {
        json::parse(manifest_like).unwrap()
    });
    print_row(&s);

    let s = bench("rng poisson stream x1000", 100, 200, || {
        let mut r = Rng::new(5);
        let mut acc = 0.0;
        for _ in 0..1000 {
            acc += r.exponential(4.0);
        }
        black_box(acc)
    });
    print_row(&s);

    let s = bench("histogram record x1000", 100, 200, || {
        let mut h = LatencyHistogram::default();
        for i in 1..=1000 {
            h.record(i as f64 * 1e-4);
        }
        black_box(h.percentile(95.0))
    });
    print_row(&s);
}
