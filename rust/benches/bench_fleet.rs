//! Fleet placement decision-overhead bench: the two-level allocator
//! (outer greedy bin-pack + local-move refinement over inner per-device
//! hill climbs) must stay interactive — the CI guard asserts the
//! 8-tenant × 4-device decision completes in under 10 ms, so online
//! rebalancing can run at the same cadence as the single-device
//! re-allocator without stalling the router.

use swapless::analytic::Tenant;
use swapless::config::HardwareSpec;
use swapless::fleet::{place, Fleet};
use swapless::model::synthetic_model;
use swapless::util::bench::{bench, print_header, print_row};

fn tenants(n: usize) -> Vec<Tenant> {
    (0..n)
        .map(|i| Tenant {
            model: synthetic_model(
                &format!("m{i}"),
                4 + (i % 5),
                2_000_000 + 500_000 * (i as u64 % 4),
                400_000_000 + 150_000_000 * (i as u64 % 3),
            ),
            // Scaled so the aggregate stays serveable per device.
            rate: (1.0 + i as f64) * 2.0 / (n as f64 + 2.0),
        })
        .collect()
}

fn main() {
    print_header("fleet two-level placement decision overhead");
    let hw = HardwareSpec::default();

    for (n, d) in [(4usize, 2usize), (8, 2), (8, 4), (16, 4)] {
        let ts = tenants(n);
        let fleet = Fleet::uniform(d, &hw);
        let s = bench(&format!("place n={n} devices={d}"), 20, 400, || {
            place(&fleet, &ts)
        });
        print_row(&s);
        if n == 8 && d == 4 {
            // The headline guard: 8 tenants x 4 devices under 10 ms.
            assert!(
                s.mean_ms() < 10.0,
                "two-level placement regressed: 8x4 mean {:.2} ms >= 10 ms",
                s.mean_ms()
            );
        }
    }

    // Sanity: the plan the benched instance produces is usable.
    let ts = tenants(8);
    let fleet = Fleet::uniform(4, &hw);
    let plan = place(&fleet, &ts);
    assert_eq!(plan.assignment.len(), 8);
    assert!(plan.devices.len() == 4);
    println!(
        "8x4 plan: assignment {:?}, objective {:.1} ms, {} inner evals, {} moves",
        plan.assignment,
        plan.objective * 1e3,
        plan.evaluations,
        plan.refine_moves
    );
}
