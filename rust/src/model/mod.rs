//! Model metadata: the artifact manifest produced by `python -m compile.aot`.
//!
//! The manifest is the contract between the build-time python layers and the
//! rust coordinator: per model, the ordered segment list with artifact paths,
//! tensor shapes, FLOPs, weight footprints (both the real scaled artifact and
//! the paper-scale simulated footprint) and MXU-utilization estimates.

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct SegmentMeta {
    pub index: usize,
    /// Artifact path relative to the artifacts dir, e.g. `squeezenet/seg0.hlo.txt`.
    pub artifact: String,
    pub in_shape: Vec<usize>,
    pub out_shape: Vec<usize>,
    pub real_flops: u64,
    pub real_param_bytes: u64,
    /// Paper-scale (Table II) weight bytes used by the TPU device model.
    pub sim_weight_bytes: u64,
    /// Paper-scale FLOPs used by the service-time cost model.
    pub sim_flops: u64,
    /// On-wire activation sizes (int8, as the paper's quantized models).
    pub in_bytes: u64,
    pub out_bytes: u64,
    /// Systolic-array fill estimate from the Pallas kernel tiling (L1).
    pub mxu_util: f64,
}

#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub name: String,
    /// `P_i` — number of candidate partition points == number of segments.
    pub partition_points: usize,
    pub table_size_mb: f64,
    pub table_flops_g: f64,
    pub input_shape: Vec<usize>,
    pub output_shape: Vec<usize>,
    pub segments: Vec<SegmentMeta>,
}

impl ModelMeta {
    /// Simulated weight bytes of the TPU prefix `[1:p]` (p segments).
    pub fn prefix_weight_bytes(&self, p: usize) -> u64 {
        self.segments[..p].iter().map(|s| s.sim_weight_bytes).sum()
    }

    pub fn total_weight_bytes(&self) -> u64 {
        self.prefix_weight_bytes(self.partition_points)
    }

    /// Simulated FLOPs of the prefix.
    pub fn prefix_flops(&self, p: usize) -> u64 {
        self.segments[..p].iter().map(|s| s.sim_flops).sum()
    }

    /// Simulated FLOPs of the suffix `[p+1:P]`.
    pub fn suffix_flops(&self, p: usize) -> u64 {
        self.segments[p..].iter().map(|s| s.sim_flops).sum()
    }

    /// On-wire bytes of the intermediate tensor at partition point p
    /// (`d_out` in Eq. 4). For p == P there is no TPU→CPU handoff, but the
    /// final output still returns over the bus; both are this value.
    pub fn boundary_bytes(&self, p: usize) -> u64 {
        if p == 0 {
            self.segments[0].in_bytes
        } else {
            self.segments[p - 1].out_bytes
        }
    }

    pub fn input_bytes(&self) -> u64 {
        self.segments[0].in_bytes
    }

    /// Highest per-segment MXU utilization in this model — normalization
    /// anchor for the Fig. 3 speedup shape (DESIGN.md §3).
    pub fn max_mxu_util(&self) -> f64 {
        self.segments
            .iter()
            .map(|s| s.mxu_util)
            .fold(f64::MIN_POSITIVE, f64::max)
    }

    fn from_json(j: &Json) -> Result<ModelMeta, String> {
        let err = |e: crate::util::json::JsonError| e.to_string();
        let mut segments = Vec::new();
        for (i, seg) in j.arr_of("segments").map_err(err)?.iter().enumerate() {
            let shape = |key: &str| -> Result<Vec<usize>, String> {
                seg.arr_of(key)
                    .map_err(|e| e.to_string())?
                    .iter()
                    .map(|v| v.as_usize().ok_or_else(|| format!("bad dim in {key}")))
                    .collect()
            };
            let m = SegmentMeta {
                index: seg.usize_of("index").map_err(err)?,
                artifact: seg.str_of("artifact").map_err(err)?,
                in_shape: shape("in_shape")?,
                out_shape: shape("out_shape")?,
                real_flops: seg.u64_of("real_flops").map_err(err)?,
                real_param_bytes: seg.u64_of("real_param_bytes").map_err(err)?,
                sim_weight_bytes: seg.u64_of("sim_weight_bytes").map_err(err)?,
                sim_flops: seg.u64_of("sim_flops").map_err(err)?,
                in_bytes: seg.u64_of("in_bytes").map_err(err)?,
                out_bytes: seg.u64_of("out_bytes").map_err(err)?,
                mxu_util: seg.f64_of("mxu_util").map_err(err)?,
            };
            if m.index != i {
                return Err(format!("segment index {} at position {i}", m.index));
            }
            segments.push(m);
        }
        let meta = ModelMeta {
            name: j.str_of("name").map_err(err)?,
            partition_points: j.usize_of("partition_points").map_err(err)?,
            table_size_mb: j.f64_of("table_size_mb").map_err(err)?,
            table_flops_g: j.f64_of("table_flops_g").map_err(err)?,
            input_shape: j
                .arr_of("input_shape")
                .map_err(err)?
                .iter()
                .filter_map(Json::as_usize)
                .collect(),
            output_shape: j
                .arr_of("output_shape")
                .map_err(err)?
                .iter()
                .filter_map(Json::as_usize)
                .collect(),
            segments,
        };
        if meta.segments.len() != meta.partition_points {
            return Err(format!(
                "{}: {} segments but {} partition points",
                meta.name,
                meta.segments.len(),
                meta.partition_points
            ));
        }
        // Shape chaining invariant.
        for w in meta.segments.windows(2) {
            if w[0].out_shape != w[1].in_shape {
                return Err(format!(
                    "{}: segment {} out {:?} != segment {} in {:?}",
                    meta.name, w[0].index, w[0].out_shape, w[1].index, w[1].in_shape
                ));
            }
        }
        Ok(meta)
    }
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub kernel_path: String,
    pub models: Vec<ModelMeta>,
    /// Directory the artifact paths are relative to.
    pub base_dir: String,
}

impl Manifest {
    pub fn load(artifacts_dir: &str) -> Result<Manifest, String> {
        let path = format!("{artifacts_dir}/manifest.json");
        let j = crate::util::json::parse_file(&path)?;
        Manifest::from_json(&j, artifacts_dir)
    }

    pub fn from_json(j: &Json, base_dir: &str) -> Result<Manifest, String> {
        let mut models = Vec::new();
        for m in j.arr_of("models").map_err(|e| e.to_string())? {
            models.push(ModelMeta::from_json(m)?);
        }
        if models.is_empty() {
            return Err("manifest has no models".into());
        }
        Ok(Manifest {
            kernel_path: j
                .get("kernel_path")
                .and_then(Json::as_str)
                .unwrap_or("pallas")
                .to_string(),
            models,
            base_dir: base_dir.to_string(),
        })
    }

    pub fn get(&self, name: &str) -> Result<&ModelMeta, String> {
        self.models
            .iter()
            .find(|m| m.name == name)
            .ok_or_else(|| {
                let have: Vec<&str> = self.models.iter().map(|m| m.name.as_str()).collect();
                format!("unknown model {name:?}; manifest has {have:?}")
            })
    }

    pub fn artifact_path(&self, seg: &SegmentMeta) -> String {
        format!("{}/{}", self.base_dir, seg.artifact)
    }

    /// A paper-scale synthetic manifest: the nine Table-II models with
    /// their real segment counts and approximate sizes/FLOPs, built from
    /// [`synthetic_model`] (no artifacts on disk). Together with the
    /// emulated exec backend this lets the full serving stack — tenant
    /// lifecycle, CPU pools, reconfiguration — run on a fresh checkout
    /// (examples, CI smoke runs, lifecycle tests).
    pub fn synthetic() -> Manifest {
        let spec: [(&str, usize, f64, f64); 9] = [
            ("squeezenet", 2, 1.4, 0.7),
            ("mobilenetv2", 5, 3.5, 0.6),
            ("efficientnet", 6, 5.3, 0.8),
            ("mnasnet", 7, 4.4, 0.6),
            ("gpunet", 5, 7.8, 1.2),
            ("densenet201", 7, 20.0, 8.6),
            ("resnet50v2", 8, 25.6, 7.0),
            ("xception", 11, 22.9, 16.8),
            ("inceptionv4", 11, 43.2, 24.6),
        ];
        Manifest {
            kernel_path: "pallas".to_string(),
            models: spec
                .iter()
                .map(|(name, segs, mb, gflops)| {
                    synthetic_model(
                        name,
                        *segs,
                        (mb * 1e6 / *segs as f64) as u64,
                        (gflops * 1e9 / *segs as f64) as u64,
                    )
                })
                .collect(),
            base_dir: "synthetic".to_string(),
        }
    }

    /// Load the real artifact manifest, falling back to the synthetic one
    /// (examples and smoke runs work without `make artifacts`).
    pub fn load_or_synthetic(artifacts_dir: &str) -> Manifest {
        match Manifest::load(artifacts_dir) {
            Ok(m) => m,
            Err(_) => {
                eprintln!(
                    "note: no artifacts at {artifacts_dir:?}; using the synthetic manifest"
                );
                Manifest::synthetic()
            }
        }
    }

    /// Subset manifest for a workload mix (preserves manifest order).
    pub fn select(&self, names: &[String]) -> Result<Vec<&ModelMeta>, String> {
        names.iter().map(|n| self.get(n)).collect()
    }
}

/// A synthetic manifest for unit tests (no artifacts on disk).
pub fn synthetic_model(name: &str, segs: usize, bytes_per_seg: u64, flops_per_seg: u64) -> ModelMeta {
    let mut segments = Vec::new();
    for i in 0..segs {
        // Utilization decays geometrically across depth (0.5 → ~parity),
        // mimicking the zoo's early-parallel/late-starved Fig. 3 shape.
        let util = 0.5 * 0.62f64.powi(i as i32);
        segments.push(SegmentMeta {
            index: i,
            artifact: format!("{name}/seg{i}.hlo.txt"),
            in_shape: vec![1, 8, 8, 8],
            out_shape: vec![1, 8, 8, 8],
            real_flops: flops_per_seg,
            real_param_bytes: bytes_per_seg,
            sim_weight_bytes: bytes_per_seg,
            sim_flops: flops_per_seg,
            in_bytes: 512,
            out_bytes: 512,
            mxu_util: util,
        });
    }
    ModelMeta {
        name: name.to_string(),
        partition_points: segs,
        table_size_mb: (bytes_per_seg * segs as u64) as f64 / 1e6,
        table_flops_g: (flops_per_seg * segs as u64) as f64 / 1e9,
        input_shape: vec![1, 8, 8, 8],
        output_shape: vec![1, 8, 8, 8],
        segments,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_json() -> Json {
        crate::util::json::parse(
            r#"{
              "kernel_path": "pallas",
              "models": [{
                "name": "m1", "partition_points": 2,
                "table_size_mb": 1.0, "table_flops_g": 0.5,
                "input_shape": [1,4,4,3], "output_shape": [1,10],
                "segments": [
                  {"index":0,"artifact":"m1/seg0.hlo.txt","in_shape":[1,4,4,3],
                   "out_shape":[1,2,2,8],"real_flops":1000,"real_param_bytes":400,
                   "sim_weight_bytes":600000,"sim_flops":300000000,
                   "in_bytes":48,"out_bytes":32,"mxu_util":0.4},
                  {"index":1,"artifact":"m1/seg1.hlo.txt","in_shape":[1,2,2,8],
                   "out_shape":[1,10],"real_flops":500,"real_param_bytes":100,
                   "sim_weight_bytes":400000,"sim_flops":200000000,
                   "in_bytes":32,"out_bytes":10,"mxu_util":0.1}
                ]
              }]
            }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_manifest() {
        let m = Manifest::from_json(&sample_json(), "artifacts").unwrap();
        assert_eq!(m.models.len(), 1);
        let m1 = m.get("m1").unwrap();
        assert_eq!(m1.partition_points, 2);
        assert_eq!(m1.prefix_weight_bytes(0), 0);
        assert_eq!(m1.prefix_weight_bytes(1), 600000);
        assert_eq!(m1.total_weight_bytes(), 1000000);
        assert_eq!(m1.prefix_flops(2), 500000000);
        assert_eq!(m1.suffix_flops(1), 200000000);
        assert_eq!(m1.boundary_bytes(0), 48);
        assert_eq!(m1.boundary_bytes(1), 32);
        assert_eq!(m1.boundary_bytes(2), 10);
    }

    #[test]
    fn unknown_model_errors() {
        let m = Manifest::from_json(&sample_json(), "artifacts").unwrap();
        assert!(m.get("nope").is_err());
    }

    #[test]
    fn shape_chain_violation_rejected() {
        let mut j = sample_json();
        // Corrupt the second segment's in_shape.
        if let Json::Obj(root) = &mut j {
            if let Some(Json::Arr(models)) = root.get_mut("models") {
                if let Json::Obj(m) = &mut models[0] {
                    if let Some(Json::Arr(segs)) = m.get_mut("segments") {
                        segs[1].set("in_shape", Json::Arr(vec![Json::Num(1.0)]));
                    }
                }
            }
        }
        assert!(Manifest::from_json(&j, "artifacts").is_err());
    }

    #[test]
    fn synthetic_manifest_covers_table2() {
        let m = Manifest::synthetic();
        assert_eq!(m.models.len(), 9);
        assert_eq!(m.get("squeezenet").unwrap().partition_points, 2);
        assert_eq!(m.get("inceptionv4").unwrap().partition_points, 11);
        // Paper-scale: inceptionv4 is far larger than SRAM (43.2 MB).
        assert!(m.get("inceptionv4").unwrap().total_weight_bytes() > 40_000_000);
        // Shape chain holds for every synthetic model.
        for model in &m.models {
            for w in model.segments.windows(2) {
                assert_eq!(w[0].out_shape, w[1].in_shape);
            }
        }
    }

    #[test]
    fn synthetic_model_shape() {
        let m = synthetic_model("x", 5, 1_000_000, 1_000_000_000);
        assert_eq!(m.partition_points, 5);
        assert!(m.segments[0].mxu_util > m.segments[4].mxu_util);
        assert_eq!(m.total_weight_bytes(), 5_000_000);
    }
}
