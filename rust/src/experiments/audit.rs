//! Audit experiment: prove the event log is a faithful account of a
//! live run by materializing it back into counters.
//!
//! A 2-device fleet serves the Table-II quad mix at nominal ρ = 1.0
//! (rates solved on the single-device full-TPU reference, the fleet
//! sweep's equal-total-load convention) with the event log attached and
//! a mid-run crash of device 0 — no recovery, so the heartbeat loop
//! fails the victims over and the log captures the outage marker, the
//! off-home reroutes, and every requeued request's second admission.
//!
//! After the run drains, the log is replayed through [`Rollup`] and
//! compared against the live [`FleetStats`] snapshot *bit-exactly*:
//! per-tenant, per-class, and per-device outcome counts, histogram
//! totals, deadline misses, and the fleet-level migration/failover
//! counters must all agree, and a mid-file offset replay merged onto
//! the prefix rollup must equal the full replay. Any divergence is a
//! mismatch row; `swapless audit` exits non-zero on any.
//!
//! [`FleetStats`]: crate::fleet::FleetStats
//! [`Rollup`]: crate::eventlog::views::Rollup

use std::time::{Duration, Instant};

use crate::analytic::Config;
use crate::coordinator::{AttachOptions, Request};
use crate::eventlog::views::Rollup;
use crate::eventlog::{read_all, read_from, EventLog, RECORD_BYTES};
use crate::fault::FaultPlan;
use crate::fleet::{Fleet, FleetServerBuilder};
use crate::runtime::service::ExecBackend;
use crate::sched::{OverloadPolicy, SloClass};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::workload::{equal_tpu_load_shares, rates_for_load_factor};

use super::common::{print_table, Ctx};
use super::fleet::MIX_QUAD;

/// Nominal full-TPU load factor the rates are solved at. Overload on
/// the single-device reference ≈ 0.5 per device before the crash, and
/// the survivor runs at the critical point afterwards — enough pressure
/// to populate every outcome counter the parity check compares.
pub const RHO: f64 = 1.0;
pub const DEVICES: usize = 2;
/// Wall-clock drive window (the run is real-time: emulated backend at
/// time scale 1.0, open-loop Poisson arrivals).
pub const DURATION_S: f64 = 2.5;
/// Crash instant for device 0 (no recovery).
pub const CRASH_AT_S: f64 = 1.0;
/// Relative deadline stamped on every request.
pub const DEADLINE_S: f64 = 0.5;
pub const CRASHED_DEVICE: usize = 0;

/// SLO classes for the quad mix, exercising all three classes.
const CLASSES: [SloClass; 4] = [
    SloClass::Interactive,
    SloClass::Standard,
    SloClass::Batch,
    SloClass::Standard,
];

/// Outcome of one audited chaos run.
#[derive(Debug, Clone)]
pub struct AuditResult {
    pub submitted: usize,
    /// Live completions (fleet-wide), after the drain.
    pub completed: u64,
    /// Tickets resolved with typed errors.
    pub failed: usize,
    /// Records the full replay consumed.
    pub records: u64,
    /// Records the writer durably appended.
    pub appended: u64,
    /// Records lost to channel overflow (must be 0 for parity to hold).
    pub dropped: u64,
    pub failovers: u64,
    pub failed_over: u64,
    pub requeued: u64,
    /// Records consumed by the mid-file offset replay (the suffix).
    pub suffix_records: u64,
    /// Human-readable divergences; empty on a clean audit.
    pub mismatches: Vec<String>,
    pub passed: bool,
}

fn check(mismatches: &mut Vec<String>, label: &str, live: u64, log: u64) {
    if live != log {
        mismatches.push(format!("{label}: live {live} != log {log}"));
    }
}

/// Run the audited chaos serve against a temp log file, then clean up.
pub fn run(ctx: &Ctx) -> Result<AuditResult, String> {
    let name = format!("swapless-audit-{}.log", std::process::id());
    let path = std::env::temp_dir().join(name);
    let res = run_at(ctx, &path);
    let _ = std::fs::remove_file(&path);
    res
}

/// Run the audited chaos serve, logging to `path` (kept on disk).
pub fn run_at(ctx: &Ctx, path: &std::path::Path) -> Result<AuditResult, String> {
    let models = &MIX_QUAD[..];
    let zero = vec![0.0; models.len()];
    let tenants0 = ctx.tenants(models, &zero)?;
    let full_cfg = Config::all_tpu(&tenants0);
    let shares = equal_tpu_load_shares(&ctx.am, &tenants0);
    let rates = rates_for_load_factor(&ctx.am, &tenants0, &full_cfg, &shares, RHO);

    let log = EventLog::create(path)?;
    let fleet = Fleet::uniform(DEVICES, &ctx.cost.hw);
    let server = FleetServerBuilder::new(&ctx.manifest, fleet)
        .backend(ExecBackend::Emulated)
        .time_scale(1.0)
        .overload(OverloadPolicy::DeadlineDrop)
        .adaptive(true)
        .faults(FaultPlan::new(ctx.seed).crash(CRASHED_DEVICE, CRASH_AT_S, None))
        .log(log.clone())
        .build()
        .map_err(|e| e.to_string())?;

    // Attach the mix; placement-aware admission spreads it over both
    // devices. Live tenants: (fleet handle, input length, rate, next).
    let mut rng = Rng::new(ctx.seed);
    let mut live = Vec::new();
    for ((name, rate), class) in models.iter().zip(&rates).zip(&CLASSES) {
        let opts = AttachOptions { rate_hint: *rate, class: *class };
        let h = server
            .attach(name, opts)
            .map_err(|e| format!("attach {name}: {e}"))?;
        let n_in: usize = ctx.manifest.get(name)?.input_shape.iter().product();
        live.push((h, n_in, *rate, rng.exponential(*rate)));
    }

    // Open-loop Poisson drive with the heartbeat failover check — the
    // serve CLI's loop, minus rebalancing (migrations stay log-visible
    // but zero here, keeping the parity row exact and deterministic).
    let t0 = Instant::now();
    let mut pending = Vec::new();
    loop {
        let now = t0.elapsed().as_secs_f64();
        if now >= DURATION_S {
            break;
        }
        let _ = server.poll_health();
        let next_arrival = live
            .iter()
            .map(|l| l.3)
            .fold(f64::INFINITY, f64::min)
            .min(DURATION_S);
        if next_arrival > now {
            std::thread::sleep(Duration::from_secs_f64((next_arrival - now).min(0.02)));
            continue;
        }
        let idx = live
            .iter()
            .enumerate()
            .min_by(|a, b| a.1 .3.partial_cmp(&b.1 .3).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        let (h, n_in, rate, _) = live[idx];
        let dl = Duration::from_secs_f64(DEADLINE_S);
        let req = Request::new(vec![0.5; n_in]).with_deadline(dl);
        pending.push(server.submit(h, req));
        live[idx].3 = now + rng.exponential(rate);
    }
    let submitted = pending.len();
    let mut failed = 0usize;
    for ticket in pending {
        if ticket.wait().is_err() {
            failed += 1;
        }
    }

    // Quiescent snapshot, then drop the server: members wind down first,
    // then the fleet closes the shared log (drain + fsync + truncate).
    let stats = server.stats();
    let live_pc = stats.per_class();
    drop(server);
    let appended = log.appended();
    let dropped = log.dropped();

    let events = read_all(path)?;
    let full = Rollup::replay(&events);
    let mut m: Vec<String> = Vec::new();

    if dropped > 0 {
        m.push(format!("writer dropped {dropped} records — parity void"));
    }
    check(&mut m, "records read vs appended", appended, full.records);
    check(&mut m, "handled outages", stats.failovers, 1);

    // Per-device outcome counters.
    if full.per_device.len() > stats.per_device.len() {
        m.push(format!(
            "log names {} devices, fleet has {}",
            full.per_device.len(),
            stats.per_device.len()
        ));
    }
    for (d, s) in stats.per_device.iter().enumerate() {
        let c = full.per_device.get(d).copied().unwrap_or_default();
        check(&mut m, &format!("device {d} accepted"), s.accepted, c.accepted);
        check(&mut m, &format!("device {d} rejected"), s.rejected, c.rejected);
        check(&mut m, &format!("device {d} shed"), s.shed, c.shed);
        check(&mut m, &format!("device {d} expired"), s.expired, c.expired);
        check(&mut m, &format!("device {d} cancelled"), s.cancelled, c.cancelled);
        check(&mut m, &format!("device {d} completed"), s.completed, c.completed);
    }

    // Per-tenant (member-server handle namespace, keyed with the device).
    let mut live_keys = std::collections::BTreeSet::new();
    for (d, s) in stats.per_device.iter().enumerate() {
        for t in &s.per_tenant {
            let key = (d as u16, t.handle.0);
            live_keys.insert(key);
            let c = full.per_tenant.get(&key).copied().unwrap_or_default();
            let label = format!("tenant {}@{d}", t.handle.0);
            check(&mut m, &format!("{label} accepted"), t.accepted, c.accepted);
            check(&mut m, &format!("{label} rejected"), t.rejected, c.rejected);
            check(&mut m, &format!("{label} dropped"), t.dropped, c.dropped());
            check(&mut m, &format!("{label} completed"), t.latency.count(), c.completed);
        }
    }
    for key in full.per_tenant.keys() {
        if !live_keys.contains(key) {
            m.push(format!("log-only tenant {}@{}", key.1, key.0));
        }
    }

    // Per-class counters, histogram totals, misses, and goodput.
    for c in SloClass::ALL {
        let n = c.name();
        let (a, b) = (&live_pc, &full.per_class);
        check(&mut m, &format!("class {n} accepted"), a.accepted(c), b.accepted(c));
        check(&mut m, &format!("class {n} rejected"), a.rejected(c), b.rejected(c));
        check(&mut m, &format!("class {n} shed"), a.shed(c), b.shed(c));
        check(&mut m, &format!("class {n} expired"), a.expired(c), b.expired(c));
        check(&mut m, &format!("class {n} cancelled"), a.cancelled(c), b.cancelled(c));
        check(&mut m, &format!("class {n} missed"), a.missed(c), b.missed(c));
        check(&mut m, &format!("class {n} histogram"), a.get(c).count(), b.get(c).count());
        check(&mut m, &format!("class {n} goodput"), a.goodput(c), b.goodput(c));
    }

    // Fleet-level counters.
    check(&mut m, "migrations", stats.migrations, full.migrations);
    check(&mut m, "failovers", stats.failovers, full.failovers);
    check(&mut m, "failed_over", stats.failed_over, full.failed_over);
    check(&mut m, "completed total", stats.completed(), full.totals().completed);

    // Offset property: a replay from a mid-file record boundary merged
    // onto the prefix rollup equals the full replay.
    let half = events.len() / 2;
    let suffix_events = read_from(path, (half * RECORD_BYTES) as u64)?;
    let suffix_n = suffix_events.len() as u64;
    check(&mut m, "suffix record count", (events.len() - half) as u64, suffix_n);
    let mut merged = Rollup::replay(&events[..half]);
    merged.merge(&Rollup::replay(&suffix_events));
    if merged.per_tenant != full.per_tenant {
        m.push("offset replay: per-tenant counts diverge from full replay".to_string());
    }
    if merged.per_device != full.per_device {
        m.push("offset replay: per-device counts diverge from full replay".to_string());
    }
    check(&mut m, "offset replay records", full.records, merged.records);
    for c in SloClass::ALL {
        let n = c.name();
        let (a, b) = (&full.per_class, &merged.per_class);
        check(&mut m, &format!("offset {n} accepted"), a.accepted(c), b.accepted(c));
        check(&mut m, &format!("offset {n} histogram"), a.get(c).count(), b.get(c).count());
    }

    let passed = m.is_empty();
    Ok(AuditResult {
        submitted,
        completed: stats.completed(),
        failed,
        records: full.records,
        appended,
        dropped,
        failovers: stats.failovers,
        failed_over: stats.failed_over,
        requeued: stats.requeued,
        suffix_records: suffix_n,
        mismatches: m,
        passed,
    })
}

impl AuditResult {
    pub fn print(&self) {
        let row = vec![vec![
            self.submitted.to_string(),
            self.completed.to_string(),
            self.records.to_string(),
            self.dropped.to_string(),
            self.failovers.to_string(),
            self.failed_over.to_string(),
            self.requeued.to_string(),
            self.mismatches.len().to_string(),
            if self.passed { "ok" } else { "FAIL" }.to_string(),
        ]];
        print_table(
            "Audit: 2-device chaos serve vs log-derived rollup (quad mix, rho 1.0)",
            &[
                "submitted",
                "completed",
                "records",
                "dropped",
                "failovers",
                "failed over",
                "requeued",
                "mismatches",
                "verdict",
            ],
            &row,
        );
        for m in &self.mismatches {
            println!("  mismatch: {m}");
        }
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("submitted", Json::Num(self.submitted as f64)),
            ("completed", Json::Num(self.completed as f64)),
            ("failed", Json::Num(self.failed as f64)),
            ("records", Json::Num(self.records as f64)),
            ("appended", Json::Num(self.appended as f64)),
            ("dropped", Json::Num(self.dropped as f64)),
            ("failovers", Json::Num(self.failovers as f64)),
            ("failed_over", Json::Num(self.failed_over as f64)),
            ("requeued", Json::Num(self.requeued as f64)),
            ("suffix_records", Json::Num(self.suffix_records as f64)),
            (
                "mismatches",
                Json::Arr(
                    self.mismatches
                        .iter()
                        .map(|s| Json::Str(s.clone()))
                        .collect(),
                ),
            ),
            ("passed", Json::Bool(self.passed)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareSpec;
    use crate::model::Manifest;

    /// The acceptance headline: a logged 2-device chaos run (one crash,
    /// failover to the survivor) audits clean — the log-derived rollup
    /// reproduces the live per-tenant/per-class/per-device counts from
    /// offset 0 and from a mid-file offset, bit-exactly.
    #[test]
    fn logged_chaos_run_audits_bit_exactly() {
        let ctx = Ctx::new(Manifest::synthetic(), HardwareSpec::default());
        let r = run(&ctx).unwrap();
        assert!(r.passed, "audit mismatches:\n  {}", r.mismatches.join("\n  "));
        assert_eq!(r.dropped, 0, "bounded channel overflowed");
        assert_eq!(r.failovers, 1, "the crash was not handled exactly once");
        assert!(r.failed_over > 0, "no request was served off its home");
        assert!(r.completed > 0, "nothing completed");
        assert!(r.records > 0, "empty log");
    }
}
