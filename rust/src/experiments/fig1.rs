//! Fig. 1 — Intra-model memory swapping overhead.
//!
//! For each over-SRAM model executed fully on the TPU (the Edge-TPU-
//! compiler default), split the per-inference service time into compute
//! vs swap streaming, and confirm with a single-tenant DES run. The paper
//! reports swap overhead between 20.2% (DenseNet201) and 62.4%
//! (InceptionV4).

use crate::analytic::Config;
use crate::util::json::Json;

use super::common::{ms, pct, print_table, Ctx};

pub const MODELS: [&str; 4] = [
    "densenet201",
    "resnet50v2",
    "xception",
    "inceptionv4",
];

#[derive(Debug, Clone)]
pub struct Row {
    pub model: String,
    pub size_mb: f64,
    pub compute_ms: f64,
    pub swap_ms: f64,
    pub swap_fraction: f64,
    pub observed_mean_ms: f64,
}

pub struct Fig1 {
    pub rows: Vec<Row>,
}

pub fn run(ctx: &Ctx) -> Result<Fig1, String> {
    let mut rows = Vec::new();
    for name in MODELS {
        let meta = ctx.manifest.get(name)?;
        let p = meta.partition_points;
        let compute = ctx.cost.hw.tpu_dispatch_s + ctx.cost.tpu_prefix_compute(meta, p);
        let swap = ctx.cost.intra_swap_time(meta, p);
        // Light single-tenant load so the observation isolates service time.
        let tenants = ctx.tenants(&[name], &[0.5])?;
        let cfg = Config {
            partitions: vec![p],
            cores: vec![0],
        };
        let obs = ctx.observe(&tenants, &cfg);
        rows.push(Row {
            model: name.into(),
            size_mb: meta.table_size_mb,
            compute_ms: compute * 1e3,
            swap_ms: swap * 1e3,
            swap_fraction: swap / (swap + compute),
            observed_mean_ms: obs.mean_latency * 1e3,
        });
    }
    Ok(Fig1 { rows })
}

impl Fig1 {
    pub fn print(&self) {
        let rows: Vec<Vec<String>> = self
            .rows
            .iter()
            .map(|r| {
                vec![
                    r.model.clone(),
                    format!("{:.1}", r.size_mb),
                    format!("{:.1}", r.compute_ms),
                    format!("{:.1}", r.swap_ms),
                    pct(r.swap_fraction),
                    format!("{:.1}", r.observed_mean_ms),
                ]
            })
            .collect();
        print_table(
            "Fig. 1: intra-model swapping overhead (full-TPU execution)",
            &[
                "model",
                "size MB",
                "compute ms",
                "swap ms",
                "swap %",
                "observed e2e ms",
            ],
            &rows,
        );
        let lo = self
            .rows
            .iter()
            .map(|r| r.swap_fraction)
            .fold(1.0f64, f64::min);
        let hi = self
            .rows
            .iter()
            .map(|r| r.swap_fraction)
            .fold(0.0f64, f64::max);
        println!(
            "range: {}..{} (paper: 20.2%..62.4%)",
            pct(lo),
            pct(hi)
        );
        let _ = ms(0.0);
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.rows
                .iter()
                .map(|r| {
                    Json::from_pairs(vec![
                        ("model", Json::Str(r.model.clone())),
                        ("size_mb", Json::Num(r.size_mb)),
                        ("compute_ms", Json::Num(r.compute_ms)),
                        ("swap_ms", Json::Num(r.swap_ms)),
                        ("swap_fraction", Json::Num(r.swap_fraction)),
                        ("observed_mean_ms", Json::Num(r.observed_mean_ms)),
                    ])
                })
                .collect(),
        )
    }
}
