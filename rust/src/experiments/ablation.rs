//! Design-choice ablations (DESIGN.md §7 extension):
//!
//! * **Optimality gap** — Alg. 1's greedy hill climb vs the exhaustive
//!   NLIP solution on every 1–2 model workload the paper evaluates,
//!   with decision-cost ratios (why the heuristic is the right trade).
//! * **Lookahead ablation** — the 2-step move rule vs a 1-step variant
//!   (the paper's justification for evaluating up to two layers).

use crate::alloc::{self, Allocation};
use crate::analytic::{AlphaMode, AnalyticModel, Config, Tenant};
use crate::metrics::mape;
use crate::util::json::Json;
use crate::workload::{equal_tpu_load_shares, rates_for_utilization};

use super::common::{print_table, Ctx};

pub struct GapRow {
    pub workload: String,
    pub hc_objective: f64,
    pub ex_objective: f64,
    pub gap_pct: f64,
    pub hc_evals: usize,
    pub ex_evals: usize,
    pub same_config: bool,
}

pub struct AlphaRow {
    pub mix: String,
    pub observed_ms: f64,
    pub conservative_ms: f64,
    pub pairwise_ms: f64,
}

pub struct Ablation {
    pub rows: Vec<GapRow>,
    pub lookahead_rows: Vec<(String, f64, f64)>, // (workload, 1-step, 2-step)
    pub alpha_rows: Vec<AlphaRow>,
    pub alpha_mape_conservative: f64,
    pub alpha_mape_pairwise: f64,
}

/// 1-step-only hill climb (ablated lookahead) for comparison.
fn hill_climb_1step(am: &AnalyticModel, tenants: &[Tenant], k_max: usize) -> Allocation {
    let n = tenants.len();
    let mut partitions = vec![0usize; n];
    let mut cores = alloc::prop_alloc(&am.cost, tenants, &partitions, k_max);
    let mut current = am.objective(
        tenants,
        &Config {
            partitions: partitions.clone(),
            cores: cores.clone(),
        },
    );
    let mut evaluations = 1usize;
    loop {
        let mut best: Option<(usize, f64, Vec<usize>)> = None;
        for m in 0..n {
            if partitions[m] + 1 > tenants[m].model.partition_points {
                continue;
            }
            let mut cand = partitions.clone();
            cand[m] += 1;
            let cand_cores = alloc::prop_alloc(&am.cost, tenants, &cand, k_max);
            let obj = am.objective(
                tenants,
                &Config {
                    partitions: cand,
                    cores: cand_cores.clone(),
                },
            );
            evaluations += 1;
            if best.as_ref().map(|(_, l, _)| obj < *l).unwrap_or(true) {
                best = Some((m, obj, cand_cores));
            }
        }
        match best {
            Some((m, obj, k_new)) if obj < current => {
                partitions[m] += 1;
                cores = k_new;
                current = obj;
            }
            _ => break,
        }
    }
    Allocation {
        config: Config { partitions, cores },
        predicted_objective: current,
        evaluations,
    }
}

const WORKLOADS: [(&[&str], f64); 6] = [
    (&["inceptionv4"], 2.0),
    (&["resnet50v2"], 3.0),
    (&["densenet201"], 3.0),
    (&["efficientnet", "gpunet"], 1.5),
    (&["mobilenetv2", "squeezenet"], 4.0),
    (&["xception", "inceptionv4"], 1.0),
];

pub fn run(ctx: &Ctx) -> Result<Ablation, String> {
    let mut rows = Vec::new();
    let mut lookahead_rows = Vec::new();
    for (names, per_rate) in WORKLOADS {
        let rates: Vec<f64> = vec![per_rate; names.len()];
        let tenants = ctx.tenants(names, &rates)?;
        let hc = alloc::hill_climb(&ctx.am, &tenants, ctx.k_max);
        let ex = alloc::exhaustive_best(&ctx.am, &tenants, ctx.k_max)
            .ok_or_else(|| format!("{}: no feasible configuration", names.join("+")))?;
        rows.push(GapRow {
            workload: names.join("+"),
            hc_objective: hc.predicted_objective,
            ex_objective: ex.predicted_objective,
            gap_pct: (hc.predicted_objective / ex.predicted_objective - 1.0) * 100.0,
            hc_evals: hc.evaluations,
            ex_evals: ex.evaluations,
            same_config: hc.config == ex.config,
        });
        let one = hill_climb_1step(&ctx.am, &tenants, ctx.k_max);
        lookahead_rows.push((
            names.join("+"),
            one.predicted_objective,
            hc.predicted_objective,
        ));
    }
    // α-refinement ablation: conservative Eq. 10 vs pairwise-conflict α,
    // validated against DES observation on mixed-size tenancies.
    let pairwise = AnalyticModel::with_alpha_mode(ctx.cost.clone(), AlphaMode::Pairwise);
    let mut alpha_rows = Vec::new();
    for mix in [
        &["efficientnet", "gpunet"][..],
        &["mobilenetv2", "squeezenet", "resnet50v2"][..],
        &["densenet201", "xception"][..],
        &["mobilenetv2", "gpunet", "densenet201"][..],
    ] {
        let zero = vec![0.0; mix.len()];
        let tenants0 = ctx.tenants(mix, &zero)?;
        let cfg = Config::all_tpu(&tenants0);
        let shares = equal_tpu_load_shares(&ctx.am, &tenants0);
        let rates = rates_for_utilization(&ctx.am, &tenants0, &cfg, &shares, 0.4);
        let tenants = ctx.tenants(mix, &rates)?;
        let observed = ctx.observe(&tenants, &cfg).mean_latency * 1e3;
        alpha_rows.push(AlphaRow {
            mix: mix.join("+"),
            observed_ms: observed,
            conservative_ms: ctx.am.mean_latency(&tenants, &cfg) * 1e3,
            pairwise_ms: pairwise.mean_latency(&tenants, &cfg) * 1e3,
        });
    }
    let obs: Vec<f64> = alpha_rows.iter().map(|r| r.observed_ms).collect();
    let alpha_mape_conservative = mape(
        &obs,
        &alpha_rows.iter().map(|r| r.conservative_ms).collect::<Vec<_>>(),
    );
    let alpha_mape_pairwise = mape(
        &obs,
        &alpha_rows.iter().map(|r| r.pairwise_ms).collect::<Vec<_>>(),
    );

    Ok(Ablation {
        rows,
        lookahead_rows,
        alpha_rows,
        alpha_mape_conservative,
        alpha_mape_pairwise,
    })
}

impl Ablation {
    pub fn print(&self) {
        let rows: Vec<Vec<String>> = self
            .rows
            .iter()
            .map(|r| {
                vec![
                    r.workload.clone(),
                    format!("{:.4}", r.hc_objective),
                    format!("{:.4}", r.ex_objective),
                    format!("{:+.2}%", r.gap_pct),
                    format!("{}", r.hc_evals),
                    format!("{}", r.ex_evals),
                    if r.same_config { "yes" } else { "no" }.into(),
                ]
            })
            .collect();
        print_table(
            "Ablation: hill-climb vs exhaustive NLIP (objective = Σ λ·T)",
            &[
                "workload",
                "hill-climb",
                "exhaustive",
                "gap",
                "hc evals",
                "ex evals",
                "same config",
            ],
            &rows,
        );

        let rows: Vec<Vec<String>> = self
            .lookahead_rows
            .iter()
            .map(|(w, one, two)| {
                vec![
                    w.clone(),
                    format!("{one:.4}"),
                    format!("{two:.4}"),
                    if two < one {
                        format!("2-step better by {:.1}%", (one / two - 1.0) * 100.0)
                    } else {
                        "tie".into()
                    },
                ]
            })
            .collect();
        print_table(
            "Ablation: lookahead h∈{1} vs h∈{1,2} (Alg. 1's spike-hopping)",
            &["workload", "1-step obj", "2-step obj", "verdict"],
            &rows,
        );

        let rows: Vec<Vec<String>> = self
            .alpha_rows
            .iter()
            .map(|r| {
                vec![
                    r.mix.clone(),
                    format!("{:.1}", r.observed_ms),
                    format!("{:.1}", r.conservative_ms),
                    format!("{:.1}", r.pairwise_ms),
                ]
            })
            .collect();
        print_table(
            "Ablation: α estimators — Eq. 10 (conservative) vs pairwise-conflict refinement",
            &["mix (equal TPU load, ρ=0.4)", "observed ms", "Eq.10 pred", "pairwise pred"],
            &rows,
        );
        println!(
            "MAPE: conservative {:.1}%  pairwise {:.1}%  (refinement targets Eq. 10's mixed-size over-prediction)",
            self.alpha_mape_conservative, self.alpha_mape_pairwise
        );
    }

    pub fn to_json(&self) -> Json {
        let alpha = Json::Arr(
            self.alpha_rows
                .iter()
                .map(|r| {
                    Json::from_pairs(vec![
                        ("mix", Json::Str(r.mix.clone())),
                        ("observed_ms", Json::Num(r.observed_ms)),
                        ("conservative_ms", Json::Num(r.conservative_ms)),
                        ("pairwise_ms", Json::Num(r.pairwise_ms)),
                    ])
                })
                .collect(),
        );
        let gaps = Json::Arr(
            self.rows
                .iter()
                .map(|r| {
                    Json::from_pairs(vec![
                        ("workload", Json::Str(r.workload.clone())),
                        ("hc_objective", Json::Num(r.hc_objective)),
                        ("ex_objective", Json::Num(r.ex_objective)),
                        ("gap_pct", Json::Num(r.gap_pct)),
                        ("hc_evals", Json::Num(r.hc_evals as f64)),
                        ("ex_evals", Json::Num(r.ex_evals as f64)),
                        ("same_config", Json::Bool(r.same_config)),
                    ])
                })
                .collect(),
        );
        Json::from_pairs(vec![
            ("gaps", gaps),
            ("alpha_refinement", alpha),
            ("alpha_mape_conservative", Json::Num(self.alpha_mape_conservative)),
            ("alpha_mape_pairwise", Json::Num(self.alpha_mape_pairwise)),
        ])
    }
}
