//! Overload experiment — bounded admission under saturation: sweep
//! ρ ∈ {0.7, 1.0, 1.5} × every [`OverloadPolicy`] on the Table-II mix.
//!
//! The workload is the scheduler ablation's mixed-class tenancy
//! (interactive/standard/standard/batch at equal per-model TPU load),
//! the configuration planned once by the SwapLess allocator at the
//! sub-critical operating point, and the *same* Poisson stream — scaled
//! to each ρ — replayed under each overload policy with a bounded
//! station queue. Every request carries a deadline of
//! [`DEADLINE_FACTOR`] × its model's analytic e2e prediction, so
//! `DeadlineDrop` has real work to do and goodput is comparable across
//! policies.
//!
//! The paper's premise made quantitative: at ρ = 1.5 the unbounded
//! `Block` baseline's queue (and every class's latency) diverges with
//! the horizon, while `ShedLowClass` holds the interactive class's p99
//! near its ρ = 0.7 value by evicting batch work — bounded queue depth,
//! bounded tails, explicit drop counters instead of implicit queueing.

use crate::alloc;
use crate::analytic::Config;
use crate::sched::{DisciplineKind, OverloadPolicy, SloClass};
use crate::sim::{SimOptions, Simulator};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::workload::{
    equal_tpu_load_shares, generate_arrivals_annotated, rates_for_load_factor, RateSchedule,
};

use super::common::{print_table, Ctx};
use super::sched_ablation::{CLASSES, MODELS};

/// Swept TPU load factors (0.7 sub-critical, 1.0 critical, 1.5 overload).
pub const RHOS: [f64; 3] = [0.7, 1.0, 1.5];
/// Station occupancy bound applied under every policy but `Block`.
/// Chosen at the knee: at ρ = 0.7 the FIFO queue's p99 occupancy is
/// already near this bound, so bounding it costs little sub-critical
/// latency while pinning the overload tails to `cap × service`.
pub const CAPACITY: usize = 8;
/// Per-request deadline = factor × the model's analytic e2e prediction
/// at the sub-critical operating point.
pub const DEADLINE_FACTOR: f64 = 4.0;

#[derive(Debug, Clone)]
pub struct OverloadRow {
    pub policy: &'static str,
    pub rho: f64,
    pub accepted: u64,
    pub completed: u64,
    pub rejected: u64,
    pub shed: u64,
    pub expired: u64,
    pub goodput: u64,
    pub interactive_mean_ms: f64,
    pub interactive_p99_ms: f64,
    pub max_tpu_occupancy: usize,
}

pub struct OverloadSweep {
    pub models: Vec<String>,
    pub config: Config,
    pub capacity: usize,
    pub deadlines_s: Vec<f64>,
    pub rows: Vec<OverloadRow>,
}

pub fn run(ctx: &Ctx) -> Result<OverloadSweep, String> {
    let names: Vec<&str> = MODELS.to_vec();
    let zero = vec![0.0; names.len()];
    let tenants0 = ctx.tenants(&names, &zero)?;
    let full = Config::all_tpu(&tenants0);
    let shares = equal_tpu_load_shares(&ctx.am, &tenants0);

    // Plan once at the sub-critical point; the overload runs keep the
    // same configuration (overload control is the queue's job, not the
    // allocator's — re-planning cannot create capacity at ρ > 1).
    let base_rates = rates_for_load_factor(&ctx.am, &tenants0, &full, &shares, RHOS[0]);
    let base_tenants = ctx.tenants(&names, &base_rates)?;
    let config = alloc::hill_climb(&ctx.am, &base_tenants, ctx.k_max).config;

    // Per-model relative deadlines from the analytic prediction at the
    // planned sub-critical operating point.
    let deadlines_s: Vec<f64> = (0..base_tenants.len())
        .map(|i| {
            let e2e = ctx.am.e2e_latency(&base_tenants, &config, i);
            if e2e.is_finite() && e2e > 0.0 {
                DEADLINE_FACTOR * e2e
            } else {
                1.0
            }
        })
        .collect();
    let rel_deadlines: Vec<Option<f64>> = deadlines_s.iter().map(|d| Some(*d)).collect();

    let horizon = ctx.horizon;
    let mut rows = Vec::new();
    for rho in RHOS {
        let rates = rates_for_load_factor(&ctx.am, &tenants0, &full, &shares, rho);
        let schedules: Vec<RateSchedule> =
            rates.iter().map(|r| RateSchedule::constant(*r)).collect();
        let tenants = ctx.tenants(&names, &rates)?;
        for policy in OverloadPolicy::ALL {
            // Identical arrival stream per (rho): same seed across policies.
            let mut rng = Rng::new(ctx.seed);
            let arrivals =
                generate_arrivals_annotated(&schedules, &CLASSES, &rel_deadlines, horizon, &mut rng);
            let mut sim = Simulator::new(
                &ctx.cost,
                &tenants,
                config.clone(),
                SimOptions {
                    horizon,
                    warmup: horizon * 0.05,
                    seed: ctx.seed,
                    discipline: DisciplineKind::Fifo,
                    capacity: Some(CAPACITY),
                    overload: policy,
                    ..SimOptions::default()
                },
            );
            let res = sim.run(&arrivals, None);
            let interactive = res.per_class.get(SloClass::Interactive);
            rows.push(OverloadRow {
                policy: policy.name(),
                rho,
                accepted: res.per_class.accepted_total(),
                completed: res.per_model.iter().map(|m| m.completed).sum(),
                rejected: res.per_class.rejected_total(),
                shed: res.per_class.shed_total(),
                expired: res.per_class.expired_total(),
                goodput: res.per_class.goodput_total(),
                interactive_mean_ms: interactive.mean() * 1e3,
                interactive_p99_ms: interactive.percentile(99.0) * 1e3,
                max_tpu_occupancy: res.max_tpu_occupancy,
            });
        }
    }
    Ok(OverloadSweep {
        models: MODELS.iter().map(|m| m.to_string()).collect(),
        config,
        capacity: CAPACITY,
        deadlines_s,
        rows,
    })
}

impl OverloadSweep {
    /// The row for (policy, rho), if present.
    pub fn row(&self, policy: OverloadPolicy, rho: f64) -> Option<&OverloadRow> {
        self.rows
            .iter()
            .find(|r| r.policy == policy.name() && (r.rho - rho).abs() < 1e-9)
    }

    pub fn print(&self) {
        println!(
            "\noverload sweep: {} @ cap {} (deadlines {:?} ms), P={:?} K={:?}",
            self.models.join("+"),
            self.capacity,
            self.deadlines_s
                .iter()
                .map(|d| (d * 1e4).round() / 10.0)
                .collect::<Vec<_>>(),
            self.config.partitions,
            self.config.cores
        );
        let rows: Vec<Vec<String>> = self
            .rows
            .iter()
            .map(|r| {
                vec![
                    r.policy.to_string(),
                    format!("{:.1}", r.rho),
                    r.accepted.to_string(),
                    r.completed.to_string(),
                    r.rejected.to_string(),
                    r.shed.to_string(),
                    r.expired.to_string(),
                    r.goodput.to_string(),
                    format!("{:.1}", r.interactive_mean_ms),
                    format!("{:.1}", r.interactive_p99_ms),
                    r.max_tpu_occupancy.to_string(),
                ]
            })
            .collect();
        print_table(
            "Overload policies x load factor (interactive-class tails)",
            &[
                "policy", "rho", "accept", "done", "reject", "shed", "expire", "goodput",
                "int mean", "int p99", "max q",
            ],
            &rows,
        );
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            (
                "models",
                Json::Arr(self.models.iter().map(|m| Json::Str(m.clone())).collect()),
            ),
            ("capacity", Json::Num(self.capacity as f64)),
            (
                "deadlines_s",
                Json::Arr(self.deadlines_s.iter().map(|d| Json::Num(*d)).collect()),
            ),
            (
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| {
                            Json::from_pairs(vec![
                                ("policy", Json::Str(r.policy.to_string())),
                                ("rho", Json::Num(r.rho)),
                                ("accepted", Json::Num(r.accepted as f64)),
                                ("completed", Json::Num(r.completed as f64)),
                                ("rejected", Json::Num(r.rejected as f64)),
                                ("shed", Json::Num(r.shed as f64)),
                                ("expired", Json::Num(r.expired as f64)),
                                ("goodput", Json::Num(r.goodput as f64)),
                                ("interactive_mean_ms", Json::Num(r.interactive_mean_ms)),
                                ("interactive_p99_ms", Json::Num(r.interactive_p99_ms)),
                                (
                                    "max_tpu_occupancy",
                                    Json::Num(r.max_tpu_occupancy as f64),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareSpec;
    use crate::model::Manifest;

    #[test]
    fn overload_sweep_bounds_queues_and_interactive_tails() {
        let mut ctx = Ctx::new(Manifest::synthetic(), HardwareSpec::default());
        ctx.horizon = 200.0;
        let r = run(&ctx).unwrap();
        assert_eq!(r.rows.len(), RHOS.len() * OverloadPolicy::ALL.len());

        // Every bounded policy honors the occupancy cap at every rho;
        // the Block baseline's queue diverges at rho = 1.5.
        for row in &r.rows {
            if row.policy != OverloadPolicy::Block.name() {
                assert!(
                    row.max_tpu_occupancy <= CAPACITY,
                    "{} @ rho {}: occupancy {} > cap {}",
                    row.policy,
                    row.rho,
                    row.max_tpu_occupancy,
                    CAPACITY
                );
            }
        }
        let block_15 = r.row(OverloadPolicy::Block, 1.5).unwrap();
        assert!(
            block_15.max_tpu_occupancy > 4 * CAPACITY,
            "Block at rho 1.5 should diverge: max occupancy {}",
            block_15.max_tpu_occupancy
        );
        assert_eq!(block_15.rejected + block_15.shed + block_15.expired, 0);

        // The acceptance criterion: ShedLowClass keeps the interactive
        // class's p99 within 2x of its sub-critical value while Block
        // diverges far past it.
        let shed_07 = r.row(OverloadPolicy::ShedLowClass, 0.7).unwrap();
        let shed_15 = r.row(OverloadPolicy::ShedLowClass, 1.5).unwrap();
        assert!(
            shed_15.interactive_p99_ms <= 2.0 * shed_07.interactive_p99_ms,
            "shed p99 {} ms vs sub-critical {} ms",
            shed_15.interactive_p99_ms,
            shed_07.interactive_p99_ms
        );
        assert!(
            block_15.interactive_p99_ms > 2.0 * shed_15.interactive_p99_ms,
            "Block p99 {} ms should dwarf shed p99 {} ms",
            block_15.interactive_p99_ms,
            shed_15.interactive_p99_ms
        );
        // Shedding actually dropped work at overload, and the shed policy
        // sheds strictly lower classes only — interactive never sheds.
        assert!(shed_15.shed + shed_15.rejected > 0);

        // DeadlineDrop converts overload into expirations and keeps
        // goodput meaningful (completions that met their deadlines).
        let dl_15 = r.row(OverloadPolicy::DeadlineDrop, 1.5).unwrap();
        assert!(dl_15.expired > 0, "DeadlineDrop must expire work at rho 1.5");
        assert!(dl_15.goodput <= dl_15.completed);

        // Reject refuses at admission (entry refusals dominate; `shed`
        // can still fire for TPU-accepted work hitting a full internal
        // CPU station) and never expires anything.
        let rej_15 = r.row(OverloadPolicy::Reject, 1.5).unwrap();
        assert!(rej_15.rejected > 0);
        assert_eq!(rej_15.expired, 0);
    }
}
