//! Scheduler ablation — FIFO vs strict priority vs weighted-fair (DRR)
//! vs shortest-predicted-service-first on the Table-II multi-tenant mix.
//!
//! The workload is the paper's mixed-size tenancy (small interactive
//! models co-located with large batch models), rates solved for equal
//! per-model TPU load at a stressed utilization, the configuration
//! planned once by the SwapLess allocator, and the *same* Poisson
//! arrival stream replayed under each discipline of the shared `sched`
//! core. Reported per discipline: overall mean/p99 and per-SLO-class
//! mean/p99 — the tail-latency trade each discipline buys is the
//! experiment's output.

use crate::alloc;
use crate::analytic::{Config, Tenant};
use crate::sched::{DisciplineKind, SloClass};
use crate::sim::{SimOptions, Simulator};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::workload::{
    equal_tpu_load_shares, generate_arrivals_classed, rates_for_utilization, RateSchedule,
};

use super::common::{print_table, Ctx};

/// The Table-II mix: two small latency-class models against two large
/// throughput-class models — the regime where discipline choice moves
/// the per-class tails the most.
pub const MODELS: [&str; 4] = ["mobilenetv2", "squeezenet", "mnasnet", "inceptionv4"];
pub const CLASSES: [SloClass; 4] = [
    SloClass::Interactive,
    SloClass::Standard,
    SloClass::Standard,
    SloClass::Batch,
];
pub const RHO_TARGET: f64 = 0.7;

#[derive(Debug, Clone)]
pub struct ClassRow {
    pub class: &'static str,
    pub completed: u64,
    pub mean_ms: f64,
    pub p99_ms: f64,
}

#[derive(Debug, Clone)]
pub struct DisciplineRow {
    pub discipline: &'static str,
    pub completed: u64,
    pub mean_ms: f64,
    pub p99_ms: f64,
    pub per_class: Vec<ClassRow>,
}

pub struct SchedAblation {
    pub models: Vec<String>,
    pub classes: Vec<&'static str>,
    pub config: Config,
    pub rho_target: f64,
    pub rows: Vec<DisciplineRow>,
}

/// Build the mix (models + classes + equal-TPU-load rates at
/// [`RHO_TARGET`]) and the SwapLess plan it runs under.
fn workload(ctx: &Ctx) -> Result<(Vec<Tenant>, Config), String> {
    let names: Vec<&str> = MODELS.to_vec();
    let zero = vec![0.0; names.len()];
    let tenants0 = ctx.tenants(&names, &zero)?;
    let full = Config::all_tpu(&tenants0);
    let shares = equal_tpu_load_shares(&ctx.am, &tenants0);
    let rates = rates_for_utilization(&ctx.am, &tenants0, &full, &shares, RHO_TARGET);
    let tenants = ctx.tenants(&names, &rates)?;
    let plan = alloc::hill_climb(&ctx.am, &tenants, ctx.k_max);
    Ok((tenants, plan.config))
}

pub fn run(ctx: &Ctx) -> Result<SchedAblation, String> {
    let (tenants, config) = workload(ctx)?;
    let horizon = ctx.horizon;
    let schedules: Vec<RateSchedule> = tenants
        .iter()
        .map(|t| RateSchedule::constant(t.rate))
        .collect();

    let mut rows = Vec::new();
    for kind in DisciplineKind::ALL {
        // Identical arrival stream for every discipline (same seed).
        let mut rng = Rng::new(ctx.seed);
        let arrivals = generate_arrivals_classed(&schedules, &CLASSES, horizon, &mut rng);
        let mut sim = Simulator::new(
            &ctx.cost,
            &tenants,
            config.clone(),
            SimOptions {
                horizon,
                warmup: horizon * 0.05,
                seed: ctx.seed,
                discipline: kind,
                ..SimOptions::default()
            },
        );
        let res = sim.run(&arrivals, None);
        let completed: u64 = res.per_model.iter().map(|m| m.completed).sum();
        let per_class: Vec<ClassRow> = res
            .per_class
            .non_empty()
            .into_iter()
            .map(|(class, hist)| ClassRow {
                class: class.name(),
                completed: hist.count(),
                mean_ms: hist.mean() * 1e3,
                p99_ms: hist.percentile(99.0) * 1e3,
            })
            .collect();
        // Overall p99 from the merged per-class histograms (identical
        // geometry by construction).
        let mut all = crate::metrics::LatencyHistogram::default();
        for (_, hist) in res.per_class.non_empty() {
            all.merge(hist);
        }
        rows.push(DisciplineRow {
            discipline: kind.name(),
            completed,
            mean_ms: res.mean_latency * 1e3,
            p99_ms: all.percentile(99.0) * 1e3,
            per_class,
        });
    }
    Ok(SchedAblation {
        models: MODELS.iter().map(|m| m.to_string()).collect(),
        classes: CLASSES.iter().map(|c| c.name()).collect(),
        config,
        rho_target: RHO_TARGET,
        rows,
    })
}

impl SchedAblation {
    pub fn print(&self) {
        println!(
            "\nscheduler ablation: {} (classes {}) @ rho {:.2}, P={:?} K={:?}",
            self.models.join("+"),
            self.classes.join("/"),
            self.rho_target,
            self.config.partitions,
            self.config.cores
        );
        let mut rows = Vec::new();
        for d in &self.rows {
            rows.push(vec![
                d.discipline.to_string(),
                "all".to_string(),
                d.completed.to_string(),
                format!("{:.1}", d.mean_ms),
                format!("{:.1}", d.p99_ms),
            ]);
            for c in &d.per_class {
                rows.push(vec![
                    String::new(),
                    c.class.to_string(),
                    c.completed.to_string(),
                    format!("{:.1}", c.mean_ms),
                    format!("{:.1}", c.p99_ms),
                ]);
            }
        }
        print_table(
            "Scheduler ablation (per-SLO-class latency)",
            &["discipline", "class", "n", "mean (ms)", "p99 (ms)"],
            &rows,
        );
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            (
                "models",
                Json::Arr(self.models.iter().map(|m| Json::Str(m.clone())).collect()),
            ),
            (
                "classes",
                Json::Arr(
                    self.classes
                        .iter()
                        .map(|c| Json::Str(c.to_string()))
                        .collect(),
                ),
            ),
            ("rho_target", Json::Num(self.rho_target)),
            (
                "disciplines",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|d| {
                            Json::from_pairs(vec![
                                ("discipline", Json::Str(d.discipline.to_string())),
                                ("completed", Json::Num(d.completed as f64)),
                                ("mean_ms", Json::Num(d.mean_ms)),
                                ("p99_ms", Json::Num(d.p99_ms)),
                                (
                                    "per_class",
                                    Json::Arr(
                                        d.per_class
                                            .iter()
                                            .map(|c| {
                                                Json::from_pairs(vec![
                                                    ("class", Json::Str(c.class.to_string())),
                                                    ("completed", Json::Num(c.completed as f64)),
                                                    ("mean_ms", Json::Num(c.mean_ms)),
                                                    ("p99_ms", Json::Num(c.p99_ms)),
                                                ])
                                            })
                                            .collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareSpec;
    use crate::model::Manifest;

    #[test]
    fn ablation_runs_all_disciplines_with_per_class_output() {
        let mut ctx = Ctx::new(Manifest::synthetic(), HardwareSpec::default());
        ctx.horizon = 150.0;
        let r = run(&ctx).unwrap();
        assert_eq!(r.rows.len(), DisciplineKind::ALL.len());
        for row in &r.rows {
            assert!(row.completed > 500, "{}: {}", row.discipline, row.completed);
            assert!(row.mean_ms.is_finite() && row.mean_ms > 0.0, "{}", row.discipline);
            assert!(row.p99_ms >= row.mean_ms * 0.5, "{}", row.discipline);
            // All three classes are present in the mix and must be
            // accounted separately.
            assert_eq!(row.per_class.len(), 3, "{}", row.discipline);
            for c in &row.per_class {
                assert!(c.completed > 0, "{} {}", row.discipline, c.class);
                assert!(c.mean_ms.is_finite() && c.p99_ms.is_finite());
            }
        }
        // The JSON blob carries the per-class mean/p99 rows.
        let j = r.to_json();
        let disc = j.arr_of("disciplines").unwrap();
        assert_eq!(disc.len(), 4);
        for d in disc {
            let pc = d.arr_of("per_class").unwrap();
            assert_eq!(pc.len(), 3);
            for c in pc {
                assert!(c.get("mean_ms").is_some());
                assert!(c.get("p99_ms").is_some());
            }
        }
    }
}
