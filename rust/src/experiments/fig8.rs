//! Fig. 8 — Dynamic workloads: MnasNet + InceptionV4 with stepped request
//! rates (5,1) → (5,3) at 300 s → (5,5) at 600 s over a 900 s horizon.
//!
//! SwapLess's online policy (sliding-window rate monitor + hill climb) is
//! compared against the static baselines; the paper reports up to 75.1%
//! latency reduction and < 2 ms allocator invocations.

use crate::alloc;
use crate::analytic::{AnalyticModel, Config, Tenant};
use crate::sim::reconfig::{StaticPolicy, SwapLessPolicy};
use crate::sim::{simulate_churn, simulate_dynamic, ChurnEvent, ChurnKind, SimOptions};
use crate::util::json::Json;
use crate::workload::RateSchedule;

use super::common::{pct, print_table, Ctx};

pub struct PolicyOutcome {
    pub policy: String,
    pub mean_ms: f64,
    pub p95_ms: f64,
    pub timeline: Vec<(f64, f64)>,
    pub reconfigs: Vec<(f64, Config)>,
    pub max_decision_us: f64,
}

pub struct Fig8 {
    pub outcomes: Vec<PolicyOutcome>,
    pub reduction_vs_static: f64,
}

pub const MODELS: [&str; 2] = ["mnasnet", "inceptionv4"];

pub fn schedules() -> Vec<RateSchedule> {
    vec![
        RateSchedule::constant(5.0),
        RateSchedule::stepped(vec![(0.0, 1.0), (300.0, 3.0), (600.0, 5.0)]),
    ]
}

pub fn run(ctx: &Ctx) -> Result<Fig8, String> {
    let tenants: Vec<Tenant> = ctx.tenants(&MODELS, &[5.0, 1.0])?;
    let horizon = 900.0;
    let opts = |seed| SimOptions {
        horizon,
        warmup: 10.0,
        seed,
        timeline_window: Some(15.0),
        ..SimOptions::default()
    };

    let mut outcomes = Vec::new();

    // Static baselines plan once for the *initial* rates.
    let compiler = alloc::edge_tpu_compiler(&ctx.am, &tenants).config;
    let threshold = alloc::threshold_partitioning(&ctx.am, &tenants, ctx.k_max, 0.10).config;
    let initial_swapless = alloc::hill_climb(&ctx.am, &tenants, ctx.k_max).config;

    for (name, cfg) in [
        ("static-compiler", compiler),
        ("static-threshold", threshold),
        ("static-swapless@t0", initial_swapless.clone()),
    ] {
        let mut policy = StaticPolicy;
        let res = simulate_dynamic(
            &ctx.cost,
            &tenants,
            &cfg,
            &schedules(),
            &mut policy,
            opts(ctx.seed),
        );
        outcomes.push(PolicyOutcome {
            policy: name.into(),
            mean_ms: res.mean_latency * 1e3,
            p95_ms: weighted_p95(&res) * 1e3,
            timeline: res.timeline.map(|t| t.series()).unwrap_or_default(),
            reconfigs: Vec::new(),
            max_decision_us: 0.0,
        });
    }

    // SwapLess adaptive.
    let am = AnalyticModel::new(ctx.cost.clone());
    let mut policy = SwapLessPolicy::new(am, ctx.k_max, tenants.len(), 45.0, 10.0, 0.20);
    let res = simulate_dynamic(
        &ctx.cost,
        &tenants,
        &initial_swapless,
        &schedules(),
        &mut policy,
        opts(ctx.seed),
    );
    let max_us = policy
        .decision_micros
        .iter()
        .fold(0.0f64, |a, b| a.max(*b));
    outcomes.push(PolicyOutcome {
        policy: "swapless-adaptive".into(),
        mean_ms: res.mean_latency * 1e3,
        p95_ms: weighted_p95(&res) * 1e3,
        timeline: res.timeline.map(|t| t.series()).unwrap_or_default(),
        reconfigs: res
            .reconfigs
            .iter()
            .map(|(t, c, _)| (*t, c.clone()))
            .collect(),
        max_decision_us: max_us,
    });

    // Compare against the *stable* static baselines (the compiler config
    // is unstable at the (5,5) RPS step — its latency diverges, which would
    // inflate the reduction meaninglessly).
    let best_reference = outcomes[..3]
        .iter()
        .filter(|o| o.mean_ms.is_finite() && o.mean_ms < 10_000.0)
        .map(|o| o.mean_ms)
        .fold(0.0f64, f64::max);
    let adaptive = outcomes[3].mean_ms;
    Ok(Fig8 {
        reduction_vs_static: if best_reference > 0.0 {
            ((best_reference - adaptive) / best_reference).max(0.0)
        } else {
            0.0
        },
        outcomes,
    })
}

/// Churn scenario (tenant lifecycle through the DES): MnasNet serves at
/// 5 RPS throughout; InceptionV4 *attaches* at t=300 s (3 RPS) and
/// *detaches* at t=600 s. The SwapLess policy is notified through its
/// `on_attach`/`on_detach` hooks and re-plans at both transitions — the
/// same code path the live coordinator drives.
pub struct Churn {
    pub mean_ms: f64,
    pub host_mean_ms: f64,
    pub guest_mean_ms: f64,
    pub guest_completed: u64,
    pub dropped: u64,
    pub reconfigs: Vec<(f64, Config)>,
    pub churn_log: Vec<(f64, String)>,
    pub timeline: Vec<(f64, f64)>,
}

pub fn run_churn(ctx: &Ctx) -> Result<Churn, String> {
    let horizon = 900.0;
    let tenants = ctx.tenants(&["mnasnet"], &[5.0])?;
    let initial = alloc::hill_climb(&ctx.am, &tenants, ctx.k_max).config;
    let churn = vec![
        ChurnEvent {
            time: 300.0,
            kind: ChurnKind::Attach {
                tenant: Tenant {
                    model: ctx.manifest.get("inceptionv4")?.clone(),
                    rate: 3.0,
                },
                schedule: RateSchedule::constant(3.0),
            },
        },
        ChurnEvent {
            time: 600.0,
            kind: ChurnKind::Detach {
                name: "inceptionv4".into(),
            },
        },
    ];
    let am = AnalyticModel::new(ctx.cost.clone());
    let mut policy = SwapLessPolicy::new(am, ctx.k_max, tenants.len(), 45.0, 10.0, 0.20);
    let res = simulate_churn(
        &ctx.cost,
        &tenants,
        &initial,
        &[RateSchedule::constant(5.0)],
        churn,
        &mut policy,
        SimOptions {
            horizon,
            warmup: 10.0,
            seed: ctx.seed,
            timeline_window: Some(15.0),
            ..SimOptions::default()
        },
    );
    let guest = res
        .retired
        .iter()
        .find(|m| m.name == "inceptionv4")
        .ok_or_else(|| "guest tenant did not retire".to_string())?;
    Ok(Churn {
        mean_ms: res.mean_latency * 1e3,
        host_mean_ms: res.per_model[0].latency.mean() * 1e3,
        guest_mean_ms: guest.latency.mean() * 1e3,
        guest_completed: guest.completed,
        dropped: res.dropped,
        reconfigs: res
            .reconfigs
            .iter()
            .map(|(t, c, _)| (*t, c.clone()))
            .collect(),
        churn_log: res.churn_log.clone(),
        timeline: res.timeline.map(|t| t.series()).unwrap_or_default(),
    })
}

impl Churn {
    pub fn print(&self) {
        println!("\n=== Churn: MnasNet@5 RPS; InceptionV4 attaches @300s (3 RPS), detaches @600s ===");
        for (t, what) in &self.churn_log {
            println!("  t={t:>5.1}s {what}");
        }
        println!(
            "mean {:.1} ms | host mean {:.1} ms | guest mean {:.1} ms over {} completions | {} dropped at churn",
            self.mean_ms, self.host_mean_ms, self.guest_mean_ms, self.guest_completed, self.dropped
        );
        for (t, cfg) in &self.reconfigs {
            println!(
                "  reconfig @ {:>5.1}s -> P={:?} K={:?}",
                t, cfg.partitions, cfg.cores
            );
        }
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("mean_ms", Json::Num(self.mean_ms)),
            ("host_mean_ms", Json::Num(self.host_mean_ms)),
            ("guest_mean_ms", Json::Num(self.guest_mean_ms)),
            ("guest_completed", Json::Num(self.guest_completed as f64)),
            ("dropped", Json::Num(self.dropped as f64)),
            (
                "timeline",
                Json::Arr(
                    self.timeline
                        .iter()
                        .map(|(t, v)| Json::Arr(vec![Json::Num(*t), Json::Num(*v)]))
                        .collect(),
                ),
            ),
            (
                "reconfigs",
                Json::Arr(
                    self.reconfigs
                        .iter()
                        .map(|(t, c)| {
                            Json::from_pairs(vec![
                                ("t", Json::Num(*t)),
                                (
                                    "partitions",
                                    Json::Arr(
                                        c.partitions
                                            .iter()
                                            .map(|p| Json::Num(*p as f64))
                                            .collect(),
                                    ),
                                ),
                                (
                                    "cores",
                                    Json::Arr(
                                        c.cores.iter().map(|k| Json::Num(*k as f64)).collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

fn weighted_p95(res: &crate::sim::SimResult) -> f64 {
    let mut merged = crate::metrics::LatencyHistogram::default();
    for m in &res.per_model {
        merged.merge(&m.latency);
    }
    merged.percentile(95.0)
}

impl Fig8 {
    pub fn print(&self) {
        let rows: Vec<Vec<String>> = self
            .outcomes
            .iter()
            .map(|o| {
                vec![
                    o.policy.clone(),
                    format!("{:.1}", o.mean_ms),
                    format!("{:.1}", o.p95_ms),
                    o.reconfigs.len().to_string(),
                    if o.max_decision_us > 0.0 {
                        format!("{:.0} µs", o.max_decision_us)
                    } else {
                        "-".into()
                    },
                ]
            })
            .collect();
        print_table(
            "Fig. 8: dynamic rates — MnasNet@5 RPS, InceptionV4 1→3→5 RPS (900 s)",
            &["policy", "mean ms", "p95 ms", "reconfigs", "max decision"],
            &rows,
        );
        println!(
            "adaptive reduction vs best stable static: {} (paper: up to 75.1% vs static; decisions < 2 ms)",
            pct(self.reduction_vs_static)
        );
        println!("(static-compiler/threshold go unstable at the (5,5) RPS step — their queues diverge)");
        // Timeline of the adaptive run (sampled).
        if let Some(adaptive) = self.outcomes.last() {
            println!("\nadaptive timeline (t s → window mean ms):");
            for chunk in adaptive.timeline.chunks(4) {
                let line: Vec<String> = chunk
                    .iter()
                    .map(|(t, v)| format!("{:>4.0}s {:>7.1}", t, v * 1e3))
                    .collect();
                println!("  {}", line.join("   "));
            }
            for (t, cfg) in &adaptive.reconfigs {
                println!(
                    "  reconfig @ {:>5.1}s -> P={:?} K={:?}",
                    t, cfg.partitions, cfg.cores
                );
            }
        }
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.outcomes
                .iter()
                .map(|o| {
                    Json::from_pairs(vec![
                        ("policy", Json::Str(o.policy.clone())),
                        ("mean_ms", Json::Num(o.mean_ms)),
                        ("p95_ms", Json::Num(o.p95_ms)),
                        ("max_decision_us", Json::Num(o.max_decision_us)),
                        (
                            "timeline",
                            Json::Arr(
                                o.timeline
                                    .iter()
                                    .map(|(t, v)| {
                                        Json::Arr(vec![Json::Num(*t), Json::Num(*v)])
                                    })
                                    .collect(),
                            ),
                        ),
                        (
                            "reconfigs",
                            Json::Arr(
                                o.reconfigs
                                    .iter()
                                    .map(|(t, c)| {
                                        Json::from_pairs(vec![
                                            ("t", Json::Num(*t)),
                                            (
                                                "partitions",
                                                Json::Arr(
                                                    c.partitions
                                                        .iter()
                                                        .map(|p| Json::Num(*p as f64))
                                                        .collect(),
                                                ),
                                            ),
                                            (
                                                "cores",
                                                Json::Arr(
                                                    c.cores
                                                        .iter()
                                                        .map(|k| Json::Num(*k as f64))
                                                        .collect(),
                                                ),
                                            ),
                                        ])
                                    })
                                    .collect(),
                            ),
                        ),
                    ])
                })
                .collect(),
        )
    }
}
