//! Fig. 6 — Multi-tenant model validation.
//!
//! (a) the α parameter across three two-model scenarios (fits; 50:50
//!     overflow; 90:10 overflow) — paper MAPE 2.2%;
//! (b) predicted vs observed mean latency across model mixes (paper MAPE
//!     6.8%), with per-model rates equalizing TPU load;
//! (c) predicted vs observed across request rates for one mix.

use crate::analytic::Config;
use crate::metrics::mape;
use crate::util::json::Json;
use crate::workload::{equal_tpu_load_shares, rates_for_utilization};

use super::common::{print_table, Ctx};

pub struct AlphaRow {
    pub scenario: String,
    pub model: String,
    pub alpha: f64,
    pub predicted_ms: f64,
    pub observed_ms: f64,
}

pub struct MixRow {
    pub mix: String,
    pub predicted_ms: f64,
    pub observed_ms: f64,
}

pub struct RateRow {
    pub total_rate: f64,
    pub predicted_ms: f64,
    pub observed_ms: f64,
}

pub struct Fig6 {
    pub alpha_rows: Vec<AlphaRow>,
    pub alpha_mape: f64,
    pub mix_rows: Vec<MixRow>,
    pub mix_mape: f64,
    pub rate_rows: Vec<RateRow>,
}

const ALPHA_SCENARIOS: [(&str, &str, f64, f64); 3] = [
    ("mobilenetv2", "squeezenet", 0.5, 0.5),
    ("efficientnet", "gpunet", 0.5, 0.5),
    ("efficientnet", "gpunet", 0.9, 0.1),
];

pub const MIXES: [&[&str]; 4] = [
    &["mobilenetv2", "squeezenet"],
    &["efficientnet", "gpunet"],
    &["mobilenetv2", "squeezenet", "resnet50v2"],
    &["densenet201", "xception"],
];

pub fn run(ctx: &Ctx, rho: f64, rate_sweep_total: &[f64]) -> Result<Fig6, String> {
    // (a) alpha validation at a fixed total rate.
    let mut alpha_rows = Vec::new();
    for (a, b, sa, sb) in ALPHA_SCENARIOS {
        let total = 1.0;
        let tenants = ctx.tenants(&[a, b], &[total * sa, total * sb])?;
        let cfg = Config::all_tpu(&tenants);
        let obs = ctx.observe(&tenants, &cfg);
        for i in 0..2 {
            alpha_rows.push(AlphaRow {
                scenario: format!("{a}+{b} {:.0}:{:.0}", sa * 100.0, sb * 100.0),
                model: tenants[i].model.name.clone(),
                alpha: ctx.am.alpha(&tenants, &cfg, i),
                predicted_ms: ctx.am.e2e_latency(&tenants, &cfg, i) * 1e3,
                observed_ms: obs.per_model[i].latency.mean() * 1e3,
            });
        }
    }
    let alpha_mape = mape(
        &alpha_rows.iter().map(|r| r.observed_ms).collect::<Vec<_>>(),
        &alpha_rows.iter().map(|r| r.predicted_ms).collect::<Vec<_>>(),
    );

    // (b) mixes at equal TPU load, target utilization rho.
    let mut mix_rows = Vec::new();
    for mix in MIXES {
        let zero: Vec<f64> = vec![0.0; mix.len()];
        let tenants0 = ctx.tenants(mix, &zero)?;
        let cfg = Config::all_tpu(&tenants0);
        let shares = equal_tpu_load_shares(&ctx.am, &tenants0);
        let rates = rates_for_utilization(&ctx.am, &tenants0, &cfg, &shares, rho);
        let tenants = ctx.tenants(mix, &rates)?;
        let predicted = ctx.am.mean_latency(&tenants, &cfg);
        let observed = ctx.observe(&tenants, &cfg).mean_latency;
        mix_rows.push(MixRow {
            mix: mix.join("+"),
            predicted_ms: predicted * 1e3,
            observed_ms: observed * 1e3,
        });
    }
    let mix_mape = mape(
        &mix_rows.iter().map(|r| r.observed_ms).collect::<Vec<_>>(),
        &mix_rows.iter().map(|r| r.predicted_ms).collect::<Vec<_>>(),
    );

    // (c) one mix across total request rates.
    let mix = MIXES[1];
    let mut rate_rows = Vec::new();
    for &total in rate_sweep_total {
        let rates: Vec<f64> = vec![total / mix.len() as f64; mix.len()];
        let tenants = ctx.tenants(mix, &rates)?;
        let cfg = Config::all_tpu(&tenants);
        let predicted = ctx.am.mean_latency(&tenants, &cfg);
        if !predicted.is_finite() {
            continue;
        }
        let observed = ctx.observe(&tenants, &cfg).mean_latency;
        rate_rows.push(RateRow {
            total_rate: total,
            predicted_ms: predicted * 1e3,
            observed_ms: observed * 1e3,
        });
    }

    Ok(Fig6 {
        alpha_rows,
        alpha_mape,
        mix_rows,
        mix_mape,
        rate_rows,
    })
}

impl Fig6 {
    pub fn print(&self) {
        let rows: Vec<Vec<String>> = self
            .alpha_rows
            .iter()
            .map(|r| {
                vec![
                    r.scenario.clone(),
                    r.model.clone(),
                    format!("{:.2}", r.alpha),
                    format!("{:.1}", r.predicted_ms),
                    format!("{:.1}", r.observed_ms),
                ]
            })
            .collect();
        print_table(
            "Fig. 6a: α validation across workload mixes",
            &["scenario", "model", "α", "predicted ms", "observed ms"],
            &rows,
        );
        println!("MAPE {:.1}% (paper: 2.2%)", self.alpha_mape);

        let rows: Vec<Vec<String>> = self
            .mix_rows
            .iter()
            .map(|r| {
                vec![
                    r.mix.clone(),
                    format!("{:.1}", r.predicted_ms),
                    format!("{:.1}", r.observed_ms),
                    format!("{:+.1}%", (r.predicted_ms - r.observed_ms) / r.observed_ms * 100.0),
                ]
            })
            .collect();
        print_table(
            "Fig. 6b: accuracy across model mixes (equal TPU load)",
            &["mix", "predicted ms", "observed ms", "error"],
            &rows,
        );
        println!("MAPE {:.1}% (paper: 6.8%)", self.mix_mape);

        let rows: Vec<Vec<String>> = self
            .rate_rows
            .iter()
            .map(|r| {
                vec![
                    format!("{:.1}", r.total_rate),
                    format!("{:.1}", r.predicted_ms),
                    format!("{:.1}", r.observed_ms),
                ]
            })
            .collect();
        print_table(
            "Fig. 6c: accuracy across request rates (efficientnet+gpunet)",
            &["total RPS", "predicted ms", "observed ms"],
            &rows,
        );
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("alpha_mape", Json::Num(self.alpha_mape)),
            ("mix_mape", Json::Num(self.mix_mape)),
            (
                "alpha_rows",
                Json::Arr(
                    self.alpha_rows
                        .iter()
                        .map(|r| {
                            Json::from_pairs(vec![
                                ("scenario", Json::Str(r.scenario.clone())),
                                ("model", Json::Str(r.model.clone())),
                                ("alpha", Json::Num(r.alpha)),
                                ("predicted_ms", Json::Num(r.predicted_ms)),
                                ("observed_ms", Json::Num(r.observed_ms)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "mix_rows",
                Json::Arr(
                    self.mix_rows
                        .iter()
                        .map(|r| {
                            Json::from_pairs(vec![
                                ("mix", Json::Str(r.mix.clone())),
                                ("predicted_ms", Json::Num(r.predicted_ms)),
                                ("observed_ms", Json::Num(r.observed_ms)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "rate_rows",
                Json::Arr(
                    self.rate_rows
                        .iter()
                        .map(|r| {
                            Json::from_pairs(vec![
                                ("total_rate", Json::Num(r.total_rate)),
                                ("predicted_ms", Json::Num(r.predicted_ms)),
                                ("observed_ms", Json::Num(r.observed_ms)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}
