//! Telemetry experiment — what stage-span tracing costs and what it
//! buys: sweep span sampling cadence × load factor on the DES and
//! measure (a) log-volume overhead (span records as a share of the
//! log), (b) calibration coverage (distinct (device, tenant, partition)
//! estimate keys), and (c) prediction drift — the observed stage
//! durations against the analytic cost model's predictions.
//!
//! The DES serves as its own oracle: virtual-time service draws *are*
//! the analytic values, so every swap/tpu/cpu span estimate must
//! reproduce its prediction bit-exactly (drift ratio exactly 1), and a
//! [`ProfiledCostModel`] calibrated from the log must rebuild every
//! tenant's [`PrefixTables`] identical to the analytic tables — the
//! closing-the-loop parity `--cost profiled` relies on. Sampling must
//! also be *inert*: for a fixed arrival stream, every outcome counter
//! is identical whether spans are off, 1-in-64, or traced exhaustively.
//!
//! [`ProfiledCostModel`]: crate::telemetry::ProfiledCostModel
//! [`PrefixTables`]: crate::tpu::PrefixTables

use std::time::Instant;

use crate::alloc;
use crate::analytic::Config;
use crate::eventlog::{read_all, views::Rollup, EventLog};
use crate::sim::{SimOptions, Simulator};
use crate::telemetry::{drift_ratio, ProfiledCostModel, SpanCollector, Stage};
use crate::tpu::PrefixTables;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::workload::{
    equal_tpu_load_shares, generate_arrivals, rates_for_load_factor, RateSchedule,
};

use super::common::{print_table, Ctx};
use super::sched_ablation::MODELS;

/// Swept sampling cadences; 0 = spans off (the baseline row).
pub const SAMPLES: [usize; 4] = [0, 1, 16, 64];
/// Swept TPU load factors (sub-critical and near-critical).
pub const RHOS: [f64; 2] = [0.6, 0.9];

#[derive(Debug, Clone)]
pub struct TelemetryRow {
    pub rho: f64,
    /// Sampling cadence (1-in-N); 0 = off.
    pub sample: usize,
    pub completed: u64,
    pub accepted: u64,
    /// Total log records (lifecycle + spans).
    pub records: u64,
    /// Span records among them.
    pub spans: u64,
    /// Span share of the log — the telemetry volume overhead.
    pub span_share: f64,
    /// Distinct (device, tenant, partition) calibration keys observed.
    pub keys: usize,
    /// Max |observed/predicted − 1| over every swap/tpu/cpu estimate;
    /// 0.0 when every stage reproduced its analytic prediction exactly.
    pub max_rel_err: f64,
    /// Every tenant's span-calibrated prefix table equals the analytic
    /// table bit-for-bit.
    pub tables_exact: bool,
    /// Wall-clock of the DES run (informational; the virtual-time engine
    /// plus log writer, not a serving-path overhead bound — that is
    /// `bench_telemetry`'s job).
    pub wall_ms: f64,
}

pub struct TelemetrySweep {
    pub models: Vec<String>,
    pub config: Config,
    pub rows: Vec<TelemetryRow>,
}

pub fn run(ctx: &Ctx) -> Result<TelemetrySweep, String> {
    let names: Vec<&str> = MODELS.to_vec();
    let zero = vec![0.0; names.len()];
    let tenants0 = ctx.tenants(&names, &zero)?;
    let full = Config::all_tpu(&tenants0);
    let shares = equal_tpu_load_shares(&ctx.am, &tenants0);

    // Plan once at the sub-critical point and hold the configuration
    // across the sweep, so every cell calibrates the same partitions.
    let base_rates = rates_for_load_factor(&ctx.am, &tenants0, &full, &shares, RHOS[0]);
    let base_tenants = ctx.tenants(&names, &base_rates)?;
    let config = alloc::hill_climb(&ctx.am, &base_tenants, ctx.k_max).config;

    let horizon = ctx.horizon;
    let mut rows = Vec::new();
    for rho in RHOS {
        let rates = rates_for_load_factor(&ctx.am, &tenants0, &full, &shares, rho);
        let schedules: Vec<RateSchedule> =
            rates.iter().map(|r| RateSchedule::constant(*r)).collect();
        let tenants = ctx.tenants(&names, &rates)?;
        // One arrival stream per rho, replayed under every cadence:
        // sampling must not perturb a single outcome counter.
        let mut rng = Rng::new(ctx.seed);
        let arrivals = generate_arrivals(&schedules, horizon, &mut rng);

        for sample in SAMPLES {
            let path = std::env::temp_dir().join(format!(
                "swapless-telemetry-{}-{}-{}.log",
                std::process::id(),
                (rho * 100.0) as u32,
                sample
            ));
            let log = EventLog::create(&path)?;
            let mut sim = Simulator::new(
                &ctx.cost,
                &tenants,
                config.clone(),
                SimOptions {
                    horizon,
                    warmup: horizon * 0.05,
                    seed: ctx.seed,
                    span_sample: sample,
                    log: Some(log.clone()),
                    ..SimOptions::default()
                },
            );
            let t0 = Instant::now();
            let res = sim.run(&arrivals, None);
            let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
            log.close();
            if log.dropped() > 0 {
                return Err(format!(
                    "telemetry rho {rho} sample {sample}: log writer dropped {} records",
                    log.dropped()
                ));
            }
            let events = read_all(&path)?;
            let _ = std::fs::remove_file(&path);
            let roll = Rollup::replay(&events);

            // Fold the spans back and compare every estimate against the
            // analytic prediction it should reproduce.
            let collector = SpanCollector::new();
            for ev in &events {
                collector.fold_event(ev);
            }
            let estimates = collector.estimates();
            let mut max_rel_err = 0.0f64;
            for (&(_, tenant, p), est) in &estimates {
                let model = &tenants[tenant as usize].model;
                let p = p as usize;
                for (stage, predicted) in [
                    (Stage::Swap, ctx.cost.load_time(model, p)),
                    (Stage::Tpu, ctx.cost.tpu_service(model, p)),
                    (Stage::Cpu, ctx.cost.cpu_service(model, p)),
                ] {
                    if let Some(s) = est.stage(stage) {
                        if let Some(r) = drift_ratio(s.estimate(), predicted) {
                            max_rel_err = max_rel_err.max((r - 1.0).abs());
                        }
                    }
                }
            }

            // Closing the loop: tables rebuilt from the log must equal
            // the analytic tables bit-for-bit.
            let pm = ProfiledCostModel::from_events(ctx.cost.clone(), &events);
            let tables_exact = tenants.iter().enumerate().all(|(i, t)| {
                let analytic = PrefixTables::new(&ctx.cost, &t.model);
                let profiled = pm.tables(0, i as u64, &t.model);
                (0..=t.model.partition_points).all(|p| {
                    profiled.tpu_service(p) == analytic.tpu_service(p)
                        && profiled.cpu_service(p) == analytic.cpu_service(p)
                        && profiled.load_time(p) == analytic.load_time(p)
                })
            });

            rows.push(TelemetryRow {
                rho,
                sample,
                completed: res.per_model.iter().map(|m| m.completed).sum(),
                accepted: res.per_class.accepted_total(),
                records: roll.records,
                spans: roll.spans,
                span_share: if roll.records > 0 {
                    roll.spans as f64 / roll.records as f64
                } else {
                    0.0
                },
                keys: estimates.len(),
                max_rel_err,
                tables_exact,
                wall_ms,
            });
        }
    }
    Ok(TelemetrySweep {
        models: MODELS.iter().map(|m| m.to_string()).collect(),
        config,
        rows,
    })
}

impl TelemetrySweep {
    /// The row for (rho, sample), if present.
    pub fn row(&self, rho: f64, sample: usize) -> Option<&TelemetryRow> {
        self.rows
            .iter()
            .find(|r| (r.rho - rho).abs() < 1e-9 && r.sample == sample)
    }

    pub fn print(&self) {
        println!(
            "\ntelemetry sweep: {} P={:?} K={:?}",
            self.models.join("+"),
            self.config.partitions,
            self.config.cores
        );
        let rows: Vec<Vec<String>> = self
            .rows
            .iter()
            .map(|r| {
                vec![
                    format!("{:.1}", r.rho),
                    if r.sample == 0 {
                        "off".to_string()
                    } else {
                        format!("1/{}", r.sample)
                    },
                    r.completed.to_string(),
                    r.records.to_string(),
                    r.spans.to_string(),
                    format!("{:.1}%", r.span_share * 100.0),
                    r.keys.to_string(),
                    format!("{:.1e}", r.max_rel_err),
                    if r.tables_exact { "exact" } else { "DRIFT" }.to_string(),
                    format!("{:.1}", r.wall_ms),
                ]
            })
            .collect();
        print_table(
            "Span sampling x load factor (drift vs analytic, log overhead)",
            &[
                "rho", "sample", "done", "records", "spans", "share", "keys", "max err",
                "tables", "wall ms",
            ],
            &rows,
        );
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            (
                "models",
                Json::Arr(self.models.iter().map(|m| Json::Str(m.clone())).collect()),
            ),
            (
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| {
                            Json::from_pairs(vec![
                                ("rho", Json::Num(r.rho)),
                                ("sample", Json::Num(r.sample as f64)),
                                ("completed", Json::Num(r.completed as f64)),
                                ("accepted", Json::Num(r.accepted as f64)),
                                ("records", Json::Num(r.records as f64)),
                                ("spans", Json::Num(r.spans as f64)),
                                ("span_share", Json::Num(r.span_share)),
                                ("keys", Json::Num(r.keys as f64)),
                                ("max_rel_err", Json::Num(r.max_rel_err)),
                                ("tables_exact", Json::Bool(r.tables_exact)),
                                ("wall_ms", Json::Num(r.wall_ms)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareSpec;
    use crate::model::Manifest;

    #[test]
    fn sampling_is_inert_and_drift_free_against_the_des_oracle() {
        let mut ctx = Ctx::new(Manifest::synthetic(), HardwareSpec::default());
        ctx.horizon = 150.0;
        let r = run(&ctx).unwrap();
        assert_eq!(r.rows.len(), RHOS.len() * SAMPLES.len());

        for rho in RHOS {
            let off = r.row(rho, 0).unwrap();
            assert_eq!(off.spans, 0, "rho {rho}: spans emitted while disabled");
            assert_eq!(off.keys, 0);

            for sample in SAMPLES {
                let row = r.row(rho, sample).unwrap();
                // Sampling must not perturb the simulation: identical
                // arrivals give identical outcome counters at every
                // cadence, and the log grows only by the span records.
                assert_eq!(row.completed, off.completed, "rho {rho} 1/{sample}");
                assert_eq!(row.accepted, off.accepted, "rho {rho} 1/{sample}");
                assert_eq!(
                    row.records - row.spans,
                    off.records,
                    "rho {rho} 1/{sample}: lifecycle record count changed"
                );
                if sample > 0 {
                    assert!(row.spans > 0, "rho {rho} 1/{sample}: no spans");
                    // Virtual-time spans reproduce the analytic service
                    // times exactly, so the calibrated tables are the
                    // analytic tables.
                    assert_eq!(row.max_rel_err, 0.0, "rho {rho} 1/{sample}");
                    assert!(row.tables_exact, "rho {rho} 1/{sample}");
                }
            }
            // Coarser cadence, fewer spans.
            let exhaustive = r.row(rho, 1).unwrap();
            let coarse = r.row(rho, 64).unwrap();
            assert!(exhaustive.spans > coarse.spans);
        }
    }
}
