//! Fault-tolerance experiment: crash schedules × routing policies on a
//! 2-device fleet (the chaos counterpart of the placement sweep).
//!
//! Per-tenant rates are solved once on the single-device full-TPU
//! reference at nominal ρ = 0.7 ([`rates_for_load_factor`] — the same
//! construction as the fleet sweep), every arrival carries a generous
//! 500 ms relative deadline, and the same deadline-annotated stream is
//! replayed under each (crash schedule, policy) cell:
//!
//! * `static` — [`run_fleet`]: the placement never reacts; the crashed
//!   device freezes with its queue and its tenants stop completing.
//! * `failover` — [`run_fleet_failover`]: arrivals landing on a Down
//!   home are rerouted to the surviving device and counted per tenant.
//!
//! The crashed device is always the one the placement routes the *most*
//! arrivals to — the worst-case single-device outage. The headline the
//! acceptance test pins: a crash at 10% of the horizon with no recovery
//! leaves static availability (completed within deadline / offered) at
//! ≤ 60%, while failover holds ≥ 90% on the identical stream.

use crate::analytic::Tenant;
use crate::fault::FaultPlan;
use crate::fleet::{place, run_fleet, run_fleet_failover, Fleet};
use crate::sched::SloClass;
use crate::sim::SimOptions;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::workload::{
    equal_tpu_load_shares, generate_arrivals_annotated, rates_for_load_factor, RateSchedule,
};

use super::common::{print_table, Ctx};
use super::fleet::MIX_QUAD;

/// Nominal full-TPU load factor the rates are solved at (sub-critical:
/// the survivor can absorb the whole mix after a failover).
pub const RHO: f64 = 0.7;
/// Relative completion deadline stamped on every arrival (seconds) —
/// generous against the ~tens-of-ms service times, so availability
/// measures outage loss, not queueing noise.
pub const DEADLINE_S: f64 = 0.5;

/// One (crash schedule, policy) cell.
#[derive(Debug, Clone)]
pub struct FaultRow {
    pub policy: &'static str,
    /// Crash time as a fraction of the horizon.
    pub crash_frac: f64,
    /// Recovery time as a fraction of the horizon (`None` = permanent).
    pub recover_frac: Option<f64>,
    /// The device the schedule crashes (the placement's busiest).
    pub crashed_device: usize,
    pub arrivals: usize,
    pub completed: u64,
    /// Completions within their deadline.
    pub goodput: u64,
    /// goodput / arrivals — the availability the sweep reports.
    pub availability: f64,
    pub failed_over: u64,
    pub shed: u64,
    pub mean_ms: f64,
}

pub struct FaultSweep {
    pub rows: Vec<FaultRow>,
}

/// Solve the quad-mix rates at nominal ρ, place on a 2-device fleet, and
/// replay one crash schedule under one routing policy.
pub fn run_one(
    ctx: &Ctx,
    policy: &'static str,
    crash_frac: f64,
    recover_frac: Option<f64>,
    horizon: f64,
) -> Result<FaultRow, String> {
    let models = &MIX_QUAD[..];
    let zero = vec![0.0; models.len()];
    let tenants0 = ctx.tenants(models, &zero)?;
    let full = crate::analytic::Config::all_tpu(&tenants0);
    let shares = equal_tpu_load_shares(&ctx.am, &tenants0);
    let rates = rates_for_load_factor(&ctx.am, &tenants0, &full, &shares, RHO);
    let tenants: Vec<Tenant> = ctx.tenants(models, &rates)?;

    let fleet = Fleet::uniform(2, &ctx.cost.hw);
    let plan = place(&fleet, &tenants);

    let schedules: Vec<RateSchedule> = tenants
        .iter()
        .map(|t| RateSchedule::constant(t.rate))
        .collect();
    let classes = vec![SloClass::Standard; tenants.len()];
    let deadlines = vec![Some(DEADLINE_S); tenants.len()];
    let mut rng = Rng::new(ctx.seed);
    let arrivals =
        generate_arrivals_annotated(&schedules, &classes, &deadlines, horizon, &mut rng);

    // Crash the device the placement routes the most arrivals to — the
    // worst single-device outage for this stream.
    let mut per_dev = vec![0usize; fleet.len()];
    for a in &arrivals {
        per_dev[plan.assignment[a.model]] += 1;
    }
    let crashed = per_dev
        .iter()
        .enumerate()
        .max_by_key(|&(_, n)| *n)
        .map(|(d, _)| d)
        .unwrap_or(0);

    let faults = match recover_frac {
        Some(r) => FaultPlan::new(ctx.seed).crash(crashed, crash_frac * horizon, Some(r * horizon)),
        None => FaultPlan::new(ctx.seed).crash(crashed, crash_frac * horizon, None),
    };
    let opts = SimOptions {
        horizon,
        warmup: 0.0,
        seed: ctx.seed,
        faults: Some(faults),
        ..SimOptions::default()
    };
    let res = match policy {
        "static" => run_fleet(&fleet, &tenants, &plan, &arrivals, &opts),
        "failover" => run_fleet_failover(&fleet, &tenants, &plan, &arrivals, &opts),
        other => return Err(format!("unknown fault policy '{other}'")),
    };

    let goodput: u64 = res
        .per_device
        .iter()
        .map(|d| d.result.per_class.goodput_total())
        .sum();
    Ok(FaultRow {
        policy,
        crash_frac,
        recover_frac,
        crashed_device: crashed,
        arrivals: arrivals.len(),
        completed: res.completed,
        goodput,
        availability: if arrivals.is_empty() {
            1.0
        } else {
            goodput as f64 / arrivals.len() as f64
        },
        failed_over: res.failed_over.iter().sum(),
        shed: res.shed,
        mean_ms: res.mean_latency * 1e3,
    })
}

/// Crash schedules swept (crash fraction, recovery fraction).
pub const SCHEDULES: [(f64, Option<f64>); 3] =
    [(0.1, None), (0.5, None), (0.25, Some(0.5))];

pub fn run(ctx: &Ctx) -> Result<FaultSweep, String> {
    let mut rows = Vec::new();
    for &(crash, recover) in &SCHEDULES {
        for policy in ["static", "failover"] {
            rows.push(run_one(ctx, policy, crash, recover, ctx.horizon)?);
        }
    }
    Ok(FaultSweep { rows })
}

impl FaultSweep {
    pub fn print(&self) {
        let table: Vec<Vec<String>> = self
            .rows
            .iter()
            .map(|r| {
                vec![
                    r.policy.to_string(),
                    format!("{:.2}", r.crash_frac),
                    match r.recover_frac {
                        Some(f) => format!("{f:.2}"),
                        None => "never".to_string(),
                    },
                    r.crashed_device.to_string(),
                    r.arrivals.to_string(),
                    r.goodput.to_string(),
                    format!("{:.1}%", r.availability * 100.0),
                    r.failed_over.to_string(),
                    r.shed.to_string(),
                    format!("{:.1}", r.mean_ms),
                ]
            })
            .collect();
        print_table(
            "Fault sweep (2-device quad mix, worst-device crash, rho 0.7)",
            &[
                "policy",
                "crash@",
                "recover@",
                "dev",
                "offered",
                "in-deadline",
                "avail",
                "failed over",
                "shed",
                "mean (ms)",
            ],
            &table,
        );
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![(
            "rows",
            Json::Arr(
                self.rows
                    .iter()
                    .map(|r| {
                        Json::from_pairs(vec![
                            ("policy", Json::Str(r.policy.to_string())),
                            ("crash_frac", Json::Num(r.crash_frac)),
                            (
                                "recover_frac",
                                match r.recover_frac {
                                    Some(f) => Json::Num(f),
                                    None => Json::Null,
                                },
                            ),
                            ("crashed_device", Json::Num(r.crashed_device as f64)),
                            ("arrivals", Json::Num(r.arrivals as f64)),
                            ("completed", Json::Num(r.completed as f64)),
                            ("goodput", Json::Num(r.goodput as f64)),
                            ("availability", Json::Num(r.availability)),
                            ("failed_over", Json::Num(r.failed_over as f64)),
                            ("shed", Json::Num(r.shed as f64)),
                            ("mean_ms", Json::Num(r.mean_ms)),
                        ])
                    })
                    .collect(),
            ),
        )])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareSpec;
    use crate::model::Manifest;

    /// The acceptance headline: under a worst-device crash at 10% of the
    /// horizon with no recovery, failover keeps ≥ 90% of offered
    /// requests completing within deadline while the static placement
    /// drops to ≤ 60% on the identical stream.
    #[test]
    fn failover_holds_availability_through_a_crash() {
        let mut ctx = Ctx::new(Manifest::synthetic(), HardwareSpec::default());
        ctx.horizon = 300.0;
        let stat = run_one(&ctx, "static", 0.1, None, ctx.horizon).unwrap();
        let fo = run_one(&ctx, "failover", 0.1, None, ctx.horizon).unwrap();
        assert!(stat.arrivals > 1000, "offered only {}", stat.arrivals);
        assert_eq!(stat.arrivals, fo.arrivals, "streams must be identical");
        assert!(
            stat.availability <= 0.60,
            "static availability {:.3} not <= 0.60",
            stat.availability
        );
        assert!(
            fo.availability >= 0.90,
            "failover availability {:.3} not >= 0.90",
            fo.availability
        );
        assert!(fo.failed_over > 0);
        assert_eq!(stat.failed_over, 0);
        assert_eq!(fo.shed, 0);
    }

    #[test]
    fn recovery_restores_static_and_failover_converges_above_it() {
        let mut ctx = Ctx::new(Manifest::synthetic(), HardwareSpec::default());
        ctx.horizon = 300.0;
        // A mid-run outage with recovery. Static's frozen queue drains
        // *late* once the device returns, so its availability (deadline
        // goodput) depends on the placement's drain rate — the robust
        // claims are about ordering, not an absolute level: recovery
        // strictly restores completions vs. the same crash left
        // unrecovered, and failover dominates static on the identical
        // stream while barely feeling a temporary outage at all.
        let stat = run_one(&ctx, "static", 0.25, Some(0.5), ctx.horizon).unwrap();
        let stat_dead = run_one(&ctx, "static", 0.25, None, ctx.horizon).unwrap();
        let fo = run_one(&ctx, "failover", 0.25, Some(0.5), ctx.horizon).unwrap();
        assert!(
            stat.completed > stat_dead.completed,
            "recovery did not drain the frozen queue: {} !> {}",
            stat.completed,
            stat_dead.completed
        );
        assert!(
            fo.availability >= stat.availability,
            "failover {:.3} < static {:.3}",
            fo.availability,
            stat.availability
        );
        assert!(
            fo.availability >= 0.85,
            "failover availability {:.3} through a temporary outage",
            fo.availability
        );
        assert!(fo.failed_over > 0);
    }
}
