//! Experiment harness: one module per paper figure/table (DESIGN.md §7).
//!
//! Every module exposes `run(&Ctx, …) -> …Result` with a `print()` that
//! emits the same rows/series the paper reports, plus `to_json()` for
//! `results/`. `swapless figure N` / `swapless table 2` dispatch here, and
//! the bench binaries reuse the same entry points.

pub mod ablation;
pub mod audit;
pub mod common;
pub mod fig1;
pub mod fig2;
pub mod fig3;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod faults;
pub mod fig8;
pub mod fleet;
pub mod overload;
pub mod scenarios;
pub mod sched_ablation;
pub mod sensitivity;
pub mod table2;
pub mod telemetry;
pub mod wire;

pub use common::Ctx;
