//! Fig. 5 — Single-tenant model validation (InceptionV4).
//!
//! (a) predicted vs observed mean latency across partition points at low
//!     load (paper: MAPE 1.9%, 92.3% within ±5%, all within ±10%);
//! (b) predicted vs observed across request rates for two partition
//!     points, exhibiting the PP-crossover (paper: PP9 best below
//!     ≈4.5 RPS, PP7 above).

use crate::alloc::prop_alloc;
use crate::analytic::Config;
use crate::metrics::{mape, within_pct};
use crate::util::json::Json;

use super::common::{print_table, Ctx};

pub struct PpRow {
    pub p: usize,
    pub cores: usize,
    pub predicted_ms: f64,
    pub observed_ms: f64,
}

pub struct RateRow {
    pub rate: f64,
    pub series: Vec<(usize, f64, f64)>, // (p, predicted_ms, observed_ms)
}

pub struct Fig5 {
    pub model: String,
    pub rho: f64,
    pub pp_rows: Vec<PpRow>,
    pub mape_pct: f64,
    pub within5: f64,
    pub within10: f64,
    pub rate_rows: Vec<RateRow>,
    pub crossover_pps: (usize, usize),
}

fn config_for(ctx: &Ctx, tenants: &[crate::analytic::Tenant], p: usize) -> Config {
    let partitions = vec![p];
    let cores = prop_alloc(&ctx.cost, tenants, &partitions, ctx.k_max);
    Config { partitions, cores }
}

pub fn run(ctx: &Ctx, model: &str, rho: f64, rate_sweep: &[f64]) -> Result<Fig5, String> {
    let meta = ctx.manifest.get(model)?;
    let pp = meta.partition_points;

    // Fix the arrival rate to hit rho on the full-TPU configuration.
    let tenants0 = ctx.tenants(&[model], &[1.0])?;
    let full = Config::all_tpu(&tenants0);
    let s_full = ctx.am.tpu_service_moments(&tenants0, &full).0;
    let rate = rho / s_full;
    let tenants = ctx.tenants(&[model], &[rate])?;

    // (a) sweep partition points.
    let mut pp_rows = Vec::new();
    for p in 0..=pp {
        let cfg = config_for(ctx, &tenants, p);
        let predicted = ctx.am.e2e_latency(&tenants, &cfg, 0);
        if !predicted.is_finite() {
            continue; // infeasible at this load (e.g. p=0 all-CPU overload)
        }
        let observed = ctx.observe(&tenants, &cfg).mean_latency;
        pp_rows.push(PpRow {
            p,
            cores: cfg.cores[0],
            predicted_ms: predicted * 1e3,
            observed_ms: observed * 1e3,
        });
    }
    let obs: Vec<f64> = pp_rows.iter().map(|r| r.observed_ms).collect();
    let pred: Vec<f64> = pp_rows.iter().map(|r| r.predicted_ms).collect();
    let mape_pct = mape(&obs, &pred);
    let within5 = within_pct(&obs, &pred, 5.0);
    let within10 = within_pct(&obs, &pred, 10.0);

    // (b) rate sweep comparing the low-load optimum against the high-load
    // optimum — the paper's PP9-vs-PP7 pair with the ≈4.5 RPS crossover.
    let best_at = |rate: f64| -> Result<usize, String> {
        let tn = ctx.tenants(&[model], &[rate])?;
        Ok((1..=pp)
            .map(|p| {
                let cfg = config_for(ctx, &tn, p);
                (p, ctx.am.e2e_latency(&tn, &cfg, 0))
            })
            .filter(|(_, l)| l.is_finite())
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .map(|(p, _)| p)
            .unwrap_or(pp))
    };
    let lo_best = best_at(rate_sweep[0])?;
    let hi_best = best_at(*rate_sweep.last().unwrap())?;
    let (pa, pb) = if lo_best == hi_best {
        (lo_best.saturating_sub(1).max(1), lo_best)
    } else {
        (hi_best.min(lo_best), hi_best.max(lo_best))
    };

    let mut rate_rows = Vec::new();
    for &r in rate_sweep {
        let tn = ctx.tenants(&[model], &[r])?;
        let mut series = Vec::new();
        for p in [pa, pb] {
            let cfg = config_for(ctx, &tn, p);
            let predicted = ctx.am.e2e_latency(&tn, &cfg, 0);
            let observed = if predicted.is_finite() {
                ctx.observe(&tn, &cfg).mean_latency
            } else {
                f64::INFINITY
            };
            series.push((p, predicted * 1e3, observed * 1e3));
        }
        rate_rows.push(RateRow { rate: r, series });
    }

    Ok(Fig5 {
        model: model.into(),
        rho,
        pp_rows,
        mape_pct,
        within5,
        within10,
        rate_rows,
        crossover_pps: (pa, pb),
    })
}

impl Fig5 {
    pub fn print(&self) {
        let rows: Vec<Vec<String>> = self
            .pp_rows
            .iter()
            .map(|r| {
                vec![
                    format!("PP{}", r.p),
                    r.cores.to_string(),
                    format!("{:.1}", r.predicted_ms),
                    format!("{:.1}", r.observed_ms),
                    format!("{:+.1}%", (r.predicted_ms - r.observed_ms) / r.observed_ms * 100.0),
                ]
            })
            .collect();
        print_table(
            &format!(
                "Fig. 5a: predicted vs observed across partition points ({}, ρ={})",
                self.model, self.rho
            ),
            &["partition", "cores", "predicted ms", "observed ms", "error"],
            &rows,
        );
        println!(
            "MAPE {:.1}%  within±5% {:.1}%  within±10% {:.1}%  (paper: 1.9%, 92.3%, 100%)",
            self.mape_pct,
            self.within5 * 100.0,
            self.within10 * 100.0
        );

        let (pa, pb) = self.crossover_pps;
        let rows: Vec<Vec<String>> = self
            .rate_rows
            .iter()
            .map(|r| {
                let mut cells = vec![format!("{:.1}", r.rate)];
                for (_, pred, obs) in &r.series {
                    cells.push(format!("{pred:.1}"));
                    cells.push(format!("{obs:.1}"));
                }
                let best = if r.series[0].2 <= r.series[1].2 { pa } else { pb };
                cells.push(format!("PP{best}"));
                cells
            })
            .collect();
        print_table(
            &format!("Fig. 5b: latency across request rates (PP{pa} vs PP{pb})"),
            &[
                "RPS",
                &format!("PP{pa} pred"),
                &format!("PP{pa} obs"),
                &format!("PP{pb} pred"),
                &format!("PP{pb} obs"),
                "best",
            ],
            &rows,
        );
        println!("(paper: optimal PP flips near 4.5 RPS — static configs are inefficient)");
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("model", Json::Str(self.model.clone())),
            ("rho", Json::Num(self.rho)),
            ("mape_pct", Json::Num(self.mape_pct)),
            ("within5", Json::Num(self.within5)),
            ("within10", Json::Num(self.within10)),
            (
                "pp_rows",
                Json::Arr(
                    self.pp_rows
                        .iter()
                        .map(|r| {
                            Json::from_pairs(vec![
                                ("p", Json::Num(r.p as f64)),
                                ("cores", Json::Num(r.cores as f64)),
                                ("predicted_ms", Json::Num(r.predicted_ms)),
                                ("observed_ms", Json::Num(r.observed_ms)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "rate_rows",
                Json::Arr(
                    self.rate_rows
                        .iter()
                        .map(|r| {
                            Json::from_pairs(vec![
                                ("rate", Json::Num(r.rate)),
                                (
                                    "series",
                                    Json::Arr(
                                        r.series
                                            .iter()
                                            .map(|(p, pred, obs)| {
                                                Json::Arr(vec![
                                                    Json::Num(*p as f64),
                                                    Json::Num(*pred),
                                                    Json::Num(*obs),
                                                ])
                                            })
                                            .collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}
