//! Fleet experiment: 1/2/4-device placement × Table-II mixes × ρ sweep.
//!
//! For each mix, per-tenant rates are solved once on the *single-device*
//! full-TPU configuration for a nominal TPU load factor ρ
//! ([`rates_for_load_factor`] — values ≥ 1 extrapolate linearly, the
//! same semantics as `serve --rho`), then held fixed while the device
//! count varies — so every row of a (mix, ρ) group replays the *same*
//! global arrival stream (same seed, same total load) and the only
//! difference is the two-level placement. ρ is *nominal*: the inner
//! allocator offloads suffixes to CPU cores, so a single device
//! genuinely saturates only around nominal 4–5 on this mix — which is
//! exactly the regime where placement pays (below it, one device's
//! combined TPU+4-core capacity hides the queueing). Reported per row:
//! the placement itself, the predicted fleet objective, the observed
//! fleet mean / worst-device mean, and the placement decision time (the
//! outer search + every inner hill climb).
//!
//! The headline the acceptance test pins: at nominal ρ = 3.5 the
//! 2-device placement beats the 1-device mean latency by well over 20%
//! at equal total load (the analytic fleet model predicts ≈ 39%) — each
//! device gets its own SRAM cache (α conflicts vanish for separated big
//! models), its own TPU queue, and its own core budget.

use std::time::Instant;

use crate::analytic::Tenant;
use crate::fleet::{place, simulate_fleet, Fleet};
use crate::sim::SimOptions;
use crate::util::json::Json;
use crate::workload::{equal_tpu_load_shares, rates_for_load_factor};

use super::common::{print_table, Ctx};

/// The Table-II quad mix (same mixed-size tenancy the scheduler ablation
/// stresses) and a heavier 8-model mix over the full manifest.
pub const MIX_QUAD: [&str; 4] = ["mobilenetv2", "squeezenet", "mnasnet", "inceptionv4"];
pub const MIX_OCTO: [&str; 8] = [
    "squeezenet",
    "mobilenetv2",
    "efficientnet",
    "mnasnet",
    "gpunet",
    "densenet201",
    "resnet50v2",
    "inceptionv4",
];
pub const DEVICE_COUNTS: [usize; 3] = [1, 2, 4];
/// Nominal full-TPU load factors (see the module docs — ≥ 1 is not
/// overload once the allocator offloads to CPU; one device saturates
/// near 5 on the quad mix).
pub const RHO_TARGETS: [f64; 3] = [0.75, 2.0, 3.5];

#[derive(Debug, Clone)]
pub struct FleetRow {
    pub mix: &'static str,
    pub rho: f64,
    pub devices: usize,
    /// Tenant→device assignment the two-level allocator chose.
    pub assignment: Vec<usize>,
    /// Predicted fleet objective (max per-device mean, ms).
    pub predicted_ms: f64,
    /// Observed fleet-wide request-weighted mean (ms).
    pub mean_ms: f64,
    /// Observed worst-device mean (ms).
    pub max_device_mean_ms: f64,
    pub completed: u64,
    /// Two-level placement decision time (µs), inner climbs included.
    pub decision_us: f64,
    pub evaluations: usize,
}

pub struct FleetSweep {
    pub rows: Vec<FleetRow>,
}

/// One (mix, ρ, device count) cell: solve rates on the 1-device
/// reference, place on `devices`, simulate, measure.
pub fn run_one(
    ctx: &Ctx,
    mix: &'static str,
    models: &[&str],
    rho: f64,
    devices: usize,
    horizon: f64,
) -> Result<FleetRow, String> {
    let zero = vec![0.0; models.len()];
    let tenants0 = ctx.tenants(models, &zero)?;
    let full = crate::analytic::Config::all_tpu(&tenants0);
    let shares = equal_tpu_load_shares(&ctx.am, &tenants0);
    let rates = rates_for_load_factor(&ctx.am, &tenants0, &full, &shares, rho);
    let tenants: Vec<Tenant> = ctx.tenants(models, &rates)?;

    let fleet = Fleet::uniform(devices, &ctx.cost.hw);
    let t0 = Instant::now();
    let plan = place(&fleet, &tenants);
    let decision_us = t0.elapsed().as_secs_f64() * 1e6;

    let res = simulate_fleet(
        &fleet,
        &tenants,
        &plan,
        SimOptions {
            horizon,
            warmup: horizon * 0.05,
            seed: ctx.seed,
            ..SimOptions::default()
        },
    );
    Ok(FleetRow {
        mix,
        rho,
        devices,
        assignment: plan.assignment.clone(),
        predicted_ms: plan.objective * 1e3,
        mean_ms: res.mean_latency * 1e3,
        max_device_mean_ms: res.max_device_mean * 1e3,
        completed: res.completed,
        decision_us,
        evaluations: plan.evaluations,
    })
}

pub fn run(ctx: &Ctx) -> Result<FleetSweep, String> {
    let mut rows = Vec::new();
    for (mix, models) in [
        ("quad", &MIX_QUAD[..]),
        ("octo", &MIX_OCTO[..]),
    ] {
        for &rho in &RHO_TARGETS {
            for &devices in &DEVICE_COUNTS {
                rows.push(run_one(ctx, mix, models, rho, devices, ctx.horizon)?);
            }
        }
    }
    Ok(FleetSweep { rows })
}

impl FleetSweep {
    pub fn print(&self) {
        let mut table = Vec::new();
        let mut base = f64::NAN;
        for r in &self.rows {
            if r.devices == 1 {
                base = r.mean_ms;
            }
            let speedup = if r.devices == 1 || !base.is_finite() || r.mean_ms <= 0.0 {
                String::new()
            } else {
                format!("{:.2}x", base / r.mean_ms)
            };
            table.push(vec![
                r.mix.to_string(),
                format!("{:.2}", r.rho),
                r.devices.to_string(),
                format!("{:?}", r.assignment),
                format!("{:.1}", r.predicted_ms),
                format!("{:.1}", r.mean_ms),
                format!("{:.1}", r.max_device_mean_ms),
                r.completed.to_string(),
                speedup,
                format!("{:.0}", r.decision_us),
            ]);
        }
        print_table(
            "Fleet placement sweep (equal total load per mix x rho group)",
            &[
                "mix",
                "rho",
                "devices",
                "placement",
                "pred (ms)",
                "mean (ms)",
                "worst dev (ms)",
                "n",
                "vs 1dev",
                "place (us)",
            ],
            &table,
        );
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![(
            "rows",
            Json::Arr(
                self.rows
                    .iter()
                    .map(|r| {
                        Json::from_pairs(vec![
                            ("mix", Json::Str(r.mix.to_string())),
                            ("rho", Json::Num(r.rho)),
                            ("devices", Json::Num(r.devices as f64)),
                            (
                                "assignment",
                                Json::Arr(
                                    r.assignment
                                        .iter()
                                        .map(|&d| Json::Num(d as f64))
                                        .collect(),
                                ),
                            ),
                            ("predicted_ms", Json::Num(r.predicted_ms)),
                            ("mean_ms", Json::Num(r.mean_ms)),
                            ("max_device_mean_ms", Json::Num(r.max_device_mean_ms)),
                            ("completed", Json::Num(r.completed as f64)),
                            ("decision_us", Json::Num(r.decision_us)),
                            ("evaluations", Json::Num(r.evaluations as f64)),
                        ])
                    })
                    .collect(),
            ),
        )])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareSpec;
    use crate::model::Manifest;

    /// The acceptance headline: 2-device placement beats 1-device mean
    /// latency by > 20% at equal total load on the Table-II quad mix at
    /// a stressed nominal load factor (3.5 ⇒ the single device runs
    /// near its true post-offload capacity; the analytic fleet model
    /// predicts a ≈ 39% win, leaving margin for the DES's LRU cache
    /// beating the conservative α).
    #[test]
    fn two_device_placement_beats_one_device_by_over_20_percent() {
        let mut ctx = Ctx::new(Manifest::synthetic(), HardwareSpec::default());
        ctx.horizon = 300.0;
        let one =
            run_one(&ctx, "quad", &MIX_QUAD, 3.5, 1, ctx.horizon).unwrap();
        let two =
            run_one(&ctx, "quad", &MIX_QUAD, 3.5, 2, ctx.horizon).unwrap();
        assert!(one.completed > 1000 && two.completed > 1000);
        // Equal total load: the same arrival stream (same seed/rates).
        assert_eq!(one.assignment.len(), 4);
        assert_eq!(two.assignment.len(), 4);
        assert!(
            two.mean_ms < one.mean_ms * 0.8,
            "2-device mean {:.1} ms not >20% below 1-device {:.1} ms",
            two.mean_ms,
            one.mean_ms
        );
        // The 2-device plan actually uses both devices.
        assert!(two.assignment.iter().any(|&d| d == 0));
        assert!(two.assignment.iter().any(|&d| d == 1));
    }

    #[test]
    fn sweep_rows_cover_the_grid_and_scale_monotonically() {
        let mut ctx = Ctx::new(Manifest::synthetic(), HardwareSpec::default());
        ctx.horizon = 200.0;
        // One (mix, rho) group across all device counts.
        let rows: Vec<FleetRow> = DEVICE_COUNTS
            .iter()
            .map(|&d| run_one(&ctx, "quad", &MIX_QUAD, 0.5, d, ctx.horizon).unwrap())
            .collect();
        for w in rows.windows(2) {
            assert!(
                w[1].mean_ms <= w[0].mean_ms * 1.05,
                "more devices must not hurt: {} -> {}",
                w[0].mean_ms,
                w[1].mean_ms
            );
        }
        for r in &rows {
            assert!(r.completed > 500, "{} devices: {}", r.devices, r.completed);
            // Debug-build sanity bound; the release-mode 10 ms guard
            // lives in benches/bench_fleet.rs.
            assert!(
                r.decision_us < 500_000.0,
                "placement too slow even for a debug build: {} us",
                r.decision_us
            );
        }
    }
}
