//! Fleet-scale scenario suite: the dynamic workloads the DES speedup
//! pays for (ROADMAP item 5; scenario shapes after arXiv 2201.07312's
//! edge-cluster traces and the multi-tenant dynamics of arXiv
//! 2107.12486).
//!
//! Four scenarios run on the octo Table-II mix over a 4-device fleet,
//! every policy replaying the *same* pre-generated arrival stream:
//!
//! * **diurnal** — every tenant's rate follows a stepped sinusoid
//!   (two cycles over the horizon, ±60%);
//! * **flash** — tenant 0's rate spikes ×6 for a tenth of the horizon;
//! * **crash** — device 0 crashes at 30% of the horizon and recovers at
//!   60%, forcing migration under the failover policy;
//! * **drift** — total load is constant but the per-model popularity
//!   split linearly reverses (the paper's model-popularity drift).
//!
//! Three policies per scenario:
//!
//! * `static` — the initial placement + per-device config, untouched;
//! * `swapless` — the same placement, but each device runs the online
//!   [`SwapLessPolicy`] re-partitioner (reported as `reconfigs`);
//! * `rebalance` — cross-device movement: the failover router for the
//!   crash scenario (migrations = tenants rerouted off the dead
//!   device), and epoch-based re-placement for the load scenarios
//!   (the horizon splits into [`EPOCHS`] epochs; each epoch re-runs the
//!   two-level placement on the previous epoch's observed rates, and
//!   `migrations` counts assignment changes). Epoch boundaries reset
//!   the queues, so the epoch path slightly *undercounts* completions
//!   — the comparison is conservative for `rebalance`.

use crate::analytic::{AnalyticModel, Tenant};
use crate::fault::FaultPlan;
use crate::fleet::{place, run_fleet, run_fleet_failover, run_fleet_with, Fleet, FleetSimResult};
use crate::sim::reconfig::SwapLessPolicy;
use crate::sim::SimOptions;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::workload::{
    drift_schedules, equal_tpu_load_shares, generate_arrivals, rates_for_load_factor, Arrival,
    RateSchedule,
};

use super::common::{print_table, Ctx};
use super::fleet::MIX_OCTO;

pub const SCENARIOS: [&str; 4] = ["diurnal", "flash", "crash", "drift"];
const DEVICES: usize = 4;
/// Nominal single-device full-TPU load factor the base rates are solved
/// at (≈ 0.75 per device once spread over the 4-device fleet).
const BASE_RHO: f64 = 3.0;
/// Re-placement epochs for the `rebalance` policy on load scenarios.
const EPOCHS: usize = 8;

#[derive(Debug, Clone)]
pub struct ScenarioRow {
    pub scenario: &'static str,
    pub policy: &'static str,
    pub completed: u64,
    pub dropped: u64,
    pub mean_ms: f64,
    /// Tenants moved across devices (failover reroutes or epoch
    /// re-placements). Always 0 for `static` and `swapless`.
    pub migrations: u64,
    /// Per-device online reconfigurations taken (SwapLess only).
    pub reconfigs: u64,
}

pub struct ScenariosResult {
    pub rows: Vec<ScenarioRow>,
}

/// The shared fixture: octo mix, base rates solved at [`BASE_RHO`] on
/// the single-device full-TPU reference, placed over a uniform 4-device
/// fleet.
struct Setting {
    fleet: Fleet,
    tenants: Vec<Tenant>,
    plan: crate::fleet::FleetPlan,
}

fn setting(ctx: &Ctx) -> Result<Setting, String> {
    let zero = vec![0.0; MIX_OCTO.len()];
    let tenants0 = ctx.tenants(&MIX_OCTO, &zero)?;
    let full = crate::analytic::Config::all_tpu(&tenants0);
    let shares = equal_tpu_load_shares(&ctx.am, &tenants0);
    let rates = rates_for_load_factor(&ctx.am, &tenants0, &full, &shares, BASE_RHO);
    let tenants = ctx.tenants(&MIX_OCTO, &rates)?;
    let fleet = Fleet::uniform(DEVICES, &ctx.cost.hw);
    let plan = place(&fleet, &tenants);
    Ok(Setting {
        fleet,
        tenants,
        plan,
    })
}

/// Per-tenant rate schedules for a scenario (None = the crash scenario,
/// which runs the constant base rates and injects faults instead).
fn schedules_for(name: &str, tenants: &[Tenant], horizon: f64) -> Vec<RateSchedule> {
    match name {
        "diurnal" => tenants
            .iter()
            .map(|t| RateSchedule::diurnal(t.rate, 0.6, horizon / 2.0, 24, horizon))
            .collect(),
        "flash" => tenants
            .iter()
            .enumerate()
            .map(|(i, t)| {
                if i == 0 {
                    RateSchedule::flash_crowd(t.rate, t.rate * 6.0, 0.4 * horizon, 0.5 * horizon)
                } else {
                    RateSchedule::constant(t.rate)
                }
            })
            .collect(),
        "drift" => {
            let total: f64 = tenants.iter().map(|t| t.rate).sum();
            let from: Vec<f64> = tenants.iter().map(|t| t.rate).collect();
            let to: Vec<f64> = from.iter().rev().copied().collect();
            drift_schedules(total, &from, &to, horizon, EPOCHS)
        }
        // crash: steady load, the fault plan is the perturbation.
        _ => tenants
            .iter()
            .map(|t| RateSchedule::constant(t.rate))
            .collect(),
    }
}

fn fault_plan_for(name: &str, horizon: f64, seed: u64) -> Option<FaultPlan> {
    if name == "crash" {
        Some(FaultPlan::new(seed).crash(0, 0.3 * horizon, Some(0.6 * horizon)))
    } else {
        None
    }
}

/// Dropped + reconfig totals across a fleet result.
fn summarize(r: &FleetSimResult) -> (u64, u64) {
    let mut dropped = r.shed;
    let mut reconfigs = 0u64;
    for dev in &r.per_device {
        for m in &dev.result.per_model {
            dropped += m.dropped();
        }
        dropped += dev.result.dropped;
        reconfigs += dev.result.reconfigs.len() as u64;
    }
    (dropped, reconfigs)
}

fn row(scenario: &'static str, policy: &'static str, r: &FleetSimResult, migrations: u64) -> ScenarioRow {
    let (dropped, reconfigs) = summarize(r);
    ScenarioRow {
        scenario,
        policy,
        completed: r.completed,
        dropped,
        mean_ms: r.mean_latency * 1e3,
        migrations,
        reconfigs,
    }
}

/// The `rebalance` policy for load scenarios: split the horizon into
/// [`EPOCHS`] epochs, re-run the two-level placement between epochs on
/// the previous epoch's observed per-tenant rates, and replay each
/// epoch's arrival slice under its plan.
fn run_epoch_rebalance(
    s: &Setting,
    arrivals: &[Arrival],
    opts: &SimOptions,
    horizon: f64,
) -> (FleetSimResultAgg, u64) {
    let elen = horizon / EPOCHS as f64;
    let mut plan = s.plan.clone();
    let mut migrations = 0u64;
    let mut agg = FleetSimResultAgg::default();
    for e in 0..EPOCHS {
        let t0 = e as f64 * elen;
        let t1 = t0 + elen;
        let slice: Vec<Arrival> = arrivals
            .iter()
            .filter(|a| a.time >= t0 && a.time < t1)
            .map(|a| Arrival {
                time: a.time - t0,
                deadline: a.deadline.map(|d| d - t0),
                ..*a
            })
            .collect();
        if e > 0 {
            // Reactive estimate: last epoch's observed counts.
            let mut counts = vec![0u64; s.tenants.len()];
            for a in arrivals {
                if a.time >= t0 - elen && a.time < t0 {
                    counts[a.model] += 1;
                }
            }
            let est: Vec<Tenant> = s
                .tenants
                .iter()
                .zip(&counts)
                .map(|(t, &c)| Tenant {
                    model: t.model.clone(),
                    rate: (c as f64 / elen).max(0.05),
                })
                .collect();
            let next = place(&s.fleet, &est);
            migrations += plan
                .assignment
                .iter()
                .zip(&next.assignment)
                .filter(|(a, b)| a != b)
                .count() as u64;
            plan = next;
        }
        let epoch_opts = SimOptions {
            horizon: elen,
            ..opts.clone()
        };
        let r = run_fleet(&s.fleet, &s.tenants, &plan, &slice, &epoch_opts);
        agg.add(&r);
    }
    (agg, migrations)
}

/// Counter aggregation across epoch runs (completion-weighted mean).
#[derive(Default)]
struct FleetSimResultAgg {
    completed: u64,
    dropped: u64,
    reconfigs: u64,
    lat_weighted: f64,
}

impl FleetSimResultAgg {
    fn add(&mut self, r: &FleetSimResult) {
        let (dropped, reconfigs) = summarize(r);
        self.completed += r.completed;
        self.dropped += dropped;
        self.reconfigs += reconfigs;
        self.lat_weighted += r.mean_latency * r.completed as f64;
    }

    fn mean_ms(&self) -> f64 {
        if self.completed > 0 {
            self.lat_weighted / self.completed as f64 * 1e3
        } else {
            0.0
        }
    }
}

/// Run one scenario: all three policies over the same arrival stream.
pub fn run_scenario(ctx: &Ctx, name: &'static str) -> Result<Vec<ScenarioRow>, String> {
    let s = setting(ctx)?;
    let horizon = ctx.horizon;
    let schedules = schedules_for(name, &s.tenants, horizon);
    let faults = fault_plan_for(name, horizon, ctx.seed);
    let mut rng = Rng::new(ctx.seed);
    let arrivals = generate_arrivals(&schedules, horizon, &mut rng);
    // warmup 0: the transients ARE the phenomenon under study, and all
    // policies share the stream, so cold-start bias cancels.
    let opts = SimOptions {
        horizon,
        warmup: 0.0,
        seed: ctx.seed,
        faults: faults.clone(),
        ..SimOptions::default()
    };

    let mut rows = Vec::new();
    let st = run_fleet(&s.fleet, &s.tenants, &s.plan, &arrivals, &opts);
    rows.push(row(name, "static", &st, 0));

    let k_max = ctx.k_max;
    let sw = run_fleet_with(&s.fleet, &s.tenants, &s.plan, &arrivals, &opts, |d, members| {
        Some(Box::new(SwapLessPolicy::new(
            AnalyticModel::new(s.fleet.device(d).cost.clone()),
            k_max,
            members.len(),
            20.0,
            5.0,
            0.10,
        )))
    });
    rows.push(row(name, "swapless", &sw, 0));

    if name == "crash" {
        let fo = run_fleet_failover(&s.fleet, &s.tenants, &s.plan, &arrivals, &opts);
        let migrations = (0..s.tenants.len())
            .filter(|&i| fo.tenant_failed_over(i) > 0)
            .count() as u64;
        rows.push(row(name, "rebalance", &fo, migrations));
    } else {
        let (agg, migrations) = run_epoch_rebalance(&s, &arrivals, &opts, horizon);
        rows.push(ScenarioRow {
            scenario: name,
            policy: "rebalance",
            completed: agg.completed,
            dropped: agg.dropped,
            mean_ms: agg.mean_ms(),
            migrations,
            reconfigs: agg.reconfigs,
        });
    }
    Ok(rows)
}

/// Run the suite; `only` filters to a single scenario (the CI smoke).
pub fn run_filtered(ctx: &Ctx, only: Option<&str>) -> Result<ScenariosResult, String> {
    let mut rows = Vec::new();
    for name in SCENARIOS {
        if let Some(f) = only {
            if f != name {
                continue;
            }
        }
        rows.push(run_scenario(ctx, name)?);
    }
    if rows.is_empty() {
        return Err(format!(
            "unknown scenario {:?} (expected one of {:?})",
            only.unwrap_or(""),
            SCENARIOS
        ));
    }
    Ok(ScenariosResult {
        rows: rows.into_iter().flatten().collect(),
    })
}

pub fn run(ctx: &Ctx) -> Result<ScenariosResult, String> {
    run_filtered(ctx, None)
}

impl ScenariosResult {
    pub fn print(&self) {
        // Greppable one-liners (CI smoke asserts on these).
        for r in &self.rows {
            println!(
                "scenario {} policy={} completed={} dropped={} mean_ms={:.1} migrations={} reconfigs={}",
                r.scenario, r.policy, r.completed, r.dropped, r.mean_ms, r.migrations, r.reconfigs
            );
        }
        let table: Vec<Vec<String>> = self
            .rows
            .iter()
            .map(|r| {
                vec![
                    r.scenario.to_string(),
                    r.policy.to_string(),
                    r.completed.to_string(),
                    r.dropped.to_string(),
                    format!("{:.1}", r.mean_ms),
                    r.migrations.to_string(),
                    r.reconfigs.to_string(),
                ]
            })
            .collect();
        print_table(
            "Scenario suite (octo mix, 4 devices, shared arrival stream per scenario)",
            &[
                "scenario",
                "policy",
                "completed",
                "dropped",
                "mean (ms)",
                "migrations",
                "reconfigs",
            ],
            &table,
        );
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![(
            "rows",
            Json::Arr(
                self.rows
                    .iter()
                    .map(|r| {
                        Json::from_pairs(vec![
                            ("scenario", Json::Str(r.scenario.to_string())),
                            ("policy", Json::Str(r.policy.to_string())),
                            ("completed", Json::Num(r.completed as f64)),
                            ("dropped", Json::Num(r.dropped as f64)),
                            ("mean_ms", Json::Num(r.mean_ms)),
                            ("migrations", Json::Num(r.migrations as f64)),
                            ("reconfigs", Json::Num(r.reconfigs as f64)),
                        ])
                    })
                    .collect(),
            ),
        )])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareSpec;
    use crate::model::Manifest;

    /// Abbreviated end-to-end smoke: the crash scenario at a short
    /// horizon must produce completions under every policy, and the
    /// failover path must actually migrate tenants off the dead device.
    #[test]
    fn crash_scenario_migrates_and_completes() {
        let mut ctx = Ctx::new(Manifest::synthetic(), HardwareSpec::default());
        ctx.horizon = 120.0;
        let rows = run_scenario(&ctx, "crash").unwrap();
        assert_eq!(rows.len(), 3);
        for r in &rows {
            assert!(r.completed > 0, "{} completed nothing", r.policy);
        }
        let rebalance = rows.iter().find(|r| r.policy == "rebalance").unwrap();
        assert!(
            rebalance.migrations > 0,
            "crash + failover must migrate tenants"
        );
        // The crash freezes device 0 for 30% of the run; rerouting its
        // tenants must not complete less than leaving them stranded.
        let stat = rows.iter().find(|r| r.policy == "static").unwrap();
        assert!(
            rebalance.completed >= stat.completed,
            "failover {} < static {}",
            rebalance.completed,
            stat.completed
        );
    }

    #[test]
    fn flash_scenario_runs_all_policies() {
        let mut ctx = Ctx::new(Manifest::synthetic(), HardwareSpec::default());
        ctx.horizon = 100.0;
        let rows = run_scenario(&ctx, "flash").unwrap();
        assert_eq!(rows.len(), 3);
        for r in &rows {
            assert!(r.completed > 0, "{} completed nothing", r.policy);
        }
        let sw = rows.iter().find(|r| r.policy == "swapless").unwrap();
        assert!(sw.reconfigs > 0, "swapless never reconfigured");
    }

    #[test]
    fn unknown_scenario_is_an_error() {
        let ctx = Ctx::new(Manifest::synthetic(), HardwareSpec::default());
        assert!(run_filtered(&ctx, Some("nope")).is_err());
    }
}
