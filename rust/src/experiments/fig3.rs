//! Fig. 3 — TPU vs CPU per-segment service time (InceptionV4).
//!
//! The collaborative-processing opportunity: early segments are several
//! times faster on the TPU, the trailing segments run comparably on the
//! CPU. Optionally cross-checked against measured PJRT wall-clock per
//! segment (`swapless profile`).

use crate::util::json::Json;

use super::common::{print_table, Ctx};

pub struct SegRow {
    pub index: usize,
    pub tpu_ms: f64,
    pub cpu_ms: f64,
    pub speedup: f64,
    pub mxu_util: f64,
}

pub struct Fig3 {
    pub model: String,
    pub rows: Vec<SegRow>,
}

pub fn run(ctx: &Ctx, model: &str) -> Result<Fig3, String> {
    let meta = ctx.manifest.get(model)?;
    let rows = meta
        .segments
        .iter()
        .map(|seg| SegRow {
            index: seg.index,
            tpu_ms: ctx.cost.tpu_segment_time(meta, seg) * 1e3,
            cpu_ms: ctx.cost.cpu_segment_time(seg) * 1e3,
            speedup: ctx.cost.segment_speedup(meta, seg),
            mxu_util: seg.mxu_util,
        })
        .collect();
    Ok(Fig3 {
        model: model.into(),
        rows,
    })
}

impl Fig3 {
    pub fn print(&self) {
        let rows: Vec<Vec<String>> = self
            .rows
            .iter()
            .map(|r| {
                vec![
                    format!("seg{}", r.index),
                    format!("{:.2}", r.tpu_ms),
                    format!("{:.2}", r.cpu_ms),
                    format!("{:.2}x", r.speedup),
                    format!("{:.3}", r.mxu_util),
                ]
            })
            .collect();
        print_table(
            &format!("Fig. 3: per-segment TPU vs CPU time ({})", self.model),
            &["segment", "TPU ms", "CPU ms", "speedup", "MXU util"],
            &rows,
        );
        let first = self.rows.first().unwrap().speedup;
        let last3: Vec<f64> = self.rows.iter().rev().take(3).map(|r| r.speedup).collect();
        println!(
            "first-segment speedup {first:.1}x; last three {:.2?}x (paper: substantial early gain, last three comparable)",
            last3
        );
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("model", Json::Str(self.model.clone())),
            (
                "segments",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| {
                            Json::from_pairs(vec![
                                ("index", Json::Num(r.index as f64)),
                                ("tpu_ms", Json::Num(r.tpu_ms)),
                                ("cpu_ms", Json::Num(r.cpu_ms)),
                                ("speedup", Json::Num(r.speedup)),
                                ("mxu_util", Json::Num(r.mxu_util)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}
