//! Table II — characteristics of the evaluated AI models.

use crate::util::json::Json;

use super::common::{print_table, Ctx};

pub struct Table2 {
    pub rows: Vec<(String, f64, f64, usize, usize)>,
}

pub fn run(ctx: &Ctx) -> Table2 {
    let rows = ctx
        .manifest
        .models
        .iter()
        .map(|m| {
            (
                m.name.clone(),
                m.table_size_mb,
                m.table_flops_g,
                m.partition_points,
                m.segments.len(),
            )
        })
        .collect();
    Table2 { rows }
}

impl Table2 {
    pub fn print(&self) {
        let rows: Vec<Vec<String>> = self
            .rows
            .iter()
            .map(|(name, mb, gf, pp, segs)| {
                vec![
                    name.clone(),
                    format!("{mb:.1}"),
                    format!("{gf:.2}"),
                    pp.to_string(),
                    segs.to_string(),
                ]
            })
            .collect();
        print_table(
            "Table II: evaluated model characteristics",
            &["model", "size (MB)", "FLOPs (G)", "partition points", "artifacts"],
            &rows,
        );
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.rows
                .iter()
                .map(|(name, mb, gf, pp, _)| {
                    Json::from_pairs(vec![
                        ("model", Json::Str(name.clone())),
                        ("size_mb", Json::Num(*mb)),
                        ("flops_g", Json::Num(*gf)),
                        ("partition_points", Json::Num(*pp as f64)),
                    ])
                })
                .collect(),
        )
    }
}
