//! Hardware sensitivity sweeps (the paper's implicit design space):
//! how SwapLess's advantage over the compiler baseline moves with SRAM
//! capacity, host↔TPU bandwidth, and CPU core count. Each sweep holds the
//! workload fixed (efficientnet+gpunet at equal TPU load, ρ = 0.5 on the
//! default hardware) and re-plans + re-observes under the varied knob.

use crate::alloc;
use crate::analytic::{AnalyticModel, Config, Tenant};
use crate::config::HardwareSpec;
use crate::tpu::CostModel;
use crate::util::json::Json;
use crate::workload::{equal_tpu_load_shares, rates_for_utilization};

use super::common::{pct, print_table, Ctx};

pub struct SweepRow {
    pub knob: String,
    pub value: String,
    pub compiler_ms: f64,
    pub swapless_ms: f64,
    pub reduction: f64,
    pub swapless_partitions: Vec<usize>,
}

pub struct Sensitivity {
    pub rows: Vec<SweepRow>,
}

const MIX: [&str; 2] = ["efficientnet", "gpunet"];

fn observe_under(
    ctx: &Ctx,
    hw: HardwareSpec,
    tenants_rates: &[f64],
) -> Result<SweepRow, String> {
    let cost = CostModel::new(hw.clone());
    let am = AnalyticModel::new(cost.clone());
    let tenants: Vec<Tenant> = MIX
        .iter()
        .zip(tenants_rates)
        .map(|(n, r)| {
            Ok(Tenant {
                model: ctx.manifest.get(n)?.clone(),
                rate: *r,
            })
        })
        .collect::<Result<_, String>>()?;
    let compiler = alloc::edge_tpu_compiler(&am, &tenants).config;
    let swapless = alloc::hill_climb(&am, &tenants, hw.cpu_cores).config;
    let sim = |cfg: &Config| {
        crate::sim::simulate(
            &cost,
            &tenants,
            cfg,
            crate::sim::SimOptions {
                horizon: ctx.horizon,
                warmup: ctx.horizon * 0.05,
                seed: ctx.seed,
                ..Default::default()
            },
        )
        .mean_latency
            * 1e3
    };
    let c = sim(&compiler);
    let s = sim(&swapless);
    Ok(SweepRow {
        knob: String::new(),
        value: String::new(),
        compiler_ms: c,
        swapless_ms: s,
        reduction: ((c - s) / c).max(0.0),
        swapless_partitions: swapless.partitions,
    })
}

pub fn run(ctx: &Ctx) -> Result<Sensitivity, String> {
    // Fix the workload once on default hardware.
    let zero = vec![0.0; MIX.len()];
    let tenants0 = ctx.tenants(&MIX, &zero)?;
    let full = Config::all_tpu(&tenants0);
    let shares = equal_tpu_load_shares(&ctx.am, &tenants0);
    let rates = rates_for_utilization(&ctx.am, &tenants0, &full, &shares, 0.5);

    let mut rows = Vec::new();

    for mb in [4u64, 8, 16, 32] {
        let mut hw = ctx.cost.hw.clone();
        hw.sram_bytes = mb * 1024 * 1024;
        let mut row = observe_under(ctx, hw, &rates)?;
        row.knob = "SRAM".into();
        row.value = format!("{mb} MB");
        rows.push(row);
    }
    for mbps in [100.0, 200.0, 400.0, 800.0] {
        let mut hw = ctx.cost.hw.clone();
        hw.bus_bytes_per_sec = mbps * 1e6;
        let mut row = observe_under(ctx, hw, &rates)?;
        row.knob = "bus".into();
        row.value = format!("{mbps:.0} MB/s");
        rows.push(row);
    }
    for cores in [1usize, 2, 4, 8] {
        let mut hw = ctx.cost.hw.clone();
        hw.cpu_cores = cores;
        let mut row = observe_under(ctx, hw, &rates)?;
        row.knob = "cores".into();
        row.value = format!("{cores}");
        rows.push(row);
    }
    Ok(Sensitivity { rows })
}

impl Sensitivity {
    pub fn print(&self) {
        let rows: Vec<Vec<String>> = self
            .rows
            .iter()
            .map(|r| {
                vec![
                    r.knob.clone(),
                    r.value.clone(),
                    format!("{:.1}", r.compiler_ms),
                    format!("{:.1}", r.swapless_ms),
                    pct(r.reduction),
                    format!("{:?}", r.swapless_partitions),
                ]
            })
            .collect();
        print_table(
            "Sensitivity: SwapLess vs compiler across hardware knobs (efficientnet+gpunet, ρ=0.5 @ defaults)",
            &["knob", "value", "compiler ms", "swapless ms", "reduction", "swapless P"],
            &rows,
        );
        println!("(expected: gains shrink as SRAM/bus grow — the memory wall closes; more cores widen the offload lever)");
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.rows
                .iter()
                .map(|r| {
                    Json::from_pairs(vec![
                        ("knob", Json::Str(r.knob.clone())),
                        ("value", Json::Str(r.value.clone())),
                        ("compiler_ms", Json::Num(r.compiler_ms)),
                        ("swapless_ms", Json::Num(r.swapless_ms)),
                        ("reduction", Json::Num(r.reduction)),
                    ])
                })
                .collect(),
        )
    }
}
