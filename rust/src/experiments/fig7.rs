//! Fig. 7 — Latency comparison against baselines across workloads and
//! TPU utilization levels ρ ∈ {0.2, 0.5}.
//!
//! Policies: Edge TPU Compiler, Threshold-based Partitioning,
//! SwapLess (α=0), SwapLess. Single-tenant (one model) and multi-tenant
//! (2–3 models, equal per-model TPU load) workloads. The paper's headline:
//! up to 63.8% (single) and 77.4% (multi) mean-latency reduction vs the
//! compiler baseline at ρ=0.5.

use crate::alloc;
use crate::analytic::{AnalyticModel, Config, Tenant};
use crate::util::json::Json;
use crate::workload::{equal_tpu_load_shares, rates_for_utilization};

use super::common::{pct, print_table, Ctx};

pub const SINGLE_WORKLOADS: [&[&str]; 4] = [
    &["mobilenetv2"],
    &["densenet201"],
    &["resnet50v2"],
    &["inceptionv4"],
];

pub const MULTI_WORKLOADS: [&[&str]; 4] = [
    &["mobilenetv2", "squeezenet"],
    &["mobilenetv2", "squeezenet", "resnet50v2"],
    &["efficientnet", "gpunet"],
    &["xception", "inceptionv4"],
];

pub const POLICIES: [&str; 4] = ["compiler", "threshold", "swapless_a0", "swapless"];

pub struct Cell {
    pub policy: String,
    pub config: Config,
    pub predicted_ms: f64,
    pub observed_ms: f64,
}

pub struct WorkloadResult {
    pub workload: String,
    pub rho: f64,
    pub cells: Vec<Cell>,
    /// Observed reduction of SwapLess vs the compiler baseline.
    pub reduction_vs_compiler: f64,
}

pub struct Fig7 {
    pub results: Vec<WorkloadResult>,
}

fn policy_config(
    ctx: &Ctx,
    policy: &str,
    tenants: &[Tenant],
) -> Config {
    match policy {
        "compiler" => alloc::edge_tpu_compiler(&ctx.am, tenants).config,
        "threshold" => alloc::threshold_partitioning(&ctx.am, tenants, ctx.k_max, 0.10).config,
        "swapless_a0" => {
            let am0 = AnalyticModel::with_alpha_zero(ctx.cost.clone());
            alloc::hill_climb(&am0, tenants, ctx.k_max).config
        }
        "swapless" => alloc::hill_climb(&ctx.am, tenants, ctx.k_max).config,
        other => panic!("unknown policy {other}"),
    }
}

pub fn run_workload(ctx: &Ctx, names: &[&str], rho: f64) -> Result<WorkloadResult, String> {
    // Rates: equal TPU load per model at utilization rho under full-TPU
    // (the workload definition is policy-independent).
    let zero: Vec<f64> = vec![0.0; names.len()];
    let tenants0 = ctx.tenants(names, &zero)?;
    let full = Config::all_tpu(&tenants0);
    let shares = equal_tpu_load_shares(&ctx.am, &tenants0);
    let rates = rates_for_utilization(&ctx.am, &tenants0, &full, &shares, rho);
    let tenants = ctx.tenants(names, &rates)?;

    let mut cells = Vec::new();
    for policy in POLICIES {
        let config = policy_config(ctx, policy, &tenants);
        let predicted = ctx.am.mean_latency(&tenants, &config);
        let observed = ctx.observe(&tenants, &config).mean_latency;
        cells.push(Cell {
            policy: policy.into(),
            config,
            predicted_ms: predicted * 1e3,
            observed_ms: observed * 1e3,
        });
    }
    let compiler_obs = cells[0].observed_ms;
    let swapless_obs = cells[3].observed_ms;
    Ok(WorkloadResult {
        workload: names.join("+"),
        rho,
        reduction_vs_compiler: ((compiler_obs - swapless_obs) / compiler_obs).max(0.0),
        cells,
    })
}

pub fn run(ctx: &Ctx, rhos: &[f64]) -> Result<Fig7, String> {
    let mut results = Vec::new();
    for &rho in rhos {
        for wl in SINGLE_WORKLOADS.iter().chain(MULTI_WORKLOADS.iter()) {
            results.push(run_workload(ctx, wl, rho)?);
        }
    }
    Ok(Fig7 { results })
}

impl Fig7 {
    pub fn print(&self) {
        for rho in [0.2, 0.5] {
            let rows: Vec<Vec<String>> = self
                .results
                .iter()
                .filter(|r| (r.rho - rho).abs() < 1e-9)
                .map(|r| {
                    let mut cells = vec![r.workload.clone()];
                    for c in &r.cells {
                        cells.push(format!("{:.1}", c.observed_ms));
                    }
                    cells.push(pct(r.reduction_vs_compiler));
                    cells
                })
                .collect();
            if rows.is_empty() {
                continue;
            }
            print_table(
                &format!("Fig. 7: observed mean latency (ms) under ρ={rho}"),
                &[
                    "workload",
                    "compiler",
                    "threshold",
                    "swapless(α=0)",
                    "swapless",
                    "reduction",
                ],
                &rows,
            );
        }
        let best_single = self
            .results
            .iter()
            .filter(|r| !r.workload.contains('+'))
            .map(|r| r.reduction_vs_compiler)
            .fold(0.0f64, f64::max);
        let best_multi = self
            .results
            .iter()
            .filter(|r| r.workload.contains('+'))
            .map(|r| r.reduction_vs_compiler)
            .fold(0.0f64, f64::max);
        println!(
            "max reduction vs compiler: single-tenant {} multi-tenant {} (paper: 63.8% / 77.4%)",
            pct(best_single),
            pct(best_multi)
        );
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.results
                .iter()
                .map(|r| {
                    Json::from_pairs(vec![
                        ("workload", Json::Str(r.workload.clone())),
                        ("rho", Json::Num(r.rho)),
                        (
                            "reduction_vs_compiler",
                            Json::Num(r.reduction_vs_compiler),
                        ),
                        (
                            "cells",
                            Json::Arr(
                                r.cells
                                    .iter()
                                    .map(|c| {
                                        Json::from_pairs(vec![
                                            ("policy", Json::Str(c.policy.clone())),
                                            (
                                                "partitions",
                                                Json::Arr(
                                                    c.config
                                                        .partitions
                                                        .iter()
                                                        .map(|p| Json::Num(*p as f64))
                                                        .collect(),
                                                ),
                                            ),
                                            (
                                                "cores",
                                                Json::Arr(
                                                    c.config
                                                        .cores
                                                        .iter()
                                                        .map(|k| Json::Num(*k as f64))
                                                        .collect(),
                                                ),
                                            ),
                                            ("predicted_ms", Json::Num(c.predicted_ms)),
                                            ("observed_ms", Json::Num(c.observed_ms)),
                                        ])
                                    })
                                    .collect(),
                            ),
                        ),
                    ])
                })
                .collect(),
        )
    }
}
