//! Fig. 2 — Inter-model swapping overhead across workload mixes.
//!
//! Two co-located full-TPU models at 50:50 and 90:10 request mixes,
//! compared against each model's standalone execution. The paper reports
//! ≈0% overhead when the combined footprint fits (MobileNetV2+SqueezeNet),
//! up to 35% at 50:50 and up to 49% for the rare model at 90:10.

use crate::analytic::Config;
use crate::util::json::Json;

use super::common::{pct, print_table, Ctx};

pub struct MixRow {
    pub mix: String,
    pub share: String,
    pub model: String,
    pub standalone_ms: f64,
    pub colocated_ms: f64,
    pub overhead_fraction: f64,
    pub alpha_predicted: f64,
    pub cache_hit_rate: f64,
}

pub struct Fig2 {
    pub rows: Vec<MixRow>,
}

/// (pair, shares) — shares are request-mix proportions.
pub const SCENARIOS: [(&str, &str, f64, f64); 4] = [
    ("mobilenetv2", "squeezenet", 0.5, 0.5),
    ("efficientnet", "gpunet", 0.5, 0.5),
    ("efficientnet", "gpunet", 0.9, 0.1),
    ("densenet201", "resnet50v2", 0.5, 0.5),
];

pub fn run(ctx: &Ctx) -> Result<Fig2, String> {
    // Total rate low enough to stay stable for every pair.
    let total_rate = 1.0;
    let mut rows = Vec::new();
    for (a, b, sa, sb) in SCENARIOS {
        let names = [a, b];
        let shares = [sa, sb];
        // Standalone baselines (single-tenant, same per-model rate).
        let mut standalone = [0.0f64; 2];
        for (i, name) in names.iter().enumerate() {
            let tenants = ctx.tenants(&[name], &[total_rate * shares[i]])?;
            let cfg = Config::all_tpu(&tenants);
            standalone[i] = ctx.observe(&tenants, &cfg).mean_latency;
        }
        // Co-located run.
        let tenants = ctx.tenants(&names, &[total_rate * sa, total_rate * sb])?;
        let cfg = Config::all_tpu(&tenants);
        let obs = ctx.observe(&tenants, &cfg);
        for i in 0..2 {
            let colocated = obs.per_model[i].latency.mean();
            rows.push(MixRow {
                mix: format!("{a}+{b}"),
                share: format!("{:.0}:{:.0}", sa * 100.0, sb * 100.0),
                model: names[i].into(),
                standalone_ms: standalone[i] * 1e3,
                colocated_ms: colocated * 1e3,
                overhead_fraction: (colocated - standalone[i]).max(0.0) / colocated.max(1e-12),
                alpha_predicted: ctx.am.alpha(&tenants, &cfg, i),
                cache_hit_rate: obs.cache_hit_rate,
            });
        }
    }
    Ok(Fig2 { rows })
}

impl Fig2 {
    pub fn print(&self) {
        let rows: Vec<Vec<String>> = self
            .rows
            .iter()
            .map(|r| {
                vec![
                    r.mix.clone(),
                    r.share.clone(),
                    r.model.clone(),
                    format!("{:.1}", r.standalone_ms),
                    format!("{:.1}", r.colocated_ms),
                    pct(r.overhead_fraction),
                    format!("{:.2}", r.alpha_predicted),
                ]
            })
            .collect();
        print_table(
            "Fig. 2: inter-model swapping overhead (co-located full-TPU)",
            &[
                "mix",
                "req mix",
                "model",
                "standalone ms",
                "co-located ms",
                "overhead %",
                "α (Eq. 10)",
            ],
            &rows,
        );
        println!("(paper: ≈0% when fits; up to 35% at 50:50; up to 49% for the rare model at 90:10)");
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.rows
                .iter()
                .map(|r| {
                    Json::from_pairs(vec![
                        ("mix", Json::Str(r.mix.clone())),
                        ("share", Json::Str(r.share.clone())),
                        ("model", Json::Str(r.model.clone())),
                        ("standalone_ms", Json::Num(r.standalone_ms)),
                        ("colocated_ms", Json::Num(r.colocated_ms)),
                        ("overhead_fraction", Json::Num(r.overhead_fraction)),
                        ("alpha_predicted", Json::Num(r.alpha_predicted)),
                        ("cache_hit_rate", Json::Num(r.cache_hit_rate)),
                    ])
                })
                .collect(),
        )
    }
}
