//! Shared experiment context + helpers.

use crate::analytic::{AnalyticModel, Config, Tenant};
use crate::config::HardwareSpec;
use crate::model::Manifest;
use crate::sim::{simulate, SimOptions, SimResult};
use crate::tpu::CostModel;
use crate::util::json::Json;

/// Everything an experiment needs: the manifest + the calibrated models.
pub struct Ctx {
    pub manifest: Manifest,
    pub cost: CostModel,
    pub am: AnalyticModel,
    pub k_max: usize,
    pub seed: u64,
    /// DES horizon for steady-state runs (seconds of virtual time).
    pub horizon: f64,
}

impl Ctx {
    pub fn new(manifest: Manifest, hw: HardwareSpec) -> Ctx {
        let cost = CostModel::new(hw.clone());
        Ctx {
            manifest,
            am: AnalyticModel::new(cost.clone()),
            cost,
            k_max: hw.cpu_cores,
            seed: 42,
            horizon: 2000.0,
        }
    }

    pub fn load(artifacts_dir: &str, hw: HardwareSpec) -> Result<Ctx, String> {
        Ok(Ctx::new(Manifest::load(artifacts_dir)?, hw))
    }

    pub fn tenants(&self, names: &[&str], rates: &[f64]) -> Result<Vec<Tenant>, String> {
        assert_eq!(names.len(), rates.len());
        names
            .iter()
            .zip(rates)
            .map(|(n, r)| {
                Ok(Tenant {
                    model: self.manifest.get(n)?.clone(),
                    rate: *r,
                })
            })
            .collect()
    }

    /// Steady-state DES under a static config.
    pub fn observe(&self, tenants: &[Tenant], cfg: &Config) -> SimResult {
        simulate(
            &self.cost,
            tenants,
            cfg,
            SimOptions {
                horizon: self.horizon,
                warmup: self.horizon * 0.05,
                seed: self.seed,
                ..SimOptions::default()
            },
        )
    }
}

/// Render a simple aligned text table.
pub fn print_table(title: &str, headers: &[&str], rows: &[Vec<String>]) {
    println!("\n=== {title} ===");
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let line = |cells: &[String]| {
        let mut s = String::new();
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!("{:<w$}  ", c, w = widths[i.min(widths.len() - 1)]));
        }
        println!("{}", s.trim_end());
    };
    line(&headers.iter().map(|h| h.to_string()).collect::<Vec<_>>());
    line(
        &widths
            .iter()
            .map(|w| "-".repeat(*w))
            .collect::<Vec<_>>(),
    );
    for row in rows {
        line(row);
    }
}

pub fn ms(x: f64) -> String {
    if x.is_infinite() {
        "∞".into()
    } else {
        format!("{:.1}", x * 1e3)
    }
}

pub fn pct(x: f64) -> String {
    format!("{:.1}%", x * 100.0)
}

/// Save a result blob under results/.
pub fn save_result(name: &str, value: &Json) -> Result<(), String> {
    crate::util::json::write_file(&format!("results/{name}.json"), value)
}
