//! The wire experiment: what does the socket edge cost?
//!
//! Brings up a real `NetListener` on a loopback ephemeral port in front
//! of an emulated single-device server, then sweeps offered rate ×
//! connection count with the open-loop load generator, measuring
//! **client-observed** latency (framing + TCP + queueing + service).
//! Each rate point also gets an in-process baseline — the same Poisson
//! stream submitted directly through `Server::submit` with a collector
//! thread timing submit → ticket resolution — so the table reads as
//! "the socket path adds X ms at rate R" (`results/wire.json`).

use super::common::{print_table, Ctx};
use crate::coordinator::{AttachOptions, Request, ServerBuilder, Ticket};
use crate::metrics::LatencyHistogram;
use crate::net::loadgen::{self, LoadgenMode, LoadgenOptions, TenantSpec};
use crate::net::{NetListener, NetOptions};
use crate::runtime::service::ExecBackend;
use crate::sched::SloClass;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::workload::RateSchedule;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

const MODELS: [&str; 2] = ["mobilenetv2", "squeezenet"];
const RATES: [f64; 2] = [20.0, 60.0];
const CONNECTIONS: [usize; 2] = [1, 4];
const DURATION_S: f64 = 1.5;

#[derive(Debug, Clone)]
pub struct WireRow {
    /// "wire" or "direct" (the in-process baseline).
    pub path: &'static str,
    /// Total offered rate across tenants (req/s).
    pub offered: f64,
    /// 0 for the direct path.
    pub connections: usize,
    pub sent: u64,
    pub completed: u64,
    pub errors: u64,
    pub unanswered: u64,
    pub achieved: f64,
    pub mean_ms: f64,
    pub p99_ms: f64,
}

pub struct WireResult {
    pub rows: Vec<WireRow>,
}

impl WireResult {
    pub fn print(&self) {
        let rows: Vec<Vec<String>> = self
            .rows
            .iter()
            .map(|r| {
                vec![
                    r.path.to_string(),
                    format!("{:.0}", r.offered),
                    if r.connections == 0 {
                        "-".to_string()
                    } else {
                        r.connections.to_string()
                    },
                    r.sent.to_string(),
                    r.completed.to_string(),
                    r.errors.to_string(),
                    format!("{:.1}", r.achieved),
                    format!("{:.2}", r.mean_ms),
                    format!("{:.2}", r.p99_ms),
                ]
            })
            .collect();
        print_table(
            "Wire: loopback socket path vs in-process submission (open loop, emulated)",
            &[
                "path", "offered", "conns", "sent", "completed", "errors", "rate", "mean ms",
                "p99 ms",
            ],
            &rows,
        );
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.rows
                .iter()
                .map(|r| {
                    Json::from_pairs(vec![
                        ("path", Json::Str(r.path.to_string())),
                        ("offered", Json::Num(r.offered)),
                        ("connections", Json::Num(r.connections as f64)),
                        ("sent", Json::Num(r.sent as f64)),
                        ("completed", Json::Num(r.completed as f64)),
                        ("errors", Json::Num(r.errors as f64)),
                        ("unanswered", Json::Num(r.unanswered as f64)),
                        ("achieved", Json::Num(r.achieved)),
                        ("mean_ms", Json::Num(r.mean_ms)),
                        ("p99_ms", Json::Num(r.p99_ms)),
                    ])
                })
                .collect(),
        )
    }
}

/// Split a total offered rate across the driven tenants.
fn per_tenant_rates(total: f64) -> Vec<f64> {
    vec![total / MODELS.len() as f64; MODELS.len()]
}

pub fn run(ctx: &Ctx) -> Result<WireResult, String> {
    let mut rows = Vec::new();

    // One server + listener serves the whole sweep, like a real
    // deployment; per-point metrics come from the client side.
    let mut builder = ServerBuilder::new(&ctx.manifest, ctx.cost.clone())
        .k_max(ctx.k_max)
        .backend(ExecBackend::Emulated)
        .adaptive(false);
    builder = builder.time_scale(0.0);
    let server = Arc::new(builder.build().map_err(|e| e.to_string())?);
    let mut input_lens = Vec::new();
    for name in MODELS {
        let h = server
            .attach(
                name,
                AttachOptions {
                    rate_hint: 40.0,
                    class: SloClass::Standard,
                },
            )
            .map_err(|e| e.to_string())?;
        let n: usize = server
            .model_meta(h)
            .expect("just attached")
            .input_shape
            .iter()
            .product();
        input_lens.push((h, n));
    }
    let listener = NetListener::bind(server.clone(), "127.0.0.1:0", NetOptions::default())?;
    let addr = listener.local_addr().to_string();

    for &offered in &RATES {
        for &conns in &CONNECTIONS {
            let report = loadgen::run(&LoadgenOptions {
                addr: addr.clone(),
                connections: conns,
                duration_s: DURATION_S,
                mode: LoadgenMode::Open,
                tenants: input_lens
                    .iter()
                    .zip(per_tenant_rates(offered))
                    .map(|((h, _), r)| TenantSpec {
                        handle: h.0,
                        schedule: RateSchedule::constant(r),
                        class: None,
                        deadline_ms: 0,
                    })
                    .collect(),
                window: 8,
                seed: ctx.seed,
            })?;
            rows.push(WireRow {
                path: "wire",
                offered,
                connections: conns,
                sent: report.sent,
                completed: report.completed,
                errors: report.errors,
                unanswered: report.unanswered,
                achieved: report.rate(),
                mean_ms: report.latency.mean() * 1e3,
                p99_ms: report.latency.percentile(99.0) * 1e3,
            });
        }
        rows.push(direct_baseline(&server, &input_lens, offered, ctx.seed));
    }

    let net = listener.shutdown();
    println!("{}", net.line());
    Ok(WireResult { rows })
}

/// The in-process baseline: same Poisson stream, `Server::submit`
/// directly, a collector thread timing submit → resolution.
fn direct_baseline(
    server: &Arc<crate::coordinator::Server>,
    tenants: &[(crate::analytic::TenantHandle, usize)],
    offered: f64,
    seed: u64,
) -> WireRow {
    let (tx, rx) = mpsc::channel::<(Instant, Ticket)>();
    let collector = std::thread::spawn(move || {
        let mut hist = LatencyHistogram::default();
        let mut completed = 0u64;
        let mut errors = 0u64;
        while let Ok((sent_at, ticket)) = rx.recv() {
            match ticket.wait() {
                Ok(_) => {
                    completed += 1;
                    hist.record(sent_at.elapsed().as_secs_f64());
                }
                Err(_) => errors += 1,
            }
        }
        (hist, completed, errors)
    });

    let rates = per_tenant_rates(offered);
    let mut rng = Rng::new(seed ^ 0x5157);
    let mut next_at: Vec<f64> = rates.iter().map(|r| rng.exponential(*r)).collect();
    let mut sent = 0u64;
    let t0 = Instant::now();
    loop {
        let now = t0.elapsed().as_secs_f64();
        if now >= DURATION_S {
            break;
        }
        let (idx, at) = next_at
            .iter()
            .copied()
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(&b.1))
            .expect("tenants non-empty");
        if at > now {
            std::thread::sleep(Duration::from_secs_f64((at.min(DURATION_S) - now).min(0.05)));
            continue;
        }
        let (h, n_in) = tenants[idx];
        let ticket = server.submit(h, Request::new(vec![0.5; n_in]));
        let _ = tx.send((Instant::now(), ticket));
        sent += 1;
        next_at[idx] = now + rng.exponential(rates[idx]);
    }
    drop(tx);
    let wall = t0.elapsed().as_secs_f64();
    let (hist, completed, errors) = collector.join().expect("collector thread");
    WireRow {
        path: "direct",
        offered,
        connections: 0,
        sent,
        completed,
        errors,
        unanswered: sent - completed - errors,
        achieved: completed as f64 / wall,
        mean_ms: hist.mean() * 1e3,
        p99_ms: hist.percentile(99.0) * 1e3,
    }
}
