//! Shortest-predicted-service-first, fed by the analytic model's
//! per-request service-time estimates (`JobMeta::service_hint`).
//!
//! Ties fall back to FIFO via the monotonic push id, so the discipline
//! stays deterministic even when every hint is identical — in which case
//! it degenerates to FIFO exactly. An unknown (NaN) hint is sanitized to
//! +inf at push — "no estimate" schedules last, FIFO among its peers —
//! which keeps the heap's ordering a total order (raw NaN would compare
//! Equal against everything and break transitivity).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::analytic::TenantHandle;

use super::{DisciplineKind, JobMeta, QueueDiscipline};

struct Item {
    /// Sanitized at push: never NaN.
    hint: f64,
    id: u64,
    tenant: TenantHandle,
}

// BinaryHeap is a max-heap; invert so the smallest hint (then the
// smallest id) is the maximum. Hints are NaN-free by construction, so
// partial_cmp always succeeds and the order is total.
impl Ord for Item {
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .hint
            .partial_cmp(&self.hint)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.id.cmp(&self.id))
    }
}

impl PartialOrd for Item {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl PartialEq for Item {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for Item {}

#[derive(Default)]
pub struct ShortestPredicted {
    heap: BinaryHeap<Item>,
}

impl ShortestPredicted {
    pub fn new() -> ShortestPredicted {
        ShortestPredicted::default()
    }
}

impl QueueDiscipline for ShortestPredicted {
    fn push(&mut self, id: u64, meta: JobMeta) {
        let hint = if meta.service_hint.is_nan() {
            f64::INFINITY
        } else {
            meta.service_hint
        };
        self.heap.push(Item {
            hint,
            id,
            tenant: meta.tenant,
        });
    }

    fn pop(&mut self) -> Option<u64> {
        self.heap.pop().map(|i| i.id)
    }

    fn len(&self) -> usize {
        self.heap.len()
    }

    fn peek_next_service_hint(&self) -> Option<f64> {
        self.heap.peek().map(|i| i.hint)
    }

    fn drain_tenant(&mut self, tenant: TenantHandle) -> Vec<u64> {
        let mut gone = Vec::new();
        let mut keep = Vec::new();
        for item in std::mem::take(&mut self.heap) {
            if item.tenant == tenant {
                gone.push(item.id);
            } else {
                keep.push(item);
            }
        }
        self.heap = keep.into();
        gone
    }

    fn remove(&mut self, id: u64, _meta: &JobMeta) -> bool {
        // O(n) heap rebuild per eviction: acceptable because admission
        // evictions happen on bounded queues (capacity-sized n); an
        // uncapped DeadlineDrop queue is the one pathological case.
        let before = self.heap.len();
        let kept: Vec<Item> = std::mem::take(&mut self.heap)
            .into_iter()
            .filter(|item| item.id != id)
            .collect();
        self.heap = kept.into();
        self.heap.len() != before
    }

    fn kind(&self) -> DisciplineKind {
        DisciplineKind::Spsf
    }
}
