//! The pluggable scheduling core shared by the DES and the live server.
//!
//! Both consumers used to hard-code FIFO twice — `sim::Simulator` kept raw
//! `VecDeque`s for its TPU and per-model CPU stations, and the live
//! coordinator kept its own in `coordinator::pools`/`server` — so the two
//! paths could silently drift and no alternative discipline could be
//! studied. This module extracts the queueing decision into one
//! [`QueueDiscipline`] trait with four implementations:
//!
//! * [`Fifo`] — first-come-first-served (the paper's baseline);
//! * [`StrictPriority`] — strict priority by [`SloClass`], FIFO within a
//!   class (no aging: batch work can starve under sustained load);
//! * [`WeightedFair`] — deficit-round-robin across tenants, quanta scaled
//!   by the head job's SLO-class weight (starvation-free);
//! * [`ShortestPredicted`] — shortest-predicted-service-first, fed by the
//!   analytic model's per-request service-time estimates.
//!
//! A discipline schedules opaque job ids against [`JobMeta`]; the
//! [`SchedQueue`] wrapper pairs a discipline with a payload store so both
//! the simulator (queueing `sim::Request`) and the live server (queueing
//! TPU/CPU jobs) drive the *same* trait objects — the sim-vs-live parity
//! test in `tests/sched_parity.rs` pins this.

use std::collections::HashMap;

use crate::analytic::TenantHandle;

mod fifo;
mod priority;
mod spsf;
mod wfq;

pub use fifo::Fifo;
pub use priority::StrictPriority;
pub use spsf::ShortestPredicted;
pub use wfq::WeightedFair;

/// Service-level-objective class of a request (or a tenant's default).
/// Lower [`priority`](SloClass::priority) numbers are more urgent.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Default)]
pub enum SloClass {
    /// Latency-critical, user-facing traffic.
    Interactive,
    /// Ordinary request/response traffic (the default).
    #[default]
    Standard,
    /// Throughput-oriented background work.
    Batch,
}

impl SloClass {
    pub const COUNT: usize = 3;
    pub const ALL: [SloClass; 3] = [SloClass::Interactive, SloClass::Standard, SloClass::Batch];

    /// Dense index (0..COUNT), usable as a histogram slot.
    pub fn index(self) -> usize {
        match self {
            SloClass::Interactive => 0,
            SloClass::Standard => 1,
            SloClass::Batch => 2,
        }
    }

    pub fn from_index(i: usize) -> Option<SloClass> {
        SloClass::ALL.get(i).copied()
    }

    /// Strict-priority rank: lower is served first.
    pub fn priority(self) -> usize {
        self.index()
    }

    /// Weighted-fair share weight (Interactive gets 4x a Batch tenant's
    /// service per round).
    pub fn weight(self) -> f64 {
        match self {
            SloClass::Interactive => 4.0,
            SloClass::Standard => 2.0,
            SloClass::Batch => 1.0,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            SloClass::Interactive => "interactive",
            SloClass::Standard => "standard",
            SloClass::Batch => "batch",
        }
    }

    pub fn parse(s: &str) -> Result<SloClass, String> {
        match s.trim().to_ascii_lowercase().as_str() {
            "interactive" => Ok(SloClass::Interactive),
            "standard" => Ok(SloClass::Standard),
            "batch" => Ok(SloClass::Batch),
            other => Err(format!(
                "unknown SLO class {other:?} (have interactive, standard, batch)"
            )),
        }
    }
}

impl std::fmt::Display for SloClass {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// What a discipline knows about a queued job.
#[derive(Debug, Clone, Copy)]
pub struct JobMeta {
    /// Stable identity of the submitting tenant (the WFQ flow key).
    pub tenant: TenantHandle,
    pub class: SloClass,
    /// Predicted service time in seconds (from the analytic model's cost
    /// tables); SPSF orders on it, WFQ charges it against tenant deficits.
    /// Zero/non-finite hints degrade gracefully to per-job costs.
    pub service_hint: f64,
}

/// A queue scheduling discipline over opaque job ids.
///
/// Push ids are allocated monotonically by the caller ([`SchedQueue`]
/// does this), so a discipline may use the id itself as the FIFO
/// tie-break: equal-key jobs must pop in ascending-id order, which keeps
/// every discipline fully deterministic.
pub trait QueueDiscipline: Send {
    fn push(&mut self, id: u64, meta: JobMeta);
    fn pop(&mut self) -> Option<u64>;
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Best-effort service-time hint of the job `pop` would consider next
    /// (`None` when empty). Consumers may use it to size batching windows
    /// or device budgets; it is advisory, not a contract.
    fn peek_next_service_hint(&self) -> Option<f64>;
    /// Remove every queued job of `tenant` (detach), returning their ids.
    fn drain_tenant(&mut self, tenant: TenantHandle) -> Vec<u64>;
    fn kind(&self) -> DisciplineKind;
}

/// The discipline selector exposed on the CLI (`--discipline`) and the
/// builder APIs; [`build`](DisciplineKind::build) is the single factory
/// both the DES and the live server construct their queues through.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum DisciplineKind {
    #[default]
    Fifo,
    Priority,
    WeightedFair,
    Spsf,
}

impl DisciplineKind {
    pub const ALL: [DisciplineKind; 4] = [
        DisciplineKind::Fifo,
        DisciplineKind::Priority,
        DisciplineKind::WeightedFair,
        DisciplineKind::Spsf,
    ];

    pub fn name(self) -> &'static str {
        match self {
            DisciplineKind::Fifo => "fifo",
            DisciplineKind::Priority => "priority",
            DisciplineKind::WeightedFair => "wfq",
            DisciplineKind::Spsf => "spsf",
        }
    }

    pub fn parse(s: &str) -> Result<DisciplineKind, String> {
        match s.trim().to_ascii_lowercase().as_str() {
            "fifo" | "fcfs" => Ok(DisciplineKind::Fifo),
            "priority" | "prio" => Ok(DisciplineKind::Priority),
            "wfq" | "drr" | "weighted-fair" => Ok(DisciplineKind::WeightedFair),
            "spsf" | "sjf" => Ok(DisciplineKind::Spsf),
            other => Err(format!(
                "unknown discipline {other:?} (have fifo, priority, wfq, spsf)"
            )),
        }
    }

    pub fn build(self) -> Box<dyn QueueDiscipline + Send> {
        match self {
            DisciplineKind::Fifo => Box::new(Fifo::new()),
            DisciplineKind::Priority => Box::new(StrictPriority::new()),
            DisciplineKind::WeightedFair => Box::new(WeightedFair::new()),
            DisciplineKind::Spsf => Box::new(ShortestPredicted::new()),
        }
    }
}

impl std::fmt::Display for DisciplineKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// A discipline paired with its payload store: the convenience wrapper
/// both consumers embed. Ids stay internal; callers see `(JobMeta, T)`.
pub struct SchedQueue<T> {
    disc: Box<dyn QueueDiscipline + Send>,
    jobs: HashMap<u64, (JobMeta, T)>,
    next_id: u64,
}

impl<T> SchedQueue<T> {
    pub fn new(disc: Box<dyn QueueDiscipline + Send>) -> SchedQueue<T> {
        SchedQueue {
            disc,
            jobs: HashMap::new(),
            next_id: 0,
        }
    }

    pub fn with_kind(kind: DisciplineKind) -> SchedQueue<T> {
        SchedQueue::new(kind.build())
    }

    pub fn kind(&self) -> DisciplineKind {
        self.disc.kind()
    }

    pub fn push(&mut self, meta: JobMeta, job: T) {
        let id = self.next_id;
        self.next_id += 1;
        self.disc.push(id, meta);
        self.jobs.insert(id, (meta, job));
    }

    pub fn pop(&mut self) -> Option<(JobMeta, T)> {
        let id = self.disc.pop()?;
        self.jobs.remove(&id)
    }

    pub fn len(&self) -> usize {
        self.disc.len()
    }

    pub fn is_empty(&self) -> bool {
        self.disc.is_empty()
    }

    pub fn peek_next_service_hint(&self) -> Option<f64> {
        self.disc.peek_next_service_hint()
    }

    /// Remove every queued job of `tenant` (detach), in id order.
    pub fn drain_tenant(&mut self, tenant: TenantHandle) -> Vec<(JobMeta, T)> {
        let mut ids = self.disc.drain_tenant(tenant);
        ids.sort_unstable();
        ids.into_iter()
            .filter_map(|id| self.jobs.remove(&id))
            .collect()
    }

    /// Pop everything in discipline order (shutdown paths).
    pub fn drain_all(&mut self) -> Vec<(JobMeta, T)> {
        let mut out = Vec::with_capacity(self.len());
        while let Some(item) = self.pop() {
            out.push(item);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(tenant: u64, class: SloClass, hint: f64) -> JobMeta {
        JobMeta {
            tenant: TenantHandle(tenant),
            class,
            service_hint: hint,
        }
    }

    /// Push `jobs` into a fresh discipline of `kind` and pop everything,
    /// returning the payload order.
    fn pop_order(kind: DisciplineKind, jobs: &[(JobMeta, u32)]) -> Vec<u32> {
        let mut q: SchedQueue<u32> = SchedQueue::with_kind(kind);
        for (m, v) in jobs {
            q.push(*m, *v);
        }
        let mut out = Vec::new();
        while let Some((_, v)) = q.pop() {
            out.push(v);
        }
        out
    }

    #[test]
    fn fifo_preserves_push_order() {
        let jobs: Vec<(JobMeta, u32)> = (0..8)
            .map(|i| (meta(i % 3, SloClass::Standard, 0.01 * i as f64), i as u32))
            .collect();
        assert_eq!(
            pop_order(DisciplineKind::Fifo, &jobs),
            (0..8).collect::<Vec<u32>>()
        );
    }

    #[test]
    fn priority_orders_by_class_then_fifo() {
        let jobs = vec![
            (meta(0, SloClass::Batch, 0.01), 0),
            (meta(1, SloClass::Standard, 0.01), 1),
            (meta(2, SloClass::Interactive, 0.01), 2),
            (meta(0, SloClass::Interactive, 0.01), 3),
            (meta(1, SloClass::Batch, 0.01), 4),
            (meta(2, SloClass::Standard, 0.01), 5),
        ];
        assert_eq!(
            pop_order(DisciplineKind::Priority, &jobs),
            vec![2, 3, 1, 5, 0, 4]
        );
    }

    #[test]
    fn spsf_orders_by_hint_with_fifo_ties() {
        let jobs = vec![
            (meta(0, SloClass::Standard, 0.030), 0),
            (meta(1, SloClass::Standard, 0.010), 1),
            (meta(2, SloClass::Standard, 0.020), 2),
            (meta(0, SloClass::Standard, 0.010), 3), // tie with job 1
            (meta(1, SloClass::Standard, 0.005), 4),
        ];
        assert_eq!(
            pop_order(DisciplineKind::Spsf, &jobs),
            vec![4, 1, 3, 2, 0]
        );
    }

    #[test]
    fn spsf_nan_hints_schedule_last_deterministically() {
        let jobs = vec![
            (meta(0, SloClass::Standard, f64::NAN), 0),
            (meta(1, SloClass::Standard, 0.020), 1),
            (meta(2, SloClass::Standard, f64::NAN), 2),
            (meta(0, SloClass::Standard, 0.010), 3),
        ];
        // Unknown hints sort after every estimate, FIFO among themselves.
        assert_eq!(pop_order(DisciplineKind::Spsf, &jobs), vec![3, 1, 0, 2]);
    }

    #[test]
    fn wfq_equal_weights_alternate() {
        // Two Batch tenants with uniform costs: DRR serves one job per
        // flow per round — strict alternation while both are backlogged.
        let mut jobs = Vec::new();
        for i in 0..6u32 {
            jobs.push((meta(0, SloClass::Batch, 0.01), i));
        }
        for i in 0..6u32 {
            jobs.push((meta(1, SloClass::Batch, 0.01), 10 + i));
        }
        let order = pop_order(DisciplineKind::WeightedFair, &jobs);
        assert_eq!(order.len(), 12);
        // Every window of 2 consecutive pops serves both tenants.
        for w in order.chunks(2) {
            assert_eq!(
                w.iter().filter(|v| **v < 10).count(),
                1,
                "not alternating: {order:?}"
            );
        }
    }

    #[test]
    fn wfq_starvation_bound() {
        // 100 jobs for tenant 0 vs 10 for tenant 1, equal weights and
        // costs: tenant 1's k-th job must pop within the first 2k + 2
        // pops (one job per flow per round — no starvation).
        let mut jobs = Vec::new();
        for i in 0..100u32 {
            jobs.push((meta(0, SloClass::Batch, 0.01), i));
        }
        for i in 0..10u32 {
            jobs.push((meta(1, SloClass::Batch, 0.01), 1000 + i));
        }
        let order = pop_order(DisciplineKind::WeightedFair, &jobs);
        for k in 0..10u32 {
            let pos = order.iter().position(|v| *v == 1000 + k).unwrap();
            assert!(
                pos <= 2 * k as usize + 2,
                "job {k} of the small flow popped at {pos}: {order:?}"
            );
        }
    }

    #[test]
    fn wfq_weights_shift_share() {
        // Interactive (w=4) vs Batch (w=1), uniform costs: over one round
        // the interactive tenant gets ~4x the service.
        let mut jobs = Vec::new();
        for i in 0..40u32 {
            jobs.push((meta(0, SloClass::Interactive, 0.01), i));
        }
        for i in 0..40u32 {
            jobs.push((meta(1, SloClass::Batch, 0.01), 100 + i));
        }
        let order = pop_order(DisciplineKind::WeightedFair, &jobs);
        let interactive_in_first_20 = order[..20].iter().filter(|v| **v < 100).count();
        assert!(
            (14..=18).contains(&interactive_in_first_20),
            "interactive got {interactive_in_first_20}/20 early slots: {order:?}"
        );
        // The batch tenant is not starved: it appears in every round of 5.
        for w in order[..40].chunks(5) {
            assert!(
                w.iter().any(|v| *v >= 100),
                "batch starved in window {w:?} of {order:?}"
            );
        }
    }

    #[test]
    fn drain_tenant_removes_only_that_tenant() {
        for kind in DisciplineKind::ALL {
            let mut q: SchedQueue<u32> = SchedQueue::with_kind(kind);
            for i in 0..9u32 {
                q.push(meta(i as u64 % 3, SloClass::Standard, 0.01 + i as f64 * 1e-3), i);
            }
            let gone = q.drain_tenant(TenantHandle(1));
            assert_eq!(gone.len(), 3, "{kind}");
            assert!(gone.iter().all(|(m, _)| m.tenant == TenantHandle(1)));
            assert_eq!(q.len(), 6, "{kind}");
            let mut rest = Vec::new();
            while let Some((m, v)) = q.pop() {
                assert_ne!(m.tenant, TenantHandle(1), "{kind}");
                rest.push(v);
            }
            assert_eq!(rest.len(), 6, "{kind}");
            // Draining an absent tenant is a no-op.
            assert!(q.drain_tenant(TenantHandle(1)).is_empty());
        }
    }

    #[test]
    fn peek_hint_matches_next_pop_for_ordered_disciplines() {
        for kind in [
            DisciplineKind::Fifo,
            DisciplineKind::Priority,
            DisciplineKind::Spsf,
        ] {
            let mut q: SchedQueue<u32> = SchedQueue::with_kind(kind);
            assert_eq!(q.peek_next_service_hint(), None, "{kind}");
            for i in 0..5u32 {
                let class = SloClass::from_index(i as usize % 3).unwrap();
                q.push(meta(i as u64, class, 0.01 * (5 - i) as f64), i);
            }
            while !q.is_empty() {
                let hinted = q.peek_next_service_hint().unwrap();
                let (m, _) = q.pop().unwrap();
                assert_eq!(hinted, m.service_hint, "{kind}");
            }
        }
    }

    #[test]
    fn empty_queue_behaves() {
        for kind in DisciplineKind::ALL {
            let mut q: SchedQueue<u32> = SchedQueue::with_kind(kind);
            assert!(q.is_empty());
            assert_eq!(q.len(), 0);
            assert!(q.pop().is_none());
            assert_eq!(q.kind(), kind);
        }
    }

    #[test]
    fn kind_parse_round_trips() {
        for kind in DisciplineKind::ALL {
            assert_eq!(DisciplineKind::parse(kind.name()).unwrap(), kind);
        }
        assert!(DisciplineKind::parse("bogus").is_err());
        for class in SloClass::ALL {
            assert_eq!(SloClass::parse(class.name()).unwrap(), class);
            assert_eq!(SloClass::from_index(class.index()).unwrap(), class);
        }
        assert!(SloClass::parse("gold").is_err());
        assert!(SloClass::from_index(3).is_none());
    }
}
