//! The pluggable scheduling core shared by the DES and the live server.
//!
//! Both consumers used to hard-code FIFO twice — `sim::Simulator` kept raw
//! `VecDeque`s for its TPU and per-model CPU stations, and the live
//! coordinator kept its own in `coordinator::pools`/`server` — so the two
//! paths could silently drift and no alternative discipline could be
//! studied. This module extracts the queueing decision into one
//! [`QueueDiscipline`] trait with four implementations:
//!
//! * [`Fifo`] — first-come-first-served (the paper's baseline);
//! * [`StrictPriority`] — strict priority by [`SloClass`], FIFO within a
//!   class (no aging: batch work can starve under sustained load);
//! * [`WeightedFair`] — deficit-round-robin across tenants, quanta scaled
//!   by the head job's SLO-class weight (starvation-free);
//! * [`ShortestPredicted`] — shortest-predicted-service-first, fed by the
//!   analytic model's per-request service-time estimates.
//!
//! A discipline schedules opaque job ids against [`JobMeta`]; the
//! [`SchedQueue`] wrapper pairs a discipline with a payload store so both
//! the simulator (queueing `sim::Request`) and the live server (queueing
//! TPU/CPU jobs) drive the *same* trait objects — the sim-vs-live parity
//! test in `tests/sched_parity.rs` pins this.

use std::collections::HashMap;

use crate::analytic::TenantHandle;

mod fifo;
mod priority;
mod spsf;
mod wfq;

pub use fifo::Fifo;
pub use priority::StrictPriority;
pub use spsf::ShortestPredicted;
pub use wfq::WeightedFair;

/// Service-level-objective class of a request (or a tenant's default).
/// Lower [`priority`](SloClass::priority) numbers are more urgent.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Default)]
pub enum SloClass {
    /// Latency-critical, user-facing traffic.
    Interactive,
    /// Ordinary request/response traffic (the default).
    #[default]
    Standard,
    /// Throughput-oriented background work.
    Batch,
}

impl SloClass {
    pub const COUNT: usize = 3;
    pub const ALL: [SloClass; 3] = [SloClass::Interactive, SloClass::Standard, SloClass::Batch];

    /// Dense index (0..COUNT), usable as a histogram slot.
    pub fn index(self) -> usize {
        match self {
            SloClass::Interactive => 0,
            SloClass::Standard => 1,
            SloClass::Batch => 2,
        }
    }

    pub fn from_index(i: usize) -> Option<SloClass> {
        SloClass::ALL.get(i).copied()
    }

    /// Strict-priority rank: lower is served first.
    pub fn priority(self) -> usize {
        self.index()
    }

    /// Weighted-fair share weight (Interactive gets 4x a Batch tenant's
    /// service per round).
    pub fn weight(self) -> f64 {
        match self {
            SloClass::Interactive => 4.0,
            SloClass::Standard => 2.0,
            SloClass::Batch => 1.0,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            SloClass::Interactive => "interactive",
            SloClass::Standard => "standard",
            SloClass::Batch => "batch",
        }
    }

    pub fn parse(s: &str) -> Result<SloClass, String> {
        match s.trim().to_ascii_lowercase().as_str() {
            "interactive" => Ok(SloClass::Interactive),
            "standard" => Ok(SloClass::Standard),
            "batch" => Ok(SloClass::Batch),
            other => Err(format!(
                "unknown SLO class {other:?} (have interactive, standard, batch)"
            )),
        }
    }
}

impl std::fmt::Display for SloClass {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// What a discipline knows about a queued job.
#[derive(Debug, Clone, Copy)]
pub struct JobMeta {
    /// Stable identity of the submitting tenant (the WFQ flow key).
    pub tenant: TenantHandle,
    pub class: SloClass,
    /// Predicted service time in seconds (from the analytic model's cost
    /// tables); SPSF orders on it, WFQ charges it against tenant deficits.
    /// Zero/non-finite hints degrade gracefully to per-job costs.
    pub service_hint: f64,
    /// Absolute completion deadline on the consumer's clock (sim time for
    /// the DES, seconds since server start for the live path). `None` =
    /// no deadline. Only the `DeadlineDrop` overload policy acts on it;
    /// other policies carry it through for goodput accounting.
    pub deadline: Option<f64>,
    /// Index of the TPU device whose station queued this job (0 on a
    /// single-device deployment). Disciplines never key on it — each
    /// device runs its own queues — but it keeps multi-device jobs
    /// self-describing for tracing and the fleet router's accounting.
    pub device: usize,
}

impl JobMeta {
    /// True when the job can no longer meet its deadline even if served
    /// immediately: `deadline < now + service_hint` (the analytic
    /// service estimate; non-finite hints degrade to `deadline < now`).
    pub fn deadline_expired(&self, now: f64) -> bool {
        let Some(d) = self.deadline else { return false };
        d < now + self.finite_hint()
    }

    fn finite_hint(&self) -> f64 {
        if self.service_hint.is_finite() && self.service_hint > 0.0 {
            self.service_hint
        } else {
            0.0
        }
    }
}

/// How a station reacts when its bounded queue is full (or a deadline
/// can no longer be met). Shared verbatim by the DES stations and the
/// live server's TPU worker + per-tenant CPU pools, so drop behavior
/// validated in simulation deploys unchanged.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum OverloadPolicy {
    /// Unbounded admission — the legacy fire-hose. Queues grow without
    /// limit and latency diverges together for every class at ρ ≥ 1.
    #[default]
    Block,
    /// Refuse new work once `queue + in-service` reaches the capacity,
    /// with a typed [`Overloaded`] carrying depth and the O(1)
    /// prefix-table wait estimate.
    Reject,
    /// Like `Reject`, but a full queue first evicts the newest queued
    /// job of a strictly lower SLO class to admit higher-class work.
    ShedLowClass,
    /// Evict jobs whose deadline can no longer be met (on admission and
    /// before each service start); a full queue otherwise rejects.
    DeadlineDrop,
}

impl OverloadPolicy {
    pub const ALL: [OverloadPolicy; 4] = [
        OverloadPolicy::Block,
        OverloadPolicy::Reject,
        OverloadPolicy::ShedLowClass,
        OverloadPolicy::DeadlineDrop,
    ];

    pub fn name(self) -> &'static str {
        match self {
            OverloadPolicy::Block => "block",
            OverloadPolicy::Reject => "reject",
            OverloadPolicy::ShedLowClass => "shed",
            OverloadPolicy::DeadlineDrop => "deadline",
        }
    }

    pub fn parse(s: &str) -> Result<OverloadPolicy, String> {
        match s.trim().to_ascii_lowercase().as_str() {
            "block" | "none" => Ok(OverloadPolicy::Block),
            "reject" => Ok(OverloadPolicy::Reject),
            "shed" | "shed-low-class" => Ok(OverloadPolicy::ShedLowClass),
            "deadline" | "deadline-drop" => Ok(OverloadPolicy::DeadlineDrop),
            other => Err(format!(
                "unknown overload policy {other:?} (have block, reject, shed, deadline)"
            )),
        }
    }
}

impl std::fmt::Display for OverloadPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Typed payload of an overload rejection: where, how deep, and how long
/// the backlog ahead would take (from the O(1) prefix-table hints).
#[derive(Debug, Clone, PartialEq)]
pub struct Overloaded {
    /// Which station refused ("tpu", "cpu tenant#3", ...).
    pub station: String,
    /// Queued + in-service jobs observed at refusal.
    pub queue_depth: usize,
    pub capacity: usize,
    /// Predicted wait for a newly admitted job: the queued predicted
    /// service divided across the station's servers.
    pub estimated_wait_s: f64,
}

impl std::fmt::Display for Overloaded {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} overloaded: {}/{} jobs, est. wait {:.1} ms",
            self.station,
            self.queue_depth,
            self.capacity,
            self.estimated_wait_s * 1e3
        )
    }
}

/// Instantaneous load of the station offering a job (for the occupancy
/// bound and the wait estimate).
#[derive(Debug, Clone, Copy)]
pub struct StationLoad {
    /// Jobs currently executing at the station.
    pub in_service: usize,
    /// Parallel servers at the station.
    pub servers: usize,
}

/// Why [`SchedQueue::offer`] refused the incoming job.
#[derive(Debug, Clone, PartialEq)]
pub enum RejectReason {
    Overloaded(Overloaded),
    /// The job's own deadline can no longer be met (`DeadlineDrop`).
    Expired,
}

/// Outcome of a bounded-admission [`SchedQueue::offer`].
pub enum Offer<T> {
    /// The job was enqueued — possibly after evicting `shed` (lower-class
    /// victims) and/or `expired` (jobs past their deadline). The caller
    /// must resolve every evicted job (fail its completion handle).
    Admitted {
        shed: Vec<(JobMeta, T)>,
        expired: Vec<(JobMeta, T)>,
    },
    /// The incoming job was refused; it comes back with the typed reason.
    /// Deadline evictions performed before the refusal (`DeadlineDrop`)
    /// still come back in `expired` and must be resolved by the caller.
    Rejected {
        meta: JobMeta,
        job: T,
        reason: RejectReason,
        expired: Vec<(JobMeta, T)>,
    },
}

/// A queue scheduling discipline over opaque job ids.
///
/// Push ids are allocated monotonically by the caller ([`SchedQueue`]
/// does this), so a discipline may use the id itself as the FIFO
/// tie-break: equal-key jobs must pop in ascending-id order, which keeps
/// every discipline fully deterministic.
pub trait QueueDiscipline: Send {
    fn push(&mut self, id: u64, meta: JobMeta);
    fn pop(&mut self) -> Option<u64>;
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Best-effort service-time hint of the job `pop` would consider next
    /// (`None` when empty). Consumers may use it to size batching windows
    /// or device budgets; it is advisory, not a contract.
    fn peek_next_service_hint(&self) -> Option<f64>;
    /// Remove every queued job of `tenant` (detach), returning their ids.
    fn drain_tenant(&mut self, tenant: TenantHandle) -> Vec<u64>;
    /// Remove one queued job by id (admission-layer evictions: deadline
    /// drains, low-class shedding). `meta` is the metadata the job was
    /// pushed with — it lets flow-keyed disciplines find the right queue
    /// without a full scan. Returns false if the id is not queued.
    fn remove(&mut self, id: u64, meta: &JobMeta) -> bool;
    fn kind(&self) -> DisciplineKind;
}

/// The discipline selector exposed on the CLI (`--discipline`) and the
/// builder APIs; [`build`](DisciplineKind::build) is the single factory
/// both the DES and the live server construct their queues through.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum DisciplineKind {
    #[default]
    Fifo,
    Priority,
    WeightedFair,
    Spsf,
}

impl DisciplineKind {
    pub const ALL: [DisciplineKind; 4] = [
        DisciplineKind::Fifo,
        DisciplineKind::Priority,
        DisciplineKind::WeightedFair,
        DisciplineKind::Spsf,
    ];

    pub fn name(self) -> &'static str {
        match self {
            DisciplineKind::Fifo => "fifo",
            DisciplineKind::Priority => "priority",
            DisciplineKind::WeightedFair => "wfq",
            DisciplineKind::Spsf => "spsf",
        }
    }

    pub fn parse(s: &str) -> Result<DisciplineKind, String> {
        match s.trim().to_ascii_lowercase().as_str() {
            "fifo" | "fcfs" => Ok(DisciplineKind::Fifo),
            "priority" | "prio" => Ok(DisciplineKind::Priority),
            "wfq" | "drr" | "weighted-fair" => Ok(DisciplineKind::WeightedFair),
            "spsf" | "sjf" => Ok(DisciplineKind::Spsf),
            other => Err(format!(
                "unknown discipline {other:?} (have fifo, priority, wfq, spsf)"
            )),
        }
    }

    pub fn build(self) -> Box<dyn QueueDiscipline + Send> {
        match self {
            DisciplineKind::Fifo => Box::new(Fifo::new()),
            DisciplineKind::Priority => Box::new(StrictPriority::new()),
            DisciplineKind::WeightedFair => Box::new(WeightedFair::new()),
            DisciplineKind::Spsf => Box::new(ShortestPredicted::new()),
        }
    }
}

impl std::fmt::Display for DisciplineKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// A discipline paired with its payload store: the convenience wrapper
/// both consumers embed. Ids stay internal; callers see `(JobMeta, T)`.
pub struct SchedQueue<T> {
    disc: Box<dyn QueueDiscipline + Send>,
    jobs: HashMap<u64, (JobMeta, T)>,
    next_id: u64,
    /// Running sum of the queued jobs' (finite) service hints — the O(1)
    /// backlog estimate behind [`Overloaded::estimated_wait_s`].
    hint_sum: f64,
    /// Queued jobs carrying a deadline — lets `drain_expired` skip its
    /// scan entirely (O(1)) for deadline-free workloads, which is every
    /// pop under `DeadlineDrop` when requests carry no deadlines.
    deadline_count: usize,
}

impl<T> SchedQueue<T> {
    pub fn new(disc: Box<dyn QueueDiscipline + Send>) -> SchedQueue<T> {
        SchedQueue {
            disc,
            jobs: HashMap::new(),
            next_id: 0,
            hint_sum: 0.0,
            deadline_count: 0,
        }
    }

    pub fn with_kind(kind: DisciplineKind) -> SchedQueue<T> {
        SchedQueue::new(kind.build())
    }

    pub fn kind(&self) -> DisciplineKind {
        self.disc.kind()
    }

    pub fn push(&mut self, meta: JobMeta, job: T) {
        let id = self.next_id;
        self.next_id += 1;
        self.disc.push(id, meta);
        self.hint_sum += meta.finite_hint();
        self.deadline_count += usize::from(meta.deadline.is_some());
        self.jobs.insert(id, (meta, job));
    }

    pub fn pop(&mut self) -> Option<(JobMeta, T)> {
        let id = self.disc.pop()?;
        let entry = self.jobs.remove(&id);
        if let Some((meta, _)) = &entry {
            self.forget(meta);
        }
        entry
    }

    /// Bookkeeping for a job leaving the queue by any path.
    fn forget(&mut self, meta: &JobMeta) {
        self.hint_sum = (self.hint_sum - meta.finite_hint()).max(0.0);
        self.deadline_count -= usize::from(meta.deadline.is_some());
    }

    /// Sum of the queued jobs' predicted service times (seconds) — the
    /// O(1) backlog reading reported on overload rejections.
    pub fn queued_service_s(&self) -> f64 {
        self.hint_sum
    }

    pub fn len(&self) -> usize {
        self.disc.len()
    }

    pub fn is_empty(&self) -> bool {
        self.disc.is_empty()
    }

    pub fn peek_next_service_hint(&self) -> Option<f64> {
        self.disc.peek_next_service_hint()
    }

    /// Number of queued jobs belonging to `tenant` — the drain check the
    /// fleet router's drain-then-move migration polls before detaching a
    /// tenant from its source device. O(queue length).
    pub fn count_tenant(&self, tenant: TenantHandle) -> usize {
        self.jobs
            .values()
            .filter(|(m, _)| m.tenant == tenant)
            .count()
    }

    /// Remove every queued job of `tenant` (detach), in id order.
    pub fn drain_tenant(&mut self, tenant: TenantHandle) -> Vec<(JobMeta, T)> {
        let mut ids = self.disc.drain_tenant(tenant);
        ids.sort_unstable();
        ids.into_iter()
            .filter_map(|id| {
                let entry = self.jobs.remove(&id);
                if let Some((meta, _)) = &entry {
                    self.forget(meta);
                }
                entry
            })
            .collect()
    }

    /// Pop everything in discipline order (shutdown paths).
    pub fn drain_all(&mut self) -> Vec<(JobMeta, T)> {
        let mut out = Vec::with_capacity(self.len());
        while let Some(item) = self.pop() {
            out.push(item);
        }
        out
    }

    /// Remove one queued job by id (meta looked up internally).
    fn take(&mut self, id: u64) -> Option<(JobMeta, T)> {
        let meta = self.jobs.get(&id).map(|(m, _)| *m)?;
        if !self.disc.remove(id, &meta) {
            return None;
        }
        self.forget(&meta);
        self.jobs.remove(&id)
    }

    /// Remove every queued job whose deadline can no longer be met at
    /// `now` (see [`JobMeta::deadline_expired`]), in push order. Workers
    /// call this before each service start under `DeadlineDrop`; when no
    /// queued job carries a deadline it is O(1).
    pub fn drain_expired(&mut self, now: f64) -> Vec<(JobMeta, T)> {
        if self.deadline_count == 0 {
            return Vec::new();
        }
        let mut ids: Vec<u64> = self
            .jobs
            .iter()
            .filter(|(_, (m, _))| m.deadline_expired(now))
            .map(|(id, _)| *id)
            .collect();
        ids.sort_unstable();
        ids.into_iter().filter_map(|id| self.take(id)).collect()
    }

    /// Evict the most-sheddable queued job of a class strictly lower
    /// than `class`: lowest class first, newest within a class — the
    /// `ShedLowClass` victim rule. `None` when no lower-class job queues.
    fn shed_victim(&mut self, class: SloClass) -> Option<(JobMeta, T)> {
        let victim = self
            .jobs
            .iter()
            .filter(|(_, (m, _))| m.class.priority() > class.priority())
            .max_by_key(|(id, (m, _))| (m.class.priority(), **id))
            .map(|(id, _)| *id)?;
        self.take(victim)
    }

    /// Bounded admission: push `job` subject to `capacity` and `policy`
    /// at a station currently carrying `load`. Occupancy is counted as
    /// `queued + in-service`, so with `Reject` it never exceeds the
    /// capacity. All evicted jobs are handed back for the caller to
    /// resolve; the incoming job is handed back on refusal.
    #[allow(clippy::too_many_arguments)]
    pub fn offer(
        &mut self,
        meta: JobMeta,
        job: T,
        now: f64,
        station: &str,
        capacity: Option<usize>,
        policy: OverloadPolicy,
        load: StationLoad,
    ) -> Offer<T> {
        let mut expired = Vec::new();
        if policy == OverloadPolicy::DeadlineDrop {
            if meta.deadline_expired(now) {
                return Offer::Rejected {
                    meta,
                    job,
                    reason: RejectReason::Expired,
                    expired,
                };
            }
            expired = self.drain_expired(now);
        }
        let occupancy = self.len() + load.in_service;
        let full = match (policy, capacity) {
            (OverloadPolicy::Block, _) | (_, None) => false,
            (_, Some(cap)) => occupancy >= cap,
        };
        if full {
            let cap = capacity.unwrap_or(usize::MAX);
            if policy == OverloadPolicy::ShedLowClass {
                if let Some(victim) = self.shed_victim(meta.class) {
                    self.push(meta, job);
                    return Offer::Admitted {
                        shed: vec![victim],
                        expired,
                    };
                }
            }
            let overloaded = Overloaded {
                station: station.to_string(),
                queue_depth: occupancy,
                capacity: cap,
                estimated_wait_s: self.hint_sum / load.servers.max(1) as f64,
            };
            return Offer::Rejected {
                meta,
                job,
                reason: RejectReason::Overloaded(overloaded),
                expired,
            };
        }
        self.push(meta, job);
        Offer::Admitted {
            shed: Vec::new(),
            expired,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(tenant: u64, class: SloClass, hint: f64) -> JobMeta {
        JobMeta {
            tenant: TenantHandle(tenant),
            class,
            service_hint: hint,
            deadline: None,
            device: 0,
        }
    }

    fn meta_dl(tenant: u64, class: SloClass, hint: f64, deadline: f64) -> JobMeta {
        JobMeta {
            deadline: Some(deadline),
            ..meta(tenant, class, hint)
        }
    }

    /// Push `jobs` into a fresh discipline of `kind` and pop everything,
    /// returning the payload order.
    fn pop_order(kind: DisciplineKind, jobs: &[(JobMeta, u32)]) -> Vec<u32> {
        let mut q: SchedQueue<u32> = SchedQueue::with_kind(kind);
        for (m, v) in jobs {
            q.push(*m, *v);
        }
        let mut out = Vec::new();
        while let Some((_, v)) = q.pop() {
            out.push(v);
        }
        out
    }

    #[test]
    fn fifo_preserves_push_order() {
        let jobs: Vec<(JobMeta, u32)> = (0..8)
            .map(|i| (meta(i % 3, SloClass::Standard, 0.01 * i as f64), i as u32))
            .collect();
        assert_eq!(
            pop_order(DisciplineKind::Fifo, &jobs),
            (0..8).collect::<Vec<u32>>()
        );
    }

    #[test]
    fn priority_orders_by_class_then_fifo() {
        let jobs = vec![
            (meta(0, SloClass::Batch, 0.01), 0),
            (meta(1, SloClass::Standard, 0.01), 1),
            (meta(2, SloClass::Interactive, 0.01), 2),
            (meta(0, SloClass::Interactive, 0.01), 3),
            (meta(1, SloClass::Batch, 0.01), 4),
            (meta(2, SloClass::Standard, 0.01), 5),
        ];
        assert_eq!(
            pop_order(DisciplineKind::Priority, &jobs),
            vec![2, 3, 1, 5, 0, 4]
        );
    }

    #[test]
    fn spsf_orders_by_hint_with_fifo_ties() {
        let jobs = vec![
            (meta(0, SloClass::Standard, 0.030), 0),
            (meta(1, SloClass::Standard, 0.010), 1),
            (meta(2, SloClass::Standard, 0.020), 2),
            (meta(0, SloClass::Standard, 0.010), 3), // tie with job 1
            (meta(1, SloClass::Standard, 0.005), 4),
        ];
        assert_eq!(
            pop_order(DisciplineKind::Spsf, &jobs),
            vec![4, 1, 3, 2, 0]
        );
    }

    #[test]
    fn spsf_nan_hints_schedule_last_deterministically() {
        let jobs = vec![
            (meta(0, SloClass::Standard, f64::NAN), 0),
            (meta(1, SloClass::Standard, 0.020), 1),
            (meta(2, SloClass::Standard, f64::NAN), 2),
            (meta(0, SloClass::Standard, 0.010), 3),
        ];
        // Unknown hints sort after every estimate, FIFO among themselves.
        assert_eq!(pop_order(DisciplineKind::Spsf, &jobs), vec![3, 1, 0, 2]);
    }

    #[test]
    fn wfq_equal_weights_alternate() {
        // Two Batch tenants with uniform costs: DRR serves one job per
        // flow per round — strict alternation while both are backlogged.
        let mut jobs = Vec::new();
        for i in 0..6u32 {
            jobs.push((meta(0, SloClass::Batch, 0.01), i));
        }
        for i in 0..6u32 {
            jobs.push((meta(1, SloClass::Batch, 0.01), 10 + i));
        }
        let order = pop_order(DisciplineKind::WeightedFair, &jobs);
        assert_eq!(order.len(), 12);
        // Every window of 2 consecutive pops serves both tenants.
        for w in order.chunks(2) {
            assert_eq!(
                w.iter().filter(|v| **v < 10).count(),
                1,
                "not alternating: {order:?}"
            );
        }
    }

    #[test]
    fn wfq_starvation_bound() {
        // 100 jobs for tenant 0 vs 10 for tenant 1, equal weights and
        // costs: tenant 1's k-th job must pop within the first 2k + 2
        // pops (one job per flow per round — no starvation).
        let mut jobs = Vec::new();
        for i in 0..100u32 {
            jobs.push((meta(0, SloClass::Batch, 0.01), i));
        }
        for i in 0..10u32 {
            jobs.push((meta(1, SloClass::Batch, 0.01), 1000 + i));
        }
        let order = pop_order(DisciplineKind::WeightedFair, &jobs);
        for k in 0..10u32 {
            let pos = order.iter().position(|v| *v == 1000 + k).unwrap();
            assert!(
                pos <= 2 * k as usize + 2,
                "job {k} of the small flow popped at {pos}: {order:?}"
            );
        }
    }

    #[test]
    fn wfq_weights_shift_share() {
        // Interactive (w=4) vs Batch (w=1), uniform costs: over one round
        // the interactive tenant gets ~4x the service.
        let mut jobs = Vec::new();
        for i in 0..40u32 {
            jobs.push((meta(0, SloClass::Interactive, 0.01), i));
        }
        for i in 0..40u32 {
            jobs.push((meta(1, SloClass::Batch, 0.01), 100 + i));
        }
        let order = pop_order(DisciplineKind::WeightedFair, &jobs);
        let interactive_in_first_20 = order[..20].iter().filter(|v| **v < 100).count();
        assert!(
            (14..=18).contains(&interactive_in_first_20),
            "interactive got {interactive_in_first_20}/20 early slots: {order:?}"
        );
        // The batch tenant is not starved: it appears in every round of 5.
        for w in order[..40].chunks(5) {
            assert!(
                w.iter().any(|v| *v >= 100),
                "batch starved in window {w:?} of {order:?}"
            );
        }
    }

    #[test]
    fn drain_tenant_removes_only_that_tenant() {
        for kind in DisciplineKind::ALL {
            let mut q: SchedQueue<u32> = SchedQueue::with_kind(kind);
            for i in 0..9u32 {
                q.push(meta(i as u64 % 3, SloClass::Standard, 0.01 + i as f64 * 1e-3), i);
            }
            let gone = q.drain_tenant(TenantHandle(1));
            assert_eq!(gone.len(), 3, "{kind}");
            assert!(gone.iter().all(|(m, _)| m.tenant == TenantHandle(1)));
            assert_eq!(q.len(), 6, "{kind}");
            let mut rest = Vec::new();
            while let Some((m, v)) = q.pop() {
                assert_ne!(m.tenant, TenantHandle(1), "{kind}");
                rest.push(v);
            }
            assert_eq!(rest.len(), 6, "{kind}");
            // Draining an absent tenant is a no-op.
            assert!(q.drain_tenant(TenantHandle(1)).is_empty());
        }
    }

    #[test]
    fn peek_hint_matches_next_pop_for_ordered_disciplines() {
        for kind in [
            DisciplineKind::Fifo,
            DisciplineKind::Priority,
            DisciplineKind::Spsf,
        ] {
            let mut q: SchedQueue<u32> = SchedQueue::with_kind(kind);
            assert_eq!(q.peek_next_service_hint(), None, "{kind}");
            for i in 0..5u32 {
                let class = SloClass::from_index(i as usize % 3).unwrap();
                q.push(meta(i as u64, class, 0.01 * (5 - i) as f64), i);
            }
            while !q.is_empty() {
                let hinted = q.peek_next_service_hint().unwrap();
                let (m, _) = q.pop().unwrap();
                assert_eq!(hinted, m.service_hint, "{kind}");
            }
        }
    }

    #[test]
    fn empty_queue_behaves() {
        for kind in DisciplineKind::ALL {
            let mut q: SchedQueue<u32> = SchedQueue::with_kind(kind);
            assert!(q.is_empty());
            assert_eq!(q.len(), 0);
            assert!(q.pop().is_none());
            assert_eq!(q.kind(), kind);
        }
    }

    #[test]
    fn remove_evicts_one_job_everywhere() {
        // `remove` must behave identically across disciplines: the
        // evicted id never pops, peers keep their order, len stays
        // consistent, and removing a missing id is a no-op.
        for kind in DisciplineKind::ALL {
            let mut q: SchedQueue<u32> = SchedQueue::with_kind(kind);
            for i in 0..6u32 {
                q.push(meta(i as u64 % 2, SloClass::Standard, 0.01 + i as f64 * 1e-3), i);
            }
            // Internal ids are allocated 0..6 in push order; take id 3.
            let (m, v) = q.take(3).expect("queued id removable");
            assert_eq!(v, 3, "{kind}");
            assert_eq!(m.tenant, TenantHandle(1), "{kind}");
            assert_eq!(q.len(), 5, "{kind}");
            assert!(q.take(3).is_none(), "{kind}: double-remove");
            let mut rest = Vec::new();
            while let Some((_, v)) = q.pop() {
                rest.push(v);
            }
            assert_eq!(rest.len(), 5, "{kind}");
            assert!(!rest.contains(&3), "{kind}: evicted job popped");
        }
    }

    #[test]
    fn drain_expired_removes_hopeless_jobs_only() {
        for kind in DisciplineKind::ALL {
            let mut q: SchedQueue<u32> = SchedQueue::with_kind(kind);
            q.push(meta(0, SloClass::Standard, 0.010), 0); // no deadline
            q.push(meta_dl(1, SloClass::Standard, 0.010, 5.0), 1); // hopeless at 10
            q.push(meta_dl(2, SloClass::Standard, 0.010, 99.0), 2); // fine
            q.push(meta_dl(0, SloClass::Standard, 0.010, 10.005), 3); // misses via hint
            let gone = q.drain_expired(10.0);
            let mut ids: Vec<u32> = gone.iter().map(|(_, v)| *v).collect();
            ids.sort_unstable();
            assert_eq!(ids, vec![1, 3], "{kind}");
            assert_eq!(q.len(), 2, "{kind}");
            assert!(q.drain_expired(10.0).is_empty(), "{kind}");
        }
    }

    #[test]
    fn offer_reject_bounds_occupancy() {
        let mut q: SchedQueue<u32> = SchedQueue::with_kind(DisciplineKind::Fifo);
        let load = StationLoad {
            in_service: 1,
            servers: 1,
        };
        let mut admitted = 0;
        let mut rejected = 0;
        for i in 0..8u32 {
            match q.offer(
                meta(0, SloClass::Standard, 0.020),
                i,
                0.0,
                "tpu",
                Some(4),
                OverloadPolicy::Reject,
                load,
            ) {
                Offer::Admitted { .. } => admitted += 1,
                Offer::Rejected { reason, .. } => {
                    rejected += 1;
                    let RejectReason::Overloaded(o) = reason else {
                        panic!("expected Overloaded");
                    };
                    assert_eq!(o.capacity, 4);
                    assert_eq!(o.queue_depth, 4, "queued 3 + 1 in service");
                    // Wait estimate = queued predicted service (3 x 20 ms).
                    assert!((o.estimated_wait_s - 0.060).abs() < 1e-12);
                }
            }
            assert!(q.len() + load.in_service <= 4, "occupancy exceeded cap");
        }
        assert_eq!(admitted, 3);
        assert_eq!(rejected, 5);
        // Block ignores the capacity entirely.
        let mut q: SchedQueue<u32> = SchedQueue::with_kind(DisciplineKind::Fifo);
        for i in 0..8u32 {
            assert!(matches!(
                q.offer(
                    meta(0, SloClass::Standard, 0.01),
                    i,
                    0.0,
                    "tpu",
                    Some(2),
                    OverloadPolicy::Block,
                    load
                ),
                Offer::Admitted { .. }
            ));
        }
        assert_eq!(q.len(), 8);
    }

    #[test]
    fn offer_shed_evicts_newest_lowest_class() {
        let mut q: SchedQueue<u32> = SchedQueue::with_kind(DisciplineKind::Fifo);
        let load = StationLoad {
            in_service: 0,
            servers: 1,
        };
        let offer = |q: &mut SchedQueue<u32>, class, v| {
            q.offer(
                meta(v as u64, class, 0.01),
                v,
                0.0,
                "tpu",
                Some(3),
                OverloadPolicy::ShedLowClass,
                load,
            )
        };
        // Fill: [batch:0, standard:1, batch:2].
        for (c, v) in [
            (SloClass::Batch, 0),
            (SloClass::Standard, 1),
            (SloClass::Batch, 2),
        ] {
            assert!(matches!(offer(&mut q, c, v), Offer::Admitted { .. }));
        }
        // Interactive arrival: evicts the NEWEST batch job (2).
        match offer(&mut q, SloClass::Interactive, 3) {
            Offer::Admitted { shed, .. } => {
                assert_eq!(shed.len(), 1);
                assert_eq!(shed[0].1, 2);
                assert_eq!(shed[0].0.class, SloClass::Batch);
            }
            Offer::Rejected { .. } => panic!("interactive must shed its way in"),
        }
        // Another interactive: the remaining batch job (0) goes before
        // the standard job — lowest class first.
        match offer(&mut q, SloClass::Interactive, 4) {
            Offer::Admitted { shed, .. } => assert_eq!(shed[0].1, 0),
            Offer::Rejected { .. } => panic!("must shed the remaining batch job"),
        }
        // Batch arrival with no lower class queued: rejected.
        assert!(matches!(
            offer(&mut q, SloClass::Batch, 5),
            Offer::Rejected {
                reason: RejectReason::Overloaded(_),
                ..
            }
        ));
        assert_eq!(q.len(), 3);
    }

    #[test]
    fn offer_deadline_drop_rejects_hopeless_and_drains() {
        let mut q: SchedQueue<u32> = SchedQueue::with_kind(DisciplineKind::Fifo);
        let load = StationLoad {
            in_service: 0,
            servers: 1,
        };
        // A job whose deadline already passed is refused outright.
        match q.offer(
            meta_dl(0, SloClass::Standard, 0.010, 0.5),
            0,
            1.0,
            "tpu",
            None,
            OverloadPolicy::DeadlineDrop,
            load,
        ) {
            Offer::Rejected { reason, .. } => assert_eq!(reason, RejectReason::Expired),
            Offer::Admitted { .. } => panic!("expired job admitted"),
        }
        // Queue a job that expires later; a subsequent offer drains it.
        assert!(matches!(
            q.offer(
                meta_dl(1, SloClass::Standard, 0.010, 2.0),
                1,
                1.0,
                "tpu",
                None,
                OverloadPolicy::DeadlineDrop,
                load
            ),
            Offer::Admitted { .. }
        ));
        match q.offer(
            meta_dl(2, SloClass::Standard, 0.010, 99.0),
            2,
            5.0,
            "tpu",
            None,
            OverloadPolicy::DeadlineDrop,
            load,
        ) {
            Offer::Admitted { expired, .. } => {
                assert_eq!(expired.len(), 1);
                assert_eq!(expired[0].1, 1);
            }
            Offer::Rejected { .. } => panic!("live-deadline job refused"),
        }
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn queued_service_sum_tracks_push_pop_evict() {
        let mut q: SchedQueue<u32> = SchedQueue::with_kind(DisciplineKind::Fifo);
        assert_eq!(q.queued_service_s(), 0.0);
        q.push(meta(0, SloClass::Standard, 0.010), 0);
        q.push(meta(1, SloClass::Standard, f64::NAN), 1); // NaN counts 0
        q.push(meta(2, SloClass::Standard, 0.030), 2);
        assert!((q.queued_service_s() - 0.040).abs() < 1e-12);
        q.pop();
        assert!((q.queued_service_s() - 0.030).abs() < 1e-12);
        q.take(2);
        assert!(q.queued_service_s().abs() < 1e-12);
    }

    #[test]
    fn overload_policy_parse_round_trips() {
        for p in OverloadPolicy::ALL {
            assert_eq!(OverloadPolicy::parse(p.name()).unwrap(), p);
        }
        assert_eq!(
            OverloadPolicy::parse("deadline-drop").unwrap(),
            OverloadPolicy::DeadlineDrop
        );
        assert!(OverloadPolicy::parse("panic").is_err());
    }

    #[test]
    fn kind_parse_round_trips() {
        for kind in DisciplineKind::ALL {
            assert_eq!(DisciplineKind::parse(kind.name()).unwrap(), kind);
        }
        assert!(DisciplineKind::parse("bogus").is_err());
        for class in SloClass::ALL {
            assert_eq!(SloClass::parse(class.name()).unwrap(), class);
            assert_eq!(SloClass::from_index(class.index()).unwrap(), class);
        }
        assert!(SloClass::parse("gold").is_err());
        assert!(SloClass::from_index(3).is_none());
    }
}
