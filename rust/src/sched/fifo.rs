//! First-come-first-served — the paper's baseline discipline.

use std::collections::VecDeque;

use crate::analytic::TenantHandle;

use super::{DisciplineKind, JobMeta, QueueDiscipline};

#[derive(Default)]
pub struct Fifo {
    q: VecDeque<(u64, JobMeta)>,
}

impl Fifo {
    pub fn new() -> Fifo {
        Fifo::default()
    }
}

impl QueueDiscipline for Fifo {
    fn push(&mut self, id: u64, meta: JobMeta) {
        self.q.push_back((id, meta));
    }

    fn pop(&mut self) -> Option<u64> {
        self.q.pop_front().map(|(id, _)| id)
    }

    fn len(&self) -> usize {
        self.q.len()
    }

    fn peek_next_service_hint(&self) -> Option<f64> {
        self.q.front().map(|(_, m)| m.service_hint)
    }

    fn drain_tenant(&mut self, tenant: TenantHandle) -> Vec<u64> {
        let mut gone = Vec::new();
        self.q.retain(|(id, m)| {
            if m.tenant == tenant {
                gone.push(*id);
                false
            } else {
                true
            }
        });
        gone
    }

    fn remove(&mut self, id: u64, _meta: &JobMeta) -> bool {
        let before = self.q.len();
        self.q.retain(|(qid, _)| *qid != id);
        self.q.len() != before
    }

    fn kind(&self) -> DisciplineKind {
        DisciplineKind::Fifo
    }
}
