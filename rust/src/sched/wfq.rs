//! Weighted-fair queueing via deficit round-robin across tenants.
//!
//! Each tenant is one DRR flow. When a flow reaches the head of the
//! active ring it is credited a quantum of `weight x max_cost`, where
//! `weight` comes from the head job's [`SloClass`](super::SloClass) and
//! `max_cost` is the largest per-job cost the flow has seen (so the
//! quantum always affords at least one job — every backlogged flow is
//! served at least once per round, which bounds starvation by the number
//! of active flows). Job costs are the analytic service-time hints;
//! missing hints degrade to a uniform unit cost, i.e. plain round-robin.

use std::collections::{HashMap, VecDeque};

use crate::analytic::TenantHandle;

use super::{DisciplineKind, JobMeta, QueueDiscipline};

/// Floor on per-job cost: keeps zero/negative/NaN hints from buying
/// unbounded service within one quantum.
const MIN_COST: f64 = 1e-6;

fn cost_of(meta: &JobMeta) -> f64 {
    if meta.service_hint.is_finite() && meta.service_hint > MIN_COST {
        meta.service_hint
    } else {
        MIN_COST
    }
}

struct Flow {
    q: VecDeque<(u64, JobMeta)>,
    deficit: f64,
    /// Largest job cost seen on this flow — the quantum base.
    max_cost: f64,
}

impl Flow {
    fn new() -> Flow {
        Flow {
            q: VecDeque::new(),
            deficit: 0.0,
            max_cost: MIN_COST,
        }
    }
}

#[derive(Default)]
pub struct WeightedFair {
    /// Invariant: contains exactly the flows with a non-empty queue,
    /// and `active` lists the same tenants in round-robin order.
    flows: HashMap<TenantHandle, Flow>,
    active: VecDeque<TenantHandle>,
    /// Whether the flow at `active.front()` already received this
    /// round's quantum.
    head_credited: bool,
    len: usize,
}

impl WeightedFair {
    pub fn new() -> WeightedFair {
        WeightedFair::default()
    }
}

impl QueueDiscipline for WeightedFair {
    fn push(&mut self, id: u64, meta: JobMeta) {
        let flow = self.flows.entry(meta.tenant).or_insert_with(Flow::new);
        if flow.q.is_empty() {
            self.active.push_back(meta.tenant);
        }
        flow.max_cost = flow.max_cost.max(cost_of(&meta));
        flow.q.push_back((id, meta));
        self.len += 1;
    }

    fn pop(&mut self) -> Option<u64> {
        loop {
            let tenant = *self.active.front()?;
            let flow = self
                .flows
                .get_mut(&tenant)
                .expect("active flow present in map");
            if !self.head_credited {
                // The quantum is weight x max_cost >= any single job's
                // cost, so a freshly credited flow always serves >= 1 job.
                let weight = flow.q.front().map(|(_, m)| m.class.weight()).unwrap_or(1.0);
                flow.deficit += weight * flow.max_cost;
                self.head_credited = true;
            }
            let head_cost = flow.q.front().map(cost_from_entry).unwrap_or(MIN_COST);
            if head_cost <= flow.deficit + 1e-12 {
                flow.deficit -= head_cost;
                let (id, _) = flow.q.pop_front().expect("non-empty active flow");
                self.len -= 1;
                if flow.q.is_empty() {
                    self.flows.remove(&tenant);
                    self.active.pop_front();
                    self.head_credited = false;
                }
                return Some(id);
            }
            // Deficit exhausted: bank nothing extra, rotate to the next
            // flow (classic DRR keeps the remaining deficit).
            self.active.rotate_left(1);
            self.head_credited = false;
        }
    }

    fn len(&self) -> usize {
        self.len
    }

    fn peek_next_service_hint(&self) -> Option<f64> {
        // Best effort: the head flow's head job (pop may rotate past it
        // when its deficit is exhausted).
        self.active
            .front()
            .and_then(|t| self.flows.get(t))
            .and_then(|f| f.q.front())
            .map(|(_, m)| m.service_hint)
    }

    fn drain_tenant(&mut self, tenant: TenantHandle) -> Vec<u64> {
        let Some(flow) = self.flows.remove(&tenant) else {
            return Vec::new();
        };
        if self.active.front() == Some(&tenant) {
            self.head_credited = false;
        }
        self.active.retain(|t| *t != tenant);
        self.len -= flow.q.len();
        flow.q.into_iter().map(|(id, _)| id).collect()
    }

    fn remove(&mut self, id: u64, meta: &JobMeta) -> bool {
        let Some(flow) = self.flows.get_mut(&meta.tenant) else {
            return false;
        };
        let before = flow.q.len();
        flow.q.retain(|(qid, _)| *qid != id);
        if flow.q.len() == before {
            return false;
        }
        self.len -= 1;
        if flow.q.is_empty() {
            // Same bookkeeping as drain_tenant: an emptied flow leaves
            // the active ring, and a removed head forfeits its credit.
            self.flows.remove(&meta.tenant);
            if self.active.front() == Some(&meta.tenant) {
                self.head_credited = false;
            }
            self.active.retain(|t| *t != meta.tenant);
        }
        true
    }

    fn kind(&self) -> DisciplineKind {
        DisciplineKind::WeightedFair
    }
}

fn cost_from_entry(entry: &(u64, JobMeta)) -> f64 {
    cost_of(&entry.1)
}
