//! Strict priority by [`SloClass`](super::SloClass), FIFO within a class.
//!
//! Interactive work always preempts queued Standard work, which preempts
//! Batch. There is no aging: a saturated high class starves the lower
//! classes — that is the point of the discipline, and the scheduler
//! ablation quantifies the resulting tail-latency trade.

use std::collections::VecDeque;

use crate::analytic::TenantHandle;

use super::{DisciplineKind, JobMeta, QueueDiscipline, SloClass};

pub struct StrictPriority {
    /// One FIFO lane per class, indexed by `SloClass::priority()`.
    lanes: [VecDeque<(u64, JobMeta)>; SloClass::COUNT],
    len: usize,
}

impl Default for StrictPriority {
    fn default() -> Self {
        StrictPriority {
            lanes: std::array::from_fn(|_| VecDeque::new()),
            len: 0,
        }
    }
}

impl StrictPriority {
    pub fn new() -> StrictPriority {
        StrictPriority::default()
    }
}

impl QueueDiscipline for StrictPriority {
    fn push(&mut self, id: u64, meta: JobMeta) {
        self.lanes[meta.class.priority()].push_back((id, meta));
        self.len += 1;
    }

    fn pop(&mut self) -> Option<u64> {
        for lane in self.lanes.iter_mut() {
            if let Some((id, _)) = lane.pop_front() {
                self.len -= 1;
                return Some(id);
            }
        }
        None
    }

    fn len(&self) -> usize {
        self.len
    }

    fn peek_next_service_hint(&self) -> Option<f64> {
        self.lanes
            .iter()
            .find_map(|lane| lane.front().map(|(_, m)| m.service_hint))
    }

    fn drain_tenant(&mut self, tenant: TenantHandle) -> Vec<u64> {
        let mut gone = Vec::new();
        for lane in self.lanes.iter_mut() {
            lane.retain(|(id, m)| {
                if m.tenant == tenant {
                    gone.push(*id);
                    false
                } else {
                    true
                }
            });
        }
        self.len -= gone.len();
        gone
    }

    fn remove(&mut self, id: u64, meta: &JobMeta) -> bool {
        let lane = &mut self.lanes[meta.class.priority()];
        let before = lane.len();
        lane.retain(|(qid, _)| *qid != id);
        if lane.len() == before {
            return false;
        }
        self.len -= 1;
        true
    }

    fn kind(&self) -> DisciplineKind {
        DisciplineKind::Priority
    }
}
