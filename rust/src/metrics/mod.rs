//! Latency statistics: streaming moments, percentile histograms (overall
//! and per SLO class), time series, and the MAPE metric the paper's
//! validation sections report.

use crate::sched::SloClass;

/// Streaming mean/variance/min/max (Welford).
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Welford {
        Welford {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Second raw moment E[X^2] — what the P-K formula needs.
    pub fn second_moment(&self) -> f64 {
        if self.n == 0 {
            return f64::NAN;
        }
        // E[X^2] = Var_pop + mean^2
        self.m2 / self.n as f64 + self.mean * self.mean
    }

    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        let m2 = self.m2
            + other.m2
            + d * d * self.n as f64 * other.n as f64 / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Log-bucketed latency histogram: O(1) insert, ~2% relative error on
/// percentile reads — plenty for the figures, and allocation-free on the
/// hot path (fixed bucket array).
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    /// Buckets are geometric: bucket i covers [min_v * g^i, min_v * g^(i+1)).
    counts: Vec<u64>,
    total: u64,
    min_v: f64,
    growth: f64,
    log_growth: f64,
    stats: Welford,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        // 1 µs .. ~17 minutes at 2% resolution.
        LatencyHistogram::new(1e-6, 1.02, 1024)
    }
}

impl LatencyHistogram {
    pub fn new(min_v: f64, growth: f64, buckets: usize) -> LatencyHistogram {
        assert!(min_v > 0.0 && growth > 1.0 && buckets > 1);
        LatencyHistogram {
            counts: vec![0; buckets],
            total: 0,
            min_v,
            growth,
            log_growth: growth.ln(),
            stats: Welford::new(),
        }
    }

    pub fn record(&mut self, v: f64) {
        self.stats.add(v);
        let idx = if v <= self.min_v {
            0
        } else {
            let i = ((v / self.min_v).ln() / self.log_growth) as usize;
            i.min(self.counts.len() - 1)
        };
        self.counts[idx] += 1;
        self.total += 1;
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        self.stats.mean()
    }

    pub fn std_dev(&self) -> f64 {
        self.stats.std_dev()
    }

    pub fn max(&self) -> f64 {
        self.stats.max()
    }

    pub fn percentile(&self, p: f64) -> f64 {
        assert!((0.0..=100.0).contains(&p));
        if self.total == 0 {
            return f64::NAN;
        }
        let target = (p / 100.0 * self.total as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                // geometric midpoint of the bucket
                return self.min_v * self.growth.powf(i as f64 + 0.5);
            }
        }
        self.stats.max()
    }

    /// Merge another histogram recorded with the *same geometry*. Bucket
    /// counts only line up when `min_v` and `growth` match — merging
    /// mismatched geometries would silently corrupt every percentile, so
    /// it is rejected here (bucket count alone is not sufficient).
    pub fn merge(&mut self, other: &LatencyHistogram) {
        assert_eq!(
            self.counts.len(),
            other.counts.len(),
            "histogram bucket-count mismatch"
        );
        assert!(
            self.min_v == other.min_v && self.growth == other.growth,
            "histogram geometry mismatch: (min_v {}, growth {}) vs (min_v {}, growth {})",
            self.min_v,
            self.growth,
            other.min_v,
            other.growth
        );
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.stats.merge(&other.stats);
    }
}

/// One latency histogram per [`SloClass`] plus the request-lifecycle
/// counters of the overload-control layer — the per-class accounting the
/// scheduler reports through `ServeStats`/`SimResult`.
///
/// Counter semantics (identical in the DES and the live server):
/// * `accepted` — admitted at the entry station;
/// * `rejected` — refused at the entry station by a bounded queue
///   (`Reject`, or `ShedLowClass` with no lower-class victim);
/// * `shed` — dropped by overload control after acceptance (evicted by
///   `ShedLowClass`, or refused mid-pipeline at a full internal station);
/// * `expired` — dropped because the deadline could no longer be met
///   (on arrival or evicted from a queue under `DeadlineDrop`);
/// * `cancelled` — cancelled via the request's token before execution;
/// * `missed` — completed (counted in the histogram) but after the
///   deadline; `goodput` subtracts these from the completions.
/// * `retried` — re-executions after a transient (retryable) fault;
///   counts extra attempts, not requests, so one request retried twice
///   adds 2.
#[derive(Debug, Clone)]
pub struct PerClassLatency {
    hists: Vec<LatencyHistogram>,
    accepted: Vec<u64>,
    rejected: Vec<u64>,
    shed: Vec<u64>,
    expired: Vec<u64>,
    cancelled: Vec<u64>,
    missed: Vec<u64>,
    retried: Vec<u64>,
}

impl Default for PerClassLatency {
    fn default() -> Self {
        PerClassLatency {
            hists: (0..SloClass::COUNT)
                .map(|_| LatencyHistogram::default())
                .collect(),
            accepted: vec![0; SloClass::COUNT],
            rejected: vec![0; SloClass::COUNT],
            shed: vec![0; SloClass::COUNT],
            expired: vec![0; SloClass::COUNT],
            cancelled: vec![0; SloClass::COUNT],
            missed: vec![0; SloClass::COUNT],
            retried: vec![0; SloClass::COUNT],
        }
    }
}

impl PerClassLatency {
    pub fn new() -> PerClassLatency {
        PerClassLatency::default()
    }

    pub fn record(&mut self, class: SloClass, v: f64) {
        self.hists[class.index()].record(v);
    }

    pub fn record_accept(&mut self, class: SloClass) {
        self.accepted[class.index()] += 1;
    }

    pub fn record_reject(&mut self, class: SloClass) {
        self.rejected[class.index()] += 1;
    }

    pub fn record_shed(&mut self, class: SloClass) {
        self.shed[class.index()] += 1;
    }

    pub fn record_expired(&mut self, class: SloClass) {
        self.expired[class.index()] += 1;
    }

    pub fn record_cancelled(&mut self, class: SloClass) {
        self.cancelled[class.index()] += 1;
    }

    /// A completion delivered after its deadline. Pair with
    /// [`record`](Self::record): the sample stays in the histogram but is
    /// excluded from [`goodput`](Self::goodput).
    pub fn record_miss(&mut self, class: SloClass) {
        self.missed[class.index()] += 1;
    }

    /// One re-execution after a transient fault (the retry itself, not
    /// the request — a request retried twice records 2).
    pub fn record_retried(&mut self, class: SloClass) {
        self.retried[class.index()] += 1;
    }

    pub fn accepted(&self, class: SloClass) -> u64 {
        self.accepted[class.index()]
    }

    pub fn rejected(&self, class: SloClass) -> u64 {
        self.rejected[class.index()]
    }

    pub fn shed(&self, class: SloClass) -> u64 {
        self.shed[class.index()]
    }

    pub fn expired(&self, class: SloClass) -> u64 {
        self.expired[class.index()]
    }

    pub fn cancelled(&self, class: SloClass) -> u64 {
        self.cancelled[class.index()]
    }

    pub fn retried(&self, class: SloClass) -> u64 {
        self.retried[class.index()]
    }

    /// Completions delivered after their deadline.
    pub fn missed(&self, class: SloClass) -> u64 {
        self.missed[class.index()]
    }

    /// Requests dropped by the overload layer (everything but
    /// completions and substrate failures).
    pub fn dropped(&self, class: SloClass) -> u64 {
        let i = class.index();
        self.rejected[i] + self.shed[i] + self.expired[i] + self.cancelled[i]
    }

    /// Completions that met their deadline (or carried none).
    pub fn goodput(&self, class: SloClass) -> u64 {
        let i = class.index();
        self.hists[i].count().saturating_sub(self.missed[i])
    }

    pub fn accepted_total(&self) -> u64 {
        self.accepted.iter().sum()
    }

    pub fn rejected_total(&self) -> u64 {
        self.rejected.iter().sum()
    }

    pub fn shed_total(&self) -> u64 {
        self.shed.iter().sum()
    }

    pub fn expired_total(&self) -> u64 {
        self.expired.iter().sum()
    }

    pub fn cancelled_total(&self) -> u64 {
        self.cancelled.iter().sum()
    }

    pub fn missed_total(&self) -> u64 {
        self.missed.iter().sum()
    }

    pub fn retried_total(&self) -> u64 {
        self.retried.iter().sum()
    }

    pub fn dropped_total(&self) -> u64 {
        SloClass::ALL.iter().map(|c| self.dropped(*c)).sum()
    }

    pub fn goodput_total(&self) -> u64 {
        SloClass::ALL.iter().map(|c| self.goodput(*c)).sum()
    }

    pub fn get(&self, class: SloClass) -> &LatencyHistogram {
        &self.hists[class.index()]
    }

    pub fn total_count(&self) -> u64 {
        self.hists.iter().map(|h| h.count()).sum()
    }

    /// All classes in priority order, including empty ones.
    pub fn by_class(&self) -> impl Iterator<Item = (SloClass, &LatencyHistogram)> {
        SloClass::ALL.into_iter().zip(self.hists.iter())
    }

    /// `(class, histogram)` rows for classes that recorded >= 1 sample.
    pub fn non_empty(&self) -> Vec<(SloClass, &LatencyHistogram)> {
        self.by_class().filter(|(_, h)| h.count() > 0).collect()
    }

    pub fn merge(&mut self, other: &PerClassLatency) {
        for (a, b) in self.hists.iter_mut().zip(&other.hists) {
            a.merge(b);
        }
        for i in 0..SloClass::COUNT {
            self.accepted[i] += other.accepted[i];
            self.rejected[i] += other.rejected[i];
            self.shed[i] += other.shed[i];
            self.expired[i] += other.expired[i];
            self.cancelled[i] += other.cancelled[i];
            self.missed[i] += other.missed[i];
            self.retried[i] += other.retried[i];
        }
    }
}

/// Shared formatters for the greppable end-of-run stats lines. The CLI
/// (serve / serve --devices N) and the audit path all print outcome
/// summaries with the same `key=value` grammar; CI greps these tokens
/// (`fleet faults:`, `device N:`, `log:`), so the format lives in one
/// place instead of being hand-rolled per call site.
#[allow(clippy::too_many_arguments)]
pub fn fmt_overload_line(
    accepted: u64,
    rejected: u64,
    shed: u64,
    expired: u64,
    cancelled: u64,
    dropped: u64,
    goodput: u64,
    failed: u64,
) -> String {
    format!(
        "overload: accepted={accepted} rejected={rejected} shed={shed} \
         expired={expired} cancelled={cancelled} dropped={dropped} \
         goodput={goodput} failed={failed}"
    )
}

/// The chaos-CI anchor line — the `fleet faults:` token must stay stable.
pub fn fmt_fleet_faults_line(
    failovers: u64,
    requeued: u64,
    failed_over: u64,
    shed_tenants: u64,
) -> String {
    format!(
        "fleet faults: failovers={failovers} requeued={requeued} \
         failed_over={failed_over} shed_tenants={shed_tenants}"
    )
}

/// One per-device outcome line of a fleet run.
#[allow(clippy::too_many_arguments)]
pub fn fmt_device_line(
    device: usize,
    completed: u64,
    accepted: u64,
    rejected: u64,
    shed: u64,
    expired: u64,
    failed: u64,
    reconfigs: u64,
    migrations: u64,
) -> String {
    format!(
        "device {device}: completed={completed} accepted={accepted} \
         rejected={rejected} shed={shed} expired={expired} failed={failed} \
         reconfigs={reconfigs} migrations={migrations}"
    )
}

/// Event-log accounting for a logged run (appended vs drop-and-count).
pub fn fmt_log_line(appended: u64, dropped: u64) -> String {
    format!("log: appended={appended} dropped={dropped}")
}

/// Network-edge accounting printed when `serve --listen` shuts down.
/// `frames_in = responses_ok + responses_err` on a graceful drain —
/// the wire-path "no silent drops" invariant, pinned by CI greps.
#[allow(clippy::too_many_arguments)]
pub fn fmt_net_line(
    conns: u64,
    shed_conns: u64,
    http: u64,
    frames_in: u64,
    responses_ok: u64,
    responses_err: u64,
    malformed: u64,
) -> String {
    format!(
        "net: conns={conns} shed_conns={shed_conns} http={http} \
         frames_in={frames_in} responses_ok={responses_ok} \
         responses_err={responses_err} malformed={malformed}"
    )
}

/// The load generator's client-side summary (the `loadgen:` CI anchor).
#[allow(clippy::too_many_arguments)]
pub fn fmt_loadgen_line(
    mode: &str,
    conns: usize,
    sent: u64,
    completed: u64,
    errors: u64,
    unanswered: u64,
    rate: f64,
    mean_ms: f64,
    p99_ms: f64,
) -> String {
    format!(
        "loadgen: mode={mode} conns={conns} sent={sent} completed={completed} \
         errors={errors} unanswered={unanswered} rate={rate:.1} \
         mean_ms={mean_ms:.2} p99_ms={p99_ms:.2}"
    )
}

/// Mean absolute percentage error — the paper's model-validation metric.
pub fn mape(observed: &[f64], predicted: &[f64]) -> f64 {
    assert_eq!(observed.len(), predicted.len());
    assert!(!observed.is_empty());
    let mut total = 0.0;
    for (o, p) in observed.iter().zip(predicted) {
        assert!(*o != 0.0, "MAPE undefined for zero observation");
        total += ((o - p) / o).abs();
    }
    100.0 * total / observed.len() as f64
}

/// Fraction of predictions within ±pct% of the observation (Fig. 5 reports
/// "92.3% within ±5%").
pub fn within_pct(observed: &[f64], predicted: &[f64], pct: f64) -> f64 {
    assert_eq!(observed.len(), predicted.len());
    let hits = observed
        .iter()
        .zip(predicted)
        .filter(|(o, p)| ((*o - *p) / *o).abs() * 100.0 <= pct)
        .count();
    hits as f64 / observed.len() as f64
}

/// Windowed time series for the Fig. 8 timeline (mean latency per window).
#[derive(Debug, Clone)]
pub struct TimeSeries {
    window: f64,
    points: Vec<Welford>,
}

impl TimeSeries {
    pub fn new(window: f64) -> TimeSeries {
        assert!(window > 0.0);
        TimeSeries {
            window,
            points: Vec::new(),
        }
    }

    pub fn record(&mut self, t: f64, v: f64) {
        let idx = (t / self.window) as usize;
        while self.points.len() <= idx {
            self.points.push(Welford::new());
        }
        self.points[idx].add(v);
    }

    /// (window_center_time, mean) for each non-empty window.
    pub fn series(&self) -> Vec<(f64, f64)> {
        self.points
            .iter()
            .enumerate()
            .filter(|(_, w)| w.count() > 0)
            .map(|(i, w)| ((i as f64 + 0.5) * self.window, w.mean()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_naive() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let mut w = Welford::new();
        for x in xs {
            w.add(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((w.mean() - mean).abs() < 1e-12);
        assert!((w.variance() - var).abs() < 1e-12);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 9.0);
        let e2 = xs.iter().map(|x| x * x).sum::<f64>() / xs.len() as f64;
        assert!((w.second_moment() - e2).abs() < 1e-12);
    }

    #[test]
    fn welford_merge_equals_combined() {
        let xs: Vec<f64> = (0..50).map(|i| (i as f64).sin() + 2.0).collect();
        let mut all = Welford::new();
        let mut a = Welford::new();
        let mut b = Welford::new();
        for (i, x) in xs.iter().enumerate() {
            all.add(*x);
            if i % 2 == 0 {
                a.add(*x)
            } else {
                b.add(*x)
            }
        }
        a.merge(&b);
        assert!((a.mean() - all.mean()).abs() < 1e-12);
        assert!((a.variance() - all.variance()).abs() < 1e-12);
    }

    #[test]
    fn histogram_percentiles_approximate() {
        let mut h = LatencyHistogram::default();
        for i in 1..=10_000 {
            h.record(i as f64 * 1e-4); // 0.1ms .. 1s uniform
        }
        let p50 = h.percentile(50.0);
        assert!((p50 - 0.5).abs() / 0.5 < 0.05, "p50={p50}");
        let p95 = h.percentile(95.0);
        assert!((p95 - 0.95).abs() / 0.95 < 0.05, "p95={p95}");
        assert!(h.percentile(100.0) <= h.max() * 1.03);
    }

    #[test]
    fn histogram_merge() {
        let mut a = LatencyHistogram::default();
        let mut b = LatencyHistogram::default();
        for i in 1..=100 {
            a.record(i as f64 * 1e-3);
            b.record(i as f64 * 2e-3);
        }
        let mean_a = a.mean();
        a.merge(&b);
        assert_eq!(a.count(), 200);
        assert!(a.mean() > mean_a);
    }

    #[test]
    #[should_panic(expected = "geometry mismatch")]
    fn histogram_merge_rejects_mismatched_geometry() {
        // Same bucket count, different (min_v, growth): merging would
        // silently corrupt percentiles, so it must panic.
        let mut a = LatencyHistogram::new(1e-6, 1.02, 256);
        let b = LatencyHistogram::new(1e-3, 1.02, 256);
        a.merge(&b);
    }

    #[test]
    #[should_panic(expected = "bucket-count mismatch")]
    fn histogram_merge_rejects_mismatched_buckets() {
        let mut a = LatencyHistogram::new(1e-6, 1.02, 256);
        let b = LatencyHistogram::new(1e-6, 1.02, 128);
        a.merge(&b);
    }

    #[test]
    fn per_class_latency_records_and_merges() {
        let mut pc = PerClassLatency::new();
        pc.record(SloClass::Interactive, 0.010);
        pc.record(SloClass::Interactive, 0.020);
        pc.record(SloClass::Batch, 0.500);
        assert_eq!(pc.get(SloClass::Interactive).count(), 2);
        assert_eq!(pc.get(SloClass::Standard).count(), 0);
        assert_eq!(pc.get(SloClass::Batch).count(), 1);
        assert_eq!(pc.total_count(), 3);
        let rows = pc.non_empty();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].0, SloClass::Interactive);
        assert_eq!(rows[1].0, SloClass::Batch);

        let mut other = PerClassLatency::new();
        other.record(SloClass::Standard, 0.050);
        pc.merge(&other);
        assert_eq!(pc.total_count(), 4);
        assert_eq!(pc.get(SloClass::Standard).count(), 1);
    }

    #[test]
    fn per_class_lifecycle_counters_and_goodput() {
        let mut pc = PerClassLatency::new();
        for _ in 0..5 {
            pc.record_accept(SloClass::Interactive);
        }
        pc.record(SloClass::Interactive, 0.010);
        pc.record(SloClass::Interactive, 0.500);
        pc.record_miss(SloClass::Interactive); // the 0.5 s one was late
        pc.record_shed(SloClass::Interactive);
        pc.record_expired(SloClass::Interactive);
        pc.record_reject(SloClass::Batch);
        pc.record_cancelled(SloClass::Batch);
        pc.record_retried(SloClass::Interactive);
        pc.record_retried(SloClass::Interactive);
        assert_eq!(pc.retried(SloClass::Interactive), 2);
        assert_eq!(pc.retried_total(), 2);
        assert_eq!(pc.accepted(SloClass::Interactive), 5);
        assert_eq!(pc.goodput(SloClass::Interactive), 1);
        assert_eq!(pc.dropped(SloClass::Interactive), 2);
        assert_eq!(pc.dropped(SloClass::Batch), 2);
        assert_eq!(pc.rejected_total(), 1);
        assert_eq!(pc.shed_total(), 1);
        assert_eq!(pc.expired_total(), 1);
        assert_eq!(pc.cancelled_total(), 1);
        assert_eq!(pc.dropped_total(), 4);
        // Conservation within the interactive class: accepted =
        // completed + shed + expired (2 + 1 + 1 under 5 accepted would
        // leave 1 in flight; here everything resolved).
        let resolved = pc.get(SloClass::Interactive).count()
            + pc.shed(SloClass::Interactive)
            + pc.expired(SloClass::Interactive);
        assert_eq!(resolved, 4);

        let mut other = PerClassLatency::new();
        other.record_accept(SloClass::Interactive);
        other.record_reject(SloClass::Interactive);
        pc.merge(&other);
        assert_eq!(pc.accepted(SloClass::Interactive), 6);
        assert_eq!(pc.rejected(SloClass::Interactive), 1);
        assert_eq!(pc.goodput_total(), 1);
    }

    #[test]
    fn stats_line_formatters_keep_grep_tokens_stable() {
        assert_eq!(
            fmt_overload_line(10, 2, 3, 4, 1, 8, 9, 0),
            "overload: accepted=10 rejected=2 shed=3 expired=4 cancelled=1 \
             dropped=8 goodput=9 failed=0"
        );
        let faults = fmt_fleet_faults_line(1, 5, 37, 0);
        assert!(faults.starts_with("fleet faults: "), "{faults}");
        assert_eq!(
            faults,
            "fleet faults: failovers=1 requeued=5 failed_over=37 shed_tenants=0"
        );
        assert_eq!(
            fmt_device_line(1, 100, 120, 3, 2, 1, 0, 4, 2),
            "device 1: completed=100 accepted=120 rejected=3 shed=2 expired=1 \
             failed=0 reconfigs=4 migrations=2"
        );
        assert_eq!(fmt_log_line(1234, 0), "log: appended=1234 dropped=0");
        assert_eq!(
            fmt_net_line(3, 1, 2, 500, 480, 20, 0),
            "net: conns=3 shed_conns=1 http=2 frames_in=500 responses_ok=480 \
             responses_err=20 malformed=0"
        );
        assert_eq!(
            fmt_loadgen_line("open", 2, 100, 90, 10, 0, 45.25, 3.141, 9.5),
            "loadgen: mode=open conns=2 sent=100 completed=90 errors=10 \
             unanswered=0 rate=45.2 mean_ms=3.14 p99_ms=9.50"
        );
    }

    #[test]
    fn mape_basic() {
        let o = [100.0, 200.0];
        let p = [110.0, 180.0];
        assert!((mape(&o, &p) - 10.0).abs() < 1e-9);
        assert_eq!(within_pct(&o, &p, 10.0), 1.0);
        assert_eq!(within_pct(&o, &p, 5.0), 0.0);
    }

    #[test]
    fn timeseries_windows() {
        let mut ts = TimeSeries::new(10.0);
        ts.record(1.0, 5.0);
        ts.record(2.0, 7.0);
        ts.record(25.0, 1.0);
        let s = ts.series();
        assert_eq!(s.len(), 2);
        assert_eq!(s[0], (5.0, 6.0));
        assert_eq!(s[1], (25.0, 1.0));
    }
}
