//! Offline profiling phase (Fig. 4, left): time every model segment
//! through the real PJRT artifacts and emit `profiles.json`.
//!
//! The measured wall-clock CPU times validate the cost model's *shape*
//! (they execute the scaled-down zoo on this host, so magnitudes differ
//! from the paper-scale `CostModel` times — both are recorded).

use anyhow::Result;

use crate::model::Manifest;
use crate::runtime::Engine;
use crate::tpu::CostModel;
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct SegmentProfile {
    pub model: String,
    pub index: usize,
    /// Measured PJRT wall-clock per execution (seconds).
    pub measured_cpu_s: f64,
    /// Paper-scale modeled times (seconds).
    pub modeled_cpu_s: f64,
    pub modeled_tpu_s: f64,
    pub speedup: f64,
}

/// Profile `models` (or all) with `iters` timed runs per segment.
pub fn profile(
    manifest: &Manifest,
    cost: &CostModel,
    models: &[String],
    iters: usize,
) -> Result<Vec<SegmentProfile>> {
    let mut engine = Engine::new()?;
    let mut out = Vec::new();
    for name in models {
        let meta = manifest.get(name).map_err(anyhow::Error::msg)?;
        engine.load_model(manifest, meta)?;
        for seg in &meta.segments {
            let n_in: usize = seg.in_shape.iter().product();
            let input = vec![0.5f32; n_in];
            // warmup
            engine.execute_segment(name, seg.index, &input)?;
            let t0 = std::time::Instant::now();
            for _ in 0..iters {
                engine.execute_segment(name, seg.index, &input)?;
            }
            let measured = t0.elapsed().as_secs_f64() / iters as f64;
            out.push(SegmentProfile {
                model: name.clone(),
                index: seg.index,
                measured_cpu_s: measured,
                modeled_cpu_s: cost.cpu_segment_time(seg),
                modeled_tpu_s: cost.tpu_segment_time(meta, seg),
                speedup: cost.segment_speedup(meta, seg),
            });
        }
    }
    Ok(out)
}

pub fn to_json(profiles: &[SegmentProfile]) -> Json {
    Json::Arr(
        profiles
            .iter()
            .map(|p| {
                Json::from_pairs(vec![
                    ("model", Json::Str(p.model.clone())),
                    ("index", Json::Num(p.index as f64)),
                    ("measured_cpu_s", Json::Num(p.measured_cpu_s)),
                    ("modeled_cpu_s", Json::Num(p.modeled_cpu_s)),
                    ("modeled_tpu_s", Json::Num(p.modeled_tpu_s)),
                    ("speedup", Json::Num(p.speedup)),
                ])
            })
            .collect(),
    )
}

pub fn save(profiles: &[SegmentProfile], path: &str) -> Result<(), String> {
    crate::util::json::write_file(path, &to_json(profiles))
}
