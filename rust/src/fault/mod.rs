//! Deterministic fault model shared by the DES and the live stack.
//!
//! A [`FaultPlan`] is a seedable schedule of device-level faults — hard
//! crashes (with optional recovery), windows of transient execution
//! errors with a fixed probability, and slow-device degradation windows.
//! Both consumers replay the *same* plan:
//!
//! * the DES ([`crate::sim::SimOptions::faults`]) turns crash/recover
//!   boundaries into `DeviceDown`/`DeviceUp` events that pause the TPU
//!   station, samples transient failures at service completion, and
//!   stretches TPU service times inside slowdown windows;
//! * the live path wraps the plan in a [`FaultInjector`] (one per member
//!   `Server`, all sharing a wall-clock origin) that the TPU worker
//!   consults before popping work (a `Down` device is *unresponsive*:
//!   queued jobs stay queued so failover can requeue them) and after
//!   each execution attempt (transient sampling).
//!
//! Transient sampling is a pure function of `(seed, device, attempt
//! sequence)` — not of time — so a replayed schedule makes the same
//! keep/fail decisions regardless of wall-clock jitter. The window
//! bounds `[from, until)` gate *whether* sampling applies at a given
//! time.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Maximum execution attempts per request under injected transient
/// faults (first try + retries). Shared by the live TPU worker and the
/// DES so both replay the same retry envelope.
pub const RETRY_BUDGET: u32 = 3;
/// Backoff before the second attempt (seconds); doubles each retry and
/// is clipped against the request's absolute deadline.
pub const RETRY_BACKOFF_S: f64 = 0.001;

/// Observed health of one device, as the detection layer reports it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Health {
    /// Serving normally.
    Up,
    /// Serving, but impaired — carries the slowdown factor (>= 1) or the
    /// observed error streak pressure mapped to a factor.
    Degraded(f64),
    /// Not serving: the device is crashed/unreachable.
    Down,
}

impl Health {
    pub fn is_down(self) -> bool {
        matches!(self, Health::Down)
    }

    pub fn is_up(self) -> bool {
        matches!(self, Health::Up)
    }
}

impl std::fmt::Display for Health {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Health::Up => write!(f, "up"),
            Health::Degraded(k) => write!(f, "degraded(x{k:.1})"),
            Health::Down => write!(f, "down"),
        }
    }
}

/// One fault on one device.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// Hard crash at `at`; the device recovers at `recover` (`None` =
    /// never). While down the device is unresponsive — it neither serves
    /// nor fails requests.
    Crash { at: f64, recover: Option<f64> },
    /// Each execution attempt inside `[from, until)` fails with
    /// probability `prob` (deterministically, see [`FaultPlan::transient_fails`]).
    Transient { from: f64, until: f64, prob: f64 },
    /// TPU service takes `factor`x as long inside `[from, until)`.
    SlowDown { from: f64, until: f64, factor: f64 },
}

/// A [`FaultKind`] bound to a device index.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceFault {
    pub device: usize,
    pub kind: FaultKind,
}

/// A deterministic, seedable schedule of device faults. Times are in the
/// consumer's clock: sim seconds for the DES, seconds since the serving
/// stack started for the live path.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub seed: u64,
    faults: Vec<DeviceFault>,
}

impl FaultPlan {
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            faults: Vec::new(),
        }
    }

    /// Schedule a hard crash of `device` at `at`, recovering at
    /// `recover` (`None` = stays down for the rest of the run).
    pub fn crash(mut self, device: usize, at: f64, recover: Option<f64>) -> FaultPlan {
        if let Some(r) = recover {
            assert!(r > at, "recovery at {r} not after crash at {at}");
        }
        assert!(at >= 0.0 && at.is_finite(), "bad crash time {at}");
        self.faults.push(DeviceFault {
            device,
            kind: FaultKind::Crash { at, recover },
        });
        self
    }

    /// Schedule transient execution errors on `device`: each attempt in
    /// `[from, until)` fails with probability `prob`.
    pub fn transient(mut self, device: usize, from: f64, until: f64, prob: f64) -> FaultPlan {
        assert!((0.0..=1.0).contains(&prob), "bad probability {prob}");
        assert!(until > from, "empty transient window [{from}, {until})");
        self.faults.push(DeviceFault {
            device,
            kind: FaultKind::Transient { from, until, prob },
        });
        self
    }

    /// Schedule a slowdown of `device` by `factor` inside `[from, until)`.
    pub fn slow_down(mut self, device: usize, from: f64, until: f64, factor: f64) -> FaultPlan {
        assert!(factor >= 1.0, "slowdown factor {factor} < 1");
        assert!(until > from, "empty slowdown window [{from}, {until})");
        self.faults.push(DeviceFault {
            device,
            kind: FaultKind::SlowDown { from, until, factor },
        });
        self
    }

    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    pub fn faults(&self) -> &[DeviceFault] {
        &self.faults
    }

    /// Is `device` inside any crash window at time `t`?
    pub fn is_down(&self, device: usize, t: f64) -> bool {
        self.faults.iter().any(|f| {
            if f.device != device {
                return false;
            }
            match f.kind {
                FaultKind::Crash { at, recover } => {
                    t >= at
                        && match recover {
                            Some(r) => t < r,
                            None => true,
                        }
                }
                _ => false,
            }
        })
    }

    /// The combined slowdown factor applied to `device` at `t` (1.0 when
    /// no window is active; overlapping windows multiply).
    pub fn slow_factor(&self, device: usize, t: f64) -> f64 {
        self.faults
            .iter()
            .filter_map(|f| match f.kind {
                FaultKind::SlowDown { from, until, factor }
                    if f.device == device && t >= from && t < until =>
                {
                    Some(factor)
                }
                _ => None,
            })
            .product()
    }

    /// The plan's view of `device` at `t` (crash dominates slowdown).
    pub fn health(&self, device: usize, t: f64) -> Health {
        if self.is_down(device, t) {
            return Health::Down;
        }
        let k = self.slow_factor(device, t);
        if k > 1.0 {
            Health::Degraded(k)
        } else {
            Health::Up
        }
    }

    /// Does execution attempt number `seq` on `device` at time `t` fail
    /// transiently? Deterministic: the decision depends only on
    /// `(seed, device, seq)`; `t` gates the active window.
    pub fn transient_fails(&self, device: usize, t: f64, seq: u64) -> bool {
        let prob = self
            .faults
            .iter()
            .filter_map(|f| match f.kind {
                FaultKind::Transient { from, until, prob }
                    if f.device == device && t >= from && t < until =>
                {
                    Some(prob)
                }
                _ => None,
            })
            .fold(0.0f64, f64::max);
        if prob <= 0.0 {
            return false;
        }
        // SplitMix64 over (seed, device, seq) -> uniform in [0, 1).
        let mut z = self
            .seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add((device as u64).wrapping_mul(0xBF58_476D_1CE4_E5B9))
            .wrapping_add(seq.wrapping_mul(0x94D0_49BB_1331_11EB));
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^= z >> 31;
        (z >> 11) as f64 / (1u64 << 53) as f64 < prob
    }

    /// Time-sorted health transitions of `device`: `(time, down?)` for
    /// every crash/recover boundary — what the DES turns into
    /// `DeviceDown`/`DeviceUp` events.
    pub fn transitions(&self, device: usize) -> Vec<(f64, bool)> {
        let mut out = Vec::new();
        for f in &self.faults {
            if f.device != device {
                continue;
            }
            if let FaultKind::Crash { at, recover } = f.kind {
                out.push((at, true));
                if let Some(r) = recover {
                    out.push((r, false));
                }
            }
        }
        out.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        out
    }

    /// Highest device index any fault names (`None` for an empty plan).
    pub fn max_device(&self) -> Option<usize> {
        self.faults.iter().map(|f| f.device).max()
    }
}

/// Live-path adapter: binds a [`FaultPlan`] to one device and a shared
/// wall-clock origin, and hands out monotone attempt sequence numbers for
/// transient sampling. All member servers of a fleet share one origin so
/// the plan's timeline is consistent across devices.
#[derive(Clone)]
pub struct FaultInjector {
    plan: Arc<FaultPlan>,
    device: usize,
    origin: Instant,
    seq: Arc<AtomicU64>,
}

impl FaultInjector {
    pub fn new(plan: Arc<FaultPlan>, device: usize, origin: Instant) -> FaultInjector {
        FaultInjector {
            plan,
            device,
            origin,
            seq: Arc::new(AtomicU64::new(0)),
        }
    }

    /// Seconds since the shared origin — the plan's live clock.
    pub fn now(&self) -> f64 {
        self.origin.elapsed().as_secs_f64()
    }

    pub fn device(&self) -> usize {
        self.device
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// The plan's current view of this device.
    pub fn health(&self) -> Health {
        self.plan.health(self.device, self.now())
    }

    pub fn is_down(&self) -> bool {
        self.plan.is_down(self.device, self.now())
    }

    pub fn slow_factor(&self) -> f64 {
        self.plan.slow_factor(self.device, self.now())
    }

    /// Sample the next execution attempt: `true` = fail transiently.
    /// Consumes one sequence number per call.
    pub fn next_transient_fails(&self) -> bool {
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        self.plan.transient_fails(self.device, self.now(), seq)
    }
}

impl std::fmt::Debug for FaultInjector {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FaultInjector")
            .field("device", &self.device)
            .field("faults", &self.plan.faults.len())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crash_windows_drive_health() {
        let plan = FaultPlan::new(7)
            .crash(0, 10.0, Some(20.0))
            .crash(1, 5.0, None);
        assert_eq!(plan.health(0, 9.9), Health::Up);
        assert_eq!(plan.health(0, 10.0), Health::Down);
        assert_eq!(plan.health(0, 19.9), Health::Down);
        assert_eq!(plan.health(0, 20.0), Health::Up);
        // No recovery: down forever.
        assert!(plan.is_down(1, 5.0) && plan.is_down(1, 1e9));
        // Unmentioned devices are always up.
        assert_eq!(plan.health(2, 15.0), Health::Up);
        assert_eq!(plan.max_device(), Some(1));
    }

    #[test]
    fn transitions_are_sorted_boundaries() {
        let plan = FaultPlan::new(1)
            .crash(0, 30.0, Some(40.0))
            .crash(0, 10.0, Some(20.0));
        assert_eq!(
            plan.transitions(0),
            vec![(10.0, true), (20.0, false), (30.0, true), (40.0, false)]
        );
        assert!(plan.transitions(1).is_empty());
    }

    #[test]
    fn slowdown_factors_multiply_and_degrade_health() {
        let plan = FaultPlan::new(1)
            .slow_down(0, 0.0, 100.0, 2.0)
            .slow_down(0, 50.0, 60.0, 3.0);
        assert_eq!(plan.slow_factor(0, 10.0), 2.0);
        assert_eq!(plan.slow_factor(0, 55.0), 6.0);
        assert_eq!(plan.slow_factor(0, 100.0), 1.0);
        assert_eq!(plan.health(0, 10.0), Health::Degraded(2.0));
        assert_eq!(plan.health(1, 10.0), Health::Up);
    }

    #[test]
    fn transient_sampling_is_deterministic_and_calibrated() {
        let plan = FaultPlan::new(42).transient(0, 0.0, 100.0, 0.3);
        let a: Vec<bool> = (0..1000).map(|s| plan.transient_fails(0, 1.0, s)).collect();
        let b: Vec<bool> = (0..1000).map(|s| plan.transient_fails(0, 1.0, s)).collect();
        assert_eq!(a, b, "same (seed, device, seq) must decide identically");
        let rate = a.iter().filter(|x| **x).count() as f64 / 1000.0;
        assert!((rate - 0.3).abs() < 0.05, "empirical rate {rate}");
        // Outside the window nothing fails; other devices unaffected.
        assert!((0..100).all(|s| !plan.transient_fails(0, 100.0, s)));
        assert!((0..100).all(|s| !plan.transient_fails(1, 1.0, s)));
        // Different seeds decide differently somewhere.
        let other = FaultPlan::new(43).transient(0, 0.0, 100.0, 0.3);
        assert!((0..1000).any(|s| other.transient_fails(0, 1.0, s) != a[s as usize]));
    }

    #[test]
    fn injector_tracks_plan_on_the_shared_clock() {
        // Crash "in the past" relative to the origin: down immediately.
        let plan = Arc::new(FaultPlan::new(3).crash(1, 0.0, None));
        let origin = Instant::now();
        let up = FaultInjector::new(plan.clone(), 0, origin);
        let down = FaultInjector::new(plan, 1, origin);
        assert!(up.health().is_up());
        assert!(down.is_down());
        // Sequence numbers are monotone per injector.
        assert!(!up.next_transient_fails());
        assert!(!up.next_transient_fails());
        assert_eq!(up.seq.load(Ordering::Relaxed), 2);
    }

    #[test]
    #[should_panic(expected = "not after crash")]
    fn crash_rejects_inverted_window() {
        let _ = FaultPlan::new(0).crash(0, 10.0, Some(5.0));
    }
}
