//! Load-generator client over real sockets (`loadgen` CLI command).
//!
//! Two drive modes against a `serve --listen` edge:
//!
//! - **Open loop**: per-tenant Poisson arrivals at rates taken from a
//!   [`RateSchedule`] (split evenly across connections), submitted
//!   without waiting — offered load is independent of server speed,
//!   which is what exposes queueing and overload behavior.
//! - **Closed loop**: a fixed window of in-flight requests per
//!   connection; a new request departs only when a response lands —
//!   the throughput-probe mode (`bench_net` drives it).
//!
//! Latency is **client-observed** (send → response frame, including
//! the wire and framing), recorded in the same
//! [`LatencyHistogram`](crate::metrics::LatencyHistogram) geometry the
//! server uses so wire and in-process numbers compare directly
//! (`experiments::wire`). The summary is one greppable `loadgen:` line
//! (pinned in `metrics`): every sent request is accounted as completed,
//! typed-error, or unanswered — an unanswered request means the
//! connection died before its response, never a silent drop.

use super::proto::{
    encode_payload, write_frame, ErrorCode, FrameHeader, FrameKind, FrameReader, WireError,
};
use crate::metrics::{fmt_loadgen_line, LatencyHistogram};
use crate::sched::SloClass;
use crate::util::rng::Rng;
use crate::util::sync::lock_or_recover;
use crate::workload::RateSchedule;
use std::io::ErrorKind;
use std::net::{Shutdown, TcpStream};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// How the generator paces requests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LoadgenMode {
    Open,
    Closed,
}

impl LoadgenMode {
    pub fn name(self) -> &'static str {
        match self {
            LoadgenMode::Open => "open",
            LoadgenMode::Closed => "closed",
        }
    }

    pub fn parse(s: &str) -> Result<LoadgenMode, String> {
        match s {
            "open" => Ok(LoadgenMode::Open),
            "closed" => Ok(LoadgenMode::Closed),
            other => Err(format!("unknown --mode {other:?} (have open, closed)")),
        }
    }
}

/// One driven tenant: the wire handle plus its offered-load shape.
#[derive(Debug, Clone)]
pub struct TenantSpec {
    pub handle: u64,
    /// Open-loop offered rate over time (total across connections).
    pub schedule: RateSchedule,
    /// Explicit SLO class per request; `None` = the tenant's default.
    pub class: Option<SloClass>,
    /// Relative deadline tagged on every request; 0 = none.
    pub deadline_ms: u32,
}

#[derive(Debug, Clone)]
pub struct LoadgenOptions {
    /// Server address, e.g. `127.0.0.1:7431`.
    pub addr: String,
    pub connections: usize,
    pub duration_s: f64,
    pub mode: LoadgenMode,
    pub tenants: Vec<TenantSpec>,
    /// Closed loop: in-flight requests per connection.
    pub window: usize,
    pub seed: u64,
}

/// Aggregated client-side outcome of a run.
pub struct LoadgenReport {
    pub mode: LoadgenMode,
    pub connections: usize,
    pub sent: u64,
    pub completed: u64,
    pub errors: u64,
    /// Requests whose connection closed before a response frame — the
    /// "no silent drops" residual (0 on a healthy run).
    pub unanswered: u64,
    /// Typed-error counts indexed by [`ErrorCode`] byte.
    pub errors_by_code: [u64; 16],
    /// Per tenant (in `tenants` order): (handle, completed, errors).
    pub per_tenant: Vec<(u64, u64, u64)>,
    /// Client-observed latency of completed requests.
    pub latency: LatencyHistogram,
    pub wall_s: f64,
    /// Connections refused by accept-time shedding.
    pub shed_conns: u64,
}

impl LoadgenReport {
    /// Completed requests per wall-clock second.
    pub fn rate(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.completed as f64 / self.wall_s
        } else {
            0.0
        }
    }

    /// The greppable summary line (pinned in `metrics`).
    pub fn line(&self) -> String {
        fmt_loadgen_line(
            self.mode.name(),
            self.connections,
            self.sent,
            self.completed,
            self.errors,
            self.unanswered,
            self.rate(),
            self.latency.mean() * 1e3,
            self.latency.percentile(99.0) * 1e3,
        )
    }

    pub fn print(&self) {
        println!("{}", self.line());
        for (handle, completed, errors) in &self.per_tenant {
            println!("  tenant {handle}: completed={completed} errors={errors}");
        }
        for (code, n) in self.errors_by_code.iter().enumerate() {
            if *n > 0 {
                let name = ErrorCode::from_u8(code as u8)
                    .map(ErrorCode::name)
                    .unwrap_or("unknown");
                println!("  error {name}: {n}");
            }
        }
        if self.shed_conns > 0 {
            println!("  shed connections: {}", self.shed_conns);
        }
    }
}

/// Per-connection accumulator, merged at the end.
struct ConnOutcome {
    sent: u64,
    completed: u64,
    errors: u64,
    unanswered: u64,
    errors_by_code: [u64; 16],
    per_tenant: Vec<(u64, u64)>,
    latency: LatencyHistogram,
    shed: bool,
}

impl ConnOutcome {
    fn new(tenants: usize) -> ConnOutcome {
        ConnOutcome {
            sent: 0,
            completed: 0,
            errors: 0,
            unanswered: 0,
            errors_by_code: [0; 16],
            per_tenant: vec![(0, 0); tenants],
            latency: LatencyHistogram::default(),
            shed: false,
        }
    }
}

/// An in-flight request: (seq, tenant index, send instant).
type Outstanding = Vec<(u64, usize, Instant)>;

fn is_poll(err: &WireError) -> bool {
    matches!(
        err,
        WireError::Io(ErrorKind::WouldBlock) | WireError::Io(ErrorKind::TimedOut)
    )
}

/// Classify one response frame against the outstanding set. Returns
/// `false` when the frame is a connection-level GOAWAY (accept-time
/// shed), which aborts the connection.
fn settle(
    header: &FrameHeader,
    outstanding: &mut Outstanding,
    out: &mut ConnOutcome,
) -> bool {
    let pos = outstanding.iter().position(|(seq, _, _)| *seq == header.seq);
    let Some(pos) = pos else {
        // Unknown seq: the listener's accept-time shed frame is
        // (kind=Error, seq=0, code=Overloaded) before anything was sent.
        if header.kind == FrameKind::Error && header.seq == 0 {
            out.shed = true;
            return false;
        }
        return true;
    };
    let (_, tenant_idx, sent_at) = outstanding.swap_remove(pos);
    match header.kind {
        FrameKind::Response => {
            out.completed += 1;
            out.per_tenant[tenant_idx].0 += 1;
            out.latency.record(sent_at.elapsed().as_secs_f64());
        }
        _ => {
            out.errors += 1;
            out.per_tenant[tenant_idx].1 += 1;
            out.errors_by_code[(header.code as usize).min(15)] += 1;
        }
    }
    true
}

/// Query the server for each tenant's input length (typed handshake).
fn probe_input_lens(addr: &str, tenants: &[TenantSpec]) -> Result<Vec<usize>, String> {
    let mut stream =
        TcpStream::connect(addr).map_err(|e| format!("connect {addr}: {e}"))?;
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(100)));
    for (i, t) in tenants.iter().enumerate() {
        write_frame(&mut stream, &FrameHeader::query(t.handle, i as u64), &[])
            .map_err(|e| format!("query tenant {}: {e}", t.handle))?;
    }
    let mut lens = vec![0usize; tenants.len()];
    let mut got = 0usize;
    let mut reader = FrameReader::new();
    let deadline = Instant::now() + Duration::from_secs(10);
    while got < tenants.len() {
        match reader.next_frame(&mut stream) {
            Ok(Some((h, _))) => {
                let idx = h.seq as usize;
                if idx >= tenants.len() {
                    return Err(format!("probe: unexpected seq {}", h.seq));
                }
                match h.kind {
                    FrameKind::Info => {
                        lens[idx] = h.arg as usize;
                        got += 1;
                    }
                    FrameKind::Error => {
                        let code = ErrorCode::from_u8(h.code)
                            .map(ErrorCode::name)
                            .unwrap_or("unknown");
                        return Err(format!(
                            "tenant {} refused: {code} (is the server attached?)",
                            h.tenant
                        ));
                    }
                    _ => return Err("probe: unexpected frame kind".into()),
                }
            }
            Ok(None) => return Err("probe: server closed the connection".into()),
            Err(e) if is_poll(&e) => {
                if Instant::now() > deadline {
                    return Err("probe: timed out waiting for Info frames".into());
                }
            }
            Err(e) => return Err(format!("probe: {e}")),
        }
    }
    let _ = stream.shutdown(Shutdown::Both);
    Ok(lens)
}

/// Drive the configured load and return the merged client-side report.
pub fn run(opts: &LoadgenOptions) -> Result<LoadgenReport, String> {
    if opts.tenants.is_empty() {
        return Err("loadgen needs at least one tenant".into());
    }
    if opts.connections == 0 {
        return Err("loadgen needs at least one connection".into());
    }
    let input_lens = probe_input_lens(&opts.addr, &opts.tenants)?;
    // Pre-encoded submit payloads, one per tenant (reused across sends).
    let payloads: Arc<Vec<Vec<u8>>> = Arc::new(
        input_lens
            .iter()
            .map(|n| {
                let mut bytes = Vec::new();
                encode_payload(&vec![0.5f32; *n], &mut bytes);
                bytes
            })
            .collect(),
    );

    let t0 = Instant::now();
    let mut workers = Vec::new();
    for conn_id in 0..opts.connections {
        let opts = opts.clone();
        let payloads = payloads.clone();
        workers.push(std::thread::spawn(move || {
            let mut rng = Rng::new(opts.seed).fork(conn_id as u64 + 1);
            match opts.mode {
                LoadgenMode::Open => run_open_conn(&opts, &payloads, &mut rng),
                LoadgenMode::Closed => run_closed_conn(&opts, &payloads, conn_id),
            }
        }));
    }

    let mut report = LoadgenReport {
        mode: opts.mode,
        connections: opts.connections,
        sent: 0,
        completed: 0,
        errors: 0,
        unanswered: 0,
        errors_by_code: [0; 16],
        per_tenant: opts.tenants.iter().map(|t| (t.handle, 0, 0)).collect(),
        latency: LatencyHistogram::default(),
        wall_s: 0.0,
        shed_conns: 0,
    };
    for w in workers {
        let out = match w.join() {
            Ok(Ok(out)) => out,
            Ok(Err(e)) => return Err(e),
            Err(_) => return Err("loadgen connection thread panicked".into()),
        };
        report.sent += out.sent;
        report.completed += out.completed;
        report.errors += out.errors;
        report.unanswered += out.unanswered;
        for (a, b) in report.errors_by_code.iter_mut().zip(&out.errors_by_code) {
            *a += b;
        }
        for (agg, per) in report.per_tenant.iter_mut().zip(&out.per_tenant) {
            agg.1 += per.0;
            agg.2 += per.1;
        }
        report.latency.merge(&out.latency);
        report.shed_conns += u64::from(out.shed);
    }
    report.wall_s = t0.elapsed().as_secs_f64();
    Ok(report)
}

fn connect(addr: &str) -> Result<TcpStream, String> {
    let stream = TcpStream::connect(addr).map_err(|e| format!("connect {addr}: {e}"))?;
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(20)));
    Ok(stream)
}

/// Closed loop: keep `window` requests in flight, tenants round-robin.
fn run_closed_conn(
    opts: &LoadgenOptions,
    payloads: &[Vec<u8>],
    conn_id: usize,
) -> Result<ConnOutcome, String> {
    let mut stream = connect(&opts.addr)?;
    let mut out = ConnOutcome::new(opts.tenants.len());
    let mut outstanding: Outstanding = Vec::with_capacity(opts.window);
    let mut reader = FrameReader::new();
    let mut seq = 1u64;
    // Stagger round-robin start so connections don't sync on tenant 0.
    let mut next_tenant = conn_id % opts.tenants.len();
    let t_end = Instant::now() + Duration::from_secs_f64(opts.duration_s);
    let window = opts.window.max(1);

    let send_one = |stream: &mut TcpStream,
                        outstanding: &mut Outstanding,
                        out: &mut ConnOutcome,
                        seq: &mut u64,
                        next_tenant: &mut usize|
     -> bool {
        let i = *next_tenant;
        *next_tenant = (*next_tenant + 1) % opts.tenants.len();
        let t = &opts.tenants[i];
        let h = FrameHeader::submit(
            t.handle,
            *seq,
            t.class,
            t.deadline_ms,
            payloads[i].len() as u32,
        );
        if write_frame(stream, &h, &payloads[i]).is_err() {
            return false;
        }
        outstanding.push((*seq, i, Instant::now()));
        out.sent += 1;
        *seq += 1;
        true
    };

    let mut writable = true;
    for _ in 0..window {
        if !send_one(&mut stream, &mut outstanding, &mut out, &mut seq, &mut next_tenant) {
            writable = false;
            break;
        }
    }
    // Settle responses; refill the window while time remains.
    let drain_deadline = t_end + Duration::from_secs(30);
    while !outstanding.is_empty() {
        match reader.next_frame(&mut stream) {
            Ok(Some((h, _payload))) => {
                if !settle(&h, &mut outstanding, &mut out) {
                    break; // shed by the listener
                }
                if writable && Instant::now() < t_end {
                    writable = send_one(
                        &mut stream,
                        &mut outstanding,
                        &mut out,
                        &mut seq,
                        &mut next_tenant,
                    );
                }
            }
            Ok(None) => break, // server closed
            Err(e) if is_poll(&e) => {
                if Instant::now() > drain_deadline {
                    break;
                }
            }
            Err(_) => break,
        }
    }
    out.unanswered += outstanding.len() as u64;
    let _ = stream.shutdown(Shutdown::Both);
    Ok(out)
}

/// Open loop: Poisson arrivals per tenant at `schedule.rate_at(t) /
/// connections`, a paired receiver thread settling responses.
fn run_open_conn(
    opts: &LoadgenOptions,
    payloads: &[Vec<u8>],
    rng: &mut Rng,
) -> Result<ConnOutcome, String> {
    let stream = connect(&opts.addr)?;
    let mut write_half = stream
        .try_clone()
        .map_err(|e| format!("clone socket: {e}"))?;
    let outstanding: Arc<Mutex<Outstanding>> = Arc::new(Mutex::new(Vec::new()));
    let shared_out: Arc<Mutex<ConnOutcome>> =
        Arc::new(Mutex::new(ConnOutcome::new(opts.tenants.len())));

    // Receiver: settle response frames until EOF (the server closes
    // once our write half shuts down and its drain completes).
    let receiver = {
        let outstanding = outstanding.clone();
        let shared_out = shared_out.clone();
        let mut stream = stream;
        std::thread::spawn(move || {
            let mut reader = FrameReader::new();
            let hard_stop = Instant::now() + Duration::from_secs(600);
            loop {
                match reader.next_frame(&mut stream) {
                    Ok(Some((h, _payload))) => {
                        let mut pend = lock_or_recover(&outstanding);
                        let mut out = lock_or_recover(&shared_out);
                        if !settle(&h, &mut pend, &mut out) {
                            return;
                        }
                    }
                    Ok(None) => return,
                    Err(e) if is_poll(&e) => {
                        if Instant::now() > hard_stop {
                            return;
                        }
                    }
                    Err(_) => return,
                }
            }
        })
    };

    // Sender: merged per-tenant Poisson streams, rate split across
    // connections. Time-varying schedules are sampled at the current
    // instant (piecewise-constant thinning).
    let share = 1.0 / opts.connections as f64;
    // A zero-rate window parks the tenant for 50 ms and re-samples —
    // `Rng::exponential` requires a positive rate.
    let gap = |rng: &mut Rng, rate: f64| {
        if rate > 0.0 {
            rng.exponential(rate)
        } else {
            0.05
        }
    };
    let t0 = Instant::now();
    let mut seq = 1u64;
    let mut next_at: Vec<f64> = opts
        .tenants
        .iter()
        .map(|t| gap(rng, t.schedule.rate_at(0.0) * share))
        .collect();
    loop {
        let now = t0.elapsed().as_secs_f64();
        if now >= opts.duration_s {
            break;
        }
        let (idx, at) = next_at
            .iter()
            .copied()
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(&b.1))
            .expect("at least one tenant");
        let fire_at = at.min(opts.duration_s);
        if fire_at > now {
            std::thread::sleep(Duration::from_secs_f64((fire_at - now).min(0.05)));
            continue;
        }
        let t = &opts.tenants[idx];
        if t.schedule.rate_at(now) * share <= 0.0 {
            // Arrival sampled under an earlier rate landed in a
            // zero-rate window: thin it out.
            next_at[idx] = now + 0.05;
            continue;
        }
        let h = FrameHeader::submit(
            t.handle,
            seq,
            t.class,
            t.deadline_ms,
            payloads[idx].len() as u32,
        );
        {
            // Register before writing so the response can't race us.
            lock_or_recover(&outstanding).push((seq, idx, Instant::now()));
        }
        if write_frame(&mut write_half, &h, &payloads[idx]).is_err() {
            lock_or_recover(&outstanding).retain(|(s, _, _)| *s != seq);
            break;
        }
        lock_or_recover(&shared_out).sent += 1;
        seq += 1;
        next_at[idx] = now + gap(rng, t.schedule.rate_at(now) * share);
    }
    // Half-close: the server reads EOF, drains every accepted request,
    // responds, and closes — then the receiver sees EOF and exits.
    let _ = write_half.shutdown(Shutdown::Write);
    let _ = receiver.join();

    let mut out = std::mem::replace(
        &mut *lock_or_recover(&shared_out),
        ConnOutcome::new(opts.tenants.len()),
    );
    out.unanswered += lock_or_recover(&outstanding).len() as u64;
    Ok(out)
}
