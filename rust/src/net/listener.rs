//! Bounded thread-per-connection TCP listener in front of the servers.
//!
//! Accepted connections run a reader thread (frame parse →
//! `submit` → ticket) and a writer thread (ticket resolve → response
//! frame), so a slow backend never stops the socket from accepting
//! pipelined frames and responses flow as soon as tickets resolve.
//! Buffers are per-connection and reused: after warmup the framing
//! layer allocates nothing per request (`bench_net` pins this with the
//! counting allocator); the only per-request allocation is the input
//! tensor the backend contract requires (`Request` owns its `Vec<f32>`,
//! exactly as in-process submitters allocate).
//!
//! Overload at the edge is handled the same way the admission layer
//! handles it: a connection cap with accept-time shedding (the refused
//! client gets a typed `Overloaded` Error frame, not a hang). Shutdown
//! is graceful: readers stop consuming new frames, writers drain every
//! in-flight `Ticket` and deliver its response (or typed error) before
//! the socket closes — no stranded clients. After shutdown,
//! `frames_in == responses_ok + responses_err`.
//!
//! A connection whose first bytes are `GET ` is served as minimal
//! HTTP/1.1 instead: `GET /stats` returns the same greppable stats
//! lines the CLI prints, and `GET /metrics` the Prometheus exposition
//! (backend serving-plane series plus this edge's `swapless_net_*`
//! section), so the edge can be scraped with `curl` or a Prometheus
//! agent.

use super::proto::{
    decode_payload, encode_payload, write_frame, ErrorCode, FrameHeader, FrameKind, FrameReader,
    WireError,
};
use super::WireBackend;
use crate::coordinator::{Request, Ticket};
use crate::metrics::fmt_net_line;
use crate::telemetry::PromWriter;
use crate::util::sync::lock_or_recover;
use std::io::{BufWriter, ErrorKind, Write};
use std::net::{IpAddr, Ipv4Addr, Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Tuning knobs for [`NetListener::bind`].
#[derive(Debug, Clone)]
pub struct NetOptions {
    /// Concurrent-connection cap; further accepts are shed with a typed
    /// `Overloaded` Error frame.
    pub max_connections: usize,
    /// Read-timeout granularity at which blocked readers poll the stop
    /// flag — the upper bound on how long shutdown waits for an idle
    /// connection to notice.
    pub read_poll: Duration,
}

impl Default for NetOptions {
    fn default() -> Self {
        NetOptions {
            max_connections: 64,
            read_poll: Duration::from_millis(25),
        }
    }
}

/// Live counters shared by the accept loop and connection threads.
#[derive(Default)]
struct NetCounters {
    accepted_conns: AtomicU64,
    shed_conns: AtomicU64,
    http_requests: AtomicU64,
    /// Submit frames parsed and handed to the backend.
    frames_in: AtomicU64,
    /// Submit tickets resolved Ok.
    responses_ok: AtomicU64,
    /// Submit tickets resolved with a typed error (Error frame written).
    responses_err: AtomicU64,
    /// Frames the edge refused to parse (typed Error frame, then close).
    malformed: AtomicU64,
}

/// Snapshot of a listener's lifetime counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NetStats {
    pub accepted_conns: u64,
    pub shed_conns: u64,
    pub http_requests: u64,
    pub frames_in: u64,
    pub responses_ok: u64,
    pub responses_err: u64,
    pub malformed: u64,
}

impl NetStats {
    fn snapshot(counters: &NetCounters) -> NetStats {
        NetStats {
            accepted_conns: counters.accepted_conns.load(Ordering::SeqCst),
            shed_conns: counters.shed_conns.load(Ordering::SeqCst),
            http_requests: counters.http_requests.load(Ordering::SeqCst),
            frames_in: counters.frames_in.load(Ordering::SeqCst),
            responses_ok: counters.responses_ok.load(Ordering::SeqCst),
            responses_err: counters.responses_err.load(Ordering::SeqCst),
            malformed: counters.malformed.load(Ordering::SeqCst),
        }
    }

    /// The greppable `net:` summary line (pinned in `metrics`).
    pub fn line(&self) -> String {
        fmt_net_line(
            self.accepted_conns,
            self.shed_conns,
            self.http_requests,
            self.frames_in,
            self.responses_ok,
            self.responses_err,
            self.malformed,
        )
    }

    /// The edge's own Prometheus section, appended to the backend's
    /// exposition on `GET /metrics`.
    pub fn render_metrics(&self, w: &mut PromWriter) {
        w.header(
            "swapless_net_connections_total",
            "TCP connections by accept-time outcome.",
            "counter",
        );
        for (state, v) in [("accepted", self.accepted_conns), ("shed", self.shed_conns)] {
            w.counter("swapless_net_connections_total", &[("state", state)], v);
        }
        w.header(
            "swapless_net_http_requests_total",
            "HTTP requests served on the wire port (stats/metrics scrapes).",
            "counter",
        );
        w.counter("swapless_net_http_requests_total", &[], self.http_requests);
        w.header(
            "swapless_net_frames_total",
            "Wire frames by outcome: parsed submits, ok/error responses, refused parses.",
            "counter",
        );
        for (kind, v) in [
            ("in", self.frames_in),
            ("ok", self.responses_ok),
            ("err", self.responses_err),
            ("malformed", self.malformed),
        ] {
            w.counter("swapless_net_frames_total", &[("kind", kind)], v);
        }
    }
}

/// What the reader hands the writer, in arrival order. A `Malformed`
/// entry is always the reader's last word on a connection — the byte
/// stream can't be resynchronized, so the reader returns right after
/// sending it and the writer closes once the queue drains.
enum Pending {
    Submit { seq: u64, tenant: u64, ticket: Ticket },
    Info { seq: u64, tenant: u64, input_len: Option<u32> },
    Malformed { seq: u64 },
}

/// Handle to a running listener. Dropping it (or calling
/// [`shutdown`](NetListener::shutdown)) drains every connection.
pub struct NetListener {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
    counters: Arc<NetCounters>,
}

impl NetListener {
    /// Bind `addr` (e.g. `127.0.0.1:7431`; port 0 picks a free port —
    /// see [`local_addr`](Self::local_addr)) and start accepting.
    pub fn bind(
        backend: Arc<dyn WireBackend>,
        addr: &str,
        opts: NetOptions,
    ) -> Result<NetListener, String> {
        let listener = TcpListener::bind(addr).map_err(|e| format!("bind {addr}: {e}"))?;
        let local = listener
            .local_addr()
            .map_err(|e| format!("local_addr: {e}"))?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let counters = Arc::new(NetCounters::default());
        let active = Arc::new(AtomicUsize::new(0));

        let accept = {
            let stop = stop.clone();
            let conns = conns.clone();
            let counters = counters.clone();
            std::thread::spawn(move || loop {
                let (stream, _) = match listener.accept() {
                    Ok(pair) => pair,
                    Err(_) => {
                        if stop.load(Ordering::SeqCst) {
                            break;
                        }
                        continue;
                    }
                };
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                if active.load(Ordering::SeqCst) >= opts.max_connections {
                    counters.shed_conns.fetch_add(1, Ordering::SeqCst);
                    shed_connection(stream);
                    continue;
                }
                counters.accepted_conns.fetch_add(1, Ordering::SeqCst);
                active.fetch_add(1, Ordering::SeqCst);
                let conn = spawn_connection(
                    stream,
                    backend.clone(),
                    stop.clone(),
                    counters.clone(),
                    active.clone(),
                    opts.read_poll,
                );
                let mut held = lock_or_recover(&conns);
                held.retain(|h| !h.is_finished());
                held.push(conn);
            })
        };

        Ok(NetListener {
            addr: local,
            stop,
            accept: Some(accept),
            conns,
            counters,
        })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Point-in-time counter snapshot.
    pub fn stats(&self) -> NetStats {
        NetStats::snapshot(&self.counters)
    }

    /// Stop accepting, drain every connection (each in-flight `Ticket`
    /// resolves and its response or typed error is written), and return
    /// the final counters.
    pub fn shutdown(mut self) -> NetStats {
        self.wind_down();
        self.stats()
    }

    fn wind_down(&mut self) {
        let Some(accept) = self.accept.take() else {
            return;
        };
        self.stop.store(true, Ordering::SeqCst);
        // Unblock the accept() call: connect once to our own port.
        let wake = if self.addr.ip().is_unspecified() {
            SocketAddr::new(IpAddr::V4(Ipv4Addr::LOCALHOST), self.addr.port())
        } else {
            self.addr
        };
        let _ = TcpStream::connect_timeout(&wake, Duration::from_secs(1));
        let _ = accept.join();
        let handles: Vec<JoinHandle<()>> = std::mem::take(&mut *lock_or_recover(&self.conns));
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for NetListener {
    fn drop(&mut self) {
        self.wind_down();
    }
}

/// Refuse a connection over the cap with a typed Error frame.
fn shed_connection(mut stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    let _ = write_frame(
        &mut stream,
        &FrameHeader::error(0, 0, ErrorCode::Overloaded),
        &[],
    );
    let _ = stream.flush();
    let _ = stream.shutdown(Shutdown::Both);
}

fn spawn_connection(
    stream: TcpStream,
    backend: Arc<dyn WireBackend>,
    stop: Arc<AtomicBool>,
    counters: Arc<NetCounters>,
    active: Arc<AtomicUsize>,
    read_poll: Duration,
) -> JoinHandle<()> {
    std::thread::spawn(move || {
        let _ = stream.set_nodelay(true);
        let _ = stream.set_read_timeout(Some(read_poll));
        let writer_stream = match stream.try_clone() {
            Ok(s) => s,
            Err(_) => {
                active.fetch_sub(1, Ordering::SeqCst);
                return;
            }
        };
        let (tx, rx) = channel::<Pending>();
        let writer = {
            let counters = counters.clone();
            std::thread::spawn(move || run_writer(writer_stream, rx, counters))
        };
        run_reader(stream, backend, tx, stop, counters);
        // tx dropped above: the writer drains every pending ticket,
        // writes its frame, flushes, and exits.
        let _ = writer.join();
        active.fetch_sub(1, Ordering::SeqCst);
    })
}

/// True for transient read errors that just mean "poll again".
fn is_poll(err: &WireError) -> bool {
    matches!(
        err,
        WireError::Io(ErrorKind::WouldBlock) | WireError::Io(ErrorKind::TimedOut)
    )
}

fn run_reader(
    mut stream: TcpStream,
    backend: Arc<dyn WireBackend>,
    tx: Sender<Pending>,
    stop: Arc<AtomicBool>,
    counters: Arc<NetCounters>,
) {
    let mut reader = FrameReader::new();

    // Sniff the first bytes: a browser/curl speaks HTTP, not frames.
    loop {
        match reader.fill_at_least(&mut stream, 4) {
            Ok(0) => return, // closed before sending anything
            Ok(_) => break,
            Err(e) if is_poll(&e) => {
                if stop.load(Ordering::SeqCst) {
                    return;
                }
            }
            Err(_) => return,
        }
    }
    if reader.buffered().starts_with(b"GET ") {
        counters.http_requests.fetch_add(1, Ordering::SeqCst);
        serve_http(stream, reader, backend, stop, &counters);
        return;
    }

    loop {
        match reader.next_frame(&mut stream) {
            Ok(Some((header, payload))) => match header.kind {
                FrameKind::Submit => {
                    // The input tensor is the backend's per-request
                    // allocation contract (`Request` owns its buffer) —
                    // the framing layer itself stays allocation-free.
                    let mut input = Vec::with_capacity(payload.len() / 4);
                    // Header validation already pinned the alignment,
                    // but never panic on wire data regardless.
                    if decode_payload(payload, &mut input).is_err() {
                        counters.malformed.fetch_add(1, Ordering::SeqCst);
                        let _ = tx.send(Pending::Malformed { seq: header.seq });
                        return;
                    }
                    counters.frames_in.fetch_add(1, Ordering::SeqCst);
                    let mut req = Request::new(input);
                    if let Some(class) = header.class {
                        req = req.with_class(class);
                    }
                    if header.arg > 0 {
                        req = req.with_deadline(Duration::from_millis(u64::from(header.arg)));
                    }
                    let ticket = backend.submit(crate::analytic::TenantHandle(header.tenant), req);
                    if tx
                        .send(Pending::Submit {
                            seq: header.seq,
                            tenant: header.tenant,
                            ticket,
                        })
                        .is_err()
                    {
                        return;
                    }
                }
                FrameKind::Query => {
                    let input_len = backend
                        .input_len(crate::analytic::TenantHandle(header.tenant))
                        .map(|n| n as u32);
                    if tx
                        .send(Pending::Info {
                            seq: header.seq,
                            tenant: header.tenant,
                            input_len,
                        })
                        .is_err()
                    {
                        return;
                    }
                }
                // A client must not send server-side kinds; treat as a
                // protocol violation and close with a typed error.
                FrameKind::Response | FrameKind::Error | FrameKind::Info => {
                    counters.malformed.fetch_add(1, Ordering::SeqCst);
                    let _ = tx.send(Pending::Malformed { seq: header.seq });
                    return;
                }
            },
            Ok(None) => return, // clean EOF at a frame boundary
            Err(e) if is_poll(&e) => {
                if stop.load(Ordering::SeqCst) {
                    // Graceful drain: stop consuming new frames; the
                    // writer resolves what was already accepted.
                    return;
                }
            }
            Err(WireError::Io(_)) => return, // peer reset etc.
            Err(_) => {
                counters.malformed.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(Pending::Malformed { seq: 0 });
                return;
            }
        }
    }
}

fn run_writer(stream: TcpStream, rx: Receiver<Pending>, counters: Arc<NetCounters>) {
    let mut w = BufWriter::with_capacity(64 * 1024, stream);
    let mut payload: Vec<u8> = Vec::new();
    // The client may already be gone (reset mid-drain); tickets must
    // still be resolved so the backend's accounting closes out, but
    // further writes are pointless.
    let mut dead = false;

    let mut handle = |p: Pending, w: &mut BufWriter<TcpStream>, dead: &mut bool| {
        let outcome = match p {
            Pending::Submit {
                seq,
                tenant,
                ticket,
            } => match ticket.wait() {
                Ok(done) => {
                    counters.responses_ok.fetch_add(1, Ordering::SeqCst);
                    if *dead {
                        return;
                    }
                    encode_payload(&done.output, &mut payload);
                    let latency_us = (done.latency_s * 1e6).min(u32::MAX as f64) as u32;
                    let h = FrameHeader::response(tenant, seq, latency_us, payload.len() as u32);
                    write_frame(w, &h, &payload)
                }
                Err(e) => {
                    counters.responses_err.fetch_add(1, Ordering::SeqCst);
                    if *dead {
                        return;
                    }
                    write_frame(w, &FrameHeader::error(tenant, seq, ErrorCode::of(&e)), &[])
                }
            },
            Pending::Info {
                seq,
                tenant,
                input_len,
            } => {
                if *dead {
                    return;
                }
                match input_len {
                    Some(n) => write_frame(w, &FrameHeader::info(tenant, seq, n), &[]),
                    None => write_frame(
                        w,
                        &FrameHeader::error(tenant, seq, ErrorCode::NotAttached),
                        &[],
                    ),
                }
            }
            Pending::Malformed { seq } => {
                if *dead {
                    return;
                }
                write_frame(w, &FrameHeader::error(0, seq, ErrorCode::Malformed), &[])
            }
        };
        if outcome.is_err() {
            *dead = true;
        }
    };

    // Block for the next pending item, then drain whatever else is
    // already queued before flushing once — write coalescing under
    // pipelined load. `recv` fails only when the reader is gone AND the
    // queue is empty, so every accepted request is resolved.
    while let Ok(p) = rx.recv() {
        handle(p, &mut w, &mut dead);
        while let Ok(p) = rx.try_recv() {
            handle(p, &mut w, &mut dead);
        }
        if !dead && w.flush().is_err() {
            dead = true;
        }
    }
    let _ = w.flush();
    if let Ok(stream) = w.into_inner() {
        let _ = stream.shutdown(Shutdown::Both);
    }
}

/// Minimal HTTP/1.1: `GET /stats` returns the greppable stats lines,
/// `GET /metrics` the Prometheus exposition (backend serving-plane
/// series + the listener's own `swapless_net_*` section). Anything else
/// — including a request line with no path at all — is a well-formed
/// 404 naming both endpoints, never a dead connection thread.
fn serve_http(
    mut stream: TcpStream,
    mut reader: FrameReader,
    backend: Arc<dyn WireBackend>,
    stop: Arc<AtomicBool>,
    counters: &NetCounters,
) {
    // Read to the end of the request headers (bounded).
    loop {
        let have = reader.buffered().len();
        if reader.buffered().windows(4).any(|win| win == b"\r\n\r\n") || have > 8192 {
            break;
        }
        match reader.fill_at_least(&mut stream, have + 1) {
            Ok(n) if n == have => break, // EOF
            Ok(_) => {}
            Err(e) if is_poll(&e) => {
                if stop.load(Ordering::SeqCst) {
                    return;
                }
            }
            Err(_) => return,
        }
    }
    let head = String::from_utf8_lossy(reader.buffered()).into_owned();
    // `nth(1)` is safe on any junk ("GET\r\n\r\n" has no path token —
    // the empty default falls through to the 404 arm below).
    let path = head.split_whitespace().nth(1).unwrap_or("");
    let (status, body) = if path == "/stats" || path.starts_with("/stats?") {
        ("200 OK", backend.stats_text())
    } else if path == "/metrics" || path.starts_with("/metrics?") {
        let mut body = backend.metrics_text();
        let mut w = PromWriter::new();
        NetStats::snapshot(counters).render_metrics(&mut w);
        body.push_str(&w.finish());
        ("200 OK", body)
    } else {
        (
            "404 Not Found",
            "not found; try GET /stats or GET /metrics\n".to_string(),
        )
    };
    let response = format!(
        "HTTP/1.1 {status}\r\nContent-Type: text/plain; charset=utf-8\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    let _ = stream.write_all(response.as_bytes());
    let _ = stream.flush();
    let _ = stream.shutdown(Shutdown::Both);
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Read;

    /// HTTP-path tests never reach `submit`; the mock only renders text.
    struct MockBackend;

    impl WireBackend for MockBackend {
        fn submit(&self, _h: crate::analytic::TenantHandle, _r: Request) -> Ticket {
            unreachable!("HTTP-path tests never submit")
        }

        fn input_len(&self, _h: crate::analytic::TenantHandle) -> Option<usize> {
            None
        }

        fn stats_text(&self) -> String {
            "overload: accepted=0 rejected=0\n".to_string()
        }

        fn metrics_text(&self) -> String {
            let mut w = PromWriter::new();
            w.header("swapless_requests_total", "Requests by outcome.", "counter");
            w.counter(
                "swapless_requests_total",
                &[("device", "0"), ("outcome", "completed")],
                7,
            );
            w.finish()
        }
    }

    fn get(addr: SocketAddr, request: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(request.as_bytes()).unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn stats_endpoint_returns_200_with_body() {
        let l = NetListener::bind(Arc::new(MockBackend), "127.0.0.1:0", NetOptions::default())
            .unwrap();
        let resp = get(l.local_addr(), "GET /stats HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(resp.starts_with("HTTP/1.1 200 OK"), "{resp}");
        assert!(resp.contains("overload: accepted=0"), "{resp}");
        let st = l.shutdown();
        assert_eq!(st.http_requests, 1);
    }

    #[test]
    fn metrics_endpoint_renders_backend_plus_edge_sections() {
        let l = NetListener::bind(Arc::new(MockBackend), "127.0.0.1:0", NetOptions::default())
            .unwrap();
        let resp = get(l.local_addr(), "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(resp.starts_with("HTTP/1.1 200 OK"), "{resp}");
        // Backend serving-plane section, verbatim.
        assert!(
            resp.contains("# TYPE swapless_requests_total counter"),
            "{resp}"
        );
        assert!(
            resp.contains("swapless_requests_total{device=\"0\",outcome=\"completed\"} 7"),
            "{resp}"
        );
        // The edge appends its own live counters — this scrape's own
        // connection is already in them (counted before rendering).
        assert!(
            resp.contains("swapless_net_connections_total{state=\"accepted\"} 1"),
            "{resp}"
        );
        assert!(resp.contains("swapless_net_http_requests_total 1"), "{resp}");
        assert!(
            resp.contains("swapless_net_frames_total{kind=\"in\"} 0"),
            "{resp}"
        );
        l.shutdown();
    }

    #[test]
    fn unknown_path_404_names_both_endpoints() {
        let l = NetListener::bind(Arc::new(MockBackend), "127.0.0.1:0", NetOptions::default())
            .unwrap();
        let resp = get(l.local_addr(), "GET /nope HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(resp.starts_with("HTTP/1.1 404 Not Found"), "{resp}");
        assert!(resp.contains("/stats"), "{resp}");
        assert!(resp.contains("/metrics"), "{resp}");
        l.shutdown();
    }

    #[test]
    fn malformed_request_line_gets_404_and_listener_survives() {
        let l = NetListener::bind(Arc::new(MockBackend), "127.0.0.1:0", NetOptions::default())
            .unwrap();
        // "GET " sniffs as HTTP but carries no path token at all.
        let resp = get(l.local_addr(), "GET \r\n\r\n");
        assert!(resp.starts_with("HTTP/1.1 404 Not Found"), "{resp}");
        // The handler answered instead of dying — and the NEXT
        // connection is served normally.
        let resp = get(l.local_addr(), "GET /stats HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(resp.starts_with("HTTP/1.1 200 OK"), "{resp}");
        let st = l.shutdown();
        assert_eq!(st.http_requests, 2);
        assert_eq!(st.accepted_conns, 2);
        assert_eq!(st.malformed, 0);
    }
}
