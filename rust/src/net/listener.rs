//! Bounded thread-per-connection TCP listener in front of the servers.
//!
//! Accepted connections run a reader thread (frame parse →
//! `submit` → ticket) and a writer thread (ticket resolve → response
//! frame), so a slow backend never stops the socket from accepting
//! pipelined frames and responses flow as soon as tickets resolve.
//! Buffers are per-connection and reused: after warmup the framing
//! layer allocates nothing per request (`bench_net` pins this with the
//! counting allocator); the only per-request allocation is the input
//! tensor the backend contract requires (`Request` owns its `Vec<f32>`,
//! exactly as in-process submitters allocate).
//!
//! Overload at the edge is handled the same way the admission layer
//! handles it: a connection cap with accept-time shedding (the refused
//! client gets a typed `Overloaded` Error frame, not a hang). Shutdown
//! is graceful: readers stop consuming new frames, writers drain every
//! in-flight `Ticket` and deliver its response (or typed error) before
//! the socket closes — no stranded clients. After shutdown,
//! `frames_in == responses_ok + responses_err`.
//!
//! A connection whose first bytes are `GET ` is served as minimal
//! HTTP/1.1 instead: `GET /stats` returns the same greppable stats
//! lines the CLI prints, so the edge can be scraped with `curl`.

use super::proto::{
    decode_payload, encode_payload, write_frame, ErrorCode, FrameHeader, FrameKind, FrameReader,
    WireError,
};
use super::WireBackend;
use crate::coordinator::{Request, Ticket};
use crate::metrics::fmt_net_line;
use crate::util::sync::lock_or_recover;
use std::io::{BufWriter, ErrorKind, Write};
use std::net::{IpAddr, Ipv4Addr, Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Tuning knobs for [`NetListener::bind`].
#[derive(Debug, Clone)]
pub struct NetOptions {
    /// Concurrent-connection cap; further accepts are shed with a typed
    /// `Overloaded` Error frame.
    pub max_connections: usize,
    /// Read-timeout granularity at which blocked readers poll the stop
    /// flag — the upper bound on how long shutdown waits for an idle
    /// connection to notice.
    pub read_poll: Duration,
}

impl Default for NetOptions {
    fn default() -> Self {
        NetOptions {
            max_connections: 64,
            read_poll: Duration::from_millis(25),
        }
    }
}

/// Live counters shared by the accept loop and connection threads.
#[derive(Default)]
struct NetCounters {
    accepted_conns: AtomicU64,
    shed_conns: AtomicU64,
    http_requests: AtomicU64,
    /// Submit frames parsed and handed to the backend.
    frames_in: AtomicU64,
    /// Submit tickets resolved Ok.
    responses_ok: AtomicU64,
    /// Submit tickets resolved with a typed error (Error frame written).
    responses_err: AtomicU64,
    /// Frames the edge refused to parse (typed Error frame, then close).
    malformed: AtomicU64,
}

/// Snapshot of a listener's lifetime counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NetStats {
    pub accepted_conns: u64,
    pub shed_conns: u64,
    pub http_requests: u64,
    pub frames_in: u64,
    pub responses_ok: u64,
    pub responses_err: u64,
    pub malformed: u64,
}

impl NetStats {
    /// The greppable `net:` summary line (pinned in `metrics`).
    pub fn line(&self) -> String {
        fmt_net_line(
            self.accepted_conns,
            self.shed_conns,
            self.http_requests,
            self.frames_in,
            self.responses_ok,
            self.responses_err,
            self.malformed,
        )
    }
}

/// What the reader hands the writer, in arrival order. A `Malformed`
/// entry is always the reader's last word on a connection — the byte
/// stream can't be resynchronized, so the reader returns right after
/// sending it and the writer closes once the queue drains.
enum Pending {
    Submit { seq: u64, tenant: u64, ticket: Ticket },
    Info { seq: u64, tenant: u64, input_len: Option<u32> },
    Malformed { seq: u64 },
}

/// Handle to a running listener. Dropping it (or calling
/// [`shutdown`](NetListener::shutdown)) drains every connection.
pub struct NetListener {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
    counters: Arc<NetCounters>,
}

impl NetListener {
    /// Bind `addr` (e.g. `127.0.0.1:7431`; port 0 picks a free port —
    /// see [`local_addr`](Self::local_addr)) and start accepting.
    pub fn bind(
        backend: Arc<dyn WireBackend>,
        addr: &str,
        opts: NetOptions,
    ) -> Result<NetListener, String> {
        let listener = TcpListener::bind(addr).map_err(|e| format!("bind {addr}: {e}"))?;
        let local = listener
            .local_addr()
            .map_err(|e| format!("local_addr: {e}"))?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let counters = Arc::new(NetCounters::default());
        let active = Arc::new(AtomicUsize::new(0));

        let accept = {
            let stop = stop.clone();
            let conns = conns.clone();
            let counters = counters.clone();
            std::thread::spawn(move || loop {
                let (stream, _) = match listener.accept() {
                    Ok(pair) => pair,
                    Err(_) => {
                        if stop.load(Ordering::SeqCst) {
                            break;
                        }
                        continue;
                    }
                };
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                if active.load(Ordering::SeqCst) >= opts.max_connections {
                    counters.shed_conns.fetch_add(1, Ordering::SeqCst);
                    shed_connection(stream);
                    continue;
                }
                counters.accepted_conns.fetch_add(1, Ordering::SeqCst);
                active.fetch_add(1, Ordering::SeqCst);
                let conn = spawn_connection(
                    stream,
                    backend.clone(),
                    stop.clone(),
                    counters.clone(),
                    active.clone(),
                    opts.read_poll,
                );
                let mut held = lock_or_recover(&conns);
                held.retain(|h| !h.is_finished());
                held.push(conn);
            })
        };

        Ok(NetListener {
            addr: local,
            stop,
            accept: Some(accept),
            conns,
            counters,
        })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Point-in-time counter snapshot.
    pub fn stats(&self) -> NetStats {
        NetStats {
            accepted_conns: self.counters.accepted_conns.load(Ordering::SeqCst),
            shed_conns: self.counters.shed_conns.load(Ordering::SeqCst),
            http_requests: self.counters.http_requests.load(Ordering::SeqCst),
            frames_in: self.counters.frames_in.load(Ordering::SeqCst),
            responses_ok: self.counters.responses_ok.load(Ordering::SeqCst),
            responses_err: self.counters.responses_err.load(Ordering::SeqCst),
            malformed: self.counters.malformed.load(Ordering::SeqCst),
        }
    }

    /// Stop accepting, drain every connection (each in-flight `Ticket`
    /// resolves and its response or typed error is written), and return
    /// the final counters.
    pub fn shutdown(mut self) -> NetStats {
        self.wind_down();
        self.stats()
    }

    fn wind_down(&mut self) {
        let Some(accept) = self.accept.take() else {
            return;
        };
        self.stop.store(true, Ordering::SeqCst);
        // Unblock the accept() call: connect once to our own port.
        let wake = if self.addr.ip().is_unspecified() {
            SocketAddr::new(IpAddr::V4(Ipv4Addr::LOCALHOST), self.addr.port())
        } else {
            self.addr
        };
        let _ = TcpStream::connect_timeout(&wake, Duration::from_secs(1));
        let _ = accept.join();
        let handles: Vec<JoinHandle<()>> = std::mem::take(&mut *lock_or_recover(&self.conns));
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for NetListener {
    fn drop(&mut self) {
        self.wind_down();
    }
}

/// Refuse a connection over the cap with a typed Error frame.
fn shed_connection(mut stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    let _ = write_frame(
        &mut stream,
        &FrameHeader::error(0, 0, ErrorCode::Overloaded),
        &[],
    );
    let _ = stream.flush();
    let _ = stream.shutdown(Shutdown::Both);
}

fn spawn_connection(
    stream: TcpStream,
    backend: Arc<dyn WireBackend>,
    stop: Arc<AtomicBool>,
    counters: Arc<NetCounters>,
    active: Arc<AtomicUsize>,
    read_poll: Duration,
) -> JoinHandle<()> {
    std::thread::spawn(move || {
        let _ = stream.set_nodelay(true);
        let _ = stream.set_read_timeout(Some(read_poll));
        let writer_stream = match stream.try_clone() {
            Ok(s) => s,
            Err(_) => {
                active.fetch_sub(1, Ordering::SeqCst);
                return;
            }
        };
        let (tx, rx) = channel::<Pending>();
        let writer = {
            let counters = counters.clone();
            std::thread::spawn(move || run_writer(writer_stream, rx, counters))
        };
        run_reader(stream, backend, tx, stop, counters);
        // tx dropped above: the writer drains every pending ticket,
        // writes its frame, flushes, and exits.
        let _ = writer.join();
        active.fetch_sub(1, Ordering::SeqCst);
    })
}

/// True for transient read errors that just mean "poll again".
fn is_poll(err: &WireError) -> bool {
    matches!(
        err,
        WireError::Io(ErrorKind::WouldBlock) | WireError::Io(ErrorKind::TimedOut)
    )
}

fn run_reader(
    mut stream: TcpStream,
    backend: Arc<dyn WireBackend>,
    tx: Sender<Pending>,
    stop: Arc<AtomicBool>,
    counters: Arc<NetCounters>,
) {
    let mut reader = FrameReader::new();

    // Sniff the first bytes: a browser/curl speaks HTTP, not frames.
    loop {
        match reader.fill_at_least(&mut stream, 4) {
            Ok(0) => return, // closed before sending anything
            Ok(_) => break,
            Err(e) if is_poll(&e) => {
                if stop.load(Ordering::SeqCst) {
                    return;
                }
            }
            Err(_) => return,
        }
    }
    if reader.buffered().starts_with(b"GET ") {
        counters.http_requests.fetch_add(1, Ordering::SeqCst);
        serve_http(stream, reader, backend, stop);
        return;
    }

    loop {
        match reader.next_frame(&mut stream) {
            Ok(Some((header, payload))) => match header.kind {
                FrameKind::Submit => {
                    // The input tensor is the backend's per-request
                    // allocation contract (`Request` owns its buffer) —
                    // the framing layer itself stays allocation-free.
                    let mut input = Vec::with_capacity(payload.len() / 4);
                    // Header validation already pinned the alignment,
                    // but never panic on wire data regardless.
                    if decode_payload(payload, &mut input).is_err() {
                        counters.malformed.fetch_add(1, Ordering::SeqCst);
                        let _ = tx.send(Pending::Malformed { seq: header.seq });
                        return;
                    }
                    counters.frames_in.fetch_add(1, Ordering::SeqCst);
                    let mut req = Request::new(input);
                    if let Some(class) = header.class {
                        req = req.with_class(class);
                    }
                    if header.arg > 0 {
                        req = req.with_deadline(Duration::from_millis(u64::from(header.arg)));
                    }
                    let ticket = backend.submit(crate::analytic::TenantHandle(header.tenant), req);
                    if tx
                        .send(Pending::Submit {
                            seq: header.seq,
                            tenant: header.tenant,
                            ticket,
                        })
                        .is_err()
                    {
                        return;
                    }
                }
                FrameKind::Query => {
                    let input_len = backend
                        .input_len(crate::analytic::TenantHandle(header.tenant))
                        .map(|n| n as u32);
                    if tx
                        .send(Pending::Info {
                            seq: header.seq,
                            tenant: header.tenant,
                            input_len,
                        })
                        .is_err()
                    {
                        return;
                    }
                }
                // A client must not send server-side kinds; treat as a
                // protocol violation and close with a typed error.
                FrameKind::Response | FrameKind::Error | FrameKind::Info => {
                    counters.malformed.fetch_add(1, Ordering::SeqCst);
                    let _ = tx.send(Pending::Malformed { seq: header.seq });
                    return;
                }
            },
            Ok(None) => return, // clean EOF at a frame boundary
            Err(e) if is_poll(&e) => {
                if stop.load(Ordering::SeqCst) {
                    // Graceful drain: stop consuming new frames; the
                    // writer resolves what was already accepted.
                    return;
                }
            }
            Err(WireError::Io(_)) => return, // peer reset etc.
            Err(_) => {
                counters.malformed.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(Pending::Malformed { seq: 0 });
                return;
            }
        }
    }
}

fn run_writer(stream: TcpStream, rx: Receiver<Pending>, counters: Arc<NetCounters>) {
    let mut w = BufWriter::with_capacity(64 * 1024, stream);
    let mut payload: Vec<u8> = Vec::new();
    // The client may already be gone (reset mid-drain); tickets must
    // still be resolved so the backend's accounting closes out, but
    // further writes are pointless.
    let mut dead = false;

    let mut handle = |p: Pending, w: &mut BufWriter<TcpStream>, dead: &mut bool| {
        let outcome = match p {
            Pending::Submit {
                seq,
                tenant,
                ticket,
            } => match ticket.wait() {
                Ok(done) => {
                    counters.responses_ok.fetch_add(1, Ordering::SeqCst);
                    if *dead {
                        return;
                    }
                    encode_payload(&done.output, &mut payload);
                    let latency_us = (done.latency_s * 1e6).min(u32::MAX as f64) as u32;
                    let h = FrameHeader::response(tenant, seq, latency_us, payload.len() as u32);
                    write_frame(w, &h, &payload)
                }
                Err(e) => {
                    counters.responses_err.fetch_add(1, Ordering::SeqCst);
                    if *dead {
                        return;
                    }
                    write_frame(w, &FrameHeader::error(tenant, seq, ErrorCode::of(&e)), &[])
                }
            },
            Pending::Info {
                seq,
                tenant,
                input_len,
            } => {
                if *dead {
                    return;
                }
                match input_len {
                    Some(n) => write_frame(w, &FrameHeader::info(tenant, seq, n), &[]),
                    None => write_frame(
                        w,
                        &FrameHeader::error(tenant, seq, ErrorCode::NotAttached),
                        &[],
                    ),
                }
            }
            Pending::Malformed { seq } => {
                if *dead {
                    return;
                }
                write_frame(w, &FrameHeader::error(0, seq, ErrorCode::Malformed), &[])
            }
        };
        if outcome.is_err() {
            *dead = true;
        }
    };

    // Block for the next pending item, then drain whatever else is
    // already queued before flushing once — write coalescing under
    // pipelined load. `recv` fails only when the reader is gone AND the
    // queue is empty, so every accepted request is resolved.
    while let Ok(p) = rx.recv() {
        handle(p, &mut w, &mut dead);
        while let Ok(p) = rx.try_recv() {
            handle(p, &mut w, &mut dead);
        }
        if !dead && w.flush().is_err() {
            dead = true;
        }
    }
    let _ = w.flush();
    if let Ok(stream) = w.into_inner() {
        let _ = stream.shutdown(Shutdown::Both);
    }
}

/// Minimal HTTP/1.1: `GET /stats` returns the greppable stats lines.
fn serve_http(
    mut stream: TcpStream,
    mut reader: FrameReader,
    backend: Arc<dyn WireBackend>,
    stop: Arc<AtomicBool>,
) {
    // Read to the end of the request headers (bounded).
    loop {
        let have = reader.buffered().len();
        if reader.buffered().windows(4).any(|win| win == b"\r\n\r\n") || have > 8192 {
            break;
        }
        match reader.fill_at_least(&mut stream, have + 1) {
            Ok(n) if n == have => break, // EOF
            Ok(_) => {}
            Err(e) if is_poll(&e) => {
                if stop.load(Ordering::SeqCst) {
                    return;
                }
            }
            Err(_) => return,
        }
    }
    let head = String::from_utf8_lossy(reader.buffered()).into_owned();
    let path = head.split_whitespace().nth(1).unwrap_or("");
    let (status, body) = if path == "/stats" || path.starts_with("/stats?") {
        ("200 OK", backend.stats_text())
    } else {
        ("404 Not Found", "not found; try GET /stats\n".to_string())
    };
    let response = format!(
        "HTTP/1.1 {status}\r\nContent-Type: text/plain; charset=utf-8\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    let _ = stream.write_all(response.as_bytes());
    let _ = stream.flush();
    let _ = stream.shutdown(Shutdown::Both);
}
