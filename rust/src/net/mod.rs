//! The network serving edge — the layer that makes "serves heavy
//! traffic" literal (ROADMAP open item 3).
//!
//! ```text
//!   loadgen ──TCP──► NetListener ──submit──► Server / FleetServer
//!     │                  │ Ticket resolves            │
//!     ◄──Response/Error──┘                     event log, stats
//! ```
//!
//! - [`proto`]: the length-prefixed binary wire protocol — fixed
//!   32-byte headers, zero-allocation encode/decode in caller buffers,
//!   typed [`WireError`](proto::WireError)s on arbitrary bytes.
//! - [`listener`]: bounded thread-per-connection TCP listener
//!   (`serve --listen ADDR`) with reusable per-connection buffers,
//!   accept-time shedding, graceful drain-on-shutdown, and a minimal
//!   HTTP/1.1 `GET /stats` endpoint.
//! - [`loadgen`]: open-/closed-loop load generator over real sockets
//!   (`loadgen` CLI command) measuring client-observed latency.
//!
//! The edge fronts either server through [`WireBackend`], and every
//! socket request flows through the same `submit` path as in-process
//! traffic — identical admission, identical counters, identical event
//! log records (`tests/net_parity.rs` pins the equality).

pub mod listener;
pub mod loadgen;
pub mod proto;

pub use listener::{NetListener, NetOptions, NetStats};
pub use loadgen::{LoadgenMode, LoadgenOptions, LoadgenReport, TenantSpec};

use crate::analytic::TenantHandle;
use crate::coordinator::{Request, Server, Ticket};
use crate::fleet::FleetServer;
use crate::metrics::{fmt_device_line, fmt_fleet_faults_line, fmt_overload_line};

/// What the listener needs from a backend: fire-and-resolve submission
/// (refusals come back as typed errors on the `Ticket`, never as a
/// failed call), the input-length handshake, and a stats rendering for
/// `GET /stats`.
pub trait WireBackend: Send + Sync {
    fn submit(&self, handle: TenantHandle, request: Request) -> Ticket;
    /// Input tensor length (f32 count) the model behind `handle`
    /// expects per request; `None` when not attached.
    fn input_len(&self, handle: TenantHandle) -> Option<usize>;
    /// The greppable stats lines, one per row (for `GET /stats`).
    fn stats_text(&self) -> String;
    /// Prometheus text exposition (for `GET /metrics`). The listener
    /// appends its own `swapless_net_*` section, so backends render only
    /// the serving-plane series.
    fn metrics_text(&self) -> String;
}

impl WireBackend for Server {
    fn submit(&self, handle: TenantHandle, request: Request) -> Ticket {
        Server::submit(self, handle, request)
    }

    fn input_len(&self, handle: TenantHandle) -> Option<usize> {
        self.model_meta(handle)
            .map(|m| m.input_shape.iter().product())
    }

    fn stats_text(&self) -> String {
        let s = self.stats();
        let mut out = String::new();
        out.push_str(&fmt_overload_line(
            s.accepted,
            s.rejected,
            s.shed,
            s.expired,
            s.cancelled,
            s.dropped(),
            s.goodput(),
            s.failed,
        ));
        out.push('\n');
        for t in &s.per_tenant {
            if t.latency.count() > 0 {
                out.push_str(&format!(
                    "  {} {}: n={} mean {:.1} ms p95 {:.1} ms\n",
                    t.name,
                    t.handle,
                    t.latency.count(),
                    t.latency.mean() * 1e3,
                    t.latency.percentile(95.0) * 1e3
                ));
            }
        }
        for (class, hist) in s.per_class.non_empty() {
            out.push_str(&format!(
                "  class {}: n={} mean {:.1} ms p99 {:.1} ms\n",
                class.name(),
                hist.count(),
                hist.mean() * 1e3,
                hist.percentile(99.0) * 1e3
            ));
        }
        out
    }

    fn metrics_text(&self) -> String {
        Server::metrics_text(self)
    }
}

impl WireBackend for FleetServer {
    fn submit(&self, handle: TenantHandle, request: Request) -> Ticket {
        FleetServer::submit(self, handle, request)
    }

    fn input_len(&self, handle: TenantHandle) -> Option<usize> {
        FleetServer::input_len(self, handle)
    }

    fn stats_text(&self) -> String {
        let s = self.stats();
        let mut out = String::new();
        out.push_str(&fmt_fleet_faults_line(
            s.failovers,
            s.requeued,
            s.failed_over,
            s.shed_tenants,
        ));
        out.push('\n');
        for (d, dev) in s.per_device.iter().enumerate() {
            out.push_str(&fmt_device_line(
                d,
                dev.completed,
                dev.accepted,
                dev.rejected,
                dev.shed,
                dev.expired,
                dev.failed,
                dev.reconfigs,
                dev.migrations,
            ));
            out.push('\n');
        }
        for (class, hist) in s.per_class().non_empty() {
            out.push_str(&format!(
                "  class {}: n={} mean {:.1} ms p99 {:.1} ms\n",
                class.name(),
                hist.count(),
                hist.mean() * 1e3,
                hist.percentile(99.0) * 1e3
            ));
        }
        out
    }

    fn metrics_text(&self) -> String {
        FleetServer::metrics_text(self)
    }
}
