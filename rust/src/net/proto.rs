//! Length-prefixed binary wire protocol for the serving edge.
//!
//! Every frame is a fixed 32-byte little-endian header followed by
//! `payload_len` bytes of raw `f32` data (Submit/Response only — control
//! frames carry none):
//!
//! ```text
//!   off  size  field
//!   0    2     magic        0x53 0x57 ("SW")
//!   2    1     version      1
//!   3    1     kind         0 Submit | 1 Response | 2 Error | 3 Query | 4 Info
//!   4    1     class        SloClass index, 0xFF = tenant default
//!   5    1     code         ErrorCode (Error frames), 0 otherwise
//!   6    2     flags        reserved, must be 0
//!   8    8     tenant       TenantHandle
//!   16   8     seq          client-chosen id, echoed in the reply
//!   24   4     arg          Submit: deadline ms (0 = none)
//!                           Response: server latency µs (saturating)
//!                           Info: model input length (f32 count)
//!   28   4     payload_len  bytes of f32 payload (multiple of 4)
//! ```
//!
//! Encode/decode work entirely in caller-provided buffers — no heap
//! allocation and no panics on arbitrary bytes (`bench_net` pins the
//! zero-allocation claim with a counting allocator). Malformed input
//! returns typed [`WireError`]s; server-side refusals travel as Error
//! frames whose [`ErrorCode`] mirrors
//! [`RequestError`](crate::coordinator::RequestError), so a socket
//! client sees the same typed outcomes an in-process caller does.

use crate::coordinator::RequestError;
use crate::sched::SloClass;
use std::io::{Read, Write};

/// First two bytes of every frame.
pub const MAGIC: [u8; 2] = [0x53, 0x57];
/// Protocol version (bumped on any layout change).
pub const VERSION: u8 = 1;
/// Fixed header size in bytes.
pub const HEADER_BYTES: usize = 32;
/// Upper bound on `payload_len` — larger than any manifest input tensor,
/// small enough that a hostile length can't balloon the read buffer.
pub const MAX_PAYLOAD_BYTES: u32 = 4 << 20;

/// Frame discriminator (byte 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum FrameKind {
    /// Client → server: one inference request.
    Submit = 0,
    /// Server → client: the completed output tensor.
    Response = 1,
    /// Server → client: a typed refusal (see [`ErrorCode`]).
    Error = 2,
    /// Client → server: describe a tenant (input length handshake).
    Query = 3,
    /// Server → client: Query reply; `arg` carries the input length.
    Info = 4,
}

impl FrameKind {
    pub fn from_u8(b: u8) -> Option<FrameKind> {
        match b {
            0 => Some(FrameKind::Submit),
            1 => Some(FrameKind::Response),
            2 => Some(FrameKind::Error),
            3 => Some(FrameKind::Query),
            4 => Some(FrameKind::Info),
            _ => None,
        }
    }
}

/// Typed refusal codes carried by Error frames — the wire image of
/// [`RequestError`], plus [`Malformed`](ErrorCode::Malformed) for frames
/// the edge itself refused to parse.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum ErrorCode {
    /// The frame itself failed to parse (bad magic/kind/length…).
    Malformed = 1,
    NotAttached = 2,
    Detached = 3,
    Cancelled = 4,
    /// Deadline expired before service (`RequestError::DeadlineExceeded`).
    Expired = 5,
    Overloaded = 6,
    Shed = 7,
    Execution = 8,
    Retryable = 9,
    Shutdown = 10,
    ChannelClosed = 11,
}

impl ErrorCode {
    pub fn from_u8(b: u8) -> Option<ErrorCode> {
        match b {
            1 => Some(ErrorCode::Malformed),
            2 => Some(ErrorCode::NotAttached),
            3 => Some(ErrorCode::Detached),
            4 => Some(ErrorCode::Cancelled),
            5 => Some(ErrorCode::Expired),
            6 => Some(ErrorCode::Overloaded),
            7 => Some(ErrorCode::Shed),
            8 => Some(ErrorCode::Execution),
            9 => Some(ErrorCode::Retryable),
            10 => Some(ErrorCode::Shutdown),
            11 => Some(ErrorCode::ChannelClosed),
            _ => None,
        }
    }

    /// The wire code for a server-side refusal.
    pub fn of(err: &RequestError) -> ErrorCode {
        match err {
            RequestError::NotAttached(_) => ErrorCode::NotAttached,
            RequestError::Detached(_) => ErrorCode::Detached,
            RequestError::Cancelled => ErrorCode::Cancelled,
            RequestError::DeadlineExceeded { .. } => ErrorCode::Expired,
            RequestError::Overloaded(_) => ErrorCode::Overloaded,
            RequestError::Shed { .. } => ErrorCode::Shed,
            RequestError::Execution(_) => ErrorCode::Execution,
            RequestError::Retryable { .. } => ErrorCode::Retryable,
            RequestError::Shutdown => ErrorCode::Shutdown,
            RequestError::ChannelClosed => ErrorCode::ChannelClosed,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ErrorCode::Malformed => "malformed",
            ErrorCode::NotAttached => "not-attached",
            ErrorCode::Detached => "detached",
            ErrorCode::Cancelled => "cancelled",
            ErrorCode::Expired => "expired",
            ErrorCode::Overloaded => "overloaded",
            ErrorCode::Shed => "shed",
            ErrorCode::Execution => "execution",
            ErrorCode::Retryable => "retryable",
            ErrorCode::Shutdown => "shutdown",
            ErrorCode::ChannelClosed => "channel-closed",
        }
    }
}

/// Everything that can go wrong parsing bytes off the wire. Every
/// variant is `Copy` — carrying scalars only keeps the error path as
/// allocation-free as the happy path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireError {
    BadMagic([u8; 2]),
    BadVersion(u8),
    UnknownKind(u8),
    UnknownClass(u8),
    /// Reserved flag bits were set.
    BadFlags(u16),
    /// `payload_len` exceeds [`MAX_PAYLOAD_BYTES`].
    Oversized { len: u32, max: u32 },
    /// `payload_len` is not a multiple of 4 (raw f32 data).
    Misaligned(u32),
    /// A control frame (Error/Query/Info) declared a payload.
    StrayPayload { kind: u8, len: u32 },
    /// The peer closed mid-frame.
    Truncated { have: usize, need: usize },
    /// The transport failed (includes read timeouts, which the listener
    /// uses as its stop-flag poll).
    Io(std::io::ErrorKind),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::BadMagic(m) => write!(f, "bad magic {m:02x?}"),
            WireError::BadVersion(v) => write!(f, "unsupported version {v}"),
            WireError::UnknownKind(k) => write!(f, "unknown frame kind {k}"),
            WireError::UnknownClass(c) => write!(f, "unknown class byte {c}"),
            WireError::BadFlags(x) => write!(f, "reserved flags set: {x:#06x}"),
            WireError::Oversized { len, max } => {
                write!(f, "payload {len} bytes exceeds max {max}")
            }
            WireError::Misaligned(len) => {
                write!(f, "payload {len} bytes is not a whole number of f32s")
            }
            WireError::StrayPayload { kind, len } => {
                write!(f, "control frame kind {kind} carries {len} payload bytes")
            }
            WireError::Truncated { have, need } => {
                write!(f, "peer closed mid-frame ({have} of {need} bytes)")
            }
            WireError::Io(kind) => write!(f, "transport: {kind:?}"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> WireError {
        WireError::Io(e.kind())
    }
}

/// Decoded frame header. `Copy`, so readers can hand it around without
/// touching the heap.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FrameHeader {
    pub kind: FrameKind,
    /// Submit only: explicit SLO class, `None` = the tenant's default.
    pub class: Option<SloClass>,
    /// Error frames: the refusal code (as u8 so unknown future codes
    /// round-trip); 0 everywhere else.
    pub code: u8,
    pub tenant: u64,
    pub seq: u64,
    /// Per-kind argument — see the module docs.
    pub arg: u32,
    pub payload_len: u32,
}

impl FrameHeader {
    pub fn submit(
        tenant: u64,
        seq: u64,
        class: Option<SloClass>,
        deadline_ms: u32,
        payload_len: u32,
    ) -> FrameHeader {
        FrameHeader {
            kind: FrameKind::Submit,
            class,
            code: 0,
            tenant,
            seq,
            arg: deadline_ms,
            payload_len,
        }
    }

    pub fn response(tenant: u64, seq: u64, latency_us: u32, payload_len: u32) -> FrameHeader {
        FrameHeader {
            kind: FrameKind::Response,
            class: None,
            code: 0,
            tenant,
            seq,
            arg: latency_us,
            payload_len,
        }
    }

    pub fn error(tenant: u64, seq: u64, code: ErrorCode) -> FrameHeader {
        FrameHeader {
            kind: FrameKind::Error,
            class: None,
            code: code as u8,
            tenant,
            seq,
            arg: 0,
            payload_len: 0,
        }
    }

    pub fn query(tenant: u64, seq: u64) -> FrameHeader {
        FrameHeader {
            kind: FrameKind::Query,
            class: None,
            code: 0,
            tenant,
            seq,
            arg: 0,
            payload_len: 0,
        }
    }

    pub fn info(tenant: u64, seq: u64, input_len: u32) -> FrameHeader {
        FrameHeader {
            kind: FrameKind::Info,
            class: None,
            code: 0,
            tenant,
            seq,
            arg: input_len,
            payload_len: 0,
        }
    }

    /// Serialize into a caller-provided buffer (no allocation).
    pub fn encode(&self, buf: &mut [u8; HEADER_BYTES]) {
        buf[0] = MAGIC[0];
        buf[1] = MAGIC[1];
        buf[2] = VERSION;
        buf[3] = self.kind as u8;
        buf[4] = self.class.map(|c| c.index() as u8).unwrap_or(0xFF);
        buf[5] = self.code;
        buf[6] = 0;
        buf[7] = 0;
        buf[8..16].copy_from_slice(&self.tenant.to_le_bytes());
        buf[16..24].copy_from_slice(&self.seq.to_le_bytes());
        buf[24..28].copy_from_slice(&self.arg.to_le_bytes());
        buf[28..32].copy_from_slice(&self.payload_len.to_le_bytes());
    }

    /// Parse and validate a header from a caller-provided buffer. Never
    /// panics on arbitrary bytes; every refusal is a typed [`WireError`].
    pub fn decode(buf: &[u8; HEADER_BYTES]) -> Result<FrameHeader, WireError> {
        if buf[0] != MAGIC[0] || buf[1] != MAGIC[1] {
            return Err(WireError::BadMagic([buf[0], buf[1]]));
        }
        if buf[2] != VERSION {
            return Err(WireError::BadVersion(buf[2]));
        }
        let kind = FrameKind::from_u8(buf[3]).ok_or(WireError::UnknownKind(buf[3]))?;
        let class = match buf[4] {
            0xFF => None,
            b => Some(SloClass::from_index(b as usize).ok_or(WireError::UnknownClass(b))?),
        };
        let flags = u16::from_le_bytes([buf[6], buf[7]]);
        if flags != 0 {
            return Err(WireError::BadFlags(flags));
        }
        let tenant = u64::from_le_bytes(buf[8..16].try_into().expect("8 bytes"));
        let seq = u64::from_le_bytes(buf[16..24].try_into().expect("8 bytes"));
        let arg = u32::from_le_bytes(buf[24..28].try_into().expect("4 bytes"));
        let payload_len = u32::from_le_bytes(buf[28..32].try_into().expect("4 bytes"));
        if payload_len > MAX_PAYLOAD_BYTES {
            return Err(WireError::Oversized {
                len: payload_len,
                max: MAX_PAYLOAD_BYTES,
            });
        }
        match kind {
            FrameKind::Submit | FrameKind::Response => {
                if payload_len % 4 != 0 {
                    return Err(WireError::Misaligned(payload_len));
                }
            }
            _ => {
                if payload_len != 0 {
                    return Err(WireError::StrayPayload {
                        kind: kind as u8,
                        len: payload_len,
                    });
                }
            }
        }
        Ok(FrameHeader {
            kind,
            class,
            code: buf[5],
            tenant,
            seq,
            arg,
            payload_len,
        })
    }
}

/// Serialize an f32 tensor into a reusable byte buffer (clear + extend:
/// after the first frame at a given size, no allocation).
pub fn encode_payload(values: &[f32], out: &mut Vec<u8>) {
    out.clear();
    out.reserve(values.len() * 4);
    for v in values {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

/// Deserialize raw payload bytes into a reusable f32 buffer.
pub fn decode_payload(bytes: &[u8], out: &mut Vec<f32>) -> Result<(), WireError> {
    if bytes.len() % 4 != 0 {
        return Err(WireError::Misaligned(bytes.len() as u32));
    }
    out.clear();
    out.reserve(bytes.len() / 4);
    for chunk in bytes.chunks_exact(4) {
        out.push(f32::from_le_bytes(chunk.try_into().expect("4 bytes")));
    }
    Ok(())
}

/// Write one frame: header from a stack buffer, payload straight from
/// the caller's slice. `header.payload_len` must equal `payload.len()`.
pub fn write_frame<W: Write>(
    w: &mut W,
    header: &FrameHeader,
    payload: &[u8],
) -> Result<(), WireError> {
    debug_assert_eq!(header.payload_len as usize, payload.len());
    let mut buf = [0u8; HEADER_BYTES];
    header.encode(&mut buf);
    w.write_all(&buf)?;
    if !payload.is_empty() {
        w.write_all(payload)?;
    }
    Ok(())
}

/// Incremental frame parser over a reusable buffer: handles partial
/// reads (a frame arriving in arbitrarily small pieces) and coalesced
/// reads (many frames in one `read`) without copying payloads or — once
/// the buffer has grown to the connection's largest frame — allocating.
pub struct FrameReader {
    buf: Vec<u8>,
    /// Parse cursor: `buf[start..end]` is unconsumed wire data.
    start: usize,
    end: usize,
}

impl Default for FrameReader {
    fn default() -> Self {
        FrameReader::new()
    }
}

impl FrameReader {
    pub fn new() -> FrameReader {
        FrameReader {
            buf: vec![0u8; 16 * 1024],
            start: 0,
            end: 0,
        }
    }

    /// Unconsumed bytes (peeking, e.g. the listener's HTTP sniff).
    pub fn buffered(&self) -> &[u8] {
        &self.buf[self.start..self.end]
    }

    /// Decode the header at the cursor (requires `HEADER_BYTES` buffered).
    fn peek_header(&self) -> Result<FrameHeader, WireError> {
        let hdr: &[u8; HEADER_BYTES] = self.buf[self.start..self.start + HEADER_BYTES]
            .try_into()
            .expect("sized slice");
        FrameHeader::decode(hdr)
    }

    /// Compact consumed bytes to the front and read once into the tail.
    /// Returns the number of bytes read (0 = EOF).
    fn fill<R: Read>(&mut self, r: &mut R) -> Result<usize, WireError> {
        if self.start > 0 {
            self.buf.copy_within(self.start..self.end, 0);
            self.end -= self.start;
            self.start = 0;
        }
        if self.end == self.buf.len() {
            // Warmup-only growth: doubles until the largest frame fits.
            self.buf.resize(self.buf.len() * 2, 0);
        }
        loop {
            match r.read(&mut self.buf[self.end..]) {
                Ok(n) => {
                    self.end += n;
                    return Ok(n);
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(WireError::Io(e.kind())),
            }
        }
    }

    /// Buffer at least `n` bytes (or until EOF). Returns the buffered
    /// length; timeouts surface as `WireError::Io` with the cursor
    /// intact, so callers can poll a stop flag and retry.
    pub fn fill_at_least<R: Read>(&mut self, r: &mut R, n: usize) -> Result<usize, WireError> {
        while self.end - self.start < n {
            if self.fill(r)? == 0 {
                break;
            }
        }
        Ok(self.end - self.start)
    }

    /// Pull the next complete frame, reading as needed. `Ok(None)` is a
    /// clean EOF at a frame boundary; EOF mid-frame is
    /// [`WireError::Truncated`]. The returned payload borrows this
    /// reader's buffer — consume it before the next call.
    pub fn next_frame<R: Read>(
        &mut self,
        r: &mut R,
    ) -> Result<Option<(FrameHeader, &[u8])>, WireError> {
        let need = loop {
            if self.end - self.start >= HEADER_BYTES {
                let h = self.peek_header()?;
                let need = HEADER_BYTES + h.payload_len as usize;
                if self.end - self.start >= need {
                    break need;
                }
                if self.buf.len() < need {
                    self.buf.resize(need.next_power_of_two(), 0);
                }
            }
            if self.fill(r)? == 0 {
                let have = self.end - self.start;
                if have == 0 {
                    return Ok(None);
                }
                let need = if have >= HEADER_BYTES {
                    HEADER_BYTES + self.peek_header()?.payload_len as usize
                } else {
                    HEADER_BYTES
                };
                return Err(WireError::Truncated { have, need });
            }
        };
        let header = self.peek_header()?;
        let frame_start = self.start;
        self.start += need;
        Ok(Some((
            header,
            &self.buf[frame_start + HEADER_BYTES..frame_start + need],
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn round_trip(h: FrameHeader) -> FrameHeader {
        let mut buf = [0u8; HEADER_BYTES];
        h.encode(&mut buf);
        FrameHeader::decode(&buf).expect("round trip")
    }

    #[test]
    fn header_round_trips_every_kind() {
        let cases = [
            FrameHeader::submit(7, 99, Some(SloClass::Interactive), 250, 2048),
            FrameHeader::submit(0, 0, None, 0, 0),
            FrameHeader::response(7, 99, 1234, 2048),
            FrameHeader::error(7, 99, ErrorCode::Overloaded),
            FrameHeader::query(3, 1),
            FrameHeader::info(3, 1, 512),
        ];
        for h in cases {
            assert_eq!(round_trip(h), h);
        }
    }

    #[test]
    fn decode_rejects_malformed_headers_typed() {
        let good = FrameHeader::submit(1, 2, None, 0, 8);
        let mut buf = [0u8; HEADER_BYTES];

        good.encode(&mut buf);
        buf[0] = 0xAA;
        assert!(matches!(
            FrameHeader::decode(&buf),
            Err(WireError::BadMagic(_))
        ));

        good.encode(&mut buf);
        buf[2] = 9;
        assert_eq!(FrameHeader::decode(&buf), Err(WireError::BadVersion(9)));

        good.encode(&mut buf);
        buf[3] = 200;
        assert_eq!(FrameHeader::decode(&buf), Err(WireError::UnknownKind(200)));

        good.encode(&mut buf);
        buf[4] = 3;
        assert_eq!(FrameHeader::decode(&buf), Err(WireError::UnknownClass(3)));

        good.encode(&mut buf);
        buf[6] = 1;
        assert_eq!(FrameHeader::decode(&buf), Err(WireError::BadFlags(1)));

        good.encode(&mut buf);
        buf[28..32].copy_from_slice(&(MAX_PAYLOAD_BYTES + 1).to_le_bytes());
        assert!(matches!(
            FrameHeader::decode(&buf),
            Err(WireError::Oversized { .. })
        ));

        good.encode(&mut buf);
        buf[28..32].copy_from_slice(&3u32.to_le_bytes());
        assert_eq!(FrameHeader::decode(&buf), Err(WireError::Misaligned(3)));

        FrameHeader::query(1, 2).encode(&mut buf);
        buf[28..32].copy_from_slice(&4u32.to_le_bytes());
        assert!(matches!(
            FrameHeader::decode(&buf),
            Err(WireError::StrayPayload { .. })
        ));
    }

    #[test]
    fn decode_never_panics_on_arbitrary_bytes() {
        // Exhaustive over each byte position at a handful of values, plus
        // a seeded random sweep — decode must always return, never panic.
        let mut rng = crate::util::rng::Rng::new(9);
        for _ in 0..10_000 {
            let mut buf = [0u8; HEADER_BYTES];
            for b in buf.iter_mut() {
                *b = rng.below(256) as u8;
            }
            let _ = FrameHeader::decode(&buf);
        }
    }

    #[test]
    fn payload_round_trips() {
        let values: Vec<f32> = (0..513).map(|i| i as f32 * 0.25 - 3.0).collect();
        let mut bytes = Vec::new();
        encode_payload(&values, &mut bytes);
        assert_eq!(bytes.len(), values.len() * 4);
        let mut back = Vec::new();
        decode_payload(&bytes, &mut back).expect("aligned");
        assert_eq!(back, values);
        assert_eq!(
            decode_payload(&bytes[..7], &mut back),
            Err(WireError::Misaligned(7))
        );
    }

    #[test]
    fn frame_reader_handles_partial_and_coalesced_reads() {
        // Three frames in one stream; feed through a reader that returns
        // 3 bytes per read (partial), then all-at-once (coalesced).
        let payloads: [Vec<f32>; 3] = [
            (0..4).map(|i| i as f32).collect(),
            vec![],
            (0..100).map(|i| -(i as f32)).collect(),
        ];
        let mut stream = Vec::new();
        for (i, p) in payloads.iter().enumerate() {
            let mut bytes = Vec::new();
            encode_payload(p, &mut bytes);
            let h = FrameHeader::submit(i as u64, 10 + i as u64, None, 0, bytes.len() as u32);
            write_frame(&mut stream, &h, &bytes).unwrap();
        }

        struct Trickle<'a>(&'a [u8], usize);
        impl Read for Trickle<'_> {
            fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
                let n = 3.min(self.0.len() - self.1).min(out.len());
                out[..n].copy_from_slice(&self.0[self.1..self.1 + n]);
                self.1 += n;
                Ok(n)
            }
        }

        for trickle in [false, true] {
            let mut reader = FrameReader::new();
            let mut got = Vec::new();
            let mut scratch = Vec::new();
            if trickle {
                let mut r = Trickle(&stream, 0);
                while let Some((h, pay)) = reader.next_frame(&mut r).unwrap() {
                    decode_payload(pay, &mut scratch).unwrap();
                    got.push((h.tenant, h.seq, scratch.clone()));
                }
            } else {
                let mut r = Cursor::new(&stream);
                while let Some((h, pay)) = reader.next_frame(&mut r).unwrap() {
                    decode_payload(pay, &mut scratch).unwrap();
                    got.push((h.tenant, h.seq, scratch.clone()));
                }
            }
            assert_eq!(got.len(), 3);
            for (i, (tenant, seq, pay)) in got.iter().enumerate() {
                assert_eq!(*tenant, i as u64);
                assert_eq!(*seq, 10 + i as u64);
                assert_eq!(pay, &payloads[i]);
            }
        }
    }

    #[test]
    fn frame_reader_reports_truncation() {
        let mut stream = Vec::new();
        let mut bytes = Vec::new();
        encode_payload(&[1.0, 2.0, 3.0], &mut bytes);
        let h = FrameHeader::submit(0, 1, None, 0, bytes.len() as u32);
        write_frame(&mut stream, &h, &bytes).unwrap();

        // Cut mid-payload and mid-header.
        for cut in [HEADER_BYTES + 5, 10] {
            let mut reader = FrameReader::new();
            let mut r = Cursor::new(&stream[..cut]);
            assert!(matches!(
                reader.next_frame(&mut r),
                Err(WireError::Truncated { .. })
            ));
        }
        // A clean close at a frame boundary is Ok(None).
        let mut reader = FrameReader::new();
        let mut r = Cursor::new(&stream);
        assert!(reader.next_frame(&mut r).unwrap().is_some());
        assert!(reader.next_frame(&mut r).unwrap().is_none());
    }

    #[test]
    fn frame_reader_rejects_garbage_typed() {
        let mut reader = FrameReader::new();
        let garbage = vec![0xABu8; 200];
        let mut r = Cursor::new(&garbage);
        assert!(matches!(
            reader.next_frame(&mut r),
            Err(WireError::BadMagic(_))
        ));
    }

    #[test]
    fn error_codes_cover_every_request_error() {
        use crate::analytic::TenantHandle;
        use crate::sched::Overloaded;
        let errs = [
            RequestError::NotAttached(TenantHandle(1)),
            RequestError::Detached(TenantHandle(1)),
            RequestError::Cancelled,
            RequestError::DeadlineExceeded {
                deadline_s: 1.0,
                now_s: 2.0,
            },
            RequestError::Overloaded(Overloaded {
                station: "tpu".into(),
                queue_depth: 3,
                capacity: 2,
                estimated_wait_s: 0.1,
            }),
            RequestError::Shed {
                station: "tpu".into(),
            },
            RequestError::Execution("x".into()),
            RequestError::Retryable {
                reason: "y".into(),
                attempts: 2,
            },
            RequestError::Shutdown,
            RequestError::ChannelClosed,
        ];
        let mut seen = std::collections::BTreeSet::new();
        for e in &errs {
            let code = ErrorCode::of(e);
            assert_eq!(ErrorCode::from_u8(code as u8), Some(code));
            seen.insert(code as u8);
        }
        assert_eq!(seen.len(), errs.len(), "codes must be distinct");
    }
}
