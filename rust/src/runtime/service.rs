//! Thread-service facade over [`Engine`]: the PJRT client is not `Send`,
//! so a dedicated executor thread owns it and serves execute requests over
//! an mpsc channel. Handles (`ExecHandle`) are cheap to clone and are used
//! by the coordinator's TPU worker and CPU pool threads.

use std::sync::mpsc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::model::Manifest;

use super::Engine;

enum Request {
    Execute {
        model: String,
        a: usize,
        b: usize,
        input: Vec<f32>,
        reply: mpsc::Sender<Result<Vec<f32>>>,
    },
    Shutdown,
}

/// Cloneable submit handle to the executor thread.
#[derive(Clone)]
pub struct ExecHandle {
    tx: mpsc::Sender<Request>,
}

impl ExecHandle {
    /// Execute segments `[a, b)` of `model`, blocking for the result.
    pub fn execute_range(&self, model: &str, a: usize, b: usize, input: Vec<f32>) -> Result<Vec<f32>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Execute {
                model: model.to_string(),
                a,
                b,
                input,
                reply,
            })
            .map_err(|_| anyhow!("executor thread gone"))?;
        rx.recv().map_err(|_| anyhow!("executor dropped reply"))?
    }
}

/// Owns the executor thread; dropping shuts it down.
pub struct ExecService {
    tx: mpsc::Sender<Request>,
    join: Option<JoinHandle<()>>,
}

impl ExecService {
    /// Spawn the executor thread and load `models` (all segments) from the
    /// manifest. Blocks until loading finishes so callers see load errors.
    pub fn start(manifest: &Manifest, models: &[String]) -> Result<ExecService> {
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let manifest = manifest.clone();
        let names: Vec<String> = models.to_vec();
        let join = std::thread::Builder::new()
            .name("pjrt-exec".into())
            .spawn(move || {
                let mut engine = match Engine::new() {
                    Ok(e) => e,
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                for name in &names {
                    let res = manifest
                        .get(name)
                        .map_err(|e| anyhow!(e))
                        .and_then(|m| engine.load_model(&manifest, m));
                    if let Err(e) = res {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                }
                let _ = ready_tx.send(Ok(()));
                while let Ok(req) = rx.recv() {
                    match req {
                        Request::Execute {
                            model,
                            a,
                            b,
                            input,
                            reply,
                        } => {
                            let out = engine.execute_range(&model, a, b, &input);
                            let _ = reply.send(out);
                        }
                        Request::Shutdown => break,
                    }
                }
            })?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("executor thread died during load"))??;
        Ok(ExecService {
            tx,
            join: Some(join),
        })
    }

    pub fn handle(&self) -> ExecHandle {
        ExecHandle {
            tx: self.tx.clone(),
        }
    }
}

impl Drop for ExecService {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}
