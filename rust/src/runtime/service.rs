//! Thread-service facade over the execution substrate: the PJRT client is
//! not `Send`, so a dedicated executor thread owns it and serves execute
//! requests over an mpsc channel. Handles (`ExecHandle`) are cheap to
//! clone and are used by the coordinator's TPU worker and CPU pool
//! threads.
//!
//! The substrate is selectable ([`ExecBackend`]): real PJRT execution of
//! the AOT artifacts, or a deterministic *emulated* engine computed from
//! manifest metadata alone — shape-faithful and composition-consistent
//! (running segments `[0,p)` then `[p,P)` equals `[0,P)`), so the full
//! serving stack (tenant lifecycle, CPU pools, reconfiguration) runs in
//! environments with no XLA distribution or artifacts (tests, CI).
//!
//! Models are loaded *dynamically*: the service starts empty and
//! [`ExecService::load`] compiles/registers one model at a time — this is
//! what lets the coordinator attach tenants at runtime.

use std::collections::HashMap;
use std::sync::mpsc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::model::{Manifest, ModelMeta};

use super::Engine;

/// Which execution substrate serves `execute_range` requests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecBackend {
    /// Real PJRT execution over the AOT artifacts.
    Pjrt,
    /// Deterministic emulation from manifest metadata (no artifacts).
    Emulated,
    /// Try PJRT; fall back to `Emulated` with a one-line notice.
    Auto,
}

enum Request {
    Execute {
        model: String,
        a: usize,
        b: usize,
        input: Vec<f32>,
        reply: mpsc::Sender<Result<Vec<f32>>>,
    },
    Load {
        model: String,
        reply: mpsc::Sender<Result<()>>,
    },
    Shutdown,
}

/// Cloneable submit handle to the executor thread.
#[derive(Clone)]
pub struct ExecHandle {
    tx: mpsc::Sender<Request>,
}

impl ExecHandle {
    /// Execute segments `[a, b)` of `model`, blocking for the result.
    pub fn execute_range(&self, model: &str, a: usize, b: usize, input: Vec<f32>) -> Result<Vec<f32>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Execute {
                model: model.to_string(),
                a,
                b,
                input,
                reply,
            })
            .map_err(|_| anyhow!("executor thread gone"))?;
        rx.recv().map_err(|_| anyhow!("executor dropped reply"))?
    }
}

/// The emulated substrate: per-segment outputs are a deterministic pure
/// function of (mean input activation, segment index) with the exact
/// output shape from the manifest, so sequential composition over any
/// partition point reproduces the same final vector bit-for-bit.
struct EmulatedEngine {
    models: HashMap<String, ModelMeta>,
}

impl EmulatedEngine {
    fn new() -> EmulatedEngine {
        EmulatedEngine {
            models: HashMap::new(),
        }
    }

    fn load(&mut self, manifest: &Manifest, name: &str) -> Result<()> {
        let meta = manifest.get(name).map_err(|e| anyhow!(e))?;
        self.models.insert(name.to_string(), meta.clone());
        Ok(())
    }

    fn execute_range(&self, model: &str, a: usize, b: usize, input: &[f32]) -> Result<Vec<f32>> {
        let meta = self
            .models
            .get(model)
            .ok_or_else(|| anyhow!("model {model} not loaded"))?;
        if a > b || b > meta.partition_points {
            return Err(anyhow!("{model}: bad segment range [{a}, {b})"));
        }
        let mut x = input.to_vec();
        for seg in a..b {
            let want: usize = meta.segments[seg].in_shape.iter().product();
            if x.len() != want {
                return Err(anyhow!(
                    "{model}/seg{seg}: input has {} elements, wants {want}",
                    x.len()
                ));
            }
            let out_len: usize = meta.segments[seg].out_shape.iter().product();
            let mean = x.iter().map(|v| *v as f64).sum::<f64>() / x.len().max(1) as f64;
            let base = ((mean + (seg as f64 + 1.0) * 0.618) * 1.37).sin() * 0.5;
            x = (0..out_len)
                .map(|j| (base + j as f64 * 1e-3).sin() as f32)
                .collect();
        }
        Ok(x)
    }
}

enum Exec {
    Pjrt(Engine),
    Emulated(EmulatedEngine),
}

impl Exec {
    fn create(backend: ExecBackend) -> Result<(Exec, ExecBackend)> {
        match backend {
            ExecBackend::Pjrt => Ok((Exec::Pjrt(Engine::new()?), ExecBackend::Pjrt)),
            ExecBackend::Emulated => {
                Ok((Exec::Emulated(EmulatedEngine::new()), ExecBackend::Emulated))
            }
            ExecBackend::Auto => match Engine::new() {
                Ok(e) => Ok((Exec::Pjrt(e), ExecBackend::Pjrt)),
                Err(e) => {
                    eprintln!(
                        "note: PJRT unavailable ({e}); serving with the emulated backend"
                    );
                    Ok((Exec::Emulated(EmulatedEngine::new()), ExecBackend::Emulated))
                }
            },
        }
    }

    fn load(&mut self, manifest: &Manifest, name: &str) -> Result<()> {
        match self {
            Exec::Pjrt(engine) => {
                let meta = manifest.get(name).map_err(|e| anyhow!(e))?.clone();
                engine.load_model(manifest, &meta)
            }
            Exec::Emulated(em) => em.load(manifest, name),
        }
    }

    fn execute_range(&self, model: &str, a: usize, b: usize, input: &[f32]) -> Result<Vec<f32>> {
        match self {
            Exec::Pjrt(engine) => engine.execute_range(model, a, b, input),
            Exec::Emulated(em) => em.execute_range(model, a, b, input),
        }
    }
}

/// Owns the executor thread; dropping shuts it down.
pub struct ExecService {
    tx: mpsc::Sender<Request>,
    backend: ExecBackend,
    join: Option<JoinHandle<()>>,
}

impl ExecService {
    /// Spawn a PJRT executor thread and load `models` from the manifest.
    /// Blocks until loading finishes so callers see load errors.
    pub fn start(manifest: &Manifest, models: &[String]) -> Result<ExecService> {
        Self::start_with_backend(manifest, models, ExecBackend::Pjrt)
    }

    /// Spawn the executor thread on the chosen backend and preload
    /// `models` (may be empty — the tenant-lifecycle path loads at
    /// attach time via [`load`](Self::load)).
    pub fn start_with_backend(
        manifest: &Manifest,
        models: &[String],
        backend: ExecBackend,
    ) -> Result<ExecService> {
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<ExecBackend>>();
        let manifest = manifest.clone();
        let names: Vec<String> = models.to_vec();
        let join = std::thread::Builder::new()
            .name("exec-service".into())
            .spawn(move || {
                let (mut exec, resolved) = match Exec::create(backend) {
                    Ok(pair) => pair,
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                for name in &names {
                    if let Err(e) = exec.load(&manifest, name) {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                }
                let _ = ready_tx.send(Ok(resolved));
                while let Ok(req) = rx.recv() {
                    match req {
                        Request::Execute {
                            model,
                            a,
                            b,
                            input,
                            reply,
                        } => {
                            let out = exec.execute_range(&model, a, b, &input);
                            let _ = reply.send(out);
                        }
                        Request::Load { model, reply } => {
                            let _ = reply.send(exec.load(&manifest, &model));
                        }
                        Request::Shutdown => break,
                    }
                }
            })?;
        let backend = ready_rx
            .recv()
            .map_err(|_| anyhow!("executor thread died during load"))??;
        Ok(ExecService {
            tx,
            backend,
            join: Some(join),
        })
    }

    /// The substrate actually serving requests (`Auto` resolved).
    pub fn backend(&self) -> ExecBackend {
        self.backend
    }

    /// Load one model's segments at runtime (blocking). Idempotent.
    pub fn load(&self, model: &str) -> Result<()> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Load {
                model: model.to_string(),
                reply,
            })
            .map_err(|_| anyhow!("executor thread gone"))?;
        rx.recv().map_err(|_| anyhow!("executor dropped reply"))?
    }

    pub fn handle(&self) -> ExecHandle {
        ExecHandle {
            tx: self.tx.clone(),
        }
    }
}

impl Drop for ExecService {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Manifest;

    fn service() -> ExecService {
        ExecService::start_with_backend(&Manifest::synthetic(), &[], ExecBackend::Emulated)
            .unwrap()
    }

    #[test]
    fn emulated_loads_and_executes() {
        let svc = service();
        svc.load("mobilenetv2").unwrap();
        let h = svc.handle();
        let meta = Manifest::synthetic();
        let meta = meta.get("mobilenetv2").unwrap().clone();
        let n_in: usize = meta.input_shape.iter().product();
        let out = h
            .execute_range("mobilenetv2", 0, meta.partition_points, vec![0.5; n_in])
            .unwrap();
        let n_out: usize = meta
            .segments
            .last()
            .unwrap()
            .out_shape
            .iter()
            .product();
        assert_eq!(out.len(), n_out);
        // Deterministic.
        let again = h
            .execute_range("mobilenetv2", 0, meta.partition_points, vec![0.5; n_in])
            .unwrap();
        assert_eq!(out, again);
    }

    #[test]
    fn emulated_split_composes_exactly() {
        // The partition invariant the serving stack relies on: prefix
        // then suffix equals the unsplit run, at every partition point.
        let svc = service();
        svc.load("efficientnet").unwrap();
        let h = svc.handle();
        let manifest = Manifest::synthetic();
        let meta = manifest.get("efficientnet").unwrap().clone();
        let n_in: usize = meta.input_shape.iter().product();
        let full = h
            .execute_range("efficientnet", 0, meta.partition_points, vec![0.25; n_in])
            .unwrap();
        for p in 1..meta.partition_points {
            let boundary = h
                .execute_range("efficientnet", 0, p, vec![0.25; n_in])
                .unwrap();
            let composed = h
                .execute_range("efficientnet", p, meta.partition_points, boundary)
                .unwrap();
            assert_eq!(composed, full, "composition broke at p={p}");
        }
    }

    #[test]
    fn emulated_rejects_bad_input_and_unloaded_model() {
        let svc = service();
        svc.load("squeezenet").unwrap();
        let h = svc.handle();
        assert!(h.execute_range("squeezenet", 0, 1, vec![0.0; 3]).is_err());
        assert!(h.execute_range("nope", 0, 1, vec![0.0; 3]).is_err());
        // load-at-attach is dynamic: a model not loaded yet errors, then works.
        assert!(h.execute_range("mnasnet", 0, 1, vec![0.0; 512]).is_err());
        svc.load("mnasnet").unwrap();
        assert!(h.execute_range("mnasnet", 0, 1, vec![0.0; 512]).is_ok());
    }
}
