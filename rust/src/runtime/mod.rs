//! PJRT runtime: load the AOT-compiled HLO-text artifacts and execute them.
//!
//! `Engine` owns a PJRT CPU client and the compiled executables — one per
//! model segment. The `xla` crate's client is `Rc`-based (not `Send`), so
//! all PJRT work runs on whichever thread built the `Engine`;
//! [`service::ExecService`] wraps an `Engine` in a dedicated executor
//! thread with an mpsc request/reply facade for the multi-threaded
//! coordinator.
//!
//! Interchange is HLO *text* (see `python/compile/aot.py` for why).

pub mod service;

use std::collections::HashMap;

use anyhow::{anyhow, Context, Result};

use crate::model::{Manifest, ModelMeta};

pub struct Engine {
    client: xla::PjRtClient,
    /// (model name, segment index) → compiled executable.
    execs: HashMap<(String, usize), xla::PjRtLoadedExecutable>,
    /// Segment metadata needed to shape inputs.
    shapes: HashMap<(String, usize), (Vec<usize>, Vec<usize>)>,
}

impl Engine {
    pub fn new() -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Engine {
            client,
            execs: HashMap::new(),
            shapes: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile every segment of `model` from the manifest's artifacts.
    pub fn load_model(&mut self, manifest: &Manifest, model: &ModelMeta) -> Result<()> {
        for seg in &model.segments {
            let key = (model.name.clone(), seg.index);
            if self.execs.contains_key(&key) {
                continue;
            }
            let path = manifest.artifact_path(seg);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parse {path}: {e}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {path}: {e}"))?;
            self.execs.insert(key.clone(), exe);
            self.shapes
                .insert(key, (seg.in_shape.clone(), seg.out_shape.clone()));
        }
        Ok(())
    }

    pub fn is_loaded(&self, model: &str, seg: usize) -> bool {
        self.execs.contains_key(&(model.to_string(), seg))
    }

    pub fn loaded_segments(&self) -> usize {
        self.execs.len()
    }

    /// Execute one segment: f32 activations in, f32 activations out.
    pub fn execute_segment(&self, model: &str, seg: usize, input: &[f32]) -> Result<Vec<f32>> {
        let key = (model.to_string(), seg);
        let exe = self
            .execs
            .get(&key)
            .ok_or_else(|| anyhow!("segment {model}/seg{seg} not loaded"))?;
        let (in_shape, _) = &self.shapes[&key];
        let want: usize = in_shape.iter().product();
        if input.len() != want {
            return Err(anyhow!(
                "{model}/seg{seg}: input has {} elements, shape {:?} wants {want}",
                input.len(),
                in_shape
            ));
        }
        let dims: Vec<i64> = in_shape.iter().map(|d| *d as i64).collect();
        let lit = xla::Literal::vec1(input)
            .reshape(&dims)
            .map_err(|e| anyhow!("reshape input: {e}"))?;
        let result = exe
            .execute::<xla::Literal>(&[lit])
            .map_err(|e| anyhow!("execute {model}/seg{seg}: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e}"))?;
        // aot.py lowers with return_tuple=True → 1-tuple.
        let out = result
            .to_tuple1()
            .map_err(|e| anyhow!("untuple: {e}"))?;
        out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e}"))
    }

    /// Execute segments `[a, b)` in order (a TPU prefix or CPU suffix).
    pub fn execute_range(
        &self,
        model: &str,
        a: usize,
        b: usize,
        input: &[f32],
    ) -> Result<Vec<f32>> {
        let mut x = input.to_vec();
        for seg in a..b {
            x = self.execute_segment(model, seg, &x)?;
        }
        Ok(x)
    }

    pub fn output_len(&self, model: &str, seg: usize) -> Option<usize> {
        self.shapes
            .get(&(model.to_string(), seg))
            .map(|(_, out)| out.iter().product())
    }
}
