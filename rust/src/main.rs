//! `swapless` — CLI for the SwapLess reproduction.
//!
//! Subcommands:
//!   table 2                  print Table II from the artifact manifest
//!   figure <1|2|3|5|6|7|8>   regenerate a paper figure (prints + saves JSON)
//!   figures                  regenerate everything (results/*.json)
//!   ablation | sensitivity   extension experiments
//!   schedulers               scheduler ablation (per-SLO-class tails)
//!   overload                 overload-policy × load-factor sweep
//!   churn                    dynamic experiment with tenant attach/detach
//!   fleet                    multi-device placement sweep (1/2/4 TPUs × ρ)
//!   scenarios                fleet-scale scenario suite (diurnal, flash
//!                            crowd, crash, popularity drift) comparing
//!                            static vs SwapLess vs rebalance policies
//!   profile                  offline profiling phase → profiles.json
//!   plan                     run the allocator on a workload, print config
//!   placement                run the two-level fleet allocator, print the
//!                            tenant→device assignment + per-device plans
//!   serve                    live serving demo with a dynamic tenant set
//!                            (--devices N serves through the fleet router;
//!                            --log FILE records the binary event log)
//!   trace                    record a Poisson arrival trace for replay
//!   replay                   plan + simulate a recorded trace (JSON trace
//!                            or a binary event log with --models)
//!   audit [FILE]             replay an event log into materialized views
//!                            (no FILE: run the audit experiment)
//!
//! Common options: --artifacts DIR --hw FILE --seed N --horizon S
//!                 --models a,b --rates x,y --rho R
//! Without artifacts on disk, a synthetic paper-scale manifest (and the
//! emulated execution backend) is substituted automatically.

use swapless::alloc;
use swapless::analytic::Tenant;
use swapless::config::HardwareSpec;
use swapless::experiments as exp;
use swapless::experiments::common::save_result;
use swapless::model::Manifest;
use swapless::util::cli;

const VALUE_OPTS: [&str; 38] = [
    "artifacts", "hw", "seed", "horizon", "models", "rates", "rho", "iters", "out", "time-scale",
    "trace", "policy", "duration", "attach-at", "detach-at", "backend", "discipline", "classes",
    "queue-cap", "overload", "deadline-ms", "devices", "crash-device", "crash-at", "recover-at",
    "log", "offset", "queue", "scenario", "listen", "connect", "connections", "mode", "window",
    "tenants", "sample", "cost", "profile",
];

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    match run(&raw) {
        Ok(()) => {}
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
}

fn usage() -> String {
    "usage: swapless <command> [options]\n\
     commands:\n\
       table 2                     print Table II from the manifest\n\
       figure <1|2|3|5|6|7|8>      regenerate a paper figure (saves results/figN.json)\n\
       figures                     regenerate everything (results/*.json)\n\
       ablation | sensitivity      extension experiments\n\
       schedulers                  scheduler ablation: fifo/priority/wfq/spsf with\n\
                                   per-SLO-class mean/p99 (results/schedulers.json)\n\
       overload                    overload-policy sweep: block/reject/shed/deadline\n\
                                   x rho {0.7, 1.0, 1.5} on the Table-II mix with\n\
                                   bounded queues (results/overload.json)\n\
       churn                       Fig-8-style dynamic run with tenant attach/detach\n\
       fleet                       multi-device placement sweep: 1/2/4 devices x\n\
                                   Table-II mixes x rho, equal total load per group\n\
                                   (results/fleet.json)\n\
       faults                      fault sweep: crash schedules x {static, failover}\n\
                                   routing on the 2-device quad mix; reports\n\
                                   completed-within-deadline availability\n\
                                   (results/faults.json)\n\
       scenarios [--scenario diurnal|flash|crash|drift]\n\
                                   fleet-scale scenario suite on the octo mix over\n\
                                   4 devices: static vs swapless vs rebalance per\n\
                                   scenario, shared arrival stream\n\
                                   (results/scenarios.json)\n\
       profile [--models a,b] [--iters N] [--out FILE]\n\
                                   offline profiling phase -> profiles.json\n\
       plan --models a,b --rates x,y\n\
                                   run the allocator, print the (P, K) config\n\
       placement --models a,b --rates x,y [--devices N]\n\
                 [--cost analytic|profiled --profile LOG]\n\
                                   run the two-level fleet allocator: print the\n\
                                   tenant->device assignment, each device's (P, K)\n\
                                   plan, and the predicted fleet objective;\n\
                                   --cost profiled calibrates the prefix tables\n\
                                   from a span-sampled event log (--profile),\n\
                                   keyed by (device, attach-order handle)\n\
       telemetry                   sampling-rate x rho sweep on the DES: span\n\
                                   conservation, log-volume overhead, and the\n\
                                   profiled-vs-analytic drift ratios per stage\n\
                                   (results/telemetry.json)\n\
       audit [FILE] [--offset BYTES] [--follow]\n\
                                   replay a binary event log into the incremental\n\
                                   view layer and print the materialized rollup\n\
                                   (per-tenant/class/device counters); --offset\n\
                                   starts mid-file at a record boundary; --follow\n\
                                   tails a live log from its current end instead,\n\
                                   printing rolling rollup deltas every second\n\
                                   (--duration S bounds the tail; ctrl-c stops);\n\
                                   without FILE, runs the audit experiment: a\n\
                                   logged 2-device chaos run whose log-derived\n\
                                   rollup must match the live ServeStats bit-\n\
                                   exactly (results/audit.json; non-zero exit on\n\
                                   drift)\n\
       serve [--models a,b] [--rates x,y | --rho R] [--classes c1,c2]\n\
             [--devices N] [--duration S] [--time-scale S] [--listen ADDR]\n\
             [--discipline fifo|priority|wfq|spsf]\n\
             [--queue-cap N] [--overload block|reject|shed|deadline]\n\
             [--deadline-ms D] [--attach-at name@t[:rate],...]\n\
             [--detach-at name@t,...] [--backend auto|pjrt|emulated]\n\
             [--crash-device D --crash-at S [--recover-at S]]\n\
             [--log FILE] [--sample N]\n\
             [--cost analytic|profiled --profile LOG]\n\
                                   live serving with a dynamic tenant set; classes\n\
                                   (interactive|standard|batch) align with --models;\n\
                                   --rho drives open-loop load at a TPU load factor\n\
                                   (>= 1 = overload); --queue-cap/--overload bound\n\
                                   every station's admission; --deadline-ms tags\n\
                                   every request with a relative deadline;\n\
                                   --devices N routes through the fleet layer\n\
                                   (placement-aware dispatch + migration;\n\
                                   --attach-at/--detach-at not supported there);\n\
                                   --crash-device/--crash-at inject a chaos crash\n\
                                   into a fleet run (failover requeues its work);\n\
                                   --log FILE appends the binary request event\n\
                                   log off the hot path (audit/replay it later);\n\
                                   --listen ADDR additionally serves the binary\n\
                                   wire protocol on a TCP socket (loadgen drives\n\
                                   it; GET /stats over HTTP for a snapshot,\n\
                                   GET /metrics for Prometheus text exposition);\n\
                                   --sample N traces 1-in-N requests with stage\n\
                                   spans into the event log (default 16; 0 off);\n\
                                   --cost profiled rebuilds every tenant's prefix\n\
                                   tables from span estimates in --profile LOG\n\
       loadgen --connect HOST:PORT [--tenants N] [--rates x,y]\n\
               [--classes c1,c2] [--deadline-ms D] [--mode open|closed]\n\
               [--connections N] [--window W] [--duration S] [--seed N]\n\
                                   drive a serve --listen edge over real sockets:\n\
                                   open loop (Poisson at --rates, split across\n\
                                   connections) or closed loop (--window in\n\
                                   flight per connection); prints the greppable\n\
                                   loadgen: client-side summary line\n\
       wire                        loopback sweep: offered rate x connections\n\
                                   through the socket edge vs direct in-process\n\
                                   submission (results/wire.json)\n\
       trace --models a,b --rates x,y [--horizon S] [--seed N] [--out FILE]\n\
                                   record a Poisson arrival trace (JSON)\n\
       replay --trace FILE [--policy swapless|compiler|threshold]\n\
              [--discipline fifo|priority|wfq|spsf] [--queue-cap N]\n\
              [--overload block|reject|shed|deadline] [--deadline-ms D]\n\
              [--models a,b] [--queue heap|calendar]\n\
                                   plan from the trace's empirical rates, then\n\
                                   simulate the exact recorded arrivals (deadlines\n\
                                   from a v3 trace, or --deadline-ms for all);\n\
                                   FILE may be a binary event log (v4) — its\n\
                                   entry records become the arrivals, --models\n\
                                   names the tenants in (device, handle) order\n\
     common options: --artifacts DIR (default artifacts; synthetic manifest if\n\
     missing) --hw FILE --seed N --horizon S --rho R"
        .to_string()
}

fn run(raw: &[String]) -> Result<(), String> {
    let args = cli::parse(raw, &VALUE_OPTS)?;
    let Some(cmd) = args.positional.first() else {
        return Err(usage());
    };

    let artifacts = args.opt_or("artifacts", "artifacts");
    let hw = match args.opt("hw") {
        Some(path) => HardwareSpec::load(path)?,
        None => HardwareSpec::default(),
    };
    let manifest = Manifest::load_or_synthetic(&artifacts);
    let mut ctx = exp::Ctx::new(manifest, hw.clone());
    ctx.seed = args.opt_u64("seed", 42)?;
    ctx.horizon = args.opt_f64("horizon", 2000.0)?;

    match cmd.as_str() {
        "table" => {
            exp::table2::run(&ctx).print();
            Ok(())
        }
        "figure" => {
            let n = args
                .positional
                .get(1)
                .ok_or_else(|| "figure needs a number (1,2,3,5,6,7,8)".to_string())?;
            run_figure(&ctx, n)
        }
        "figures" => {
            exp::table2::run(&ctx).print();
            for n in ["1", "2", "3", "5", "6", "7", "8"] {
                run_figure(&ctx, n)?;
            }
            run_named(&ctx, "ablation")?;
            run_named(&ctx, "sensitivity")?;
            run_named(&ctx, "schedulers")
        }
        "ablation" | "sensitivity" | "churn" | "schedulers" | "overload" | "fleet"
        | "faults" | "wire" | "telemetry" => run_named(&ctx, cmd),
        "loadgen" => loadgen_cmd(&args),
        "scenarios" => {
            let r = exp::scenarios::run_filtered(&ctx, args.opt("scenario"))?;
            r.print();
            save_result("scenarios", &r.to_json())
        }
        "profile" => {
            let models = if args.opt("models").is_some() {
                args.opt_list("models")
            } else {
                ctx.manifest.models.iter().map(|m| m.name.clone()).collect()
            };
            let iters = args.opt_usize("iters", 10)?;
            let profiles =
                swapless::profiler::profile(&ctx.manifest, &ctx.cost, &models, iters)
                    .map_err(|e| e.to_string())?;
            let out = args.opt_or("out", "results/profiles.json");
            swapless::profiler::save(&profiles, &out)?;
            println!("profiled {} segments -> {out}", profiles.len());
            for p in &profiles {
                println!(
                    "  {}/seg{}: measured {:.2} ms | modeled cpu {:.2} ms tpu {:.2} ms ({:.1}x)",
                    p.model,
                    p.index,
                    p.measured_cpu_s * 1e3,
                    p.modeled_cpu_s * 1e3,
                    p.modeled_tpu_s * 1e3,
                    p.speedup
                );
            }
            Ok(())
        }
        "plan" => {
            let names = args.opt_list("models");
            if names.is_empty() {
                return Err("plan needs --models a,b".into());
            }
            let rates: Vec<f64> = args
                .opt_list("rates")
                .iter()
                .map(|r| r.parse::<f64>().map_err(|_| format!("bad rate {r}")))
                .collect::<Result<_, _>>()?;
            if rates.len() != names.len() {
                return Err("--rates must match --models".into());
            }
            let tenants: Vec<Tenant> = names
                .iter()
                .zip(&rates)
                .map(|(n, r)| {
                    Ok(Tenant {
                        model: ctx.manifest.get(n)?.clone(),
                        rate: *r,
                    })
                })
                .collect::<Result<_, String>>()?;
            let t0 = std::time::Instant::now();
            let plan = alloc::hill_climb(&ctx.am, &tenants, ctx.k_max);
            let dt = t0.elapsed();
            println!("workload:");
            for (n, r) in names.iter().zip(&rates) {
                println!("  {n}: {r} rps");
            }
            println!(
                "plan: P={:?} K={:?}  predicted objective {:.4}  ({} evals, {:?})",
                plan.config.partitions,
                plan.config.cores,
                plan.predicted_objective,
                plan.evaluations,
                dt
            );
            for (i, t) in tenants.iter().enumerate() {
                println!(
                    "  {}: e2e {:.1} ms (α={:.2})",
                    t.model.name,
                    ctx.am.e2e_latency(&tenants, &plan.config, i) * 1e3,
                    ctx.am.alpha(&tenants, &plan.config, i)
                );
            }
            Ok(())
        }
        "placement" => placement(&ctx, &args),
        "serve" => {
            let devices = args.opt_usize("devices", 1)?;
            if devices > 1 {
                serve_fleet(&ctx, &args, &hw, devices)
            } else if args.opt("crash-device").is_some()
                || args.opt("crash-at").is_some()
                || args.opt("recover-at").is_some()
            {
                Err("--crash-device/--crash-at/--recover-at require --devices > 1 \
                     (chaos injection exercises the fleet failover path)"
                    .into())
            } else {
                serve(&ctx, &args, &hw)
            }
        }
        "trace" => trace_record(&ctx, &args),
        "replay" => trace_replay(&ctx, &args),
        "audit" => match args.positional.get(1) {
            Some(path) if args.flag("follow") => audit_follow(path, &args),
            Some(path) => audit_log(path, &args),
            None => run_named(&ctx, "audit"),
        },
        // Unknown commands print the full usage and exit non-zero via
        // main's error path.
        _ => Err(usage()),
    }
}

/// `swapless placement --models a,b --rates x,y --devices N` — run the
/// two-level fleet allocator and print the assignment + per-device plans.
fn placement(ctx: &exp::Ctx, args: &cli::Args) -> Result<(), String> {
    use swapless::fleet::{place, place_with_tables, Fleet};
    let names = args.opt_list("models");
    if names.is_empty() {
        return Err("placement needs --models a,b".into());
    }
    let rates: Vec<f64> = args
        .opt_list("rates")
        .iter()
        .map(|r| r.parse::<f64>().map_err(|_| format!("bad rate {r}")))
        .collect::<Result<_, _>>()?;
    if rates.len() != names.len() {
        return Err("--rates must match --models".into());
    }
    let devices = args.opt_usize("devices", 2)?;
    if devices == 0 {
        return Err("--devices must be >= 1".into());
    }
    let tenants: Vec<Tenant> = names
        .iter()
        .zip(&rates)
        .map(|(n, r)| {
            Ok(Tenant {
                model: ctx.manifest.get(n)?.clone(),
                rate: *r,
            })
        })
        .collect::<Result<_, String>>()?;
    let fleet = Fleet::uniform(devices, &ctx.cost.hw);
    // --cost profiled --profile LOG: the span estimates are keyed by
    // (device, attach-order handle), so --models must list the tenants
    // in the profiled run's attach order. Calibration (log replay)
    // happens before the timer so `dt` stays pure search time.
    let pm = profiled_cost(args, &ctx.cost.hw)?;
    let t0 = std::time::Instant::now();
    let plan = match pm {
        Some(pm) => {
            let tables = (0..devices)
                .map(|d| {
                    tenants
                        .iter()
                        .enumerate()
                        .map(|(i, t)| pm.tables(d, i as u64, &t.model))
                        .collect()
                })
                .collect();
            place_with_tables(&fleet, &tenants, tables)
        }
        None => place(&fleet, &tenants),
    };
    let dt = t0.elapsed();
    println!("two-level placement over {devices} device(s):");
    for (i, n) in names.iter().enumerate() {
        println!(
            "  {n} @ {:.2} rps -> device {}",
            rates[i], plan.assignment[i]
        );
    }
    for dp in &plan.devices {
        let members: Vec<&str> = dp.tenants.iter().map(|&i| names[i].as_str()).collect();
        if members.is_empty() {
            println!("  device {}: idle", dp.device);
        } else {
            println!(
                "  device {}: {:?} P={:?} K={:?} mean {:.1} ms rho {:.2}",
                dp.device,
                members,
                dp.config.partitions,
                dp.config.cores,
                dp.mean_latency * 1e3,
                dp.tpu_utilization
            );
        }
    }
    println!(
        "fleet objective (worst device mean): {:.1} ms | {} inner evaluations, \
         {} refinement moves, {:?}",
        plan.objective * 1e3,
        plan.evaluations,
        plan.refine_moves,
        dt
    );
    if !plan.is_stable() {
        println!("warning: no stable configuration on at least one device (rho >= 1)");
    }
    Ok(())
}

/// Resolve `--cost analytic|profiled [--profile LOG]` into an optional
/// profiled cost model: replay the span-sampled log, fold its `Span*`
/// records into per-(device, tenant, partition) stage estimates, and
/// calibrate the analytic model with them (uncalibrated prefix-table
/// entries stay analytic).
fn profiled_cost(
    args: &cli::Args,
    hw: &HardwareSpec,
) -> Result<Option<std::sync::Arc<swapless::telemetry::ProfiledCostModel>>, String> {
    use swapless::telemetry::ProfiledCostModel;
    use swapless::tpu::CostModel;
    match args.opt_or("cost", "analytic").as_str() {
        "analytic" => {
            if args.opt("profile").is_some() {
                return Err("--profile needs --cost profiled".into());
            }
            Ok(None)
        }
        "profiled" => {
            let path = args
                .opt("profile")
                .ok_or("--cost profiled needs --profile LOG (a span-sampled event log)")?;
            let events = swapless::eventlog::read_all(path)?;
            let pm = ProfiledCostModel::from_events(CostModel::new(hw.clone()), &events);
            if pm.calibrated_points() == 0 {
                return Err(format!(
                    "--profile {path} holds no span records (was the run sampled? \
                     see --sample); a zero-point profiled model is just the \
                     analytic model"
                ));
            }
            println!(
                "profiled cost model: {} calibration point(s) from {} record(s) in {path}",
                pm.calibrated_points(),
                events.len()
            );
            Ok(Some(std::sync::Arc::new(pm)))
        }
        other => Err(format!("unknown --cost {other} (analytic|profiled)")),
    }
}

/// `swapless trace --models a,b --rates x,y --horizon S --out trace.json`
/// — record a Poisson arrival trace for later replay.
fn trace_record(ctx: &exp::Ctx, args: &cli::Args) -> Result<(), String> {
    use swapless::util::rng::Rng;
    use swapless::workload::{generate_arrivals, trace, RateSchedule};
    let names = args.opt_list("models");
    if names.is_empty() {
        return Err("trace needs --models a,b".into());
    }
    let rates: Vec<f64> = args
        .opt_list("rates")
        .iter()
        .map(|r| r.parse::<f64>().map_err(|_| format!("bad rate {r}")))
        .collect::<Result<_, _>>()?;
    if rates.len() != names.len() {
        return Err("--rates must match --models".into());
    }
    for n in &names {
        ctx.manifest.get(n)?; // validate names early
    }
    let horizon = args.opt_f64("horizon", 600.0)?;
    let schedules: Vec<RateSchedule> =
        rates.iter().map(|r| RateSchedule::constant(*r)).collect();
    let mut rng = Rng::new(args.opt_u64("seed", 42)?);
    let arrivals = generate_arrivals(&schedules, horizon, &mut rng);
    let out = args.opt_or("out", "results/trace.json");
    trace::save(&out, &arrivals, &names)?;
    println!("recorded {} arrivals over {horizon}s -> {out}", arrivals.len());
    Ok(())
}

/// `swapless replay --trace trace.json [--policy swapless|compiler|threshold]`
/// — plan from the trace's empirical rates, then simulate the exact trace.
fn trace_replay(ctx: &exp::Ctx, args: &cli::Args) -> Result<(), String> {
    use swapless::sim::{SimOptions, Simulator};
    use swapless::workload::trace;
    let path = args
        .opt("trace")
        .ok_or_else(|| "replay needs --trace FILE".to_string())?;
    // A binary event log (v4) replays its entry records; tenant handles
    // carry no model names, so --models must supply them in the log's
    // (device, handle) order — attach order on a single-device log.
    let (mut arrivals, names) = if trace::is_event_log(path) {
        let (arrivals, n_models) = trace::load_log(path)?;
        let names = args.opt_list("models");
        if names.len() != n_models {
            return Err(format!(
                "replaying an event log needs --models naming its {n_models} \
                 tenant(s) in (device, handle) order (got {})",
                names.len()
            ));
        }
        (arrivals, names)
    } else {
        trace::load(path)?
    };
    // --deadline-ms D annotates every arrival with a relative deadline
    // (overriding any recorded in a v3 trace).
    if let Some(ms) = args.opt("deadline-ms") {
        let ms: f64 = ms.parse().map_err(|_| format!("bad --deadline-ms {ms}"))?;
        for a in &mut arrivals {
            a.deadline = Some(a.time + ms * 1e-3);
        }
    }
    let horizon = arrivals.last().map(|a| a.time).unwrap_or(0.0) + 1.0;
    let rates = trace::empirical_rates(&arrivals, names.len(), horizon);
    let tenants: Vec<Tenant> = names
        .iter()
        .zip(&rates)
        .map(|(n, r)| {
            Ok(Tenant {
                model: ctx.manifest.get(n)?.clone(),
                rate: *r,
            })
        })
        .collect::<Result<_, String>>()?;
    let policy = args.opt_or("policy", "swapless");
    let cfg = match policy.as_str() {
        "swapless" => alloc::hill_climb(&ctx.am, &tenants, ctx.k_max).config,
        "compiler" => alloc::edge_tpu_compiler(&ctx.am, &tenants).config,
        "threshold" => {
            alloc::threshold_partitioning(&ctx.am, &tenants, ctx.k_max, 0.10).config
        }
        other => return Err(format!("unknown --policy {other}")),
    };
    let discipline = swapless::sched::DisciplineKind::parse(&args.opt_or("discipline", "fifo"))?;
    let overload = swapless::sched::OverloadPolicy::parse(&args.opt_or("overload", "block"))?;
    let queue = swapless::sim::QueueKind::parse(&args.opt_or("queue", "calendar"))?;
    let capacity = match args.opt("queue-cap") {
        Some(v) => Some(v.parse::<usize>().map_err(|_| format!("bad --queue-cap {v}"))?),
        None => None,
    };
    if capacity.is_some() && overload == swapless::sched::OverloadPolicy::Block {
        return Err(
            "--queue-cap has no effect under --overload block (unbounded); \
             pick --overload reject|shed|deadline"
                .into(),
        );
    }
    println!(
        "replaying {} arrivals ({horizon:.0}s, empirical rates {:?})",
        arrivals.len(),
        rates.iter().map(|r| (r * 100.0).round() / 100.0).collect::<Vec<_>>()
    );
    println!(
        "[{policy}/{discipline}/{overload}{}] P={:?} K={:?}",
        capacity.map(|c| format!(" cap {c}")).unwrap_or_default(),
        cfg.partitions,
        cfg.cores
    );
    let mut sim = Simulator::new(
        &ctx.cost,
        &tenants,
        cfg,
        SimOptions {
            horizon,
            warmup: horizon * 0.05,
            seed: ctx.seed,
            discipline,
            capacity,
            overload,
            queue,
            ..SimOptions::default()
        },
    );
    let res = sim.run(&arrivals, None);
    println!(
        "mean {:.1} ms | ρ(TPU) {:.2} | cache hit {:.2} | max queue {} | \
         accepted={} rejected={} shed={} expired={} goodput={}",
        res.mean_latency * 1e3,
        res.tpu_utilization,
        res.cache_hit_rate,
        res.max_tpu_occupancy,
        res.per_class.accepted_total(),
        res.per_class.rejected_total(),
        res.per_class.shed_total(),
        res.per_class.expired_total(),
        res.per_class.goodput_total(),
    );
    for (i, m) in res.per_model.iter().enumerate() {
        if m.completed > 0 {
            println!(
                "  {:<14} n={:<6} mean {:>7.1} ms  p95 {:>7.1} ms",
                names[i],
                m.completed,
                m.latency.mean() * 1e3,
                m.latency.percentile(95.0) * 1e3
            );
        }
    }
    for (class, hist) in res.per_class.non_empty() {
        println!(
            "  class {:<11}: n={} mean {:.1} ms p99 {:.1} ms",
            class.name(),
            hist.count(),
            hist.mean() * 1e3,
            hist.percentile(99.0) * 1e3
        );
    }
    Ok(())
}

/// `swapless audit FILE [--offset BYTES]` — replay a binary event log
/// through the incremental view layer and print the materialized rollup.
/// `--offset` starts mid-file (must land on a record boundary); the
/// resulting rollup equals a full replay minus the skipped prefix.
fn audit_log(path: &str, args: &cli::Args) -> Result<(), String> {
    use swapless::eventlog::{read_from, views::Rollup, RECORD_BYTES};
    let offset = args.opt_u64("offset", 0)?;
    if offset % RECORD_BYTES as u64 != 0 {
        return Err(format!(
            "--offset {offset} is not a record boundary (records are {RECORD_BYTES} bytes)"
        ));
    }
    let events = read_from(path, offset)?;
    let r = Rollup::replay(&events);
    let t = r.totals();
    println!("audit {path} from byte {offset}: {} records", r.records);
    println!(
        "rollup: accepted={} rejected={} shed={} expired={} cancelled={} \
         dropped={} goodput={} started={} completed={}",
        t.accepted,
        t.rejected,
        t.shed,
        t.expired,
        t.cancelled,
        t.dropped(),
        r.goodput(),
        r.started,
        t.completed,
    );
    println!(
        "fleet: migrations={} failovers={} failed_over={}",
        r.migrations, r.failovers, r.failed_over
    );
    for (d, c) in r.per_device.iter().enumerate() {
        println!(
            "device {d}: completed={} accepted={} rejected={} shed={} expired={} cancelled={}",
            c.completed, c.accepted, c.rejected, c.shed, c.expired, c.cancelled
        );
    }
    for ((d, h), c) in &r.per_tenant {
        println!(
            "  tenant {h} @ device {d}: accepted={} completed={} rejected={} dropped={}",
            c.accepted,
            c.completed,
            c.rejected,
            c.dropped()
        );
    }
    for (class, hist) in r.per_class.non_empty() {
        println!(
            "  class {:<11}: n={} mean {:.1} ms p99 {:.1} ms",
            class.name(),
            hist.count(),
            hist.mean() * 1e3,
            hist.percentile(99.0) * 1e3
        );
    }
    Ok(())
}

/// `swapless audit FILE --follow` — tail a live event log: start at the
/// current end (or `--offset`), poll once a second, fold every newly
/// appended record into a rolling [`Rollup`], and print a delta line per
/// poll that saw records. Stops after `--duration S` (default: runs
/// until ctrl-c) or when the writer's close-time truncate shrinks the
/// file below the tail offset.
///
/// [`Rollup`]: swapless::eventlog::views::Rollup
fn audit_follow(path: &str, args: &cli::Args) -> Result<(), String> {
    use swapless::eventlog::{read_from, views::Rollup, RECORD_BYTES};
    use std::time::{Duration, Instant};

    let rec = RECORD_BYTES as u64;
    // Whole-record clamp: the writer appends records atomically from the
    // reader's perspective only at record granularity, so a torn
    // in-flight tail is never handed to the decoder.
    let file_end = || -> Result<u64, String> {
        std::fs::metadata(path)
            .map(|m| m.len() / rec * rec)
            .map_err(|e| format!("stat {path}: {e}"))
    };
    let mut offset = match args.opt("offset") {
        Some(_) => {
            let o = args.opt_u64("offset", 0)?;
            if o % rec != 0 {
                return Err(format!(
                    "--offset {o} is not a record boundary (records are {RECORD_BYTES} bytes)"
                ));
            }
            o
        }
        None => file_end()?,
    };
    let duration = args.opt_f64("duration", f64::INFINITY)?;
    println!("following {path} from byte {offset} (ctrl-c to stop)");
    let mut roll = Rollup::new();
    let t0 = Instant::now();
    while t0.elapsed().as_secs_f64() < duration {
        std::thread::sleep(Duration::from_secs_f64(
            1.0f64.min(duration - t0.elapsed().as_secs_f64()).max(0.0),
        ));
        let end = file_end()?;
        if end < offset {
            println!("log shrank below the tail offset (writer closed); stopping");
            break;
        }
        if end == offset {
            continue;
        }
        let events = read_from(path, offset)?;
        let n = events.len() as u64;
        if n == 0 {
            continue;
        }
        offset += n * rec;
        let delta = Rollup::replay(&events);
        roll.merge(&delta);
        let (t, dt) = (roll.totals(), delta.totals());
        println!(
            "t={:>6.1}s +{n} records: accepted +{} completed +{} dropped +{} spans +{} | \
             totals accepted={} completed={} dropped={} goodput={} spans={}",
            t0.elapsed().as_secs_f64(),
            dt.accepted,
            dt.completed,
            dt.dropped(),
            delta.spans,
            t.accepted,
            t.completed,
            t.dropped(),
            roll.goodput(),
            roll.spans,
        );
    }
    println!(
        "followed {} record(s): accepted={} completed={} dropped={} goodput={} spans={}",
        roll.records,
        roll.totals().accepted,
        roll.totals().completed,
        roll.totals().dropped(),
        roll.goodput(),
        roll.spans,
    );
    Ok(())
}

fn run_named(ctx: &exp::Ctx, which: &str) -> Result<(), String> {
    match which {
        "ablation" => {
            let r = exp::ablation::run(ctx)?;
            r.print();
            save_result("ablation", &r.to_json())
        }
        "sensitivity" => {
            let r = exp::sensitivity::run(ctx)?;
            r.print();
            save_result("sensitivity", &r.to_json())
        }
        "churn" => {
            let r = exp::fig8::run_churn(ctx)?;
            r.print();
            save_result("churn", &r.to_json())
        }
        "schedulers" => {
            let r = exp::sched_ablation::run(ctx)?;
            r.print();
            save_result("schedulers", &r.to_json())
        }
        "overload" => {
            let r = exp::overload::run(ctx)?;
            r.print();
            save_result("overload", &r.to_json())
        }
        "fleet" => {
            let r = exp::fleet::run(ctx)?;
            r.print();
            save_result("fleet", &r.to_json())
        }
        "faults" => {
            let r = exp::faults::run(ctx)?;
            r.print();
            save_result("faults", &r.to_json())
        }
        "audit" => {
            let r = exp::audit::run(ctx)?;
            r.print();
            save_result("audit", &r.to_json())?;
            if !r.passed {
                return Err("audit: log-derived rollup diverged from live stats".into());
            }
            Ok(())
        }
        "wire" => {
            let r = exp::wire::run(ctx)?;
            r.print();
            save_result("wire", &r.to_json())
        }
        "telemetry" => {
            let r = exp::telemetry::run(ctx)?;
            r.print();
            save_result("telemetry", &r.to_json())
        }
        _ => Err(format!("unknown experiment {which}")),
    }
}

/// `swapless loadgen --connect HOST:PORT` — drive a `serve --listen`
/// edge over real sockets and print the client-observed summary.
fn loadgen_cmd(args: &cli::Args) -> Result<(), String> {
    use swapless::net::loadgen;
    use swapless::net::{LoadgenMode, LoadgenOptions, TenantSpec};
    use swapless::sched::SloClass;
    use swapless::workload::RateSchedule;

    let addr = args
        .opt("connect")
        .ok_or("loadgen needs --connect HOST:PORT")?
        .to_string();
    let n_tenants = args.opt_usize("tenants", 1)?;
    if n_tenants == 0 {
        return Err("--tenants must be at least 1".into());
    }
    let rates: Vec<f64> = if args.opt("rates").is_some() {
        args.opt_list("rates")
            .iter()
            .map(|r| r.parse::<f64>().map_err(|_| format!("bad rate {r}")))
            .collect::<Result<_, _>>()?
    } else {
        vec![5.0; n_tenants]
    };
    if rates.len() != n_tenants {
        return Err("--rates must match --tenants".into());
    }
    let classes: Vec<Option<SloClass>> = if args.opt("classes").is_some() {
        args.opt_list("classes")
            .iter()
            .map(|c| SloClass::parse(c).map(Some))
            .collect::<Result<_, _>>()?
    } else {
        vec![None; n_tenants]
    };
    if classes.len() != n_tenants {
        return Err("--classes must match --tenants".into());
    }
    let deadline_ms = args.opt_u64("deadline-ms", 0)? as u32;
    let mode = LoadgenMode::parse(&args.opt_or("mode", "open"))?;
    let report = loadgen::run(&LoadgenOptions {
        addr,
        connections: args.opt_usize("connections", 1)?,
        duration_s: args.opt_f64("duration", 4.0)?,
        mode,
        tenants: rates
            .iter()
            .zip(&classes)
            .enumerate()
            .map(|(handle, (rate, class))| TenantSpec {
                handle: handle as u64,
                schedule: RateSchedule::constant(*rate),
                class: *class,
                deadline_ms,
            })
            .collect(),
        window: args.opt_usize("window", 8)?,
        seed: args.opt_u64("seed", 42)?,
    })?;
    report.print();
    Ok(())
}

fn run_figure(ctx: &exp::Ctx, n: &str) -> Result<(), String> {
    match n {
        "1" => {
            let r = exp::fig1::run(ctx)?;
            r.print();
            save_result("fig1", &r.to_json())
        }
        "2" => {
            let r = exp::fig2::run(ctx)?;
            r.print();
            save_result("fig2", &r.to_json())
        }
        "3" => {
            let r = exp::fig3::run(ctx, "inceptionv4")?;
            r.print();
            save_result("fig3", &r.to_json())
        }
        "5" => {
            let r = exp::fig5::run(ctx, "inceptionv4", 0.2, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0])?;
            r.print();
            save_result("fig5", &r.to_json())
        }
        "6" => {
            let r = exp::fig6::run(ctx, 0.4, &[0.5, 1.0, 1.5, 2.0, 2.5])?;
            r.print();
            save_result("fig6", &r.to_json())
        }
        "7" => {
            let r = exp::fig7::run(ctx, &[0.2, 0.5])?;
            r.print();
            save_result("fig7", &r.to_json())
        }
        "8" => {
            let r = exp::fig8::run(ctx)?;
            r.print();
            save_result("fig8", &r.to_json())
        }
        _ => Err(format!("unknown figure {n} (have 1,2,3,5,6,7,8)")),
    }
}

/// One scheduled lifecycle transition: `(time, model, rate, attach?)`.
struct LifecycleEvent {
    at: f64,
    name: String,
    rate: f64,
    attach: bool,
}

/// Parse `name@t[:rate]` entries (comma-separated list option).
fn parse_lifecycle(
    args: &cli::Args,
    opt: &str,
    attach: bool,
    default_rate: f64,
) -> Result<Vec<LifecycleEvent>, String> {
    let mut events = Vec::new();
    for spec in args.opt_list(opt) {
        let (name, rest) = spec
            .split_once('@')
            .ok_or_else(|| format!("--{opt} entry {spec:?} is not name@t[:rate]"))?;
        let (t, rate) = match rest.split_once(':') {
            Some((t, r)) => (
                t.parse::<f64>().map_err(|_| format!("bad time in {spec:?}"))?,
                r.parse::<f64>().map_err(|_| format!("bad rate in {spec:?}"))?,
            ),
            None => (
                rest.parse::<f64>().map_err(|_| format!("bad time in {spec:?}"))?,
                default_rate,
            ),
        };
        events.push(LifecycleEvent {
            at: t,
            name: name.to_string(),
            rate,
            attach,
        });
    }
    Ok(events)
}

/// `swapless serve --devices N` (N > 1) — live serving through the fleet
/// router: tenants attach to the fleet (placement-aware admission lands
/// each on the best device), an open-loop Poisson workload drives every
/// tenant, periodic `rebalance()` lets the placement policy migrate
/// tenants between devices, and per-device statistics are reported.
fn serve_fleet(
    ctx: &exp::Ctx,
    args: &cli::Args,
    hw: &HardwareSpec,
    devices: usize,
) -> Result<(), String> {
    use swapless::analytic::TenantHandle;
    use swapless::coordinator::{AttachOptions, Request};
    use swapless::eventlog::EventLog;
    use swapless::fleet::{Fleet, FleetServerBuilder};
    use swapless::metrics::{fmt_device_line, fmt_fleet_faults_line, fmt_log_line};
    use swapless::runtime::service::ExecBackend;
    use swapless::sched::{DisciplineKind, OverloadPolicy, SloClass};
    use swapless::util::rng::Rng;
    use std::time::{Duration, Instant};

    // Tenant churn schedules are a single-device serve feature for now;
    // fail loudly rather than silently ignoring the flags.
    if args.opt("attach-at").is_some() || args.opt("detach-at").is_some() {
        return Err(
            "--attach-at/--detach-at are not supported with --devices > 1 yet; \
             use the fleet API (FleetServer::attach/detach) or a single device"
                .into(),
        );
    }
    let names = if args.opt("models").is_some() {
        args.opt_list("models")
    } else {
        vec!["mobilenetv2".to_string(), "inceptionv4".to_string()]
    };
    // --rho R drives the mix at a nominal TPU load factor measured on
    // the 1-DEVICE full-TPU reference (the fleet experiment's equal-
    // total-load convention, `rates_for_load_factor` semantics);
    // otherwise --rates (default 2 rps each) applies.
    let rates: Vec<f64> = if let Some(v) = args.opt("rho") {
        let rho: f64 = v.parse().map_err(|_| format!("bad --rho {v}"))?;
        let tenants: Vec<Tenant> = names
            .iter()
            .map(|n| {
                Ok(Tenant {
                    model: ctx.manifest.get(n)?.clone(),
                    rate: 0.0,
                })
            })
            .collect::<Result<_, String>>()?;
        let full = swapless::analytic::Config::all_tpu(&tenants);
        let shares = swapless::workload::equal_tpu_load_shares(&ctx.am, &tenants);
        swapless::workload::rates_for_load_factor(&ctx.am, &tenants, &full, &shares, rho)
    } else if args.opt("rates").is_some() {
        args.opt_list("rates")
            .iter()
            .map(|r| r.parse::<f64>().map_err(|_| format!("bad rate {r}")))
            .collect::<Result<_, _>>()?
    } else {
        vec![2.0; names.len()]
    };
    if rates.len() != names.len() {
        return Err("--rates must match --models".into());
    }
    let classes: Vec<SloClass> = if args.opt("classes").is_some() {
        args.opt_list("classes")
            .iter()
            .map(|c| SloClass::parse(c))
            .collect::<Result<_, _>>()?
    } else {
        vec![SloClass::Standard; names.len()]
    };
    if classes.len() != names.len() {
        return Err("--classes must match --models".into());
    }
    let discipline = DisciplineKind::parse(&args.opt_or("discipline", "fifo"))?;
    let overload = OverloadPolicy::parse(&args.opt_or("overload", "block"))?;
    let queue_cap = match args.opt("queue-cap") {
        Some(v) => Some(v.parse::<usize>().map_err(|_| format!("bad --queue-cap {v}"))?),
        None => None,
    };
    if queue_cap.is_some() && overload == OverloadPolicy::Block {
        return Err(
            "--queue-cap has no effect under --overload block (unbounded); \
             pick --overload reject|shed|deadline"
                .into(),
        );
    }
    let deadline = match args.opt("deadline-ms") {
        Some(v) => {
            let ms: f64 = v.parse().map_err(|_| format!("bad --deadline-ms {v}"))?;
            Some(Duration::from_secs_f64(ms * 1e-3))
        }
        None => None,
    };
    let duration = args.opt_f64("duration", 8.0)?;
    let time_scale = args.opt_f64("time-scale", 0.0)?;
    let backend = match args.opt_or("backend", "auto").as_str() {
        "auto" => ExecBackend::Auto,
        "pjrt" => ExecBackend::Pjrt,
        "emulated" => ExecBackend::Emulated,
        other => return Err(format!("unknown --backend {other}")),
    };
    // --log FILE records every request lifecycle transition (including
    // fleet-level migrate/failover records) to a binary append-only log.
    let log = match args.opt("log") {
        Some(path) => Some(EventLog::create(path)?),
        None => None,
    };
    // Chaos injection: --crash-device D --crash-at S [--recover-at S]
    // builds a one-crash FaultPlan against the run's wall clock.
    let crash = match args.opt("crash-device") {
        Some(v) => {
            let d: usize = v
                .parse()
                .map_err(|_| format!("bad --crash-device {v}"))?;
            if d >= devices {
                return Err(format!(
                    "--crash-device {d} out of range for {devices} devices"
                ));
            }
            let at = match args.opt("crash-at") {
                Some(t) => t.parse::<f64>().map_err(|_| format!("bad --crash-at {t}"))?,
                None => return Err("--crash-device needs --crash-at S".into()),
            };
            let recover = match args.opt("recover-at") {
                Some(t) => {
                    let r: f64 = t
                        .parse()
                        .map_err(|_| format!("bad --recover-at {t}"))?;
                    if r <= at {
                        return Err(format!("--recover-at {r} must be after --crash-at {at}"));
                    }
                    Some(r)
                }
                None => None,
            };
            Some((d, at, recover))
        }
        None => {
            if args.opt("crash-at").is_some() || args.opt("recover-at").is_some() {
                return Err("--crash-at/--recover-at need --crash-device D".into());
            }
            None
        }
    };

    let fleet = Fleet::uniform(devices, hw);
    let mut builder = FleetServerBuilder::new(&ctx.manifest, fleet)
        .backend(backend)
        .time_scale(time_scale)
        .discipline(discipline)
        .overload(overload)
        .adaptive(true);
    if let Some(cap) = queue_cap {
        builder = builder.queue_capacity(cap);
    }
    // --sample N: stage-span cadence for every member server (1-in-N;
    // 0 disables); the default DEFAULT_SPAN_SAMPLE applies otherwise.
    if args.opt("sample").is_some() {
        builder = builder.span_sample(args.opt_usize("sample", 0)?);
    }
    // --cost profiled --profile LOG: span-calibrated prefix tables,
    // keyed per (device, attach-order handle).
    if let Some(pm) = profiled_cost(args, hw)? {
        builder = builder.profile(pm);
    }
    if let Some((d, at, recover)) = crash {
        builder = builder.faults(
            swapless::fault::FaultPlan::new(args.opt_u64("seed", 42)?).crash(d, at, recover),
        );
    }
    if let Some(l) = &log {
        builder = builder.log(l.clone());
    }
    let server = std::sync::Arc::new(builder.build().map_err(|e| e.to_string())?);
    // --listen ADDR: serve the binary wire protocol in front of the
    // fleet router (socket requests share the same submit path).
    let listener = match args.opt("listen") {
        Some(addr) => {
            let l = swapless::net::NetListener::bind(
                server.clone(),
                addr,
                swapless::net::NetOptions::default(),
            )?;
            println!("listening on {}", l.local_addr());
            Some(l)
        }
        None => None,
    };
    println!(
        "fleet: {devices} devices | discipline: {discipline} | overload: {overload}{}",
        queue_cap.map(|c| format!(" cap {c}")).unwrap_or_default()
    );
    if let Some((d, at, recover)) = crash {
        println!(
            "chaos: crash device {d} at t={at:.1}s{}",
            recover
                .map(|r| format!(", recover at t={r:.1}s"))
                .unwrap_or_default()
        );
    }

    // Live tenants: (handle, name, input length, drive rate, next arrival).
    let mut live: Vec<(TenantHandle, String, usize, f64, f64)> = Vec::new();
    let mut rng = Rng::new(args.opt_u64("seed", 42)?);
    for ((n, r), c) in names.iter().zip(&rates).zip(&classes) {
        match server.attach(
            n,
            AttachOptions {
                rate_hint: *r,
                class: *c,
            },
        ) {
            Ok(h) => {
                let d = server.device_of(h).expect("just attached");
                println!("attach {n} @ {r:.2} rps ({c}) -> {h} on device {d}");
                let n_in: usize = ctx.manifest.get(n)?.input_shape.iter().product();
                // Rate 0 = attach but don't drive locally (wire-only
                // traffic via --listen).
                let next = if *r > 0.0 {
                    rng.exponential(*r)
                } else {
                    f64::INFINITY
                };
                live.push((h, n.clone(), n_in, *r, next));
            }
            Err(e) => println!("attach {n} REFUSED: {e}"),
        }
    }

    let t0 = Instant::now();
    let mut pending = Vec::new();
    // Rebalance on the same cadence as the single-device re-allocator
    // (the placement policy applies its own rate-change damping on top).
    let rebalance_period = swapless::config::RuntimeConfig::default().realloc_period_s;
    let mut next_rebalance = rebalance_period;
    loop {
        let now = t0.elapsed().as_secs_f64();
        if now >= duration {
            break;
        }
        // Heartbeat: detect a newly-Down device and force failover
        // (requeues its queued work onto survivors).
        let moved = server.poll_health();
        if moved > 0 {
            println!("t={now:.1}s failover moved {moved} tenant(s) off a down device");
        }
        if now >= next_rebalance {
            // Don't counter-migrate during an outage: the placement
            // planner doesn't see health, so let failover's layout stand
            // until every device is back up.
            if server.health().iter().all(|h| !h.is_down()) {
                let moved = server.rebalance();
                if moved > 0 {
                    println!("t={now:.1}s rebalance migrated {moved} tenant(s)");
                }
            }
            next_rebalance = now + rebalance_period;
            continue;
        }
        let next_arrival = live
            .iter()
            .map(|(_, _, _, _, t)| *t)
            .fold(f64::INFINITY, f64::min);
        let next = next_arrival.min(next_rebalance).min(duration);
        if next > now {
            std::thread::sleep(Duration::from_secs_f64((next - now).min(0.05)));
            continue;
        }
        let idx = live
            .iter()
            .enumerate()
            .min_by(|a, b| a.1 .4.partial_cmp(&b.1 .4).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        let (h, _, n_in, rate, _) = &live[idx];
        let mut req = Request::new(vec![0.5; *n_in]);
        if let Some(d) = deadline {
            req = req.with_deadline(d);
        }
        pending.push(server.submit(*h, req));
        let step = rng.exponential(*rate);
        live[idx].4 = now + step;
    }
    let mut ok = 0usize;
    let mut failed = 0usize;
    for ticket in pending {
        match ticket.wait() {
            Ok(_) => ok += 1,
            Err(_) => failed += 1,
        }
    }
    // Graceful wire drain: every accepted socket request resolves and
    // its response is written before the counters are read.
    if let Some(l) = listener {
        println!("{}", l.shutdown().line());
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = server.stats();
    println!(
        "\nserved {ok} requests in {wall:.2}s ({:.1} req/s); {failed} resolved with \
         typed errors; {} migrations",
        ok as f64 / wall,
        stats.migrations
    );
    println!(
        "{}",
        fmt_fleet_faults_line(
            stats.failovers,
            stats.requeued,
            stats.failed_over,
            stats.shed_tenants
        )
    );
    for (d, s) in stats.per_device.iter().enumerate() {
        println!(
            "{}",
            fmt_device_line(
                d,
                s.completed,
                s.accepted,
                s.rejected,
                s.shed,
                s.expired,
                s.failed,
                s.reconfigs,
                s.migrations
            )
        );
        for t in &s.per_tenant {
            if t.latency.count() > 0 {
                println!(
                    "  {:<14} {}{}: n={} mean {:.1} ms p95 {:.1} ms",
                    t.name,
                    t.handle,
                    if t.detached { " (detached)" } else { "" },
                    t.latency.count(),
                    t.latency.mean() * 1e3,
                    t.latency.percentile(95.0) * 1e3
                );
            }
        }
    }
    for (class, hist) in stats.per_class().non_empty() {
        println!(
            "  class {:<11}: n={} mean {:.1} ms p99 {:.1} ms",
            class.name(),
            hist.count(),
            hist.mean() * 1e3,
            hist.percentile(99.0) * 1e3
        );
    }
    if let Some(log) = log {
        // Dropping the fleet server winds down every member, then closes
        // the shared log (drain + fsync + truncate). Report the writer's
        // accounting once the file is final.
        drop(server);
        println!("{}", fmt_log_line(log.appended(), log.dropped()));
    }
    Ok(())
}

/// `swapless serve` — live serving demo with a dynamic tenant set: the
/// initial models attach through admission control, then `--attach-at` /
/// `--detach-at` schedules replay churn against the running server while
/// an open-loop Poisson workload drives each live tenant at its rate.
fn serve(ctx: &exp::Ctx, args: &cli::Args, hw: &HardwareSpec) -> Result<(), String> {
    use swapless::analytic::{Config, TenantHandle};
    use swapless::coordinator::{AttachOptions, Request, ServerBuilder};
    use swapless::eventlog::EventLog;
    use swapless::metrics::{fmt_log_line, fmt_overload_line};
    use swapless::model::ModelMeta;
    use swapless::runtime::service::ExecBackend;
    use swapless::sched::{DisciplineKind, OverloadPolicy, SloClass};
    use swapless::tpu::CostModel;
    use swapless::util::rng::Rng;
    use swapless::workload::{equal_tpu_load_shares, rates_for_load_factor};
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    let names = if args.opt("models").is_some() {
        args.opt_list("models")
    } else {
        vec!["mobilenetv2".to_string(), "squeezenet".to_string()]
    };
    // --rho R drives the mix at a target TPU load factor (>= 1 =
    // overload); otherwise --rates (default 2 rps each) applies.
    let rho_target = match args.opt("rho") {
        Some(v) => Some(v.parse::<f64>().map_err(|_| format!("bad --rho {v}"))?),
        None => None,
    };
    let rates: Vec<f64> = if let Some(rho) = rho_target {
        let tenants: Vec<Tenant> = names
            .iter()
            .map(|n| {
                Ok(Tenant {
                    model: ctx.manifest.get(n)?.clone(),
                    rate: 0.0,
                })
            })
            .collect::<Result<_, String>>()?;
        let full = Config::all_tpu(&tenants);
        let shares = equal_tpu_load_shares(&ctx.am, &tenants);
        rates_for_load_factor(&ctx.am, &tenants, &full, &shares, rho)
    } else if args.opt("rates").is_some() {
        args.opt_list("rates")
            .iter()
            .map(|r| r.parse::<f64>().map_err(|_| format!("bad rate {r}")))
            .collect::<Result<_, _>>()?
    } else {
        vec![2.0; names.len()]
    };
    if rates.len() != names.len() {
        return Err("--rates must match --models".into());
    }
    // Rate hints for admission control: the actual driven rates when
    // stable, or a sub-critical fraction when deliberately overloading
    // (declared vs offered load — the admission plan must exist for the
    // overload policies to have a running server to protect).
    let attach_hints: Vec<f64> = match rho_target {
        Some(rho) if rho >= 0.9 => rates.iter().map(|r| r * (0.7 / rho)).collect(),
        _ => rates.clone(),
    };
    let classes: Vec<SloClass> = if args.opt("classes").is_some() {
        args.opt_list("classes")
            .iter()
            .map(|c| SloClass::parse(c))
            .collect::<Result<_, _>>()?
    } else {
        vec![SloClass::Standard; names.len()]
    };
    if classes.len() != names.len() {
        return Err("--classes must match --models".into());
    }
    let discipline = DisciplineKind::parse(&args.opt_or("discipline", "fifo"))?;
    let overload = OverloadPolicy::parse(&args.opt_or("overload", "block"))?;
    let queue_cap = match args.opt("queue-cap") {
        Some(v) => Some(v.parse::<usize>().map_err(|_| format!("bad --queue-cap {v}"))?),
        None => None,
    };
    if queue_cap.is_some() && overload == OverloadPolicy::Block {
        return Err(
            "--queue-cap has no effect under --overload block (unbounded); \
             pick --overload reject|shed|deadline"
                .into(),
        );
    }
    let deadline = match args.opt("deadline-ms") {
        Some(v) => {
            let ms: f64 = v.parse().map_err(|_| format!("bad --deadline-ms {v}"))?;
            Some(Duration::from_secs_f64(ms * 1e-3))
        }
        None => None,
    };
    let duration = args.opt_f64("duration", 8.0)?;
    let time_scale = args.opt_f64("time-scale", 0.0)?;
    let backend = match args.opt_or("backend", "auto").as_str() {
        "auto" => ExecBackend::Auto,
        "pjrt" => ExecBackend::Pjrt,
        "emulated" => ExecBackend::Emulated,
        other => return Err(format!("unknown --backend {other}")),
    };
    // --log FILE records every request lifecycle transition to a binary
    // append-only log off the hot path (audit / replay it afterwards).
    let log = match args.opt("log") {
        Some(path) => Some(EventLog::create(path)?),
        None => None,
    };

    let mut schedule: Vec<LifecycleEvent> = parse_lifecycle(args, "attach-at", true, 2.0)?;
    schedule.extend(parse_lifecycle(args, "detach-at", false, 0.0)?);
    schedule.sort_by(|a, b| a.at.partial_cmp(&b.at).unwrap());
    for ev in &schedule {
        ctx.manifest.get(&ev.name)?; // validate names early
    }

    let mut builder = ServerBuilder::new(&ctx.manifest, CostModel::new(hw.clone()))
        .k_max(ctx.k_max)
        .time_scale(time_scale)
        .backend(backend)
        .discipline(discipline)
        .overload(overload)
        .adaptive(true);
    if let Some(cap) = queue_cap {
        builder = builder.queue_capacity(cap);
    }
    if let Some(l) = &log {
        builder = builder.log(l.clone());
    }
    // --sample N: stage-span cadence (1-in-N; 0 disables). The default
    // stays DEFAULT_SPAN_SAMPLE, so /metrics drift gauges populate even
    // without the flag.
    if args.opt("sample").is_some() {
        builder = builder.span_sample(args.opt_usize("sample", 0)?);
    }
    // --cost profiled --profile LOG: rebuild every tenant's prefix
    // tables from span estimates instead of the analytic model.
    if let Some(pm) = profiled_cost(args, hw)? {
        builder = builder.profile(pm);
    }
    let server = Arc::new(builder.build().map_err(|e| e.to_string())?);
    // --listen ADDR: serve the binary wire protocol alongside the local
    // open-loop drive (socket requests share the same submit path).
    let listener = match args.opt("listen") {
        Some(addr) => {
            let l = swapless::net::NetListener::bind(
                server.clone(),
                addr,
                swapless::net::NetOptions::default(),
            )?;
            println!("listening on {}", l.local_addr());
            Some(l)
        }
        None => None,
    };
    println!(
        "backend: {:?} | discipline: {} | overload: {}{}{}",
        server.backend(),
        server.discipline(),
        server.overload(),
        server
            .queue_capacity()
            .map(|c| format!(" cap {c}"))
            .unwrap_or_default(),
        rho_target
            .map(|r| format!(" | target rho {r:.2}"))
            .unwrap_or_default(),
    );

    // Live tenants: (handle, name, meta, drive rate, next arrival time).
    let mut live: Vec<(TenantHandle, String, Arc<ModelMeta>, f64, f64)> = Vec::new();
    let mut rng = Rng::new(args.opt_u64("seed", 42)?);
    let attach = |live: &mut Vec<(TenantHandle, String, Arc<ModelMeta>, f64, f64)>,
                      name: &str,
                      hint: f64,
                      rate: f64,
                      class: SloClass,
                      at: f64,
                      rng: &mut Rng| {
        match server.attach(name, AttachOptions { rate_hint: hint, class }) {
            Ok(h) => {
                let meta = server.model_meta(h).expect("just attached");
                let cfg = server.current_config();
                println!(
                    "t={at:.1}s attach {name} @ {rate:.2} rps ({class}) -> {h}  plan P={:?} K={:?}",
                    cfg.partitions, cfg.cores
                );
                // Rate 0 = attach but don't drive locally (wire-only
                // traffic via --listen).
                let next = if rate > 0.0 {
                    at + rng.exponential(rate)
                } else {
                    f64::INFINITY
                };
                live.push((h, name.to_string(), meta, rate, next));
            }
            Err(e) => println!("t={at:.1}s attach {name} REFUSED: {e}"),
        }
    };

    for (((n, hint), r), c) in names.iter().zip(&attach_hints).zip(&rates).zip(&classes) {
        attach(&mut live, n, *hint, *r, *c, 0.0, &mut rng);
    }

    let t0 = Instant::now();
    let mut pending = Vec::new();
    let mut schedule = schedule.into_iter().peekable();
    loop {
        let now = t0.elapsed().as_secs_f64();
        if now >= duration {
            break;
        }
        // Next lifecycle transition vs next request arrival.
        let next_event = schedule.peek().map(|e| e.at).unwrap_or(f64::INFINITY);
        let next_arrival = live
            .iter()
            .map(|(_, _, _, _, t)| *t)
            .fold(f64::INFINITY, f64::min);
        let next = next_event.min(next_arrival).min(duration);
        if next > now {
            std::thread::sleep(Duration::from_secs_f64((next - now).min(0.05)));
            continue;
        }
        if next_event <= next_arrival {
            let ev = schedule.next().unwrap();
            if ev.attach {
                // A scheduled attach keeps the class declared for that
                // model via --classes (Standard for models not listed).
                let class = names
                    .iter()
                    .position(|n| *n == ev.name)
                    .map(|i| classes[i])
                    .unwrap_or_default();
                attach(&mut live, &ev.name, ev.rate, ev.rate, class, ev.at, &mut rng);
            } else if let Some(pos) = live.iter().position(|(_, n, _, _, _)| *n == ev.name) {
                let (h, name, _, _, _) = live.remove(pos);
                match server.detach(h) {
                    Ok(stats) => println!(
                        "t={:.1}s detach {name} ({h}): n={} mean {:.1} ms",
                        ev.at,
                        stats.latency.count(),
                        stats.latency.mean() * 1e3
                    ),
                    Err(e) => println!("t={:.1}s detach {name}: {e}", ev.at),
                }
            } else {
                println!("t={:.1}s detach {}: not attached", ev.at, ev.name);
            }
            continue;
        }
        // Fire the due arrival.
        let idx = live
            .iter()
            .enumerate()
            .min_by(|a, b| a.1 .4.partial_cmp(&b.1 .4).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        let (h, _, meta, rate, _) = &live[idx];
        let n_in: usize = meta.input_shape.iter().product();
        let mut req = Request::new(vec![0.5; n_in]);
        if let Some(d) = deadline {
            req = req.with_deadline(d);
        }
        pending.push(server.submit(*h, req));
        let step = rng.exponential(*rate);
        live[idx].4 = now + step;
    }
    // Drain in-flight tickets.
    let mut ok = 0usize;
    let mut failed = 0usize;
    for ticket in pending {
        match ticket.wait() {
            Ok(_) => ok += 1,
            Err(_) => failed += 1,
        }
    }
    // Graceful wire drain: every accepted socket request resolves and
    // its response is written before the counters are read.
    if let Some(l) = listener {
        println!("{}", l.shutdown().line());
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = server.stats();
    println!(
        "\nserved {} requests in {wall:.2}s ({:.1} req/s); {failed} resolved with \
         typed errors; {} reconfigs, {} allocator decisions",
        ok,
        ok as f64 / wall,
        stats.reconfigs,
        stats.decision_micros.len()
    );
    println!(
        "{}",
        fmt_overload_line(
            stats.accepted,
            stats.rejected,
            stats.shed,
            stats.expired,
            stats.cancelled,
            stats.dropped(),
            stats.goodput(),
            stats.failed,
        )
    );
    for t in &stats.per_tenant {
        if t.latency.count() > 0 {
            println!(
                "  {:<14} {}{}: n={} mean {:.1} ms p95 {:.1} ms",
                t.name,
                t.handle,
                if t.detached { " (detached)" } else { "" },
                t.latency.count(),
                t.latency.mean() * 1e3,
                t.latency.percentile(95.0) * 1e3
            );
        }
    }
    for (class, hist) in stats.per_class.non_empty() {
        println!(
            "  class {:<11}: n={} mean {:.1} ms p99 {:.1} ms | accepted {} dropped {} goodput {}",
            class.name(),
            hist.count(),
            hist.mean() * 1e3,
            hist.percentile(99.0) * 1e3,
            stats.per_class.accepted(class),
            stats.per_class.dropped(class),
            stats.per_class.goodput(class),
        );
    }
    if let Some(log) = log {
        // Dropping the server closes the log (drain + fsync + truncate);
        // the attach closure borrows it, so that goes first. Report the
        // writer's accounting once the file is final.
        drop(attach);
        drop(server);
        println!("{}", fmt_log_line(log.appended(), log.dropped()));
    }
    Ok(())
}
