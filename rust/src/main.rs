//! `swapless` — CLI for the SwapLess reproduction.
//!
//! Subcommands:
//!   table 2                  print Table II from the artifact manifest
//!   figure <1|2|3|5|6|7|8>   regenerate a paper figure (prints + saves JSON)
//!   figures                  regenerate everything (results/*.json)
//!   profile                  offline profiling phase → profiles.json
//!   plan                     run the allocator on a workload, print config
//!   serve                    live serving demo over the PJRT artifacts
//!
//! Common options: --artifacts DIR --hw FILE --seed N --horizon S
//!                 --models a,b --rates x,y --rho R

use swapless::alloc;
use swapless::analytic::Tenant;
use swapless::config::HardwareSpec;
use swapless::experiments as exp;
use swapless::experiments::common::save_result;
use swapless::util::cli;

const VALUE_OPTS: [&str; 12] = [
    "artifacts", "hw", "seed", "horizon", "models", "rates", "rho", "iters", "out", "time-scale",
    "trace", "policy",
];

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    match run(&raw) {
        Ok(()) => {}
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
}

fn usage() -> String {
    "usage: swapless <table 2 | figure N | figures | ablation | sensitivity | profile | plan | serve | trace | replay> [options]\n\
     options: --artifacts DIR (default artifacts) --hw FILE --seed N --horizon S\n\
              --models a,b --rates x,y --rho R --iters N --out FILE --time-scale S"
        .to_string()
}

fn run(raw: &[String]) -> Result<(), String> {
    let args = cli::parse(raw, &VALUE_OPTS)?;
    let Some(cmd) = args.positional.first() else {
        return Err(usage());
    };

    let artifacts = args.opt_or("artifacts", "artifacts");
    let hw = match args.opt("hw") {
        Some(path) => HardwareSpec::load(path)?,
        None => HardwareSpec::default(),
    };
    let mut ctx = exp::Ctx::load(&artifacts, hw.clone())?;
    ctx.seed = args.opt_u64("seed", 42)?;
    ctx.horizon = args.opt_f64("horizon", 2000.0)?;

    match cmd.as_str() {
        "table" => {
            exp::table2::run(&ctx).print();
            Ok(())
        }
        "figure" => {
            let n = args
                .positional
                .get(1)
                .ok_or_else(|| "figure needs a number (1,2,3,5,6,7,8)".to_string())?;
            run_figure(&ctx, n)
        }
        "figures" => {
            exp::table2::run(&ctx).print();
            for n in ["1", "2", "3", "5", "6", "7", "8"] {
                run_figure(&ctx, n)?;
            }
            run_named(&ctx, "ablation")?;
            run_named(&ctx, "sensitivity")
        }
        "ablation" | "sensitivity" => run_named(&ctx, cmd),
        "profile" => {
            let models = if args.opt("models").is_some() {
                args.opt_list("models")
            } else {
                ctx.manifest.models.iter().map(|m| m.name.clone()).collect()
            };
            let iters = args.opt_usize("iters", 10)?;
            let profiles =
                swapless::profiler::profile(&ctx.manifest, &ctx.cost, &models, iters)
                    .map_err(|e| e.to_string())?;
            let out = args.opt_or("out", "results/profiles.json");
            swapless::profiler::save(&profiles, &out)?;
            println!("profiled {} segments -> {out}", profiles.len());
            for p in &profiles {
                println!(
                    "  {}/seg{}: measured {:.2} ms | modeled cpu {:.2} ms tpu {:.2} ms ({:.1}x)",
                    p.model,
                    p.index,
                    p.measured_cpu_s * 1e3,
                    p.modeled_cpu_s * 1e3,
                    p.modeled_tpu_s * 1e3,
                    p.speedup
                );
            }
            Ok(())
        }
        "plan" => {
            let names = args.opt_list("models");
            if names.is_empty() {
                return Err("plan needs --models a,b".into());
            }
            let rates: Vec<f64> = args
                .opt_list("rates")
                .iter()
                .map(|r| r.parse::<f64>().map_err(|_| format!("bad rate {r}")))
                .collect::<Result<_, _>>()?;
            if rates.len() != names.len() {
                return Err("--rates must match --models".into());
            }
            let tenants: Vec<Tenant> = names
                .iter()
                .zip(&rates)
                .map(|(n, r)| {
                    Ok(Tenant {
                        model: ctx.manifest.get(n)?.clone(),
                        rate: *r,
                    })
                })
                .collect::<Result<_, String>>()?;
            let t0 = std::time::Instant::now();
            let plan = alloc::hill_climb(&ctx.am, &tenants, ctx.k_max);
            let dt = t0.elapsed();
            println!("workload:");
            for (n, r) in names.iter().zip(&rates) {
                println!("  {n}: {r} rps");
            }
            println!(
                "plan: P={:?} K={:?}  predicted objective {:.4}  ({} evals, {:?})",
                plan.config.partitions,
                plan.config.cores,
                plan.predicted_objective,
                plan.evaluations,
                dt
            );
            for (i, t) in tenants.iter().enumerate() {
                println!(
                    "  {}: e2e {:.1} ms (α={:.2})",
                    t.model.name,
                    ctx.am.e2e_latency(&tenants, &plan.config, i) * 1e3,
                    ctx.am.alpha(&tenants, &plan.config, i)
                );
            }
            Ok(())
        }
        "serve" => serve(&ctx, &args, &hw),
        "trace" => trace_record(&ctx, &args),
        "replay" => trace_replay(&ctx, &args),
        _ => Err(usage()),
    }
}

/// `swapless trace --models a,b --rates x,y --horizon S --out trace.json`
/// — record a Poisson arrival trace for later replay.
fn trace_record(ctx: &exp::Ctx, args: &cli::Args) -> Result<(), String> {
    use swapless::util::rng::Rng;
    use swapless::workload::{generate_arrivals, trace, RateSchedule};
    let names = args.opt_list("models");
    if names.is_empty() {
        return Err("trace needs --models a,b".into());
    }
    let rates: Vec<f64> = args
        .opt_list("rates")
        .iter()
        .map(|r| r.parse::<f64>().map_err(|_| format!("bad rate {r}")))
        .collect::<Result<_, _>>()?;
    if rates.len() != names.len() {
        return Err("--rates must match --models".into());
    }
    for n in &names {
        ctx.manifest.get(n)?; // validate names early
    }
    let horizon = args.opt_f64("horizon", 600.0)?;
    let schedules: Vec<RateSchedule> =
        rates.iter().map(|r| RateSchedule::constant(*r)).collect();
    let mut rng = Rng::new(args.opt_u64("seed", 42)?);
    let arrivals = generate_arrivals(&schedules, horizon, &mut rng);
    let out = args.opt_or("out", "results/trace.json");
    trace::save(&out, &arrivals, &names)?;
    println!("recorded {} arrivals over {horizon}s -> {out}", arrivals.len());
    Ok(())
}

/// `swapless replay --trace trace.json [--policy swapless|compiler|threshold]`
/// — plan from the trace's empirical rates, then simulate the exact trace.
fn trace_replay(ctx: &exp::Ctx, args: &cli::Args) -> Result<(), String> {
    use swapless::sim::{Simulator, SimOptions};
    use swapless::workload::trace;
    let path = args
        .opt("trace")
        .ok_or_else(|| "replay needs --trace FILE".to_string())?;
    let (arrivals, names) = trace::load(path)?;
    let horizon = arrivals.last().map(|a| a.time).unwrap_or(0.0) + 1.0;
    let rates = trace::empirical_rates(&arrivals, names.len(), horizon);
    let tenants: Vec<Tenant> = names
        .iter()
        .zip(&rates)
        .map(|(n, r)| {
            Ok(Tenant {
                model: ctx.manifest.get(n)?.clone(),
                rate: *r,
            })
        })
        .collect::<Result<_, String>>()?;
    let policy = args.opt_or("policy", "swapless");
    let cfg = match policy.as_str() {
        "swapless" => alloc::hill_climb(&ctx.am, &tenants, ctx.k_max).config,
        "compiler" => alloc::edge_tpu_compiler(&ctx.am, &tenants).config,
        "threshold" => {
            alloc::threshold_partitioning(&ctx.am, &tenants, ctx.k_max, 0.10).config
        }
        other => return Err(format!("unknown --policy {other}")),
    };
    println!(
        "replaying {} arrivals ({horizon:.0}s, empirical rates {:?})",
        arrivals.len(),
        rates.iter().map(|r| (r * 100.0).round() / 100.0).collect::<Vec<_>>()
    );
    println!("[{policy}] P={:?} K={:?}", cfg.partitions, cfg.cores);
    let mut sim = Simulator::new(
        &ctx.cost,
        &tenants,
        cfg,
        SimOptions {
            horizon,
            warmup: horizon * 0.05,
            seed: ctx.seed,
            timeline_window: None,
        },
    );
    let res = sim.run(&arrivals, None);
    println!(
        "mean {:.1} ms | ρ(TPU) {:.2} | cache hit {:.2}",
        res.mean_latency * 1e3,
        res.tpu_utilization,
        res.cache_hit_rate
    );
    for (i, m) in res.per_model.iter().enumerate() {
        if m.completed > 0 {
            println!(
                "  {:<14} n={:<6} mean {:>7.1} ms  p95 {:>7.1} ms",
                names[i],
                m.completed,
                m.latency.mean() * 1e3,
                m.latency.percentile(95.0) * 1e3
            );
        }
    }
    Ok(())
}

fn run_named(ctx: &exp::Ctx, which: &str) -> Result<(), String> {
    match which {
        "ablation" => {
            let r = exp::ablation::run(ctx)?;
            r.print();
            save_result("ablation", &r.to_json())
        }
        "sensitivity" => {
            let r = exp::sensitivity::run(ctx)?;
            r.print();
            save_result("sensitivity", &r.to_json())
        }
        _ => Err(format!("unknown experiment {which}")),
    }
}

fn run_figure(ctx: &exp::Ctx, n: &str) -> Result<(), String> {
    match n {
        "1" => {
            let r = exp::fig1::run(ctx)?;
            r.print();
            save_result("fig1", &r.to_json())
        }
        "2" => {
            let r = exp::fig2::run(ctx)?;
            r.print();
            save_result("fig2", &r.to_json())
        }
        "3" => {
            let r = exp::fig3::run(ctx, "inceptionv4")?;
            r.print();
            save_result("fig3", &r.to_json())
        }
        "5" => {
            let r = exp::fig5::run(ctx, "inceptionv4", 0.2, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0])?;
            r.print();
            save_result("fig5", &r.to_json())
        }
        "6" => {
            let r = exp::fig6::run(ctx, 0.4, &[0.5, 1.0, 1.5, 2.0, 2.5])?;
            r.print();
            save_result("fig6", &r.to_json())
        }
        "7" => {
            let r = exp::fig7::run(ctx, &[0.2, 0.5])?;
            r.print();
            save_result("fig7", &r.to_json())
        }
        "8" => {
            let r = exp::fig8::run(ctx)?;
            r.print();
            save_result("fig8", &r.to_json())
        }
        _ => Err(format!("unknown figure {n} (have 1,2,3,5,6,7,8)")),
    }
}

fn serve(ctx: &exp::Ctx, args: &cli::Args, hw: &HardwareSpec) -> Result<(), String> {
    use swapless::coordinator::{Server, ServerOptions};
    use swapless::tpu::CostModel;

    let names = if args.opt("models").is_some() {
        args.opt_list("models")
    } else {
        vec!["mobilenetv2".to_string(), "squeezenet".to_string()]
    };
    let n_req = args.opt_usize("iters", 50)?;
    let time_scale = args.opt_f64("time-scale", 0.0)?;

    println!("loading {} models: {names:?}", names.len());
    let tenants: Vec<Tenant> = names
        .iter()
        .map(|n| {
            Ok(Tenant {
                model: ctx.manifest.get(n)?.clone(),
                rate: 1.0,
            })
        })
        .collect::<Result<_, String>>()?;
    let plan = alloc::hill_climb(&ctx.am, &tenants, ctx.k_max);
    println!(
        "initial plan: P={:?} K={:?}",
        plan.config.partitions, plan.config.cores
    );
    let server = Server::start(
        &ctx.manifest,
        &names,
        CostModel::new(hw.clone()),
        plan.config,
        ServerOptions {
            time_scale,
            adaptive: true,
            ..Default::default()
        },
    )
    .map_err(|e| e.to_string())?;

    let t0 = std::time::Instant::now();
    for i in 0..n_req {
        let m = i % names.len();
        let meta = &server.tenants()[m].model;
        let n_in: usize = meta.input_shape.iter().product();
        let done = server
            .infer(m, vec![0.5f32; n_in])
            .map_err(|e| e.to_string())?;
        if i < 3 {
            println!(
                "  req {i} ({}) -> {} outputs, {:.1} ms",
                meta.name,
                done.output.len(),
                done.latency_s * 1e3
            );
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = server.stats();
    println!(
        "served {} requests in {:.2}s ({:.1} req/s)",
        stats.completed,
        wall,
        stats.completed as f64 / wall
    );
    for (i, h) in stats.per_model.iter().enumerate() {
        if h.count() > 0 {
            println!(
                "  {}: n={} mean {:.1} ms p95 {:.1} ms",
                names[i],
                h.count(),
                h.mean() * 1e3,
                h.percentile(95.0) * 1e3
            );
        }
    }
    Ok(())
}
