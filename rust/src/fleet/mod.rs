//! The fleet layer: multi-TPU device registry, two-level tenant placement,
//! placement-aware routing, and a multi-device DES.
//!
//! SwapLess (the paper) adapts partition points and CPU cores for ONE
//! memory-constrained Edge TPU. Real deployments attach several
//! accelerators per host or edge site, and there *placement* — which
//! tenant lives on which device — dominates swapping behavior, because
//! each device has its own SRAM cache and therefore its own inter-model
//! conflict set α. This module generalizes the whole stack from one TPU
//! to a registry of heterogeneous devices:
//!
//! * [`Fleet`] — the device registry: per-device SRAM size, host-transfer
//!   bandwidth, and CPU core budget ([`DeviceSpec`] wraps a full
//!   [`HardwareSpec`]), with the derived [`CostModel`]/[`AnalyticModel`]
//!   built once per device.
//! * [`place`](place::place) — the **two-level allocator**: an outer
//!   greedy bin-pack of tenants onto devices by predicted load
//!   contribution plus local-move refinement, scoring every candidate
//!   with the *inner* per-device hill climb (prefix tables +
//!   delta-evaluation engine, built once per device and reused across
//!   every inner evaluation). The fleet-wide objective is the max over
//!   devices of the per-device analytic mean response time.
//! * [`FleetServer`](server::FleetServer) — the live router: one
//!   [`Server`](crate::coordinator::Server) per device (own TPU worker
//!   queue, own SRAM cache, own CPU pools), placement-aware dispatch of
//!   ticketed requests, and **tenant migration** between devices
//!   (drain-then-move), driven through the
//!   [`ReconfigPolicy::decide_placement`](crate::sim::reconfig::ReconfigPolicy::decide_placement)
//!   hook.
//! * [`simulate_fleet`](sim::simulate_fleet) — the **multi-device DES**:
//!   one TPU station set per device with a per-device cache, replaying
//!   one global arrival stream split by the placement, so placement
//!   policies are evaluated offline before they touch live traffic
//!   (`tests/fleet_parity.rs` pins sim-vs-live count parity).
//!
//! Devices do not share queues or caches, so given a placement the fleet
//! decomposes exactly into independent per-device SwapLess instances —
//! which is what lets both engines reuse the validated single-device
//! machinery unchanged under the outer placement search.

pub mod place;
pub mod server;
pub mod sim;

pub use place::{place, place_with_tables, DevicePlan, FleetPlan};
pub use server::{FleetServer, FleetServerBuilder, FleetStats};
pub use sim::{
    run_fleet, run_fleet_failover, run_fleet_with, simulate_fleet, DeviceSimResult, FleetSimResult,
};

use crate::analytic::AnalyticModel;
use crate::config::HardwareSpec;
use crate::tpu::CostModel;

/// One TPU device entry in the registry. The [`HardwareSpec`] carries
/// everything that can differ per device: SRAM capacity, host-transfer
/// bandwidth, core budget, and the speedup calibration.
#[derive(Debug, Clone)]
pub struct DeviceSpec {
    pub name: String,
    pub hw: HardwareSpec,
}

/// A registered device with its derived cost/queueing models (built once;
/// every placement evaluation and engine instance reuses them).
#[derive(Debug, Clone)]
pub struct FleetDevice {
    pub spec: DeviceSpec,
    pub cost: CostModel,
    pub am: AnalyticModel,
}

impl FleetDevice {
    /// The device's own CPU core budget (`K_max` of its inner allocator).
    pub fn k_max(&self) -> usize {
        self.spec.hw.cpu_cores
    }
}

/// The device registry. Index order is identity: tenant→device
/// assignments, per-device plans, DES stations, and live member servers
/// are all positionally aligned with it.
#[derive(Debug, Clone)]
pub struct Fleet {
    devices: Vec<FleetDevice>,
}

impl Fleet {
    pub fn new(specs: Vec<DeviceSpec>) -> Fleet {
        assert!(!specs.is_empty(), "a fleet needs at least one device");
        Fleet {
            devices: specs
                .into_iter()
                .map(|spec| {
                    let cost = CostModel::new(spec.hw.clone());
                    FleetDevice {
                        am: AnalyticModel::new(cost.clone()),
                        cost,
                        spec,
                    }
                })
                .collect(),
        }
    }

    /// `n` identical devices (`tpu0..tpuN-1`), each with its own copy of
    /// `hw` — the homogeneous multi-TPU host case.
    pub fn uniform(n: usize, hw: &HardwareSpec) -> Fleet {
        assert!(n > 0, "a fleet needs at least one device");
        Fleet::new(
            (0..n)
                .map(|d| DeviceSpec {
                    name: format!("tpu{d}"),
                    hw: hw.clone(),
                })
                .collect(),
        )
    }

    pub fn len(&self) -> usize {
        self.devices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    pub fn device(&self, d: usize) -> &FleetDevice {
        &self.devices[d]
    }

    pub fn devices(&self) -> &[FleetDevice] {
        &self.devices
    }

    /// True when every device shares one hardware spec — device labels
    /// are then interchangeable, so migration-minimizing relabeling of a
    /// placement is cost-free.
    pub fn is_homogeneous(&self) -> bool {
        self.devices.windows(2).all(|w| w[0].spec.hw == w[1].spec.hw)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_fleet_builds_per_device_models() {
        let hw = HardwareSpec::default();
        let fleet = Fleet::uniform(3, &hw);
        assert_eq!(fleet.len(), 3);
        for (d, dev) in fleet.devices().iter().enumerate() {
            assert_eq!(dev.spec.name, format!("tpu{d}"));
            assert_eq!(dev.cost.hw.sram_bytes, hw.sram_bytes);
            assert_eq!(dev.k_max(), hw.cpu_cores);
        }
    }

    #[test]
    fn heterogeneous_fleet_keeps_per_device_hw() {
        let big = HardwareSpec {
            sram_bytes: HardwareSpec::default().sram_bytes * 4,
            cpu_cores: 8,
            ..HardwareSpec::default()
        };
        let fleet = Fleet::new(vec![
            DeviceSpec {
                name: "small".into(),
                hw: HardwareSpec::default(),
            },
            DeviceSpec {
                name: "big".into(),
                hw: big,
            },
        ]);
        assert_eq!(fleet.device(1).cost.hw.sram_bytes, fleet.device(0).cost.hw.sram_bytes * 4);
        assert_eq!(fleet.device(1).k_max(), 8);
    }

    #[test]
    #[should_panic(expected = "at least one device")]
    fn empty_fleet_panics() {
        Fleet::uniform(0, &HardwareSpec::default());
    }
}
