//! Multi-device DES: one per-device station set (TPU queue + SRAM cache
//! + CPU stations) per registry entry, replaying a single global arrival
//! stream split by a placement.
//!
//! Devices share nothing — no queue, no cache, no cores — so given a
//! [`FleetPlan`] the fleet decomposes exactly into independent
//! single-device simulations over the split streams: every station is
//! the *same* validated [`Simulator`] the single-TPU experiments run
//! (per-device SRAM cache and all), tagged with its device index via
//! [`SimOptions::device`]. The global stream is generated once from the
//! tenant rates — independent of the placement and the device count — so
//! 1/2/4-device plans are compared at identical total load, request for
//! request (`tests/fleet_parity.rs` pins sim-vs-live count parity on the
//! same construction).

use crate::analytic::{Config, Tenant};
use crate::sim::{SimOptions, SimResult, Simulator};
use crate::util::rng::Rng;
use crate::workload::{generate_arrivals, split_by_placement, Arrival, RateSchedule};

use super::place::FleetPlan;
use super::Fleet;

/// One device's DES outcome.
#[derive(Debug)]
pub struct DeviceSimResult {
    pub device: usize,
    /// Global tenant indices (ascending) — positionally aligned with
    /// `result.per_model`.
    pub tenants: Vec<usize>,
    pub result: SimResult,
}

/// The fleet-wide DES outcome.
#[derive(Debug)]
pub struct FleetSimResult {
    /// One entry per device, indexed by device.
    pub per_device: Vec<DeviceSimResult>,
    /// Completions across every device (post-warmup).
    pub completed: u64,
    /// Request-weighted mean latency across the fleet.
    pub mean_latency: f64,
    /// The worst device's request-weighted mean (the fleet objective,
    /// observed).
    pub max_device_mean: f64,
    /// Arrivals in the global stream (pre-split, pre-warmup).
    pub total_arrivals: usize,
}

impl FleetSimResult {
    /// Completions of global tenant `i` on the device its placement
    /// routed it to (0 if the tenant is unknown to every device).
    pub fn tenant_completed(&self, i: usize) -> u64 {
        for dev in &self.per_device {
            if let Some(pos) = dev.tenants.iter().position(|&t| t == i) {
                return dev.result.per_model[pos].completed;
            }
        }
        0
    }
}

/// Replay an explicit global arrival stream (`Arrival::model` = global
/// tenant index) through the fleet under `plan`.
pub fn run_fleet(
    fleet: &Fleet,
    tenants: &[Tenant],
    plan: &FleetPlan,
    arrivals: &[Arrival],
    opts: &SimOptions,
) -> FleetSimResult {
    assert_eq!(plan.assignment.len(), tenants.len());
    assert_eq!(plan.devices.len(), fleet.len());
    let streams = split_by_placement(arrivals, &plan.assignment, fleet.len());

    let mut per_device = Vec::with_capacity(fleet.len());
    let mut completed = 0u64;
    let mut lat_weighted = 0.0f64;
    let mut max_device_mean = 0.0f64;
    for (d, dplan) in plan.devices.iter().enumerate() {
        let members: Vec<Tenant> = dplan.tenants.iter().map(|&i| tenants[i].clone()).collect();
        let dev_opts = SimOptions {
            device: d,
            ..opts.clone()
        };
        let result = if members.is_empty() {
            // An idle device still reports an (empty) result so the
            // per-device vectors stay index-aligned with the registry.
            let empty = Config {
                partitions: Vec::new(),
                cores: Vec::new(),
            };
            Simulator::new(&fleet.device(d).cost, &[], empty, dev_opts).run(&[], None)
        } else {
            let mut sim = Simulator::new(
                &fleet.device(d).cost,
                &members,
                dplan.config.clone(),
                dev_opts,
            );
            sim.run(&streams[d], None)
        };
        let dev_completed: u64 = result.per_model.iter().map(|m| m.completed).sum();
        completed += dev_completed;
        if dev_completed > 0 {
            lat_weighted += result.mean_latency * dev_completed as f64;
            max_device_mean = max_device_mean.max(result.mean_latency);
        }
        per_device.push(DeviceSimResult {
            device: d,
            tenants: dplan.tenants.clone(),
            result,
        });
    }

    FleetSimResult {
        per_device,
        completed,
        mean_latency: if completed > 0 {
            lat_weighted / completed as f64
        } else {
            0.0
        },
        max_device_mean,
        total_arrivals: arrivals.len(),
    }
}

/// Steady-state fleet run: generate the global Poisson stream from the
/// tenant rates (placement-independent — same seed, same arrivals for
/// any device count) and replay it under `plan`.
pub fn simulate_fleet(
    fleet: &Fleet,
    tenants: &[Tenant],
    plan: &FleetPlan,
    opts: SimOptions,
) -> FleetSimResult {
    let schedules: Vec<RateSchedule> = tenants
        .iter()
        .map(|t| RateSchedule::constant(t.rate))
        .collect();
    let mut rng = Rng::new(opts.seed);
    let arrivals = generate_arrivals(&schedules, opts.horizon, &mut rng);
    run_fleet(fleet, tenants, plan, &arrivals, &opts)
}

#[cfg(test)]
mod tests {
    use super::super::place::place;
    use super::*;
    use crate::config::HardwareSpec;
    use crate::model::synthetic_model;
    use crate::sched::SloClass;

    fn tenants() -> Vec<Tenant> {
        vec![
            Tenant {
                model: synthetic_model("big_a", 6, 2_000_000, 700_000_000),
                rate: 3.0,
            },
            Tenant {
                model: synthetic_model("big_b", 6, 2_000_000, 700_000_000),
                rate: 3.0,
            },
            Tenant {
                model: synthetic_model("small", 4, 500_000, 150_000_000),
                rate: 4.0,
            },
        ]
    }

    fn opts(horizon: f64, seed: u64) -> SimOptions {
        SimOptions {
            horizon,
            warmup: 0.0,
            seed,
            ..SimOptions::default()
        }
    }

    #[test]
    fn identical_stream_for_any_device_count() {
        // The global arrival stream depends only on (rates, seed,
        // horizon) — the foundation of the equal-total-load comparison.
        let ts = tenants();
        let schedules: Vec<RateSchedule> =
            ts.iter().map(|t| RateSchedule::constant(t.rate)).collect();
        let a = generate_arrivals(&schedules, 100.0, &mut Rng::new(7));
        let b = generate_arrivals(&schedules, 100.0, &mut Rng::new(7));
        assert_eq!(a.len(), b.len());
        assert_eq!(a.first(), b.first());
        assert_eq!(a.last(), b.last());
    }

    #[test]
    fn fleet_des_conserves_requests_across_devices() {
        let ts = tenants();
        let fleet = Fleet::uniform(2, &HardwareSpec::default());
        let plan = place(&fleet, &ts);
        let res = simulate_fleet(&fleet, &ts, &plan, opts(200.0, 11));
        // Every arrival is routed to exactly one device and (warmup 0,
        // Block overload) eventually completes or is still in flight at
        // the horizon — conservation within the in-flight tail.
        let routed: usize = res
            .per_device
            .iter()
            .map(|d| {
                d.result.per_model.iter().map(|m| m.completed as usize).sum::<usize>()
            })
            .sum();
        assert_eq!(routed as u64, res.completed);
        assert!(res.completed > 0);
        assert!(
            res.total_arrivals as u64 >= res.completed,
            "{} arrivals < {} completions",
            res.total_arrivals,
            res.completed
        );
        let tail = res.total_arrivals as u64 - res.completed;
        assert!(tail < 50, "in-flight tail too large: {tail}");
        // Both devices served work (the mix splits under the planner).
        for d in &res.per_device {
            let n: u64 = d.result.per_model.iter().map(|m| m.completed).sum();
            assert!(n > 0, "device {} idle", d.device);
        }
        // Per-class accounting sums to the fleet total.
        let class_total: u64 = res
            .per_device
            .iter()
            .map(|d| d.result.per_class.get(SloClass::Standard).count())
            .sum();
        assert_eq!(class_total, res.completed);
    }

    #[test]
    fn two_devices_beat_one_at_equal_load() {
        let ts = tenants();
        let one = Fleet::uniform(1, &HardwareSpec::default());
        let two = Fleet::uniform(2, &HardwareSpec::default());
        let plan1 = place(&one, &ts);
        let plan2 = place(&two, &ts);
        let r1 = simulate_fleet(&one, &ts, &plan1, opts(400.0, 3));
        let r2 = simulate_fleet(&two, &ts, &plan2, opts(400.0, 3));
        assert!(
            r2.mean_latency < r1.mean_latency,
            "2-device {} !< 1-device {}",
            r2.mean_latency,
            r1.mean_latency
        );
        // Observed fleet objective tracks the planner's prediction
        // direction too.
        assert!(plan2.objective < plan1.objective);
    }

    #[test]
    fn fleet_des_is_deterministic() {
        let ts = tenants();
        let fleet = Fleet::uniform(2, &HardwareSpec::default());
        let plan = place(&fleet, &ts);
        let a = simulate_fleet(&fleet, &ts, &plan, opts(150.0, 23));
        let b = simulate_fleet(&fleet, &ts, &plan, opts(150.0, 23));
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.mean_latency, b.mean_latency);
        for (x, y) in a.per_device.iter().zip(&b.per_device) {
            for (mx, my) in x.result.per_model.iter().zip(&y.result.per_model) {
                assert_eq!(mx.completed, my.completed);
            }
        }
    }
}
