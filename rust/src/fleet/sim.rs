//! Multi-device DES: one per-device station set (TPU queue + SRAM cache
//! + CPU stations) per registry entry, replaying a single global arrival
//! stream split by a placement.
//!
//! Devices share nothing — no queue, no cache, no cores — so given a
//! [`FleetPlan`] the fleet decomposes exactly into independent
//! single-device simulations over the split streams: every station is
//! the *same* validated [`Simulator`] the single-TPU experiments run
//! (per-device SRAM cache and all), tagged with its device index via
//! [`SimOptions::device`]. The global stream is generated once from the
//! tenant rates — independent of the placement and the device count — so
//! 1/2/4-device plans are compared at identical total load, request for
//! request (`tests/fleet_parity.rs` pins sim-vs-live count parity on the
//! same construction).

use crate::analytic::{Config, Tenant};
use crate::eventlog::{Event as LogEvent, EventKind as LogKind};
use crate::sim::{SimOptions, SimResult, Simulator};
use crate::util::rng::Rng;
use crate::workload::{generate_arrivals, split_by_placement, Arrival, RateSchedule};

use super::place::FleetPlan;
use super::Fleet;

/// One device's DES outcome.
#[derive(Debug)]
pub struct DeviceSimResult {
    pub device: usize,
    /// Global tenant indices (ascending) — positionally aligned with
    /// `result.per_model`.
    pub tenants: Vec<usize>,
    pub result: SimResult,
}

/// The fleet-wide DES outcome.
#[derive(Debug)]
pub struct FleetSimResult {
    /// One entry per device, indexed by device.
    pub per_device: Vec<DeviceSimResult>,
    /// Completions across every device (post-warmup).
    pub completed: u64,
    /// Request-weighted mean latency across the fleet.
    pub mean_latency: f64,
    /// The worst device's request-weighted mean (the fleet objective,
    /// observed).
    pub max_device_mean: f64,
    /// Arrivals in the global stream (pre-split, pre-warmup).
    pub total_arrivals: usize,
    /// Per global tenant: arrivals rerouted away from a Down home device
    /// by [`run_fleet_failover`] (all zero under [`run_fleet`]).
    pub failed_over: Vec<u64>,
    /// Arrivals dropped because their home device was Down and no
    /// surviving device could take them (all zero under [`run_fleet`]).
    pub shed: u64,
}

impl FleetSimResult {
    /// Completions of global tenant `i`, summed over every device that
    /// served it — under failover a tenant completes on both its home
    /// device (pre-crash) and its landing device (post-crash).
    pub fn tenant_completed(&self, i: usize) -> u64 {
        let mut n = 0u64;
        for dev in &self.per_device {
            if let Some(pos) = dev.tenants.iter().position(|&t| t == i) {
                n += dev.result.per_model[pos].completed;
            }
        }
        n
    }

    /// Arrivals of global tenant `i` that were rerouted off a Down home
    /// device (0 when the tenant is unknown or never failed over).
    pub fn tenant_failed_over(&self, i: usize) -> u64 {
        self.failed_over.get(i).copied().unwrap_or(0)
    }
}

/// Replay an explicit global arrival stream (`Arrival::model` = global
/// tenant index) through the fleet under `plan`.
pub fn run_fleet(
    fleet: &Fleet,
    tenants: &[Tenant],
    plan: &FleetPlan,
    arrivals: &[Arrival],
    opts: &SimOptions,
) -> FleetSimResult {
    run_fleet_with(fleet, tenants, plan, arrivals, opts, |_, _| None)
}

/// Like [`run_fleet`], but each device's simulator runs under a
/// reconfiguration policy built by `make_policy(device, members)` —
/// `None` keeps the device static. This is how the scenario suite runs
/// per-device SwapLess re-planning inside a fleet replay.
pub fn run_fleet_with<F>(
    fleet: &Fleet,
    tenants: &[Tenant],
    plan: &FleetPlan,
    arrivals: &[Arrival],
    opts: &SimOptions,
    mut make_policy: F,
) -> FleetSimResult
where
    F: FnMut(usize, &[Tenant]) -> Option<Box<dyn crate::sim::ReconfigPolicy>>,
{
    assert_eq!(plan.assignment.len(), tenants.len());
    assert_eq!(plan.devices.len(), fleet.len());
    let streams = split_by_placement(arrivals, &plan.assignment, fleet.len());

    let mut per_device = Vec::with_capacity(fleet.len());
    let mut completed = 0u64;
    let mut lat_weighted = 0.0f64;
    let mut max_device_mean = 0.0f64;
    for (d, dplan) in plan.devices.iter().enumerate() {
        let members: Vec<Tenant> = dplan.tenants.iter().map(|&i| tenants[i].clone()).collect();
        let dev_opts = SimOptions {
            device: d,
            ..opts.clone()
        };
        let result = if members.is_empty() {
            // An idle device still reports an (empty) result so the
            // per-device vectors stay index-aligned with the registry.
            let empty = Config {
                partitions: Vec::new(),
                cores: Vec::new(),
            };
            Simulator::new(&fleet.device(d).cost, &[], empty, dev_opts).run(&[], None)
        } else {
            let mut sim = Simulator::new(
                &fleet.device(d).cost,
                &members,
                dplan.config.clone(),
                dev_opts,
            );
            let mut policy = make_policy(d, &members);
            sim.run(&streams[d], policy.as_deref_mut())
        };
        let dev_completed: u64 = result.per_model.iter().map(|m| m.completed).sum();
        completed += dev_completed;
        if dev_completed > 0 {
            lat_weighted += result.mean_latency * dev_completed as f64;
            max_device_mean = max_device_mean.max(result.mean_latency);
        }
        per_device.push(DeviceSimResult {
            device: d,
            tenants: dplan.tenants.clone(),
            result,
        });
    }

    FleetSimResult {
        per_device,
        completed,
        mean_latency: if completed > 0 {
            lat_weighted / completed as f64
        } else {
            0.0
        },
        max_device_mean,
        total_arrivals: arrivals.len(),
        failed_over: vec![0; tenants.len()],
        shed: 0,
    }
}

/// Failover-mode replay: like [`run_fleet`], but arrivals whose home
/// device is Down (per `opts.faults`) at their arrival instant are
/// rerouted to the tenant's failover target — the least-populated device
/// the plan never crashes — and counted in
/// [`FleetSimResult::failed_over`]. The landing device gains the foreign
/// tenant as an extra full-TPU member station, mirroring the live
/// [`super::FleetServer::fail_over`] re-placement; what the scenarios
/// and `tests/fleet_parity.rs` pin is the per-tenant *count* accounting,
/// not the landing latency. The crashed device still replays its own
/// fault schedule, so pre-crash service is identical to [`run_fleet`]
/// and work queued there at crash time stays frozen until recovery.
///
/// Without `opts.faults` this is exactly [`run_fleet`].
pub fn run_fleet_failover(
    fleet: &Fleet,
    tenants: &[Tenant],
    plan: &FleetPlan,
    arrivals: &[Arrival],
    opts: &SimOptions,
) -> FleetSimResult {
    let faults = match opts.faults.clone() {
        Some(f) => f,
        None => return run_fleet(fleet, tenants, plan, arrivals, opts),
    };
    assert_eq!(plan.assignment.len(), tenants.len());
    assert_eq!(plan.devices.len(), fleet.len());
    let n_dev = fleet.len();

    // Devices the plan ever takes Down inside the horizon.
    let ever_down: Vec<bool> = (0..n_dev)
        .map(|d| {
            faults
                .transitions(d)
                .iter()
                .any(|&(t, down)| down && t < opts.horizon)
        })
        .collect();
    // One failover target per tenant: the never-crashing device with the
    // fewest planned tenants (lowest index on ties). Tenants homed on an
    // always-up device need no target; `None` with a crashing home means
    // every other device also crashes — those arrivals are shed.
    let target: Vec<Option<usize>> = plan
        .assignment
        .iter()
        .map(|&home| {
            if !ever_down[home] {
                return None;
            }
            (0..n_dev)
                .filter(|&d| d != home && !ever_down[d])
                .min_by_key(|&d| (plan.devices[d].tenants.len(), d))
        })
        .collect();

    // Per-device member lists: the planned tenants, then foreign
    // failover landings appended in ascending global order, each landing
    // added to the device config as a full-TPU station.
    let mut members_of: Vec<Vec<usize>> = (0..n_dev)
        .map(|d| plan.devices[d].tenants.clone())
        .collect();
    let mut configs: Vec<Config> = (0..n_dev).map(|d| plan.devices[d].config.clone()).collect();
    for (i, t) in target.iter().enumerate() {
        if let Some(d) = *t {
            members_of[d].push(i);
            configs[d].partitions.push(tenants[i].model.partition_points);
            configs[d].cores.push(0);
        }
    }
    let mut local_of: Vec<Vec<Option<usize>>> = vec![vec![None; tenants.len()]; n_dev];
    for (d, members) in members_of.iter().enumerate() {
        for (pos, &i) in members.iter().enumerate() {
            local_of[d][i] = Some(pos);
        }
    }

    // Route: home while up, failover target while Down.
    let mut streams: Vec<Vec<Arrival>> = (0..n_dev).map(|_| Vec::new()).collect();
    let mut failed_over = vec![0u64; tenants.len()];
    let mut shed = 0u64;
    for a in arrivals {
        let home = plan.assignment[a.model];
        let dev = if faults.is_down(home, a.time) {
            match target[a.model] {
                Some(t) => {
                    failed_over[a.model] += 1;
                    if a.time >= opts.warmup {
                        if let Some(log) = &opts.log {
                            // Same record the live submit path emits for
                            // an off-home request: `tenant` is the GLOBAL
                            // tenant index (the fleet-level namespace),
                            // `device` the home, `aux` the landing device.
                            let mut ev = LogEvent::new(
                                LogKind::Failover,
                                a.time,
                                home,
                                a.model as u64,
                                a.class,
                            );
                            ev.aux = t as u16;
                            log.emit(ev);
                        }
                    }
                    t
                }
                None => {
                    shed += 1;
                    continue;
                }
            }
        } else {
            home
        };
        let mut routed = *a;
        routed.model = local_of[dev][a.model].expect("routed to a non-member device");
        streams[dev].push(routed);
    }

    let mut per_device = Vec::with_capacity(n_dev);
    let mut completed = 0u64;
    let mut lat_weighted = 0.0f64;
    let mut max_device_mean = 0.0f64;
    for d in 0..n_dev {
        let members: Vec<Tenant> = members_of[d].iter().map(|&i| tenants[i].clone()).collect();
        let dev_opts = SimOptions {
            device: d,
            ..opts.clone()
        };
        let result = if members.is_empty() {
            let empty = Config {
                partitions: Vec::new(),
                cores: Vec::new(),
            };
            Simulator::new(&fleet.device(d).cost, &[], empty, dev_opts).run(&[], None)
        } else {
            let mut sim = Simulator::new(
                &fleet.device(d).cost,
                &members,
                configs[d].clone(),
                dev_opts,
            );
            sim.run(&streams[d], None)
        };
        let dev_completed: u64 = result.per_model.iter().map(|m| m.completed).sum();
        completed += dev_completed;
        if dev_completed > 0 {
            lat_weighted += result.mean_latency * dev_completed as f64;
            max_device_mean = max_device_mean.max(result.mean_latency);
        }
        per_device.push(DeviceSimResult {
            device: d,
            tenants: members_of[d].clone(),
            result,
        });
    }

    FleetSimResult {
        per_device,
        completed,
        mean_latency: if completed > 0 {
            lat_weighted / completed as f64
        } else {
            0.0
        },
        max_device_mean,
        total_arrivals: arrivals.len(),
        failed_over,
        shed,
    }
}

/// Steady-state fleet run: generate the global Poisson stream from the
/// tenant rates (placement-independent — same seed, same arrivals for
/// any device count) and replay it under `plan`.
pub fn simulate_fleet(
    fleet: &Fleet,
    tenants: &[Tenant],
    plan: &FleetPlan,
    opts: SimOptions,
) -> FleetSimResult {
    let schedules: Vec<RateSchedule> = tenants
        .iter()
        .map(|t| RateSchedule::constant(t.rate))
        .collect();
    let mut rng = Rng::new(opts.seed);
    let arrivals = generate_arrivals(&schedules, opts.horizon, &mut rng);
    run_fleet(fleet, tenants, plan, &arrivals, &opts)
}

#[cfg(test)]
mod tests {
    use super::super::place::place;
    use super::*;
    use crate::config::HardwareSpec;
    use crate::model::synthetic_model;
    use crate::sched::SloClass;

    fn tenants() -> Vec<Tenant> {
        vec![
            Tenant {
                model: synthetic_model("big_a", 6, 2_000_000, 700_000_000),
                rate: 3.0,
            },
            Tenant {
                model: synthetic_model("big_b", 6, 2_000_000, 700_000_000),
                rate: 3.0,
            },
            Tenant {
                model: synthetic_model("small", 4, 500_000, 150_000_000),
                rate: 4.0,
            },
        ]
    }

    fn opts(horizon: f64, seed: u64) -> SimOptions {
        SimOptions {
            horizon,
            warmup: 0.0,
            seed,
            ..SimOptions::default()
        }
    }

    #[test]
    fn identical_stream_for_any_device_count() {
        // The global arrival stream depends only on (rates, seed,
        // horizon) — the foundation of the equal-total-load comparison.
        let ts = tenants();
        let schedules: Vec<RateSchedule> =
            ts.iter().map(|t| RateSchedule::constant(t.rate)).collect();
        let a = generate_arrivals(&schedules, 100.0, &mut Rng::new(7));
        let b = generate_arrivals(&schedules, 100.0, &mut Rng::new(7));
        assert_eq!(a.len(), b.len());
        assert_eq!(a.first(), b.first());
        assert_eq!(a.last(), b.last());
    }

    #[test]
    fn fleet_des_conserves_requests_across_devices() {
        let ts = tenants();
        let fleet = Fleet::uniform(2, &HardwareSpec::default());
        let plan = place(&fleet, &ts);
        let res = simulate_fleet(&fleet, &ts, &plan, opts(200.0, 11));
        // Every arrival is routed to exactly one device and (warmup 0,
        // Block overload) eventually completes or is still in flight at
        // the horizon — conservation within the in-flight tail.
        let routed: usize = res
            .per_device
            .iter()
            .map(|d| {
                d.result.per_model.iter().map(|m| m.completed as usize).sum::<usize>()
            })
            .sum();
        assert_eq!(routed as u64, res.completed);
        assert!(res.completed > 0);
        assert!(
            res.total_arrivals as u64 >= res.completed,
            "{} arrivals < {} completions",
            res.total_arrivals,
            res.completed
        );
        let tail = res.total_arrivals as u64 - res.completed;
        assert!(tail < 50, "in-flight tail too large: {tail}");
        // Both devices served work (the mix splits under the planner).
        for d in &res.per_device {
            let n: u64 = d.result.per_model.iter().map(|m| m.completed).sum();
            assert!(n > 0, "device {} idle", d.device);
        }
        // Per-class accounting sums to the fleet total.
        let class_total: u64 = res
            .per_device
            .iter()
            .map(|d| d.result.per_class.get(SloClass::Standard).count())
            .sum();
        assert_eq!(class_total, res.completed);
    }

    #[test]
    fn two_devices_beat_one_at_equal_load() {
        let ts = tenants();
        let one = Fleet::uniform(1, &HardwareSpec::default());
        let two = Fleet::uniform(2, &HardwareSpec::default());
        let plan1 = place(&one, &ts);
        let plan2 = place(&two, &ts);
        let r1 = simulate_fleet(&one, &ts, &plan1, opts(400.0, 3));
        let r2 = simulate_fleet(&two, &ts, &plan2, opts(400.0, 3));
        assert!(
            r2.mean_latency < r1.mean_latency,
            "2-device {} !< 1-device {}",
            r2.mean_latency,
            r1.mean_latency
        );
        // Observed fleet objective tracks the planner's prediction
        // direction too.
        assert!(plan2.objective < plan1.objective);
    }

    #[test]
    fn failover_reroutes_post_crash_arrivals() {
        use crate::fault::FaultPlan;
        let ts = tenants();
        let fleet = Fleet::uniform(2, &HardwareSpec::default());
        let plan = place(&fleet, &ts);
        let dead = plan.assignment[0];
        let schedules: Vec<RateSchedule> =
            ts.iter().map(|t| RateSchedule::constant(t.rate)).collect();
        let arrivals = generate_arrivals(&schedules, 300.0, &mut Rng::new(17));
        let mut o = opts(300.0, 17);
        o.faults = Some(FaultPlan::new(5).crash(dead, 100.0, None));
        let static_res = run_fleet(&fleet, &ts, &plan, &arrivals, &o);
        let failover = run_fleet_failover(&fleet, &ts, &plan, &arrivals, &o);
        // Static: the crashed device freezes and its tenants stop
        // completing; failover keeps serving them on the survivor.
        assert!(
            failover.completed > static_res.completed,
            "failover {} !> static {}",
            failover.completed,
            static_res.completed
        );
        assert_eq!(failover.shed, 0);
        for (i, &home) in plan.assignment.iter().enumerate() {
            if home == dead {
                assert!(
                    failover.tenant_failed_over(i) > 0,
                    "tenant {i} homed on crashed device never failed over"
                );
            } else {
                assert_eq!(failover.tenant_failed_over(i), 0, "tenant {i}");
            }
        }
        // Static accounting stays all-zero.
        assert!(static_res.failed_over.iter().all(|&n| n == 0));
        // Per-tenant completions (home + landing) sum to the fleet total.
        let by_tenant: u64 = (0..ts.len()).map(|i| failover.tenant_completed(i)).sum();
        assert_eq!(by_tenant, failover.completed);
    }

    #[test]
    fn failover_without_faults_matches_static() {
        let ts = tenants();
        let fleet = Fleet::uniform(2, &HardwareSpec::default());
        let plan = place(&fleet, &ts);
        let schedules: Vec<RateSchedule> =
            ts.iter().map(|t| RateSchedule::constant(t.rate)).collect();
        let arrivals = generate_arrivals(&schedules, 150.0, &mut Rng::new(29));
        let o = opts(150.0, 29);
        let a = run_fleet(&fleet, &ts, &plan, &arrivals, &o);
        let b = run_fleet_failover(&fleet, &ts, &plan, &arrivals, &o);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.mean_latency, b.mean_latency);
        assert!(b.failed_over.iter().all(|&n| n == 0));
        assert_eq!(b.shed, 0);
    }

    #[test]
    fn failover_with_no_survivors_sheds_down_arrivals() {
        use crate::fault::FaultPlan;
        let ts = tenants();
        let fleet = Fleet::uniform(1, &HardwareSpec::default());
        let plan = place(&fleet, &ts);
        let schedules: Vec<RateSchedule> =
            ts.iter().map(|t| RateSchedule::constant(t.rate)).collect();
        let arrivals = generate_arrivals(&schedules, 200.0, &mut Rng::new(41));
        let mut o = opts(200.0, 41);
        o.faults = Some(FaultPlan::new(5).crash(0, 50.0, None));
        let res = run_fleet_failover(&fleet, &ts, &plan, &arrivals, &o);
        // Nowhere to land: post-crash arrivals are shed, none failed over.
        assert!(res.shed > 0);
        assert!(res.failed_over.iter().all(|&n| n == 0));
        let post_crash = arrivals.iter().filter(|a| a.time >= 50.0).count() as u64;
        assert_eq!(res.shed, post_crash);
    }

    #[test]
    fn failover_replay_is_deterministic() {
        use crate::fault::FaultPlan;
        let ts = tenants();
        let fleet = Fleet::uniform(2, &HardwareSpec::default());
        let plan = place(&fleet, &ts);
        let schedules: Vec<RateSchedule> =
            ts.iter().map(|t| RateSchedule::constant(t.rate)).collect();
        let arrivals = generate_arrivals(&schedules, 200.0, &mut Rng::new(53));
        let mut o = opts(200.0, 53);
        o.faults = Some(FaultPlan::new(9).crash(0, 80.0, Some(140.0)));
        let a = run_fleet_failover(&fleet, &ts, &plan, &arrivals, &o);
        let b = run_fleet_failover(&fleet, &ts, &plan, &arrivals, &o);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.failed_over, b.failed_over);
        assert_eq!(a.shed, b.shed);
        assert_eq!(a.mean_latency, b.mean_latency);
    }

    #[test]
    fn fleet_des_is_deterministic() {
        let ts = tenants();
        let fleet = Fleet::uniform(2, &HardwareSpec::default());
        let plan = place(&fleet, &ts);
        let a = simulate_fleet(&fleet, &ts, &plan, opts(150.0, 23));
        let b = simulate_fleet(&fleet, &ts, &plan, opts(150.0, 23));
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.mean_latency, b.mean_latency);
        for (x, y) in a.per_device.iter().zip(&b.per_device) {
            for (mx, my) in x.result.per_model.iter().zip(&y.result.per_model) {
                assert_eq!(mx.completed, my.completed);
            }
        }
    }
}
