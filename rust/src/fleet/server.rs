//! The fleet router: one live [`Server`] per registered device —
//! each with its own TPU worker queue, SRAM cache, CPU pools, and
//! per-device SwapLess re-allocator — behind a placement-aware dispatch
//! layer with tenant migration.
//!
//! Tenants attach *to the fleet*: admission scores the candidate on every
//! device with the inner allocator (the same two-level criterion as
//! [`place`](super::place::place), incrementally) and lands the tenant on
//! the device that minimizes the fleet objective. Requests carry
//! fleet-scoped [`TenantHandle`]s; [`FleetServer::submit`] routes each to
//! the owning device's server, which runs the full validated
//! single-device request lifecycle (bounded admission, typed
//! backpressure, tickets).
//!
//! **Migration** is drain-then-move: attach on the target device
//! (admission-checked — a refused migration leaves the tenant where it
//! is), reroute new submits, wait for the source device's queued and
//! in-flight work to drain, then detach from the source (stragglers past
//! the drain window fail with typed errors, exactly like a detach).
//! Moves are counted per device in [`ServeStats::migrations`] and
//! fleet-wide in [`FleetStats::migrations`].
//!
//! Re-placement is policy-driven through
//! [`ReconfigPolicy::decide_placement`]: the submit path feeds the
//! policy's rate monitor (buffered, like the single-device server), and
//! [`FleetServer::rebalance`] asks the policy for a target assignment and
//! executes the migrations it implies.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::alloc::{self, AdmissionError};
use crate::analytic::{Config, Tenant, TenantHandle};
use crate::config::RuntimeConfig;
use crate::coordinator::{
    AttachError, AttachOptions, ConfigError, Request, RequestError, ServeStats, Server,
    ServerBuilder, ServerOptions, TenantStats, Ticket,
};
use crate::model::Manifest;
use crate::runtime::service::ExecBackend;
use crate::sim::reconfig::{ReconfigPolicy, SwapLessPolicy};

use super::Fleet;

/// Fluent construction of a [`FleetServer`].
pub struct FleetServerBuilder {
    manifest: Manifest,
    fleet: Fleet,
    opts: ServerOptions,
    placement: Option<Box<dyn ReconfigPolicy + Send>>,
}

impl FleetServerBuilder {
    pub fn new(manifest: &Manifest, fleet: Fleet) -> FleetServerBuilder {
        FleetServerBuilder {
            manifest: manifest.clone(),
            fleet,
            opts: ServerOptions::default(),
            placement: None,
        }
    }

    /// Base options applied to every member server (`device` and `k_max`
    /// are overridden per device from the registry).
    pub fn options(mut self, opts: ServerOptions) -> Self {
        self.opts = opts;
        self
    }

    pub fn backend(mut self, b: crate::runtime::service::ExecBackend) -> Self {
        self.opts.backend = b;
        self
    }

    pub fn time_scale(mut self, v: f64) -> Self {
        self.opts.time_scale = v;
        self
    }

    pub fn adaptive(mut self, on: bool) -> Self {
        self.opts.adaptive = on;
        self
    }

    pub fn discipline(mut self, d: crate::sched::DisciplineKind) -> Self {
        self.opts.discipline = d;
        self
    }

    pub fn overload(mut self, p: crate::sched::OverloadPolicy) -> Self {
        self.opts.overload = p;
        self
    }

    pub fn queue_capacity(mut self, cap: usize) -> Self {
        self.opts.queue_capacity = Some(cap);
        self
    }

    /// Install a custom placement policy (drives
    /// [`FleetServer::rebalance`]); defaults to a [`SwapLessPolicy`]
    /// whose `decide_placement` runs the two-level search on monitored
    /// rates.
    pub fn placement_policy(mut self, p: Box<dyn ReconfigPolicy + Send>) -> Self {
        self.placement = Some(p);
        self
    }

    pub fn build(self) -> Result<FleetServer> {
        FleetServer::new(self.manifest, self.fleet, self.opts, self.placement)
    }
}

/// One fleet-attached tenant and where it currently lives.
struct FleetTenant {
    handle: TenantHandle,
    /// Model + declared rate hint (what placement scoring plans with).
    tenant: Tenant,
    class: crate::sched::SloClass,
    device: usize,
    /// The tenant's handle on `servers[device]`.
    inner: TenantHandle,
}

/// Aggregated fleet statistics: the per-device [`ServeStats`] (with
/// their `migrations` counters filled in) plus fleet totals.
#[derive(Debug, Clone)]
pub struct FleetStats {
    /// Indexed by device.
    pub per_device: Vec<ServeStats>,
    /// Tenant moves completed (each drain-then-move counts once).
    pub migrations: u64,
}

impl FleetStats {
    pub fn completed(&self) -> u64 {
        self.per_device.iter().map(|s| s.completed).sum()
    }

    pub fn failed(&self) -> u64 {
        self.per_device.iter().map(|s| s.failed).sum()
    }

    pub fn accepted(&self) -> u64 {
        self.per_device.iter().map(|s| s.accepted).sum()
    }

    pub fn dropped(&self) -> u64 {
        self.per_device.iter().map(|s| s.dropped()).sum()
    }

    pub fn completed_per_device(&self) -> Vec<u64> {
        self.per_device.iter().map(|s| s.completed).collect()
    }

    /// Per-SLO-class accounting merged across devices.
    pub fn per_class(&self) -> crate::metrics::PerClassLatency {
        let mut merged = crate::metrics::PerClassLatency::new();
        for s in &self.per_device {
            merged.merge(&s.per_class);
        }
        merged
    }
}

/// Live multi-device inference router (see the module docs).
pub struct FleetServer {
    fleet: Fleet,
    servers: Vec<Server>,
    manifest: Manifest,
    state: Mutex<Vec<FleetTenant>>,
    /// Placement policy + its buffered arrival feed (same
    /// never-block-submitters pattern as the single-device server).
    placement: Mutex<Box<dyn ReconfigPolicy + Send>>,
    arrivals: Mutex<Vec<(f64, usize)>>,
    next_handle: AtomicU64,
    migrations: AtomicU64,
    per_device_migrations: Mutex<Vec<u64>>,
    /// How long a migration waits for the source device to drain before
    /// detaching (stragglers past it fail with typed errors). Scaled up
    /// under real-time emulation, where one service spans many polls.
    drain_budget: Duration,
    started: Instant,
}

impl FleetServer {
    fn new(
        manifest: Manifest,
        fleet: Fleet,
        opts: ServerOptions,
        placement: Option<Box<dyn ReconfigPolicy + Send>>,
    ) -> Result<FleetServer> {
        let mut servers = Vec::with_capacity(fleet.len());
        for (d, dev) in fleet.devices().iter().enumerate() {
            let member_opts = ServerOptions {
                device: d,
                k_max: dev.k_max(),
                ..opts.clone()
            };
            // Reuse the registry's per-device cost model — the single
            // derivation the whole fleet layer plans against.
            servers.push(
                ServerBuilder::new(&manifest, dev.cost.clone())
                    .options(member_opts)
                    .build()?,
            );
        }
        // The default placement policy honors the operator's runtime
        // knobs (rate window etc.), exactly like the member servers'
        // own re-allocators do.
        let rt: &RuntimeConfig = &opts.runtime;
        let placement = placement.unwrap_or_else(|| {
            Box::new(SwapLessPolicy::new(
                fleet.device(0).am.clone(),
                fleet.device(0).k_max(),
                0,
                rt.rate_window_s,
                rt.realloc_period_s,
                rt.realloc_threshold,
            ))
        });
        let n_devices = fleet.len();
        // Fast emulation drains in microseconds; real-time emulation or
        // a hardware backend needs queue-depth × service-time headroom.
        let drain_budget = if opts.time_scale > 0.0 || opts.backend == ExecBackend::Pjrt {
            Duration::from_secs(10)
        } else {
            Duration::from_millis(500)
        };
        Ok(FleetServer {
            fleet,
            servers,
            manifest,
            state: Mutex::new(Vec::new()),
            placement: Mutex::new(placement),
            arrivals: Mutex::new(Vec::new()),
            next_handle: AtomicU64::new(0),
            migrations: AtomicU64::new(0),
            per_device_migrations: Mutex::new(vec![0; n_devices]),
            drain_budget,
            started: Instant::now(),
        })
    }

    fn now(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Number of devices in the registry.
    pub fn devices(&self) -> usize {
        self.servers.len()
    }

    /// Direct access to a member server (tests, config overrides).
    pub fn server(&self, d: usize) -> &Server {
        &self.servers[d]
    }

    /// The device currently serving `handle`, if attached.
    pub fn device_of(&self, handle: TenantHandle) -> Option<usize> {
        self.state
            .lock()
            .unwrap()
            .iter()
            .find(|t| t.handle == handle)
            .map(|t| t.device)
    }

    /// Fleet-scoped handles in attach order.
    pub fn handles(&self) -> Vec<TenantHandle> {
        self.state.lock().unwrap().iter().map(|t| t.handle).collect()
    }

    /// Manually install a (P, K) configuration on one device (parity
    /// tests, static baselines). Dimensions are validated against the
    /// device's live tenant count.
    pub fn set_device_config(
        &self,
        device: usize,
        cfg: Config,
    ) -> std::result::Result<(), ConfigError> {
        self.servers[device].set_config(cfg)
    }

    /// Snapshot each device's current member tenants (placement-scoring
    /// input) without holding the state lock any longer than the copy.
    fn members_by_device(&self) -> Vec<Vec<Tenant>> {
        let st = self.state.lock().unwrap();
        (0..self.servers.len())
            .map(|d| {
                st.iter()
                    .filter(|t| t.device == d)
                    .map(|t| t.tenant.clone())
                    .collect()
            })
            .collect()
    }

    /// Per-device Eq. 5 objective of each device's member set (the
    /// incremental placement scoring baseline — same per-device score as
    /// [`super::place::place`]).
    fn device_objectives(&self, members: &[Vec<Tenant>]) -> Vec<f64> {
        members
            .iter()
            .enumerate()
            .map(|(d, m)| {
                if m.is_empty() {
                    return 0.0;
                }
                let dev = self.fleet.device(d);
                alloc::hill_climb(&dev.am, m, dev.k_max()).predicted_objective
            })
            .collect()
    }

    /// Admit a tenant onto the fleet: score the candidate on every device
    /// with the inner allocator and attach where the fleet objective
    /// (max over devices of the per-device Eq. 5 objective, landing
    /// device as tie-break) ends lowest. Refused with
    /// [`AttachError::Admission`] only when no device has a stable
    /// configuration for it.
    pub fn attach(&self, model: &str, opts: AttachOptions) -> Result<TenantHandle, AttachError> {
        let meta = self
            .manifest
            .get(model)
            .map_err(AttachError::UnknownModel)?
            .clone();
        let newcomer = Tenant {
            model: meta,
            rate: opts.rate_hint,
        };
        // Score OUTSIDE the state lock: a hill climb is ms-scale and
        // submit() routes through the same lock — request routing must
        // not stall behind admission scoring. A racing attach may score
        // against a slightly stale snapshot; the member server still
        // enforces admission, and `rebalance` repairs placement drift.
        let members = self.members_by_device();
        let current = self.device_objectives(&members);
        let n_attached: usize = members.iter().map(Vec::len).sum();
        let mut best: Option<(f64, f64, usize)> = None;
        let mut refusal: Option<AdmissionError> = None;
        for (d, m) in members.iter().enumerate() {
            let dev = self.fleet.device(d);
            let mut cand: Vec<Tenant> = m.clone();
            cand.push(newcomer.clone());
            let plan = alloc::hill_climb(&dev.am, &cand, dev.k_max());
            if !plan.predicted_objective.is_finite() {
                let err = AdmissionError {
                    predicted_objective: plan.predicted_objective,
                    tpu_utilization: dev.am.tpu_utilization(&cand, &plan.config),
                    n_tenants: cand.len(),
                };
                if refusal.is_none() {
                    refusal = Some(err);
                }
                continue;
            }
            let mut objs = current.clone();
            objs[d] = plan.predicted_objective;
            let max = objs.iter().cloned().fold(0.0f64, f64::max);
            // All-finite tuple compare: (fleet max of per-device Eq. 5
            // objectives, landing device's objective). This is the same
            // lexicographic score the offline search minimizes — the
            // other devices' objectives are constants across the
            // candidate devices, so tie-breaking on the landing
            // objective is equivalent to tie-breaking on the fleet sum.
            // Unlike `place()`, existing tenants stay pinned (this is
            // incremental admission, not a re-layout; `rebalance`
            // handles that), which is why the scoring is a handful of
            // fresh climbs here instead of the memoized `Inner`.
            let better = match best {
                None => true,
                Some((bm, bd, _)) => (max, plan.predicted_objective) < (bm, bd),
            };
            if better {
                best = Some((max, plan.predicted_objective, d));
            }
        }
        let Some((_, _, d)) = best else {
            return Err(AttachError::Admission(refusal.unwrap_or(AdmissionError {
                predicted_objective: f64::INFINITY,
                tpu_utilization: f64::INFINITY,
                n_tenants: n_attached + 1,
            })));
        };
        self.attach_on(model, opts, d)
    }

    /// Attach pinned to a specific device (operators forcing a layout,
    /// and the sim-vs-live parity tests replaying a [`super::FleetPlan`]
    /// assignment). The device's own admission control still applies.
    pub fn attach_on(
        &self,
        model: &str,
        opts: AttachOptions,
        device: usize,
    ) -> Result<TenantHandle, AttachError> {
        assert!(device < self.servers.len(), "device {device} out of range");
        let meta = self
            .manifest
            .get(model)
            .map_err(AttachError::UnknownModel)?
            .clone();
        let rate_hint = opts.rate_hint;
        let class = opts.class;
        let inner = self.servers[device].attach(model, opts)?;
        let handle = TenantHandle(self.next_handle.fetch_add(1, Ordering::SeqCst));
        let index = {
            let mut st = self.state.lock().unwrap();
            st.push(FleetTenant {
                handle,
                tenant: Tenant {
                    model: meta,
                    rate: rate_hint,
                },
                class,
                device,
                inner,
            });
            st.len() - 1
        };
        self.flush_arrivals();
        self.placement.lock().unwrap().on_attach(self.now(), index);
        Ok(handle)
    }

    /// Remove a tenant from the fleet (routes to its device's detach:
    /// queued jobs fail typed, stats retire under the device handle).
    pub fn detach(&self, handle: TenantHandle) -> Result<TenantStats> {
        let (index, device, inner) = {
            let mut st = self.state.lock().unwrap();
            let Some(i) = st.iter().position(|t| t.handle == handle) else {
                return Err(anyhow::anyhow!("{handle} is not attached to the fleet"));
            };
            let t = st.remove(i);
            (i, t.device, t.inner)
        };
        self.flush_arrivals();
        self.placement.lock().unwrap().on_detach(self.now(), index);
        self.servers[device].detach(inner)
    }

    /// Route a request to the owning device. The returned [`Ticket`] is
    /// the member server's (its `tenant()` is the device-scoped handle);
    /// an unknown fleet handle resolves immediately with
    /// [`RequestError::NotAttached`].
    pub fn submit(&self, handle: TenantHandle, request: impl Into<Request>) -> Ticket {
        let request = request.into();
        let routed = {
            let st = self.state.lock().unwrap();
            st.iter()
                .position(|t| t.handle == handle)
                .map(|i| (i, st[i].device, st[i].inner))
        };
        match routed {
            Some((index, device, inner)) => {
                {
                    // Feed the placement policy's rate monitor. Bounded:
                    // a deployment that never calls `rebalance` must not
                    // leak observations without limit — beyond the cap,
                    // older buffered entries are dropped (the monitor's
                    // sliding window would discard them anyway). The
                    // positional index can be stale by the time it is
                    // flushed (a racing detach renumbers positions) —
                    // the same bounded misattribution the single-device
                    // server accepts: at worst one monitor window of one
                    // tenant's arrivals credited to a shifted peer, and
                    // out-of-range indices are ignored by the monitor.
                    let mut buf = self.arrivals.lock().unwrap();
                    if buf.len() >= 100_000 {
                        buf.drain(..50_000);
                    }
                    buf.push((self.now(), index));
                }
                self.servers[device].submit(inner, request)
            }
            None => {
                let cancel = request.cancel_token();
                let (tx, rx) = mpsc::channel();
                let _ = tx.send(Err(RequestError::NotAttached(handle)));
                crate::coordinator::request::Ticket::new(rx, cancel, handle)
            }
        }
    }

    /// Drain buffered submit observations into the placement policy's
    /// rate monitor. Caller must NOT hold the placement lock.
    fn flush_arrivals(&self) {
        let batch: Vec<(f64, usize)> = std::mem::take(&mut *self.arrivals.lock().unwrap());
        if batch.is_empty() {
            return;
        }
        let mut policy = self.placement.lock().unwrap();
        for (t, i) in batch {
            policy.observe_arrival(t, i);
        }
    }

    /// Drain-then-move migration of `handle` to `to_device`:
    /// admission-attach on the target, reroute new submits, wait for the
    /// source device to drain the tenant's queued/in-flight work, then
    /// detach from the source. Returns `Ok(false)` if the tenant already
    /// lives there (or raced a detach); admission refusal on the target
    /// is an error and leaves the tenant untouched.
    pub fn migrate(&self, handle: TenantHandle, to_device: usize) -> Result<bool> {
        if to_device >= self.servers.len() {
            return Err(anyhow::anyhow!(
                "device {to_device} out of range ({} devices)",
                self.servers.len()
            ));
        }
        let Some((src, old_inner, name, rate_hint, class)) = ({
            let st = self.state.lock().unwrap();
            st.iter().find(|t| t.handle == handle).map(|t| {
                (
                    t.device,
                    t.inner,
                    t.tenant.model.name.clone(),
                    t.tenant.rate,
                    t.class,
                )
            })
        }) else {
            return Err(anyhow::anyhow!("{handle} is not attached to the fleet"));
        };
        if src == to_device {
            return Ok(false);
        }
        // 1. Admission-checked attach on the target.
        let new_inner = self.servers[to_device]
            .attach(&name, AttachOptions { rate_hint, class })
            .map_err(|e| anyhow::anyhow!("migration to device {to_device} refused: {e}"))?;
        // 2. Reroute — new submits flow to the target from here on.
        let rerouted = {
            let mut st = self.state.lock().unwrap();
            match st
                .iter_mut()
                .find(|t| t.handle == handle && t.device == src && t.inner == old_inner)
            {
                Some(t) => {
                    t.device = to_device;
                    t.inner = new_inner;
                    true
                }
                None => false,
            }
        };
        if !rerouted {
            // Raced a detach or another migration: undo the target attach.
            let _ = self.servers[to_device].detach(new_inner);
            return Ok(false);
        }
        // 3. Drain: wait (bounded by `drain_budget`) until the source
        // holds no queued or executing work for the tenant — in-service
        // TPU work is visible to `pending_for`; two consecutive zero
        // readings guard the microsecond station-handoff windows.
        let deadline = Instant::now() + self.drain_budget;
        let mut zeros = 0;
        while zeros < 2 && Instant::now() < deadline {
            if self.servers[src].pending_for(old_inner) == 0 {
                zeros += 1;
            } else {
                zeros = 0;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        // 4. Move: detach from the source. Stragglers past the drain
        // window fail with the same typed errors a plain detach produces.
        self.servers[src].detach(old_inner)?;
        self.migrations.fetch_add(1, Ordering::SeqCst);
        {
            let mut per = self.per_device_migrations.lock().unwrap();
            per[src] += 1;
            per[to_device] += 1;
        }
        Ok(true)
    }

    /// Ask the placement policy for a target assignment
    /// ([`ReconfigPolicy::decide_placement`] over the monitored rates)
    /// and execute the migrations it implies. Returns the number of
    /// tenants moved; a per-tenant admission refusal skips that move and
    /// continues.
    pub fn rebalance(&self) -> usize {
        let (handles, tenants, current) = {
            let st = self.state.lock().unwrap();
            (
                st.iter().map(|t| t.handle).collect::<Vec<_>>(),
                st.iter().map(|t| t.tenant.clone()).collect::<Vec<_>>(),
                st.iter().map(|t| t.device).collect::<Vec<_>>(),
            )
        };
        if tenants.is_empty() {
            return 0;
        }
        self.flush_arrivals();
        let target = self.placement.lock().unwrap().decide_placement(
            self.now(),
            &tenants,
            &self.fleet,
            &current,
        );
        let Some(target) = target else { return 0 };
        if target.len() != handles.len() {
            return 0;
        }
        let mut moved = 0;
        for ((&h, &dst), &src) in handles.iter().zip(&target).zip(&current) {
            if dst != src && dst < self.servers.len() {
                if let Ok(true) = self.migrate(h, dst) {
                    moved += 1;
                }
            }
        }
        moved
    }

    /// Aggregated statistics: per-device [`ServeStats`] with their
    /// `migrations` counters filled in, plus the fleet totals.
    pub fn stats(&self) -> FleetStats {
        let per = self.per_device_migrations.lock().unwrap().clone();
        let per_device: Vec<ServeStats> = self
            .servers
            .iter()
            .zip(&per)
            .map(|(s, &m)| {
                let mut stats = s.stats();
                stats.migrations = m;
                stats
            })
            .collect();
        FleetStats {
            per_device,
            migrations: self.migrations.load(Ordering::SeqCst),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareSpec;
    use crate::runtime::service::ExecBackend;

    fn builder(devices: usize) -> FleetServerBuilder {
        FleetServerBuilder::new(
            &Manifest::synthetic(),
            Fleet::uniform(devices, &HardwareSpec::default()),
        )
        .backend(ExecBackend::Emulated)
        .adaptive(false)
    }

    fn input_for(fs: &FleetServer, d: usize, inner_model: &str) -> Vec<f32> {
        let meta = fs.servers[d]
            .tenants()
            .iter()
            .find(|t| t.model.name == inner_model)
            .map(|t| t.model.clone())
            .expect("attached");
        vec![0.5; meta.input_shape.iter().product()]
    }

    #[test]
    fn routes_per_device_and_counts() {
        let fs = builder(2).build().unwrap();
        let ha = fs
            .attach_on("mobilenetv2", AttachOptions::default(), 0)
            .unwrap();
        let hb = fs
            .attach_on("squeezenet", AttachOptions::default(), 1)
            .unwrap();
        assert_eq!(fs.device_of(ha), Some(0));
        assert_eq!(fs.device_of(hb), Some(1));
        let ia = input_for(&fs, 0, "mobilenetv2");
        let ib = input_for(&fs, 1, "squeezenet");
        let mut pending = Vec::new();
        for _ in 0..10 {
            pending.push(fs.submit(ha, ia.clone()));
            pending.push(fs.submit(hb, ib.clone()));
        }
        for t in pending {
            t.wait().unwrap();
        }
        let stats = fs.stats();
        assert_eq!(stats.completed_per_device(), vec![10, 10]);
        assert_eq!(stats.completed(), 20);
        assert_eq!(stats.failed(), 0);
        assert_eq!(stats.migrations, 0);
        assert_eq!(stats.per_class().total_count(), 20);
    }

    #[test]
    fn fleet_attach_spreads_conflicting_tenants() {
        // Two big-prefix tenants cannot co-reside in one SRAM: unpinned
        // fleet attach must land them on different devices.
        let fs = builder(2).build().unwrap();
        let h1 = fs
            .attach(
                "inceptionv4",
                AttachOptions {
                    rate_hint: 2.0,
                    ..Default::default()
                },
            )
            .unwrap();
        let h2 = fs
            .attach(
                "xception",
                AttachOptions {
                    rate_hint: 2.0,
                    ..Default::default()
                },
            )
            .unwrap();
        assert_ne!(fs.device_of(h1), fs.device_of(h2));
    }

    #[test]
    fn migration_drain_then_move() {
        let fs = builder(2).build().unwrap();
        let ha = fs
            .attach_on("mobilenetv2", AttachOptions::default(), 0)
            .unwrap();
        let hb = fs
            .attach_on("squeezenet", AttachOptions::default(), 0)
            .unwrap();
        let ia = input_for(&fs, 0, "mobilenetv2");
        let ib = input_for(&fs, 0, "squeezenet");
        for _ in 0..5 {
            fs.submit(ha, ia.clone()).wait().unwrap();
            fs.submit(hb, ib.clone()).wait().unwrap();
        }
        assert!(fs.migrate(hb, 1).unwrap());
        assert_eq!(fs.device_of(hb), Some(1));
        // Self-move is a no-op.
        assert!(!fs.migrate(hb, 1).unwrap());
        for _ in 0..5 {
            fs.submit(hb, ib.clone()).wait().unwrap();
        }
        let stats = fs.stats();
        assert_eq!(stats.migrations, 1);
        assert_eq!(stats.per_device[0].migrations, 1);
        assert_eq!(stats.per_device[1].migrations, 1);
        // Device 1 served the migrated tenant's post-move traffic.
        assert_eq!(stats.per_device[1].completed, 5);
        // Drained before the move: nothing failed.
        assert_eq!(stats.failed(), 0);
        assert_eq!(stats.completed(), 15);
    }

    #[test]
    fn unknown_handle_resolves_not_attached() {
        let fs = builder(1).build().unwrap();
        match fs.submit(TenantHandle(99), vec![0.5; 4]).wait() {
            Err(RequestError::NotAttached(h)) => assert_eq!(h, TenantHandle(99)),
            other => panic!("expected NotAttached, got {other:?}"),
        }
        assert!(fs.detach(TenantHandle(99)).is_err());
        assert!(fs.migrate(TenantHandle(99), 0).is_err());
    }

    #[test]
    fn rebalance_splits_colocated_tenants_once_rates_are_seen() {
        let fs = builder(2).build().unwrap();
        let ha = fs
            .attach_on("inceptionv4", AttachOptions::default(), 0)
            .unwrap();
        let hb = fs
            .attach_on("xception", AttachOptions::default(), 0)
            .unwrap();
        // No observed traffic: the policy has no rates, no move.
        assert_eq!(fs.rebalance(), 0);
        let ia = input_for(&fs, 0, "inceptionv4");
        let ib = input_for(&fs, 0, "xception");
        for _ in 0..12 {
            fs.submit(ha, ia.clone()).wait().unwrap();
            fs.submit(hb, ib.clone()).wait().unwrap();
        }
        let moved = fs.rebalance();
        assert!(moved >= 1, "no migration despite conflicting colocation");
        assert_ne!(fs.device_of(ha), fs.device_of(hb));
        assert_eq!(fs.stats().migrations, moved as u64);
    }
}
