//! The fleet router: one live [`Server`] per registered device —
//! each with its own TPU worker queue, SRAM cache, CPU pools, and
//! per-device SwapLess re-allocator — behind a placement-aware dispatch
//! layer with tenant migration.
//!
//! Tenants attach *to the fleet*: admission scores the candidate on every
//! device with the inner allocator (the same two-level criterion as
//! [`place`](super::place::place), incrementally) and lands the tenant on
//! the device that minimizes the fleet objective. Requests carry
//! fleet-scoped [`TenantHandle`]s; [`FleetServer::submit`] routes each to
//! the owning device's server, which runs the full validated
//! single-device request lifecycle (bounded admission, typed
//! backpressure, tickets).
//!
//! **Migration** is drain-then-move: attach on the target device
//! (admission-checked — a refused migration leaves the tenant where it
//! is), reroute new submits, wait for the source device's queued and
//! in-flight work to drain, then detach from the source (stragglers past
//! the drain window fail with typed errors, exactly like a detach).
//! Moves are counted per device in [`ServeStats::migrations`] and
//! fleet-wide in [`FleetStats::migrations`].
//!
//! Re-placement is policy-driven through
//! [`ReconfigPolicy::decide_placement`]: the submit path feeds the
//! policy's rate monitor (buffered, like the single-device server), and
//! [`FleetServer::rebalance`] asks the policy for a target assignment and
//! executes the migrations it implies.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::alloc::{self, AdmissionError};
use crate::analytic::{Config, Tenant, TenantHandle};
use crate::config::RuntimeConfig;
use crate::coordinator::{
    AttachError, AttachOptions, ConfigError, Request, RequestError, ServeStats, Server,
    ServerBuilder, ServerOptions, TenantStats, Ticket,
};
use crate::eventlog::{Event as LogEvent, EventKind as LogKind, EventLog};
use crate::fault::{FaultPlan, Health};
use crate::model::Manifest;
use crate::runtime::service::ExecBackend;
use crate::sched::SloClass;
use crate::sim::reconfig::{ReconfigPolicy, SwapLessPolicy};
use crate::telemetry::{ProfiledCostModel, PromWriter};
use crate::util::sync::lock_or_recover;

use super::Fleet;

/// Fluent construction of a [`FleetServer`].
pub struct FleetServerBuilder {
    manifest: Manifest,
    fleet: Fleet,
    opts: ServerOptions,
    placement: Option<Box<dyn ReconfigPolicy + Send>>,
}

impl FleetServerBuilder {
    pub fn new(manifest: &Manifest, fleet: Fleet) -> FleetServerBuilder {
        FleetServerBuilder {
            manifest: manifest.clone(),
            fleet,
            opts: ServerOptions::default(),
            placement: None,
        }
    }

    /// Base options applied to every member server (`device` and `k_max`
    /// are overridden per device from the registry).
    pub fn options(mut self, opts: ServerOptions) -> Self {
        self.opts = opts;
        self
    }

    pub fn backend(mut self, b: crate::runtime::service::ExecBackend) -> Self {
        self.opts.backend = b;
        self
    }

    pub fn time_scale(mut self, v: f64) -> Self {
        self.opts.time_scale = v;
        self
    }

    pub fn adaptive(mut self, on: bool) -> Self {
        self.opts.adaptive = on;
        self
    }

    pub fn discipline(mut self, d: crate::sched::DisciplineKind) -> Self {
        self.opts.discipline = d;
        self
    }

    pub fn overload(mut self, p: crate::sched::OverloadPolicy) -> Self {
        self.opts.overload = p;
        self
    }

    pub fn queue_capacity(mut self, cap: usize) -> Self {
        self.opts.queue_capacity = Some(cap);
        self
    }

    /// Stage-span sampling cadence (1-in-`every`; 0 disables) applied to
    /// every member server.
    pub fn span_sample(mut self, every: usize) -> Self {
        self.opts.span_sample = every;
        self
    }

    /// Span-calibrated profiled cost model shared by every member
    /// server: each member keys its tenants' tables with its own device
    /// index, so per-device calibration points land on the right device.
    pub fn profile(mut self, pm: Arc<ProfiledCostModel>) -> Self {
        self.opts.profile = Some(pm);
        self
    }

    /// Install a custom placement policy (drives
    /// [`FleetServer::rebalance`]); defaults to a [`SwapLessPolicy`]
    /// whose `decide_placement` runs the two-level search on monitored
    /// rates.
    pub fn placement_policy(mut self, p: Box<dyn ReconfigPolicy + Send>) -> Self {
        self.placement = Some(p);
        self
    }

    /// Inject a deterministic fleet-wide fault schedule: every member
    /// server gets a [`FaultInjector`](crate::fault::FaultInjector) for
    /// its device, all anchored at one shared wall-clock origin so the
    /// plan replays consistently across the fleet.
    pub fn faults(mut self, plan: FaultPlan) -> Self {
        self.opts.faults = Some(Arc::new(plan));
        self
    }

    /// Attach an append-only event log shared by every member server:
    /// each device stamps its records with its device index, and the
    /// fleet layer adds migration/failover records. The fleet owns the
    /// log's lifetime — it is flushed and closed when the
    /// [`FleetServer`] drops, after every member has wound down.
    pub fn log(mut self, log: EventLog) -> Self {
        self.opts.log = Some(log);
        self
    }

    pub fn build(self) -> Result<FleetServer> {
        FleetServer::new(self.manifest, self.fleet, self.opts, self.placement)
    }
}

/// One fleet-attached tenant and where it currently lives.
struct FleetTenant {
    handle: TenantHandle,
    /// Model + declared rate hint (what placement scoring plans with).
    tenant: Tenant,
    class: SloClass,
    device: usize,
    /// The tenant's handle on `servers[device]`.
    inner: TenantHandle,
    /// The device its current *intended* placement chose (attach or
    /// policy-driven migration). `device != home` means the tenant is
    /// running on a failover target.
    home: usize,
    /// Requests routed away from `home` (served by a failover target) —
    /// the live half of the sim-vs-live failed-over parity accounting.
    failed_over: u64,
}

/// Aggregated fleet statistics: the per-device [`ServeStats`] (with
/// their `migrations` counters filled in) plus fleet totals.
#[derive(Debug, Clone)]
pub struct FleetStats {
    /// Indexed by device.
    pub per_device: Vec<ServeStats>,
    /// Tenant moves completed (each drain-then-move counts once).
    pub migrations: u64,
    /// Forced failovers executed (one per handled device outage).
    pub failovers: u64,
    /// Queued tickets requeued from a crashed device onto a survivor
    /// with their completion senders intact.
    pub requeued: u64,
    /// Requests routed away from their tenant's home placement, i.e.
    /// served by a failover target.
    pub failed_over: u64,
    /// Tenants shed during failover because no surviving capacity
    /// remained even for a CPU-only degrade placement.
    pub shed_tenants: u64,
}

impl FleetStats {
    pub fn completed(&self) -> u64 {
        self.per_device.iter().map(|s| s.completed).sum()
    }

    pub fn failed(&self) -> u64 {
        self.per_device.iter().map(|s| s.failed).sum()
    }

    pub fn accepted(&self) -> u64 {
        self.per_device.iter().map(|s| s.accepted).sum()
    }

    pub fn dropped(&self) -> u64 {
        self.per_device.iter().map(|s| s.dropped()).sum()
    }

    pub fn completed_per_device(&self) -> Vec<u64> {
        self.per_device.iter().map(|s| s.completed).collect()
    }

    /// Per-SLO-class accounting merged across devices.
    pub fn per_class(&self) -> crate::metrics::PerClassLatency {
        let mut merged = crate::metrics::PerClassLatency::new();
        for s in &self.per_device {
            merged.merge(&s.per_class);
        }
        merged
    }
}

/// Live multi-device inference router (see the module docs).
pub struct FleetServer {
    fleet: Fleet,
    servers: Vec<Server>,
    manifest: Manifest,
    state: Mutex<Vec<FleetTenant>>,
    /// Placement policy + its buffered arrival feed (same
    /// never-block-submitters pattern as the single-device server).
    placement: Mutex<Box<dyn ReconfigPolicy + Send>>,
    arrivals: Mutex<Vec<(f64, usize)>>,
    next_handle: AtomicU64,
    migrations: AtomicU64,
    per_device_migrations: Mutex<Vec<u64>>,
    /// How long a migration waits for the source device to drain before
    /// detaching (stragglers past it fail with typed errors). Scaled up
    /// under real-time emulation, where one service spans many polls.
    drain_budget: Duration,
    /// Devices whose current outage has already been failed over —
    /// [`poll_health`](Self::poll_health) triggers once per outage and
    /// re-arms when the device comes back up.
    down_handled: Mutex<Vec<bool>>,
    failovers: AtomicU64,
    requeued: AtomicU64,
    failed_over: AtomicU64,
    shed_tenants: AtomicU64,
    /// Shared event log (fleet-owned: members carry `log_owned: false`).
    log: Option<EventLog>,
    started: Instant,
}

impl FleetServer {
    fn new(
        manifest: Manifest,
        fleet: Fleet,
        mut opts: ServerOptions,
        placement: Option<Box<dyn ReconfigPolicy + Send>>,
    ) -> Result<FleetServer> {
        // One shared origin anchors the fault plan's timeline for every
        // member, so crash/recovery windows line up fleet-wide.
        if opts.faults.is_some() && opts.fault_origin.is_none() {
            opts.fault_origin = Some(Instant::now());
        }
        let mut servers = Vec::with_capacity(fleet.len());
        for (d, dev) in fleet.devices().iter().enumerate() {
            let member_opts = ServerOptions {
                device: d,
                k_max: dev.k_max(),
                // The fleet closes the shared log once, after every
                // member has wound down — members must not.
                log_owned: false,
                ..opts.clone()
            };
            // Reuse the registry's per-device cost model — the single
            // derivation the whole fleet layer plans against.
            servers.push(
                ServerBuilder::new(&manifest, dev.cost.clone())
                    .options(member_opts)
                    .build()?,
            );
        }
        // The default placement policy honors the operator's runtime
        // knobs (rate window etc.), exactly like the member servers'
        // own re-allocators do.
        let rt: &RuntimeConfig = &opts.runtime;
        let placement = placement.unwrap_or_else(|| {
            Box::new(SwapLessPolicy::new(
                fleet.device(0).am.clone(),
                fleet.device(0).k_max(),
                0,
                rt.rate_window_s,
                rt.realloc_period_s,
                rt.realloc_threshold,
            ))
        });
        let n_devices = fleet.len();
        // Fast emulation drains in microseconds; real-time emulation or
        // a hardware backend needs queue-depth × service-time headroom.
        let drain_budget = if opts.time_scale > 0.0 || opts.backend == ExecBackend::Pjrt {
            Duration::from_secs(10)
        } else {
            Duration::from_millis(500)
        };
        Ok(FleetServer {
            fleet,
            servers,
            manifest,
            state: Mutex::new(Vec::new()),
            placement: Mutex::new(placement),
            arrivals: Mutex::new(Vec::new()),
            next_handle: AtomicU64::new(0),
            migrations: AtomicU64::new(0),
            per_device_migrations: Mutex::new(vec![0; n_devices]),
            drain_budget,
            down_handled: Mutex::new(vec![false; n_devices]),
            failovers: AtomicU64::new(0),
            requeued: AtomicU64::new(0),
            failed_over: AtomicU64::new(0),
            shed_tenants: AtomicU64::new(0),
            log: opts.log.clone(),
            started: Instant::now(),
        })
    }

    fn now(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Number of devices in the registry.
    pub fn devices(&self) -> usize {
        self.servers.len()
    }

    /// Direct access to a member server (tests, config overrides).
    pub fn server(&self, d: usize) -> &Server {
        &self.servers[d]
    }

    /// The device currently serving `handle`, if attached.
    pub fn device_of(&self, handle: TenantHandle) -> Option<usize> {
        lock_or_recover(&self.state)
            .iter()
            .find(|t| t.handle == handle)
            .map(|t| t.device)
    }

    /// Fleet-scoped handles in attach order.
    pub fn handles(&self) -> Vec<TenantHandle> {
        lock_or_recover(&self.state).iter().map(|t| t.handle).collect()
    }

    /// Input tensor length (f32 count) `handle`'s model expects per
    /// request; `None` when not attached (the wire handshake).
    pub fn input_len(&self, handle: TenantHandle) -> Option<usize> {
        lock_or_recover(&self.state)
            .iter()
            .find(|t| t.handle == handle)
            .map(|t| t.tenant.model.input_shape.iter().product())
    }

    /// Manually install a (P, K) configuration on one device (parity
    /// tests, static baselines). Dimensions are validated against the
    /// device's live tenant count.
    pub fn set_device_config(
        &self,
        device: usize,
        cfg: Config,
    ) -> std::result::Result<(), ConfigError> {
        self.servers[device].set_config(cfg)
    }

    /// Snapshot each device's current member tenants (placement-scoring
    /// input) without holding the state lock any longer than the copy.
    fn members_by_device(&self) -> Vec<Vec<Tenant>> {
        let st = lock_or_recover(&self.state);
        (0..self.servers.len())
            .map(|d| {
                st.iter()
                    .filter(|t| t.device == d)
                    .map(|t| t.tenant.clone())
                    .collect()
            })
            .collect()
    }

    /// Per-device Eq. 5 objective of each device's member set (the
    /// incremental placement scoring baseline — same per-device score as
    /// [`super::place::place`]).
    fn device_objectives(&self, members: &[Vec<Tenant>]) -> Vec<f64> {
        members
            .iter()
            .enumerate()
            .map(|(d, m)| {
                if m.is_empty() {
                    return 0.0;
                }
                let dev = self.fleet.device(d);
                alloc::hill_climb(&dev.am, m, dev.k_max()).predicted_objective
            })
            .collect()
    }

    /// Admit a tenant onto the fleet: score the candidate on every device
    /// with the inner allocator and attach where the fleet objective
    /// (max over devices of the per-device Eq. 5 objective, landing
    /// device as tie-break) ends lowest. Refused with
    /// [`AttachError::Admission`] only when no device has a stable
    /// configuration for it.
    pub fn attach(&self, model: &str, opts: AttachOptions) -> Result<TenantHandle, AttachError> {
        let meta = self
            .manifest
            .get(model)
            .map_err(AttachError::UnknownModel)?
            .clone();
        let newcomer = Tenant {
            model: meta,
            rate: opts.rate_hint,
        };
        // Score OUTSIDE the state lock: a hill climb is ms-scale and
        // submit() routes through the same lock — request routing must
        // not stall behind admission scoring. A racing attach may score
        // against a slightly stale snapshot; the member server still
        // enforces admission, and `rebalance` repairs placement drift.
        let members = self.members_by_device();
        let current = self.device_objectives(&members);
        let n_attached: usize = members.iter().map(Vec::len).sum();
        let mut best: Option<(f64, f64, usize)> = None;
        let mut refusal: Option<AdmissionError> = None;
        for (d, m) in members.iter().enumerate() {
            let dev = self.fleet.device(d);
            let mut cand: Vec<Tenant> = m.clone();
            cand.push(newcomer.clone());
            let plan = alloc::hill_climb(&dev.am, &cand, dev.k_max());
            if !plan.predicted_objective.is_finite() {
                let err = AdmissionError {
                    predicted_objective: plan.predicted_objective,
                    tpu_utilization: dev.am.tpu_utilization(&cand, &plan.config),
                    n_tenants: cand.len(),
                };
                if refusal.is_none() {
                    refusal = Some(err);
                }
                continue;
            }
            let mut objs = current.clone();
            objs[d] = plan.predicted_objective;
            let max = objs.iter().cloned().fold(0.0f64, f64::max);
            // All-finite tuple compare: (fleet max of per-device Eq. 5
            // objectives, landing device's objective). This is the same
            // lexicographic score the offline search minimizes — the
            // other devices' objectives are constants across the
            // candidate devices, so tie-breaking on the landing
            // objective is equivalent to tie-breaking on the fleet sum.
            // Unlike `place()`, existing tenants stay pinned (this is
            // incremental admission, not a re-layout; `rebalance`
            // handles that), which is why the scoring is a handful of
            // fresh climbs here instead of the memoized `Inner`.
            let better = match best {
                None => true,
                Some((bm, bd, _)) => (max, plan.predicted_objective) < (bm, bd),
            };
            if better {
                best = Some((max, plan.predicted_objective, d));
            }
        }
        let Some((_, _, d)) = best else {
            return Err(AttachError::Admission(refusal.unwrap_or(AdmissionError {
                predicted_objective: f64::INFINITY,
                tpu_utilization: f64::INFINITY,
                n_tenants: n_attached + 1,
            })));
        };
        self.attach_on(model, opts, d)
    }

    /// Attach pinned to a specific device (operators forcing a layout,
    /// and the sim-vs-live parity tests replaying a [`super::FleetPlan`]
    /// assignment). The device's own admission control still applies.
    pub fn attach_on(
        &self,
        model: &str,
        opts: AttachOptions,
        device: usize,
    ) -> Result<TenantHandle, AttachError> {
        assert!(device < self.servers.len(), "device {device} out of range");
        let meta = self
            .manifest
            .get(model)
            .map_err(AttachError::UnknownModel)?
            .clone();
        let rate_hint = opts.rate_hint;
        let class = opts.class;
        let inner = self.servers[device].attach(model, opts)?;
        let handle = TenantHandle(self.next_handle.fetch_add(1, Ordering::SeqCst));
        let index = {
            let mut st = lock_or_recover(&self.state);
            st.push(FleetTenant {
                handle,
                tenant: Tenant {
                    model: meta,
                    rate: rate_hint,
                },
                class,
                device,
                inner,
                home: device,
                failed_over: 0,
            });
            st.len() - 1
        };
        self.flush_arrivals();
        lock_or_recover(&self.placement).on_attach(self.now(), index);
        Ok(handle)
    }

    /// Remove a tenant from the fleet (routes to its device's detach:
    /// queued jobs fail typed, stats retire under the device handle).
    pub fn detach(&self, handle: TenantHandle) -> Result<TenantStats> {
        let (index, device, inner) = {
            let mut st = lock_or_recover(&self.state);
            let Some(i) = st.iter().position(|t| t.handle == handle) else {
                return Err(anyhow::anyhow!("{handle} is not attached to the fleet"));
            };
            let t = st.remove(i);
            (i, t.device, t.inner)
        };
        self.flush_arrivals();
        lock_or_recover(&self.placement).on_detach(self.now(), index);
        self.servers[device].detach(inner)
    }

    /// Route a request to the owning device. The returned [`Ticket`] is
    /// the member server's (its `tenant()` is the device-scoped handle);
    /// an unknown fleet handle resolves immediately with
    /// [`RequestError::NotAttached`].
    pub fn submit(&self, handle: TenantHandle, request: impl Into<Request>) -> Ticket {
        let request = request.into();
        let routed = {
            let mut st = lock_or_recover(&self.state);
            match st.iter().position(|t| t.handle == handle) {
                Some(i) => {
                    let t = &mut st[i];
                    // Routed off its home placement = served by a
                    // failover target; counted for the sim-vs-live
                    // failed-over parity accounting.
                    if t.device != t.home {
                        t.failed_over += 1;
                        self.failed_over.fetch_add(1, Ordering::SeqCst);
                        if let Some(log) = &self.log {
                            // Fleet-scoped record: `tenant` is the FLEET
                            // handle (a separate namespace from member
                            // handles), `device` the home placement,
                            // `aux` the serving failover target.
                            let mut ev = LogEvent::new(
                                LogKind::Failover,
                                self.now(),
                                t.home,
                                handle.0,
                                t.class,
                            );
                            ev.aux = t.device as u16;
                            log.emit(ev);
                        }
                    }
                    Some((i, t.device, t.inner))
                }
                None => None,
            }
        };
        match routed {
            Some((index, device, inner)) => {
                {
                    // Feed the placement policy's rate monitor. Bounded:
                    // a deployment that never calls `rebalance` must not
                    // leak observations without limit — beyond the cap,
                    // older buffered entries are dropped (the monitor's
                    // sliding window would discard them anyway). The
                    // positional index can be stale by the time it is
                    // flushed (a racing detach renumbers positions) —
                    // the same bounded misattribution the single-device
                    // server accepts: at worst one monitor window of one
                    // tenant's arrivals credited to a shifted peer, and
                    // out-of-range indices are ignored by the monitor.
                    let mut buf = lock_or_recover(&self.arrivals);
                    if buf.len() >= 100_000 {
                        buf.drain(..50_000);
                    }
                    buf.push((self.now(), index));
                }
                self.servers[device].submit(inner, request)
            }
            None => {
                let cancel = request.cancel_token();
                let (tx, rx) = mpsc::channel();
                let _ = tx.send(Err(RequestError::NotAttached(handle)));
                crate::coordinator::request::Ticket::new(rx, cancel, handle)
            }
        }
    }

    /// Drain buffered submit observations into the placement policy's
    /// rate monitor. Caller must NOT hold the placement lock.
    fn flush_arrivals(&self) {
        let batch: Vec<(f64, usize)> = std::mem::take(&mut *lock_or_recover(&self.arrivals));
        if batch.is_empty() {
            return;
        }
        let mut policy = lock_or_recover(&self.placement);
        for (t, i) in batch {
            policy.observe_arrival(t, i);
        }
    }

    /// Drain-then-move migration of `handle` to `to_device`:
    /// admission-attach on the target, reroute new submits, wait for the
    /// source device to drain the tenant's queued/in-flight work, then
    /// detach from the source. Returns `Ok(false)` if the tenant already
    /// lives there (or raced a detach); admission refusal on the target
    /// is an error and leaves the tenant untouched.
    pub fn migrate(&self, handle: TenantHandle, to_device: usize) -> Result<bool> {
        if to_device >= self.servers.len() {
            return Err(anyhow::anyhow!(
                "device {to_device} out of range ({} devices)",
                self.servers.len()
            ));
        }
        let Some((src, old_inner, name, rate_hint, class)) = ({
            let st = lock_or_recover(&self.state);
            st.iter().find(|t| t.handle == handle).map(|t| {
                (
                    t.device,
                    t.inner,
                    t.tenant.model.name.clone(),
                    t.tenant.rate,
                    t.class,
                )
            })
        }) else {
            return Err(anyhow::anyhow!("{handle} is not attached to the fleet"));
        };
        if src == to_device {
            return Ok(false);
        }
        // 1. Admission-checked attach on the target.
        let new_inner = self.servers[to_device]
            .attach(&name, AttachOptions { rate_hint, class })
            .map_err(|e| anyhow::anyhow!("migration to device {to_device} refused: {e}"))?;
        // 2. Reroute — new submits flow to the target from here on.
        let rerouted = {
            let mut st = lock_or_recover(&self.state);
            match st
                .iter_mut()
                .find(|t| t.handle == handle && t.device == src && t.inner == old_inner)
            {
                Some(t) => {
                    t.device = to_device;
                    t.inner = new_inner;
                    // A policy-driven move re-homes the tenant (unlike a
                    // forced failover, which keeps `home` pointing at the
                    // intended placement).
                    t.home = to_device;
                    true
                }
                None => false,
            }
        };
        if !rerouted {
            // Raced a detach or another migration: undo the target attach.
            let _ = self.servers[to_device].detach(new_inner);
            return Ok(false);
        }
        // 3. Drain: wait (bounded by `drain_budget`) until the source
        // holds no queued or executing work for the tenant — in-service
        // TPU work is visible to `pending_for`; two consecutive zero
        // readings guard the microsecond station-handoff windows.
        let deadline = Instant::now() + self.drain_budget;
        let mut zeros = 0;
        while zeros < 2 && Instant::now() < deadline {
            if self.servers[src].pending_for(old_inner) == 0 {
                zeros += 1;
            } else {
                zeros = 0;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        // 4. Move: detach from the source. Stragglers past the drain
        // window fail with the same typed errors a plain detach produces.
        // A concurrent fleet-level detach that won the race detaches the
        // TARGET handle, never this source handle, so a failure here is
        // tolerated rather than propagated — the reroute above is already
        // effective and every source-side ticket has resolved typed.
        let _ = self.servers[src].detach(old_inner);
        self.migrations.fetch_add(1, Ordering::SeqCst);
        {
            let mut per = lock_or_recover(&self.per_device_migrations);
            per[src] += 1;
            per[to_device] += 1;
        }
        if let Some(log) = &self.log {
            // `device` = source, `aux` = target, `tenant` = fleet handle.
            let mut ev = LogEvent::new(LogKind::Migrate, self.now(), src, handle.0, class);
            ev.aux = to_device as u16;
            log.emit(ev);
        }
        Ok(true)
    }

    /// Ask the placement policy for a target assignment
    /// ([`ReconfigPolicy::decide_placement`] over the monitored rates)
    /// and execute the migrations it implies. Returns the number of
    /// tenants moved; a per-tenant admission refusal skips that move and
    /// continues.
    pub fn rebalance(&self) -> usize {
        let (handles, tenants, current) = {
            let st = lock_or_recover(&self.state);
            (
                st.iter().map(|t| t.handle).collect::<Vec<_>>(),
                st.iter().map(|t| t.tenant.clone()).collect::<Vec<_>>(),
                st.iter().map(|t| t.device).collect::<Vec<_>>(),
            )
        };
        if tenants.is_empty() {
            return 0;
        }
        self.flush_arrivals();
        let target = lock_or_recover(&self.placement).decide_placement(
            self.now(),
            &tenants,
            &self.fleet,
            &current,
        );
        let Some(target) = target else { return 0 };
        if target.len() != handles.len() {
            return 0;
        }
        let mut moved = 0;
        for ((&h, &dst), &src) in handles.iter().zip(&target).zip(&current) {
            if dst != src && dst < self.servers.len() {
                if let Ok(true) = self.migrate(h, dst) {
                    moved += 1;
                }
            }
        }
        moved
    }

    /// Health of every member device, indexed by device: the injected
    /// fault plan's view (a plan-driven `Down` dominates) combined with
    /// each worker's consecutive-execution-failure streak.
    pub fn health(&self) -> Vec<Health> {
        self.servers.iter().map(|s| s.health()).collect()
    }

    /// Requests `handle` has had routed away from its home placement
    /// (served by a failover target) — the live half of the sim-vs-live
    /// failed-over parity accounting.
    pub fn failed_over_of(&self, handle: TenantHandle) -> u64 {
        lock_or_recover(&self.state)
            .iter()
            .find(|t| t.handle == handle)
            .map(|t| t.failed_over)
            .unwrap_or(0)
    }

    /// Heartbeat hook: scan member health and run a forced failover for
    /// every device newly observed `Down`. Triggers once per outage (a
    /// recovered device re-arms the trigger). Deployments call this from
    /// their driver/control loop at heartbeat period — the CLI's serve
    /// driver does, as do the chaos tests. Returns tenants moved.
    pub fn poll_health(&self) -> usize {
        let mut moved = 0;
        for d in 0..self.servers.len() {
            let down = self.servers[d].health().is_down();
            let newly = {
                let mut seen = lock_or_recover(&self.down_handled);
                let newly = down && !seen[d];
                seen[d] = down;
                newly
            };
            if newly {
                moved += self.fail_over(d);
            }
        }
        moved
    }

    /// Forced failover of every tenant on a crashed device: extract its
    /// queued tickets (senders intact), re-place each tenant on the best
    /// surviving device through the normal admission path — highest SLO
    /// classes first, so they claim surviving capacity before lower
    /// classes — degrade to a CPU-only placement (partition 0) when no
    /// survivor admits the declared rate, and shed with typed errors
    /// only when even that fails. Requeued tickets get their deadlines
    /// translated onto the target's clock. Returns tenants re-placed.
    pub fn fail_over(&self, device: usize) -> usize {
        assert!(device < self.servers.len(), "device {device} out of range");
        let mut victims: Vec<(TenantHandle, TenantHandle, String, f64, SloClass)> = {
            let st = lock_or_recover(&self.state);
            st.iter()
                .filter(|t| t.device == device)
                .map(|t| {
                    (
                        t.handle,
                        t.inner,
                        t.tenant.model.name.clone(),
                        t.tenant.rate,
                        t.class,
                    )
                })
                .collect()
        };
        victims.sort_by_key(|v| v.4.priority());
        let mut moved = 0;
        for (handle, old_inner, name, rate, class) in victims {
            // Extract queued tickets BEFORE the detach below, whose purge
            // would resolve them with `Detached` instead of requeueing.
            let drained = self.servers[device].drain_for_failover(old_inner);
            match self.place_survivor(device, &name, rate, class) {
                Some((to, new_inner)) => {
                    // Reroute; tolerate a racing fleet-level detach.
                    let rerouted = {
                        let mut st = lock_or_recover(&self.state);
                        match st
                            .iter_mut()
                            .find(|t| t.handle == handle && t.inner == old_inner)
                        {
                            Some(t) => {
                                t.device = to;
                                t.inner = new_inner;
                                true
                            }
                            None => false,
                        }
                    };
                    if !rerouted {
                        let _ = self.servers[to].detach(new_inner);
                        for job in drained {
                            let _ = job.done.send(Err(RequestError::Detached(handle)));
                        }
                        let _ = self.servers[device].detach(old_inner);
                        continue;
                    }
                    let src_now = self.servers[device].now_s();
                    let dst_now = self.servers[to].now_s();
                    for job in drained {
                        let deadline = match job.deadline {
                            Some(d) => Some(d - src_now + dst_now),
                            None => None,
                        };
                        self.servers[to].resubmit_failover(new_inner, job, deadline);
                        self.requeued.fetch_add(1, Ordering::SeqCst);
                    }
                    let _ = self.servers[device].detach(old_inner);
                    moved += 1;
                }
                None => {
                    // No capacity anywhere, not even degraded: shed the
                    // tenant — every stranded ticket resolves typed.
                    {
                        let mut st = lock_or_recover(&self.state);
                        if let Some(i) = st
                            .iter()
                            .position(|t| t.handle == handle && t.inner == old_inner)
                        {
                            st.remove(i);
                        }
                    }
                    for job in drained {
                        let _ = job.done.send(Err(RequestError::Shed {
                            station: "fleet".to_string(),
                        }));
                    }
                    let _ = self.servers[device].detach(old_inner);
                    self.shed_tenants.fetch_add(1, Ordering::SeqCst);
                }
            }
        }
        self.failovers.fetch_add(1, Ordering::SeqCst);
        if let Some(log) = &self.log {
            // Outage marker: one record per handled device outage
            // (`tenant` = sentinel, distinct from the per-request
            // off-home `Failover` records emitted on the submit path).
            let mut ev = LogEvent::new(
                LogKind::Failover,
                self.now(),
                device,
                u64::MAX,
                SloClass::Standard,
            );
            ev.marker = true;
            log.emit(ev);
        }
        moved
    }

    /// Failover target selection: the normal admission scoring
    /// (incremental two-level criterion) restricted to devices that are
    /// not `Down`. Falls back to a zero-rate attach pinned to partition
    /// 0 on the emptiest survivor — CPU-only degrade — when no survivor
    /// admits the declared rate; `None` = shed (no survivors, or even
    /// the degrade attach refused).
    fn place_survivor(
        &self,
        dead: usize,
        name: &str,
        rate: f64,
        class: SloClass,
    ) -> Option<(usize, TenantHandle)> {
        let survivors: Vec<usize> = (0..self.servers.len())
            .filter(|&d| d != dead && !self.servers[d].health().is_down())
            .collect();
        if survivors.is_empty() {
            return None;
        }
        let members = self.members_by_device();
        let current = self.device_objectives(&members);
        let meta = match self.manifest.get(name) {
            Ok(m) => m.clone(),
            Err(_) => return None,
        };
        let newcomer = Tenant { model: meta, rate };
        let mut best: Option<(f64, f64, usize)> = None;
        for &d in &survivors {
            let dev = self.fleet.device(d);
            let mut cand: Vec<Tenant> = members[d].clone();
            cand.push(newcomer.clone());
            let plan = alloc::hill_climb(&dev.am, &cand, dev.k_max());
            if !plan.predicted_objective.is_finite() {
                continue;
            }
            let mut objs = current.clone();
            objs[d] = plan.predicted_objective;
            let max = objs.iter().cloned().fold(0.0f64, f64::max);
            let better = match best {
                None => true,
                Some((bm, bd, _)) => (max, plan.predicted_objective) < (bm, bd),
            };
            if better {
                best = Some((max, plan.predicted_objective, d));
            }
        }
        if let Some((_, _, d)) = best {
            if let Ok(inner) = self.servers[d].attach(
                name,
                AttachOptions {
                    rate_hint: rate,
                    class,
                },
            ) {
                return Some((d, inner));
            }
        }
        // CPU-only degrade: land a zero-rate attach on the emptiest
        // survivor and pin the newcomer to partition 0 (its requests
        // bypass the TPU entirely and run on the CPU pools), granting it
        // one core if the budget allows or can be rebalanced.
        let emptiest = survivors.iter().copied().min_by_key(|&d| members[d].len())?;
        let inner = self.servers[emptiest]
            .attach(
                name,
                AttachOptions {
                    rate_hint: 0.0,
                    class,
                },
            )
            .ok()?;
        let mut cfg = self.servers[emptiest].current_config();
        let idx = self.servers[emptiest]
            .handles()
            .iter()
            .position(|&h| h == inner)?;
        cfg.partitions[idx] = 0;
        if cfg.cores[idx] == 0 {
            let k_max = self.fleet.device(emptiest).k_max();
            let total: usize = cfg.cores.iter().sum();
            if total < k_max {
                cfg.cores[idx] = 1;
            } else {
                let rich = (0..cfg.cores.len())
                    .filter(|&i| i != idx)
                    .max_by_key(|&i| cfg.cores[i]);
                if let Some(rich) = rich {
                    if cfg.cores[rich] > 1 {
                        cfg.cores[rich] -= 1;
                        cfg.cores[idx] = 1;
                    }
                }
            }
        }
        let _ = self.servers[emptiest].set_config(cfg);
        Some((emptiest, inner))
    }

    /// Aggregated statistics: per-device [`ServeStats`] with their
    /// `migrations` counters filled in, plus the fleet totals.
    pub fn stats(&self) -> FleetStats {
        let per = lock_or_recover(&self.per_device_migrations).clone();
        let per_device: Vec<ServeStats> = self
            .servers
            .iter()
            .zip(&per)
            .map(|(s, &m)| {
                let mut stats = s.stats();
                stats.migrations = m;
                stats
            })
            .collect();
        FleetStats {
            per_device,
            migrations: self.migrations.load(Ordering::SeqCst),
            failovers: self.failovers.load(Ordering::SeqCst),
            requeued: self.requeued.load(Ordering::SeqCst),
            failed_over: self.failed_over.load(Ordering::SeqCst),
            shed_tenants: self.shed_tenants.load(Ordering::SeqCst),
        }
    }

    /// Fleet-wide Prometheus exposition: every member server renders
    /// into ONE writer (`# HELP`/`# TYPE` headers dedup across devices,
    /// the `device` label keeps the series distinct), then the fleet
    /// control plane appends its own counters.
    pub fn metrics_text(&self) -> String {
        let mut w = PromWriter::new();
        for s in &self.servers {
            s.render_metrics(&mut w);
        }
        w.header(
            "swapless_fleet_migrations_total",
            "Policy-driven tenant migrations executed, by source device.",
            "counter",
        );
        let per = lock_or_recover(&self.per_device_migrations).clone();
        for (d, m) in per.iter().enumerate() {
            w.counter(
                "swapless_fleet_migrations_total",
                &[("device", &d.to_string())],
                *m,
            );
        }
        w.header(
            "swapless_fleet_events_total",
            "Fleet control-plane event totals by kind.",
            "counter",
        );
        for (kind, v) in [
            ("migrations", self.migrations.load(Ordering::SeqCst)),
            ("failovers", self.failovers.load(Ordering::SeqCst)),
            ("requeued", self.requeued.load(Ordering::SeqCst)),
            ("failed_over", self.failed_over.load(Ordering::SeqCst)),
            ("shed_tenants", self.shed_tenants.load(Ordering::SeqCst)),
        ] {
            w.counter("swapless_fleet_events_total", &[("event", kind)], v);
        }
        w.header(
            "swapless_fleet_device_up",
            "1 while the member device is serving, 0 while crashed.",
            "gauge",
        );
        for (d, s) in self.servers.iter().enumerate() {
            w.gauge(
                "swapless_fleet_device_up",
                &[("device", &d.to_string())],
                if s.health().is_down() { 0.0 } else { 1.0 },
            );
        }
        w.finish()
    }
}

impl Drop for FleetServer {
    fn drop(&mut self) {
        // Members share the fleet's log with `log_owned: false`; wind
        // them down first (joining their emitting threads), then flush,
        // fsync, and truncate the log exactly once.
        let log = self.log.take();
        self.servers.clear();
        if let Some(log) = log {
            log.close();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareSpec;
    use crate::runtime::service::ExecBackend;

    fn builder(devices: usize) -> FleetServerBuilder {
        FleetServerBuilder::new(
            &Manifest::synthetic(),
            Fleet::uniform(devices, &HardwareSpec::default()),
        )
        .backend(ExecBackend::Emulated)
        .adaptive(false)
    }

    fn input_for(fs: &FleetServer, d: usize, inner_model: &str) -> Vec<f32> {
        let meta = fs.servers[d]
            .tenants()
            .iter()
            .find(|t| t.model.name == inner_model)
            .map(|t| t.model.clone())
            .expect("attached");
        vec![0.5; meta.input_shape.iter().product()]
    }

    #[test]
    fn routes_per_device_and_counts() {
        let fs = builder(2).build().unwrap();
        let ha = fs
            .attach_on("mobilenetv2", AttachOptions::default(), 0)
            .unwrap();
        let hb = fs
            .attach_on("squeezenet", AttachOptions::default(), 1)
            .unwrap();
        assert_eq!(fs.device_of(ha), Some(0));
        assert_eq!(fs.device_of(hb), Some(1));
        let ia = input_for(&fs, 0, "mobilenetv2");
        let ib = input_for(&fs, 1, "squeezenet");
        let mut pending = Vec::new();
        for _ in 0..10 {
            pending.push(fs.submit(ha, ia.clone()));
            pending.push(fs.submit(hb, ib.clone()));
        }
        for t in pending {
            t.wait().unwrap();
        }
        let stats = fs.stats();
        assert_eq!(stats.completed_per_device(), vec![10, 10]);
        assert_eq!(stats.completed(), 20);
        assert_eq!(stats.failed(), 0);
        assert_eq!(stats.migrations, 0);
        assert_eq!(stats.per_class().total_count(), 20);
    }

    #[test]
    fn fleet_attach_spreads_conflicting_tenants() {
        // Two big-prefix tenants cannot co-reside in one SRAM: unpinned
        // fleet attach must land them on different devices.
        let fs = builder(2).build().unwrap();
        let h1 = fs
            .attach(
                "inceptionv4",
                AttachOptions {
                    rate_hint: 2.0,
                    ..Default::default()
                },
            )
            .unwrap();
        let h2 = fs
            .attach(
                "xception",
                AttachOptions {
                    rate_hint: 2.0,
                    ..Default::default()
                },
            )
            .unwrap();
        assert_ne!(fs.device_of(h1), fs.device_of(h2));
    }

    #[test]
    fn migration_drain_then_move() {
        let fs = builder(2).build().unwrap();
        let ha = fs
            .attach_on("mobilenetv2", AttachOptions::default(), 0)
            .unwrap();
        let hb = fs
            .attach_on("squeezenet", AttachOptions::default(), 0)
            .unwrap();
        let ia = input_for(&fs, 0, "mobilenetv2");
        let ib = input_for(&fs, 0, "squeezenet");
        for _ in 0..5 {
            fs.submit(ha, ia.clone()).wait().unwrap();
            fs.submit(hb, ib.clone()).wait().unwrap();
        }
        assert!(fs.migrate(hb, 1).unwrap());
        assert_eq!(fs.device_of(hb), Some(1));
        // Self-move is a no-op.
        assert!(!fs.migrate(hb, 1).unwrap());
        for _ in 0..5 {
            fs.submit(hb, ib.clone()).wait().unwrap();
        }
        let stats = fs.stats();
        assert_eq!(stats.migrations, 1);
        assert_eq!(stats.per_device[0].migrations, 1);
        assert_eq!(stats.per_device[1].migrations, 1);
        // Device 1 served the migrated tenant's post-move traffic.
        assert_eq!(stats.per_device[1].completed, 5);
        // Drained before the move: nothing failed.
        assert_eq!(stats.failed(), 0);
        assert_eq!(stats.completed(), 15);
    }

    #[test]
    fn unknown_handle_resolves_not_attached() {
        let fs = builder(1).build().unwrap();
        match fs.submit(TenantHandle(99), vec![0.5; 4]).wait() {
            Err(RequestError::NotAttached(h)) => assert_eq!(h, TenantHandle(99)),
            other => panic!("expected NotAttached, got {other:?}"),
        }
        assert!(fs.detach(TenantHandle(99)).is_err());
        assert!(fs.migrate(TenantHandle(99), 0).is_err());
    }

    #[test]
    fn rebalance_splits_colocated_tenants_once_rates_are_seen() {
        let fs = builder(2).build().unwrap();
        let ha = fs
            .attach_on("inceptionv4", AttachOptions::default(), 0)
            .unwrap();
        let hb = fs
            .attach_on("xception", AttachOptions::default(), 0)
            .unwrap();
        // No observed traffic: the policy has no rates, no move.
        assert_eq!(fs.rebalance(), 0);
        let ia = input_for(&fs, 0, "inceptionv4");
        let ib = input_for(&fs, 0, "xception");
        for _ in 0..12 {
            fs.submit(ha, ia.clone()).wait().unwrap();
            fs.submit(hb, ib.clone()).wait().unwrap();
        }
        let moved = fs.rebalance();
        assert!(moved >= 1, "no migration despite conflicting colocation");
        assert_ne!(fs.device_of(ha), fs.device_of(hb));
        assert_eq!(fs.stats().migrations, moved as u64);
    }

    #[test]
    fn detach_racing_migration_never_loses_tickets() {
        // Regression: a fleet-level detach racing a drain-then-move
        // migration used to strand the source device's queued tickets —
        // the migration rerouted state to the target, the detach removed
        // the target handle, and nothing ever purged the source queue.
        // Every ticket must resolve (completion or typed error), never
        // hang or drop its channel.
        let fs = Arc::new(builder(2).build().unwrap());
        for _ in 0..5 {
            let h = fs
                .attach_on("squeezenet", AttachOptions::default(), 0)
                .unwrap();
            let input = input_for(&fs, 0, "squeezenet");
            let mut tickets = Vec::new();
            for _ in 0..8 {
                tickets.push(fs.submit(h, input.clone()));
            }
            let fs_mig = fs.clone();
            let mig = std::thread::spawn(move || {
                let _ = fs_mig.migrate(h, 1);
            });
            let fs_det = fs.clone();
            let det = std::thread::spawn(move || {
                let _ = fs_det.detach(h);
            });
            mig.join().unwrap();
            det.join().unwrap();
            for mut t in tickets {
                match t.wait_timeout(Duration::from_secs(5)) {
                    Some(Ok(_)) => {}
                    Some(Err(e)) => {
                        assert_ne!(e, RequestError::ChannelClosed, "ticket lost its sender");
                    }
                    None => panic!("ticket unresolved after a detach/migrate race"),
                }
            }
            // Whichever side won, the handle is gone from the fleet.
            assert_eq!(fs.device_of(h), None);
        }
    }

    #[test]
    fn failover_requeues_queued_work_onto_a_survivor() {
        // Device 0 is down from t=0 with no recovery: its worker parks,
        // submits queue, and poll_health must move the tenant (and its
        // queued tickets, senders intact) onto device 1.
        let fs = builder(2)
            .faults(FaultPlan::new(1).crash(0, 0.0, None))
            .build()
            .unwrap();
        let ha = fs
            .attach_on("mobilenetv2", AttachOptions::default(), 0)
            .unwrap();
        // Pin a TPU-resident config so submits queue at the (parked) TPU
        // worker instead of bypassing it through the CPU pools.
        fs.set_device_config(0, Config::all_tpu(&fs.server(0).tenants()))
            .unwrap();
        let ia = input_for(&fs, 0, "mobilenetv2");
        let mut pending = Vec::new();
        for _ in 0..5 {
            pending.push(fs.submit(ha, ia.clone()));
        }
        assert!(fs.health()[0].is_down());
        assert_eq!(fs.poll_health(), 1);
        assert_eq!(fs.device_of(ha), Some(1));
        for t in pending {
            t.wait().unwrap();
        }
        // Post-failover traffic routes to the survivor and is counted as
        // failed-over (the tenant is off its home placement).
        for _ in 0..3 {
            fs.submit(ha, ia.clone()).wait().unwrap();
        }
        let stats = fs.stats();
        assert_eq!(stats.failovers, 1);
        assert_eq!(stats.requeued, 5);
        assert_eq!(stats.failed_over, 3);
        assert_eq!(stats.shed_tenants, 0);
        assert_eq!(fs.failed_over_of(ha), 3);
        // The outage is ongoing: a second poll must not re-trigger.
        assert_eq!(fs.poll_health(), 0);
        assert_eq!(fs.stats().failovers, 1);
    }

    #[test]
    fn failover_with_no_survivors_sheds_typed() {
        let fs = builder(1)
            .faults(FaultPlan::new(3).crash(0, 0.0, None))
            .build()
            .unwrap();
        let h = fs
            .attach_on("squeezenet", AttachOptions::default(), 0)
            .unwrap();
        fs.set_device_config(0, Config::all_tpu(&fs.server(0).tenants()))
            .unwrap();
        let input = input_for(&fs, 0, "squeezenet");
        let t = fs.submit(h, input);
        assert_eq!(fs.poll_health(), 0);
        match t.wait() {
            Err(RequestError::Shed { station }) => assert_eq!(station, "fleet"),
            other => panic!("expected a typed shed, got {other:?}"),
        }
        let stats = fs.stats();
        assert_eq!(stats.shed_tenants, 1);
        assert_eq!(fs.device_of(h), None);
    }
}
