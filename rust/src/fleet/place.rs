//! The two-level fleet allocator: an outer tenant→device placement
//! search over the inner per-device SwapLess hill climb.
//!
//! **Outer level** — greedy bin-pack: tenants are placed in descending
//! order of predicted TPU load contribution (`λ_i · s^TPU_i(P_i)` on the
//! reference device), each onto the device that minimizes the fleet
//! objective, followed by local-move refinement (try relocating every
//! tenant to every other device; commit strict improvements) until a
//! fixed point.
//!
//! **Inner level** — for every candidate member set the device runs the
//! paper's hill-climbing allocator over its own cost model, on prefix
//! tables built once per (device, tenant) pair and reused across every
//! candidate (the climb itself scores moves through the O(1)
//! [`DeltaEvaluator`](crate::analytic::DeltaEvaluator) engine). Candidate
//! member sets repeat heavily during refinement, so inner results are
//! memoized by (device, member set).
//!
//! **Fleet objective** — the search minimizes the max over devices of
//! the per-device Eq. 5 objective (`Σ λ_i · T_i` restricted to the
//! device's members — the paper's objective generalized per device),
//! with the fleet-wide sum (the global Eq. 5 objective) as tie-break:
//! minimizing the worst device's weighted-latency burden balances load
//! while letting the inner allocator exploit per-device α structure
//! (two conflicting big models land on different SRAM caches). The
//! rate-weighted *sum* is deliberate: a per-device *mean* would let a
//! fast co-tenant dilute a slow model's latency and reward exactly the
//! colocations placement exists to avoid. The reported
//! [`FleetPlan::objective`] is the max per-device mean response time —
//! the operator-facing "worst device's predicted latency".

use std::collections::HashMap;

use crate::alloc;
use crate::analytic::{Config, Tenant};
use crate::tpu::PrefixTables;

use super::Fleet;

/// One device's share of a [`FleetPlan`].
#[derive(Debug, Clone)]
pub struct DevicePlan {
    pub device: usize,
    /// Global tenant indices served by this device, ascending — the
    /// positional order its inner config, DES station, and arrival
    /// stream splits all use.
    pub tenants: Vec<usize>,
    /// The inner allocator's (P, K) plan for exactly those tenants.
    pub config: Config,
    /// Eq. 5 objective of the device's member set (`Σ λ_i · T_i`).
    pub predicted_objective: f64,
    /// Request-weighted mean response time (objective / Σλ); 0.0 for an
    /// empty or zero-rate device.
    pub mean_latency: f64,
    pub tpu_utilization: f64,
}

/// A complete two-level allocation across the fleet.
#[derive(Debug, Clone)]
pub struct FleetPlan {
    /// Tenant index → device index.
    pub assignment: Vec<usize>,
    /// One entry per device (possibly empty), indexed by device.
    pub devices: Vec<DevicePlan>,
    /// Fleet objective: max over devices of `mean_latency`.
    pub objective: f64,
    /// Inner-allocator candidate evaluations performed (decision-
    /// overhead metric, aggregated across every memoized inner climb).
    pub evaluations: usize,
    /// Local-move refinement relocations committed after the greedy pass.
    pub refine_moves: usize,
}

impl FleetPlan {
    /// True when every device's predicted latency is finite (ρ < 1
    /// everywhere) — the fleet-level admission criterion.
    pub fn is_stable(&self) -> bool {
        self.objective.is_finite()
    }
}

/// One memoized inner evaluation: the device's plan for a member set.
#[derive(Clone)]
struct DeviceScore {
    mean: f64,
    objective: f64,
    rho: f64,
    config: Config,
}

impl DeviceScore {
    fn empty() -> DeviceScore {
        DeviceScore {
            mean: 0.0,
            objective: 0.0,
            rho: 0.0,
            config: Config {
                partitions: Vec::new(),
                cores: Vec::new(),
            },
        }
    }
}

/// Inner-level evaluator: per-(device, member set) hill climbs with
/// memoization over prebuilt per-device prefix tables. Each distinct
/// member set is climbed exactly once — `evaluations` counts the true
/// search cost, and plan materialization reads the memo instead of
/// re-climbing.
struct Inner<'a> {
    fleet: &'a Fleet,
    tenants: &'a [Tenant],
    /// `tables[d][i]`: tenant `i`'s prefix tables under device `d`'s cost
    /// model (devices are heterogeneous, so the tables differ per device).
    tables: Vec<Vec<PrefixTables>>,
    memo: HashMap<(usize, Vec<usize>), DeviceScore>,
    evaluations: usize,
}

impl<'a> Inner<'a> {
    fn new(fleet: &'a Fleet, tenants: &'a [Tenant]) -> Inner<'a> {
        let tables = fleet
            .devices()
            .iter()
            .map(|dev| PrefixTables::for_tenants(&dev.cost, tenants))
            .collect();
        Inner::with_tables(fleet, tenants, tables)
    }

    fn with_tables(
        fleet: &'a Fleet,
        tenants: &'a [Tenant],
        tables: Vec<Vec<PrefixTables>>,
    ) -> Inner<'a> {
        Inner {
            fleet,
            tenants,
            tables,
            memo: HashMap::new(),
            evaluations: 0,
        }
    }

    /// Memoized inner evaluation of a member set on device `d`.
    fn eval(&mut self, d: usize, members: &[usize]) -> DeviceScore {
        if members.is_empty() {
            return DeviceScore::empty();
        }
        let key = (d, members.to_vec());
        if let Some(v) = self.memo.get(&key) {
            return v.clone();
        }
        let subset: Vec<Tenant> = members.iter().map(|&i| self.tenants[i].clone()).collect();
        let tables: Vec<PrefixTables> =
            members.iter().map(|&i| self.tables[d][i].clone()).collect();
        let dev = self.fleet.device(d);
        let plan = alloc::hill_climb_with_tables(&dev.am, &subset, &tables, dev.k_max());
        self.evaluations += plan.evaluations;
        let rate: f64 = subset.iter().map(|t| t.rate).sum();
        let mean = if rate > 0.0 {
            plan.predicted_objective / rate
        } else {
            0.0
        };
        let rho = dev.am.tpu_utilization(&subset, &plan.config);
        let v = DeviceScore {
            mean,
            objective: plan.predicted_objective,
            rho,
            config: plan.config,
        };
        self.memo.insert(key, v.clone());
        v
    }

    /// (mean response time, objective, ρ) of a member set on device `d`.
    fn score(&mut self, d: usize, members: &[usize]) -> (f64, f64, f64) {
        let v = self.eval(d, members);
        (v.mean, v.objective, v.rho)
    }
}

/// Fleet search score of a per-device Eq. 5 objective vector:
/// lexicographic (max, sum) — the worst device's weighted-latency
/// burden, tie-broken by the global Eq. 5 objective so non-bottleneck
/// devices keep balancing.
fn fleet_score(objs: &[f64]) -> (f64, f64) {
    let max = objs.iter().cloned().fold(0.0f64, f64::max);
    let sum = objs.iter().sum();
    (max, sum)
}

/// Strict lexicographic improvement with a relative tolerance (so f64
/// noise in equal-cost permutations never cycles the refinement).
fn lex_improves(new: (f64, f64), cur: (f64, f64)) -> bool {
    let lt = |a: f64, b: f64| -> bool {
        if b.is_infinite() {
            return a.is_finite();
        }
        a < b - 1e-9 * b.abs().max(1e-12)
    };
    let eq = |a: f64, b: f64| -> bool { !lt(a, b) && !lt(b, a) };
    lt(new.0, cur.0) || (eq(new.0, cur.0) && lt(new.1, cur.1))
}

/// Insert `x` into an ascending-sorted vector.
fn insert_sorted(v: &mut Vec<usize>, x: usize) {
    let pos = v.partition_point(|&y| y < x);
    v.insert(pos, x);
}

/// The two-level placement search. Deterministic: iteration orders are
/// fixed, ties break toward the lower device index.
pub fn place(fleet: &Fleet, tenants: &[Tenant]) -> FleetPlan {
    search(Inner::new(fleet, tenants))
}

/// The same two-level search over caller-supplied per-device prefix
/// tables (`tables[d][i]` = tenant `i`'s tables under device `d`'s cost
/// model) — the `--cost profiled` placement path, where span-calibrated
/// tables replace the analytic ones that [`place`] builds internally.
/// TPU-utilization and load-ordering estimates stay analytic (spans do
/// not measure bus occupancy).
pub fn place_with_tables(
    fleet: &Fleet,
    tenants: &[Tenant],
    tables: Vec<Vec<PrefixTables>>,
) -> FleetPlan {
    assert_eq!(tables.len(), fleet.len(), "one table set per device");
    for per_device in &tables {
        assert_eq!(per_device.len(), tenants.len(), "one table per tenant");
    }
    search(Inner::with_tables(fleet, tenants, tables))
}

fn search(mut inner: Inner<'_>) -> FleetPlan {
    let fleet = inner.fleet;
    let tenants = inner.tenants;
    let n = tenants.len();
    let d_count = fleet.len();

    // Outer pass 1 — greedy bin-pack in descending predicted TPU load on
    // the reference device (heaviest tenants choose first, so they end up
    // spread across caches instead of stacked on the last device).
    let ref_dev = fleet.device(0);
    let mut order: Vec<usize> = (0..n).collect();
    let load = |i: usize| -> f64 {
        let t = &tenants[i];
        t.rate * ref_dev.cost.tpu_service(&t.model, t.model.partition_points)
    };
    order.sort_by(|&a, &b| {
        load(b)
            .partial_cmp(&load(a))
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });

    let mut members: Vec<Vec<usize>> = vec![Vec::new(); d_count];
    let mut objs: Vec<f64> = vec![0.0; d_count];
    let mut assignment = vec![0usize; n];
    for &t in &order {
        // (score, occupancy, device, device objective). Exact score ties
        // — including the all-unstable case where every option evaluates
        // to ∞ — break toward the least-occupied device, so overloaded
        // mixes still spread instead of stacking on device 0.
        let mut best: Option<((f64, f64), usize, usize, f64)> = None;
        for d in 0..d_count {
            let mut cand = members[d].clone();
            insert_sorted(&mut cand, t);
            let (_, obj_d, _) = inner.score(d, &cand);
            let mut cand_objs = objs.clone();
            cand_objs[d] = obj_d;
            let sc = fleet_score(&cand_objs);
            let occupancy = members[d].len();
            let better = match &best {
                None => true,
                Some((bs, bo, _, _)) => {
                    lex_improves(sc, *bs)
                        || (!lex_improves(*bs, sc) && occupancy < *bo)
                }
            };
            if better {
                best = Some((sc, occupancy, d, obj_d));
            }
        }
        let (_, _, d, obj_d) = best.expect("non-empty fleet");
        insert_sorted(&mut members[d], t);
        objs[d] = obj_d;
        assignment[t] = d;
    }

    // Outer pass 2 — local-move refinement: relocate single tenants while
    // the fleet score strictly improves (bounded passes; each commit
    // strictly lowers the lexicographic score, so this terminates fast).
    let mut refine_moves = 0usize;
    for _pass in 0..4 {
        let mut improved = false;
        for t in 0..n {
            let src = assignment[t];
            let cur_score = fleet_score(&objs);
            let mut best: Option<((f64, f64), usize, f64, f64)> = None;
            for dst in 0..d_count {
                if dst == src {
                    continue;
                }
                let cand_src: Vec<usize> =
                    members[src].iter().copied().filter(|&x| x != t).collect();
                let mut cand_dst = members[dst].clone();
                insert_sorted(&mut cand_dst, t);
                let (_, obj_src, _) = inner.score(src, &cand_src);
                let (_, obj_dst, _) = inner.score(dst, &cand_dst);
                let mut cand_objs = objs.clone();
                cand_objs[src] = obj_src;
                cand_objs[dst] = obj_dst;
                let sc = fleet_score(&cand_objs);
                let better = match &best {
                    None => lex_improves(sc, cur_score),
                    Some((bs, _, _, _)) => lex_improves(sc, *bs),
                };
                if better {
                    best = Some((sc, dst, obj_src, obj_dst));
                }
            }
            if let Some((_, dst, obj_src, obj_dst)) = best {
                members[src].retain(|&x| x != t);
                insert_sorted(&mut members[dst], t);
                objs[src] = obj_src;
                objs[dst] = obj_dst;
                assignment[t] = dst;
                refine_moves += 1;
                improved = true;
            }
        }
        if !improved {
            break;
        }
    }

    // Materialize per-device plans straight from the memo (every final
    // member set was already climbed during the search).
    let mut devices = Vec::with_capacity(d_count);
    for d in 0..d_count {
        let v = inner.eval(d, &members[d]);
        devices.push(DevicePlan {
            device: d,
            tenants: members[d].clone(),
            config: v.config,
            predicted_objective: v.objective,
            mean_latency: v.mean,
            tpu_utilization: v.rho,
        });
    }
    let objective = devices
        .iter()
        .map(|p| p.mean_latency)
        .fold(0.0f64, f64::max);

    FleetPlan {
        assignment,
        devices,
        objective,
        evaluations: inner.evaluations,
        refine_moves,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareSpec;
    use crate::model::synthetic_model;

    fn tenant(name: &str, segs: usize, mb: f64, gflops: f64, rate: f64) -> Tenant {
        Tenant {
            model: synthetic_model(
                name,
                segs,
                (mb * 1e6 / segs as f64) as u64,
                (gflops * 1e9 / segs as f64) as u64,
            ),
            rate,
        }
    }

    #[test]
    fn single_device_fleet_matches_inner_allocator() {
        let fleet = Fleet::uniform(1, &HardwareSpec::default());
        let tenants = vec![
            tenant("big", 10, 40.0, 12.0, 2.0),
            tenant("small", 5, 4.0, 0.5, 2.0),
        ];
        let plan = place(&fleet, &tenants);
        assert_eq!(plan.assignment, vec![0, 0]);
        let direct = crate::alloc::hill_climb(&fleet.device(0).am, &tenants, 4);
        assert_eq!(plan.devices[0].config, direct.config);
        let rate: f64 = tenants.iter().map(|t| t.rate).sum();
        assert!((plan.objective - direct.predicted_objective / rate).abs() < 1e-9);
    }

    #[test]
    fn place_with_analytic_tables_matches_place() {
        // `place` is `place_with_tables` over analytic tables — feeding
        // those tables in explicitly must reproduce the search exactly.
        let fleet = Fleet::uniform(2, &HardwareSpec::default());
        let tenants = vec![
            tenant("big", 10, 40.0, 12.0, 2.0),
            tenant("small", 5, 4.0, 0.5, 2.0),
            tenant("mid", 7, 14.0, 3.0, 1.0),
        ];
        let tables: Vec<Vec<PrefixTables>> = fleet
            .devices()
            .iter()
            .map(|dev| PrefixTables::for_tenants(&dev.cost, &tenants))
            .collect();
        let a = place(&fleet, &tenants);
        let b = place_with_tables(&fleet, &tenants, tables);
        assert_eq!(a.assignment, b.assignment);
        assert_eq!(a.objective, b.objective);
        assert_eq!(a.evaluations, b.evaluations);
        for (da, db) in a.devices.iter().zip(&b.devices) {
            assert_eq!(da.config, db.config);
        }
    }

    #[test]
    fn conflicting_big_models_split_across_devices() {
        // Two oversized prefixes cannot co-reside in one 8 MB SRAM: on a
        // single device they pay α-reloads; two devices give each its own
        // cache, so the planner must separate them.
        let fleet = Fleet::uniform(2, &HardwareSpec::default());
        let tenants = vec![
            tenant("big_a", 6, 12.0, 4.0, 3.0),
            tenant("big_b", 6, 12.0, 4.0, 3.0),
        ];
        let plan = place(&fleet, &tenants);
        assert_ne!(
            plan.assignment[0], plan.assignment[1],
            "conflicting tenants stacked: {:?}",
            plan.assignment
        );
        assert!(plan.is_stable());
        // Each device plans exactly one tenant.
        for p in &plan.devices {
            assert_eq!(p.tenants.len(), 1);
            assert_eq!(p.config.partitions.len(), 1);
        }
        // And beats the forced one-device packing.
        let one = place(&Fleet::uniform(1, &HardwareSpec::default()), &tenants);
        assert!(
            plan.objective < one.objective * 0.95,
            "2-device {} !<< 1-device {}",
            plan.objective,
            one.objective
        );
    }

    #[test]
    fn placement_is_deterministic_and_plans_every_device_slot() {
        let fleet = Fleet::uniform(4, &HardwareSpec::default());
        let tenants: Vec<Tenant> = (0..8)
            .map(|i| {
                tenant(
                    &format!("m{i}"),
                    4 + i % 5,
                    5.0 + 3.0 * i as f64,
                    0.5 + 0.4 * i as f64,
                    0.5 + 0.25 * i as f64,
                )
            })
            .collect();
        let a = place(&fleet, &tenants);
        let b = place(&fleet, &tenants);
        assert_eq!(a.assignment, b.assignment);
        assert_eq!(a.objective, b.objective);
        assert_eq!(a.evaluations, b.evaluations);
        assert_eq!(a.devices.len(), 4);
        // Per-device plans are positionally aligned and cover every tenant.
        let mut covered = vec![false; tenants.len()];
        for (d, p) in a.devices.iter().enumerate() {
            assert_eq!(p.device, d);
            assert_eq!(p.tenants.len(), p.config.partitions.len());
            let mut prev = None;
            for &t in &p.tenants {
                assert_eq!(a.assignment[t], d);
                assert!(prev.map(|x| x < t).unwrap_or(true), "unsorted members");
                prev = Some(t);
                covered[t] = true;
            }
        }
        assert!(covered.iter().all(|&c| c));
        assert!(a.is_stable());
    }

    #[test]
    fn heavier_sram_device_attracts_the_big_model() {
        // Heterogeneous fleet: device 1 has 4x the SRAM. A model whose
        // full prefix fits only there should land there.
        let small_hw = HardwareSpec::default();
        let big_hw = HardwareSpec {
            sram_bytes: small_hw.sram_bytes * 4,
            ..small_hw.clone()
        };
        let fleet = Fleet::new(vec![
            super::super::DeviceSpec {
                name: "small".into(),
                hw: small_hw,
            },
            super::super::DeviceSpec {
                name: "big".into(),
                hw: big_hw,
            },
        ]);
        let tenants = vec![tenant("huge", 8, 24.0, 8.0, 2.0)];
        let plan = place(&fleet, &tenants);
        assert_eq!(plan.assignment, vec![1], "big-SRAM device not chosen");
    }
}
