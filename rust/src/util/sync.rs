//! Poison-tolerant locking.
//!
//! `Mutex::lock().unwrap()` turns one panicking worker into a cascade:
//! every later lock attempt on the poisoned mutex panics too, so a single
//! bug inside a lock-holding thread aborts the whole server. Nothing this
//! crate guards with a mutex has invariants that a panic can half-apply
//! in a dangerous way (counters, queues of self-contained jobs, config
//! snapshots swapped atomically), so the right recovery is to take the
//! inner data and keep serving ([`std::sync::PoisonError::into_inner`]).

use std::sync::{Condvar, Mutex, MutexGuard};
use std::time::Duration;

/// Lock `m`, recovering the inner data if a previous holder panicked.
pub fn lock_or_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// `Condvar::wait` that recovers a poisoned guard instead of panicking.
pub fn wait_or_recover<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(|e| e.into_inner())
}

/// `Condvar::wait_timeout` that recovers a poisoned guard; the timeout
/// flag is dropped (callers here re-check their predicate regardless).
pub fn wait_timeout_or_recover<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    timeout: Duration,
) -> MutexGuard<'a, T> {
    match cv.wait_timeout(guard, timeout) {
        Ok((g, _)) => g,
        Err(e) => e.into_inner().0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    #[test]
    fn recovers_after_a_panicking_holder() {
        let m = Arc::new(Mutex::new(7usize));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.lock().is_err(), "mutex should be poisoned");
        // lock_or_recover still yields the data; writes keep working.
        *lock_or_recover(&m) += 1;
        assert_eq!(*lock_or_recover(&m), 8);
    }

    #[test]
    fn wait_timeout_recovers_and_returns() {
        let m = Arc::new(Mutex::new(0usize));
        let cv = Condvar::new();
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        let g = lock_or_recover(&m);
        let g = wait_timeout_or_recover(&cv, g, Duration::from_millis(1));
        assert_eq!(*g, 0);
    }
}
