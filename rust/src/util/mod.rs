//! From-scratch substrates: JSON, CLI parsing, PRNG, bench harness.
//!
//! The offline build environment reaches only the `xla` crate's dependency
//! closure, so SwapLess implements these itself (DESIGN.md §3).

pub mod bench;
pub mod cli;
pub mod count_alloc;
pub mod json;
pub mod rng;
pub mod sync;
