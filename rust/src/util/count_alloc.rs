//! Counting allocator for zero-allocation proofs.
//!
//! A thin wrapper over the system allocator that counts allocations —
//! globally and per thread — so tests and benches can *prove* a hot
//! path performs no heap allocation after warmup instead of asserting
//! it in a comment. The library never installs it; a bench or test
//! binary opts in at its own crate root:
//!
//! ```ignore
//! #[global_allocator]
//! static ALLOC: swapless::util::count_alloc::CountingAlloc = CountingAlloc;
//!
//! let before = thread_allocs();
//! hot_loop();
//! assert_eq!(thread_allocs() - before, 0);
//! ```
//!
//! The per-thread counter is the one to assert on: a server running on
//! background threads allocates concurrently, and only the measured
//! thread's count says anything about the measured loop. The counter is
//! a `const`-initialized `thread_local` `Cell`, so reading or bumping
//! it never allocates (no lazy init, no destructor registration).

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};

/// Process-wide allocation count (all threads).
static GLOBAL_ALLOCS: AtomicU64 = AtomicU64::new(0);

thread_local! {
    static THREAD_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

/// Allocations observed on the calling thread since it started.
pub fn thread_allocs() -> u64 {
    THREAD_ALLOCS.with(|c| c.get())
}

/// Allocations observed process-wide since start.
pub fn global_allocs() -> u64 {
    GLOBAL_ALLOCS.load(Ordering::Relaxed)
}

/// `#[global_allocator]`-installable wrapper over [`System`] that
/// counts every `alloc`/`realloc` (frees are not counted: a loop that
/// only ever frees warmup buffers is still allocation-free).
pub struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        GLOBAL_ALLOCS.fetch_add(1, Ordering::Relaxed);
        THREAD_ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        GLOBAL_ALLOCS.fetch_add(1, Ordering::Relaxed);
        THREAD_ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        GLOBAL_ALLOCS.fetch_add(1, Ordering::Relaxed);
        THREAD_ALLOCS.with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }
}
