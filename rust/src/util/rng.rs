//! Deterministic PRNG + the distributions the workload generators need.
//!
//! xoshiro256** (public-domain reference algorithm) — fast, solid, and
//! seedable so every experiment in EXPERIMENTS.md is exactly reproducible.

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        // SplitMix64 seeding, per the xoshiro reference implementation.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        (self.f64() * n as f64) as usize % n
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Exponential with rate `lambda` (mean 1/lambda) — Poisson inter-arrivals.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0, "exponential rate must be positive");
        let mut u = self.f64();
        if u == 0.0 {
            u = f64::MIN_POSITIVE;
        }
        -u.ln() / lambda
    }

    /// Pick an index with probability proportional to `weights`.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted() needs positive total weight");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Standard normal via Box–Muller (used by jittered service times).
    pub fn normal(&mut self) -> f64 {
        let mut u1 = self.f64();
        if u1 == 0.0 {
            u1 = f64::MIN_POSITIVE;
        }
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fork a child generator (stable stream splitting for sub-components).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn exponential_mean_matches_rate() {
        let mut r = Rng::new(11);
        let lambda = 4.0;
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| r.exponential(lambda)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / lambda).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn weighted_respects_proportions() {
        let mut r = Rng::new(13);
        let mut counts = [0usize; 3];
        for _ in 0..100_000 {
            counts[r.weighted(&[1.0, 2.0, 7.0])] += 1;
        }
        let total: usize = counts.iter().sum();
        assert!((counts[2] as f64 / total as f64 - 0.7).abs() < 0.02);
        assert!((counts[1] as f64 / total as f64 - 0.2).abs() < 0.02);
    }

    #[test]
    fn normal_mean_and_var() {
        let mut r = Rng::new(17);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(23);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
    }
}
