//! Tiny CLI argument parser (no `clap` in the offline environment).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

/// Option names that take a value (everything else starting with `--` is a flag).
pub fn parse(raw: &[String], value_opts: &[&str]) -> Result<Args, String> {
    let mut args = Args::default();
    let mut i = 0;
    while i < raw.len() {
        let a = &raw[i];
        if let Some(stripped) = a.strip_prefix("--") {
            if let Some((k, v)) = stripped.split_once('=') {
                if !value_opts.contains(&k) {
                    return Err(format!("option --{k} does not take a value"));
                }
                args.options.insert(k.to_string(), v.to_string());
            } else if value_opts.contains(&stripped) {
                i += 1;
                let v = raw
                    .get(i)
                    .ok_or_else(|| format!("option --{stripped} needs a value"))?;
                args.options.insert(stripped.to_string(), v.clone());
            } else {
                args.flags.push(stripped.to_string());
            }
        } else {
            args.positional.push(a.clone());
        }
        i += 1;
    }
    Ok(args)
}

impl Args {
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_or(&self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or(default).to_string()
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse::<f64>()
                .map_err(|_| format!("--{name} expects a number, got {v:?}")),
        }
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse::<usize>()
                .map_err(|_| format!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn opt_u64(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse::<u64>()
                .map_err(|_| format!("--{name} expects an integer, got {v:?}")),
        }
    }

    /// Comma-separated list option.
    pub fn opt_list(&self, name: &str) -> Vec<String> {
        match self.opt(name) {
            None => Vec::new(),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| s.trim().to_string())
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_mixed() {
        let a = parse(
            &s(&["figure", "7", "--rho", "0.5", "--seed=9", "--verbose"]),
            &["rho", "seed"],
        )
        .unwrap();
        assert_eq!(a.positional, vec!["figure", "7"]);
        assert_eq!(a.opt_f64("rho", 0.0).unwrap(), 0.5);
        assert_eq!(a.opt_u64("seed", 0).unwrap(), 9);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn missing_value_errors() {
        assert!(parse(&s(&["--rho"]), &["rho"]).is_err());
    }

    #[test]
    fn unknown_value_option_errors() {
        assert!(parse(&s(&["--bogus=1"]), &["rho"]).is_err());
    }

    #[test]
    fn bad_number_errors() {
        let a = parse(&s(&["--rho", "abc"]), &["rho"]).unwrap();
        assert!(a.opt_f64("rho", 0.0).is_err());
    }

    #[test]
    fn list_option() {
        let a = parse(&s(&["--models", "a,b, c"]), &["models"]).unwrap();
        assert_eq!(a.opt_list("models"), vec!["a", "b", "c"]);
        assert!(a.opt_list("none").is_empty());
    }
}
