//! Criterion-less micro-benchmark harness (offline environment carries no
//! criterion). Provides warmup, repeated timed runs, and robust statistics;
//! `cargo bench` binaries use this to print one table per paper figure.

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl BenchStats {
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1_000.0
    }

    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1_000_000.0
    }
}

/// Time `f` for at least `min_iters` iterations and ~`budget_ms` of wall
/// clock, whichever is larger. The closure's return is black-boxed.
pub fn bench<T, F: FnMut() -> T>(name: &str, min_iters: usize, budget_ms: u64, mut f: F) -> BenchStats {
    // Warmup: 10% of budget.
    let warm_until = Instant::now() + std::time::Duration::from_millis(budget_ms / 10 + 1);
    while Instant::now() < warm_until {
        black_box(f());
    }

    let mut samples_ns: Vec<f64> = Vec::new();
    let run_until = Instant::now() + std::time::Duration::from_millis(budget_ms);
    while samples_ns.len() < min_iters || (Instant::now() < run_until && samples_ns.len() < 10_000_000) {
        let t0 = Instant::now();
        black_box(f());
        samples_ns.push(t0.elapsed().as_nanos() as f64);
        if samples_ns.len() >= min_iters && Instant::now() >= run_until {
            break;
        }
    }

    samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples_ns.len();
    let mean = samples_ns.iter().sum::<f64>() / n as f64;
    BenchStats {
        name: name.to_string(),
        iters: n,
        mean_ns: mean,
        median_ns: samples_ns[n / 2],
        p95_ns: samples_ns[(n as f64 * 0.95) as usize % n],
        min_ns: samples_ns[0],
    }
}

/// Prevent the optimizer from deleting the benchmarked computation.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

pub fn print_header(title: &str) {
    println!("\n== {title} ==");
    println!(
        "{:<44} {:>10} {:>12} {:>12} {:>12}",
        "benchmark", "iters", "mean", "median", "p95"
    );
}

pub fn print_row(s: &BenchStats) {
    println!(
        "{:<44} {:>10} {:>12} {:>12} {:>12}",
        s.name,
        s.iters,
        fmt_ns(s.mean_ns),
        fmt_ns(s.median_ns),
        fmt_ns(s.p95_ns)
    );
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.3} s", ns / 1_000_000_000.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_sane_stats() {
        let s = bench("noop", 10, 5, || 1 + 1);
        assert!(s.iters >= 10);
        assert!(s.min_ns <= s.median_ns);
        assert!(s.median_ns <= s.p95_ns * 1.001);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5_000.0).ends_with("µs"));
        assert!(fmt_ns(5_000_000.0).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with(" s"));
    }
}
