//! Minimal JSON parser + serializer.
//!
//! The offline build environment carries no `serde`, so SwapLess ships its
//! own JSON substrate: a recursive-descent parser and a pretty-printer,
//! sufficient for the artifact manifest, profiles, configs, and experiment
//! result files. Numbers are stored as `f64` (the manifest's integer fields
//! are well inside the 2^53 exact range).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ----- constructors -------------------------------------------------
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn from_pairs(pairs: Vec<(&str, Json)>) -> Json {
        let mut m = BTreeMap::new();
        for (k, v) in pairs {
            m.insert(k.to_string(), v);
        }
        Json::Obj(m)
    }

    // ----- accessors ----------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or_else(|| JsonError {
            msg: format!("missing key {key:?}"),
            pos: 0,
        })
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Typed field lookups (error includes the key name).
    pub fn f64_of(&self, key: &str) -> Result<f64, JsonError> {
        self.req(key)?.as_f64().ok_or_else(|| JsonError {
            msg: format!("key {key:?} is not a number"),
            pos: 0,
        })
    }

    pub fn u64_of(&self, key: &str) -> Result<u64, JsonError> {
        self.req(key)?.as_u64().ok_or_else(|| JsonError {
            msg: format!("key {key:?} is not a non-negative integer"),
            pos: 0,
        })
    }

    pub fn usize_of(&self, key: &str) -> Result<usize, JsonError> {
        Ok(self.u64_of(key)? as usize)
    }

    pub fn str_of(&self, key: &str) -> Result<String, JsonError> {
        Ok(self
            .req(key)?
            .as_str()
            .ok_or_else(|| JsonError {
                msg: format!("key {key:?} is not a string"),
                pos: 0,
            })?
            .to_string())
    }

    pub fn arr_of(&self, key: &str) -> Result<&[Json], JsonError> {
        self.req(key)?.as_arr().ok_or_else(|| JsonError {
            msg: format!("key {key:?} is not an array"),
            pos: 0,
        })
    }

    /// Insert into an object (panics if not an object — construction-time use).
    pub fn set(&mut self, key: &str, value: Json) {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), value);
            }
            _ => panic!("Json::set on non-object"),
        }
    }

    // ----- serialization --------------------------------------------------
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&" ".repeat(indent + 1));
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&" ".repeat(indent + 1));
                    }
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1, pretty);
                }
                if pretty {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent));
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

pub fn parse(input: &str) -> Result<Json, JsonError> {
    let bytes = input.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        return Err(p.err("trailing data after top-level value"));
    }
    Ok(v)
}

pub fn parse_file(path: &str) -> Result<Json, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
    parse(&text).map_err(|e| format!("parse {path}: {e}"))
}

pub fn write_file(path: &str, value: &Json) -> Result<(), String> {
    if let Some(parent) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(parent).map_err(|e| format!("mkdir {parent:?}: {e}"))?;
    }
    std::fs::write(path, value.to_string_pretty()).map_err(|e| format!("write {path}: {e}"))
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(&format!("unexpected byte {:?}", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(self.err(&format!("expected {word:?}")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{0008}'),
                        Some(b'f') => s.push('\u{000c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs are not needed for our files;
                            // map unpaired surrogates to the replacement char.
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let rest = &self.bytes[start..];
                    let len = utf8_len(rest[0]);
                    if rest.len() < len {
                        return Err(self.err("truncated UTF-8"));
                    }
                    let chunk = std::str::from_utf8(&rest[..len])
                        .map_err(|_| self.err("invalid UTF-8"))?;
                    s.push_str(chunk);
                    self.pos += len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b >> 5 == 0b110 {
        2
    } else if b >> 4 == 0b1110 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.arr_of("a").unwrap().len(), 3);
        assert_eq!(v.str_of("c").unwrap(), "x\ny");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn roundtrips() {
        let orig = r#"{"models": [{"name": "squeezenet", "size": 1.4, "pp": 2, "ok": true}], "v": 1}"#;
        let v = parse(orig).unwrap();
        let text = v.to_string_pretty();
        assert_eq!(parse(&text).unwrap(), v);
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\te\u{0007}".into());
        assert_eq!(parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::Num(5.0).to_string_pretty(), "5");
        assert_eq!(Json::Num(5.25).to_string_pretty(), "5.25");
    }

    #[test]
    fn typed_accessors() {
        let v = parse(r#"{"n": 3, "s": "x", "f": 1.5}"#).unwrap();
        assert_eq!(v.usize_of("n").unwrap(), 3);
        assert_eq!(v.f64_of("f").unwrap(), 1.5);
        assert!(v.u64_of("f").is_err());
        assert!(v.str_of("missing").is_err());
    }
}
