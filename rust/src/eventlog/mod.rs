//! Append-only binary request event log.
//!
//! Every request-lifecycle transition the serving stack counts —
//! admit/reject/shed/expire/start/complete/cancel plus the fleet's
//! migrate/failover moves — can additionally be written as a compact
//! fixed-width record to an append-only file. The log is the durable,
//! lossless counterpart of the in-memory aggregates: a logged run can be
//! audited after the fact ([`views::Rollup`] re-materializes the same
//! `ServeStats`-shaped counters from the file), replayed from any record
//! offset, and loaded back as an arrival trace
//! (`workload::trace::load_log`).
//!
//! Writing is **off the hot path**: [`EventLog::emit`] pushes the record
//! onto a bounded channel and returns; a dedicated writer thread encodes
//! and appends. When the channel is full the record is dropped and
//! counted ([`EventLog::dropped`]) — the serving path never blocks on
//! the log. On [`EventLog::close`] (or the last clone dropping) the
//! writer flushes, truncates any torn tail to a whole-record boundary,
//! and fsyncs, so a reader never sees a partial record it cannot detect:
//! [`read_from`] additionally ignores a trailing partial record, which
//! covers a crash that kills the process before the clean shutdown runs.
//!
//! Record layout (fixed 40 bytes, little-endian):
//!
//! | bytes | field  | meaning                                              |
//! |-------|--------|------------------------------------------------------|
//! | 0     | kind   | [`EventKind`] discriminant (0..=12)                  |
//! | 1     | class  | [`SloClass`] dense index                             |
//! | 2     | flags  | bit0 missed, bit1 entry, bit2 outage marker          |
//! | 3     | magic  | `0xE7` (format guard / corruption detector)          |
//! | 4..6  | device | fleet device index (u16)                             |
//! | 6..8  | aux    | migrate/failover target device (u16); partition `p`  |
//! |       |        | on `Span*` records                                   |
//! | 8..16 | seq    | record index in this file (writer-assigned, u64)     |
//! | 16..24| tenant | tenant handle (live) or tenant index (DES) (u64);    |
//! |       |        | on `Span*` records the high 32 bits carry the span   |
//! |       |        | id ([`Event::span_id`]) and the low 32 bits the      |
//! |       |        | (truncated) tenant ([`Event::span_tenant`])          |
//! | 24..32| t      | event time, seconds on the producer's clock (f64)    |
//! | 32..40| value  | deadline on entry events (NaN = none); latency on    |
//! |       |        | `Complete`; stage duration on `Span*`; NaN otherwise |

use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::sched::SloClass;
use crate::util::sync::lock_or_recover;

pub mod views;

/// Fixed record width in bytes.
pub const RECORD_BYTES: usize = 40;
/// Byte 3 of every record — a cheap format guard.
pub const MAGIC: u8 = 0xE7;
/// Bounded channel depth between emitters and the writer thread. Sized
/// so a burst of ~64k records (a few hundred ms of saturated serving)
/// absorbs without drops; overflow drops-and-counts rather than blocks.
const CHANNEL_CAPACITY: usize = 65_536;

/// The request-lifecycle transition a record describes. Discriminants
/// are the on-disk byte values — append-only, never renumber.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum EventKind {
    /// Admitted at its entry station (the `accepted` counter).
    Admit = 0,
    /// Refused at its entry station by a bounded queue.
    Reject = 1,
    /// Dropped by overload control after acceptance.
    Shed = 2,
    /// Dropped because the deadline could no longer be met.
    Expire = 3,
    /// Service started at a station (TPU or CPU pool).
    Start = 4,
    /// Completed; `value` carries the end-to-end latency.
    Complete = 5,
    /// Cancelled via the request's token before execution.
    Cancel = 6,
    /// A tenant migrated between devices (`device` = source, `aux` =
    /// destination).
    Migrate = 7,
    /// Failover: with the marker flag set, one device outage being
    /// handled (`device` = the crashed device); without it, one request
    /// served off its home device (`device` = home, `aux` = serving
    /// device, `tenant` = the fleet-level handle).
    Failover = 8,
    /// Span stage: total time the request spent queued across every
    /// station. `t` is the *admission* time, so the span burst alone
    /// reconstructs end-to-end latency (`last.t - span_queue.t`).
    SpanQueue = 9,
    /// Span stage: swap-in (prefix load) time. Emitted only on a cache
    /// miss, so calibration never averages in hit-path zeros.
    SpanSwap = 10,
    /// Span stage: pure TPU service time for the request's prefix
    /// (excludes swap-in and transfers). Emitted iff the partition has a
    /// TPU segment (`p > 0`).
    SpanTpu = 11,
    /// Span stage: CPU suffix execution time. Emitted iff the partition
    /// leaves CPU work (`p < P`).
    SpanCpu = 12,
}

impl EventKind {
    pub const ALL: [EventKind; 13] = [
        EventKind::Admit,
        EventKind::Reject,
        EventKind::Shed,
        EventKind::Expire,
        EventKind::Start,
        EventKind::Complete,
        EventKind::Cancel,
        EventKind::Migrate,
        EventKind::Failover,
        EventKind::SpanQueue,
        EventKind::SpanSwap,
        EventKind::SpanTpu,
        EventKind::SpanCpu,
    ];

    /// True for the sampled per-stage span records (9..=12).
    pub fn is_span(self) -> bool {
        matches!(
            self,
            EventKind::SpanQueue
                | EventKind::SpanSwap
                | EventKind::SpanTpu
                | EventKind::SpanCpu
        )
    }

    pub fn from_u8(b: u8) -> Option<EventKind> {
        EventKind::ALL.get(b as usize).copied()
    }

    pub fn name(self) -> &'static str {
        match self {
            EventKind::Admit => "admit",
            EventKind::Reject => "reject",
            EventKind::Shed => "shed",
            EventKind::Expire => "expire",
            EventKind::Start => "start",
            EventKind::Complete => "complete",
            EventKind::Cancel => "cancel",
            EventKind::Migrate => "migrate",
            EventKind::Failover => "failover",
            EventKind::SpanQueue => "span_queue",
            EventKind::SpanSwap => "span_swap",
            EventKind::SpanTpu => "span_tpu",
            EventKind::SpanCpu => "span_cpu",
        }
    }
}

/// One decoded log record. Emitters leave `seq` at 0 — the writer thread
/// assigns the file-local record index, so `seq` is strictly monotone
/// within a file regardless of emitter interleaving.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Event {
    pub kind: EventKind,
    pub class: SloClass,
    /// Completion delivered after its deadline (`Complete` only).
    pub missed: bool,
    /// The record describes the request's *entry* into the system —
    /// `Admit` always, `Reject` always, and an `Expire` refused at the
    /// entry station (vs. one evicted from a queue post-admission).
    /// Entry records are what `trace::load_log` reconstructs arrivals
    /// from.
    pub entry: bool,
    /// On `Failover`: this record is the per-outage marker, not a
    /// per-request reroute.
    pub marker: bool,
    pub device: u16,
    /// Migrate/failover target device; 0 otherwise.
    pub aux: u16,
    pub seq: u64,
    pub tenant: u64,
    /// Event time in seconds — wall-clock since server start for live
    /// producers, virtual sim time for the DES.
    pub t: f64,
    /// Deadline (entry events, NaN = none) or latency (`Complete`).
    pub value: f64,
}

impl Event {
    pub fn new(kind: EventKind, t: f64, device: usize, tenant: u64, class: SloClass) -> Event {
        Event {
            kind,
            class,
            missed: false,
            entry: false,
            marker: false,
            device: device.min(u16::MAX as usize) as u16,
            aux: 0,
            seq: 0,
            tenant,
            t,
            value: f64::NAN,
        }
    }

    /// Build a `Span*` stage record. The tenant field packs the span id
    /// into its high 32 bits (`(id << 32) | (tenant & 0xFFFF_FFFF)`) so
    /// a multi-record timeline can be regrouped after interleaved
    /// emission; tenants are truncated to 32 bits, which every producer
    /// in this crate satisfies. `aux` carries the partition point `p`
    /// and `value` the stage duration in seconds.
    pub fn span(
        kind: EventKind,
        t: f64,
        device: usize,
        tenant: u64,
        class: SloClass,
        span_id: u32,
        p: usize,
        duration: f64,
    ) -> Event {
        debug_assert!(kind.is_span());
        let mut ev = Event::new(
            kind,
            t,
            device,
            (u64::from(span_id) << 32) | (tenant & 0xFFFF_FFFF),
            class,
        );
        ev.aux = p.min(u16::MAX as usize) as u16;
        ev.value = duration;
        ev
    }

    /// The span id a `Span*` record's tenant field packs.
    pub fn span_id(&self) -> u32 {
        (self.tenant >> 32) as u32
    }

    /// The (32-bit truncated) tenant a `Span*` record's tenant field
    /// packs.
    pub fn span_tenant(&self) -> u64 {
        self.tenant & 0xFFFF_FFFF
    }

    /// The deadline this record carries (`None` encoded as NaN).
    pub fn deadline(&self) -> Option<f64> {
        if self.value.is_nan() {
            None
        } else {
            Some(self.value)
        }
    }

    pub fn encode(&self, buf: &mut [u8; RECORD_BYTES]) {
        buf[0] = self.kind as u8;
        buf[1] = self.class.index() as u8;
        buf[2] = u8::from(self.missed)
            | u8::from(self.entry) << 1
            | u8::from(self.marker) << 2;
        buf[3] = MAGIC;
        buf[4..6].copy_from_slice(&self.device.to_le_bytes());
        buf[6..8].copy_from_slice(&self.aux.to_le_bytes());
        buf[8..16].copy_from_slice(&self.seq.to_le_bytes());
        buf[16..24].copy_from_slice(&self.tenant.to_le_bytes());
        buf[24..32].copy_from_slice(&self.t.to_le_bytes());
        buf[32..40].copy_from_slice(&self.value.to_le_bytes());
    }

    pub fn decode(buf: &[u8]) -> Result<Event, String> {
        if buf.len() < RECORD_BYTES {
            return Err(format!(
                "short record: {} bytes (need {RECORD_BYTES})",
                buf.len()
            ));
        }
        if buf[3] != MAGIC {
            return Err(format!("bad record magic {:#04x}", buf[3]));
        }
        let kind = EventKind::from_u8(buf[0])
            .ok_or_else(|| format!("unknown event kind {}", buf[0]))?;
        let class = SloClass::from_index(buf[1] as usize)
            .ok_or_else(|| format!("unknown SLO class index {}", buf[1]))?;
        Ok(Event {
            kind,
            class,
            missed: buf[2] & 1 != 0,
            entry: buf[2] & 2 != 0,
            marker: buf[2] & 4 != 0,
            device: u16::from_le_bytes([buf[4], buf[5]]),
            aux: u16::from_le_bytes([buf[6], buf[7]]),
            seq: u64::from_le_bytes(buf[8..16].try_into().unwrap()),
            tenant: u64::from_le_bytes(buf[16..24].try_into().unwrap()),
            t: f64::from_le_bytes(buf[24..32].try_into().unwrap()),
            value: f64::from_le_bytes(buf[32..40].try_into().unwrap()),
        })
    }
}

/// Channel payload: records, plus the shutdown sentinel `close` enqueues
/// so the writer can exit even while per-handle senders are still alive.
enum Msg {
    Record(Event),
    Shutdown,
}

/// Counters shared between the handles and the writer thread. The writer
/// holds only this `Arc` — never `LogInner` itself — so the last
/// external handle dropping really does run `LogInner::drop` (a strong
/// reference from the writer would keep the inner alive forever and the
/// implicit close-on-last-drop would never fire).
struct Counters {
    appended: AtomicU64,
    dropped: AtomicU64,
}

struct LogInner {
    /// Sender reserved for the shutdown sentinel. Only `close` touches
    /// this lock — emission goes through each handle's own sender clone.
    tx: Mutex<Option<SyncSender<Msg>>>,
    thread: Mutex<Option<JoinHandle<()>>>,
    /// Set before the sentinel is sent; emission checks it so records
    /// emitted after close are counted dropped instead of piling up in
    /// the (now unconsumed) channel.
    closed: AtomicBool,
    counters: Arc<Counters>,
    path: PathBuf,
}

impl LogInner {
    fn close(&self) {
        self.closed.store(true, Ordering::SeqCst);
        // The sentinel (a blocking send — close is allowed to wait while
        // the backlog drains) tells the writer to stop: it cannot rely
        // on channel disconnection because every live handle still owns
        // a sender clone. The writer drains everything queued ahead of
        // the sentinel, flushes, truncates to a whole-record boundary,
        // and fsyncs before exiting. Idempotent: a second call finds
        // both slots empty.
        let tx = lock_or_recover(&self.tx).take();
        if let Some(tx) = tx {
            let _ = tx.send(Msg::Shutdown);
        }
        if let Some(t) = lock_or_recover(&self.thread).take() {
            let _ = t.join();
        }
    }
}

impl Drop for LogInner {
    fn drop(&mut self) {
        self.close();
    }
}

/// Handle to an open event log. Cheap to clone (all clones feed the same
/// writer); emission never blocks. Closed explicitly via
/// [`close`](EventLog::close) or implicitly when the last clone drops.
#[derive(Clone)]
pub struct EventLog {
    /// Per-handle sender clone: emission is lock-free; the Mutex inside
    /// `LogInner` only coordinates `close`.
    tx: SyncSender<Msg>,
    inner: Arc<LogInner>,
}

impl std::fmt::Debug for EventLog {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("EventLog")
            .field("path", &self.inner.path)
            .field("appended", &self.appended())
            .field("dropped", &self.dropped())
            .finish()
    }
}

impl EventLog {
    /// Create (truncating any existing file) and start the writer thread.
    pub fn create<P: AsRef<Path>>(path: P) -> Result<EventLog, String> {
        let path = path.as_ref().to_path_buf();
        let file = OpenOptions::new()
            .write(true)
            .create(true)
            .truncate(true)
            .open(&path)
            .map_err(|e| format!("create {}: {e}", path.display()))?;
        let (tx, rx) = sync_channel::<Msg>(CHANNEL_CAPACITY);
        let counters = Arc::new(Counters {
            appended: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
        });
        let writer_counters = counters.clone();
        let handle = std::thread::Builder::new()
            .name("eventlog-writer".into())
            .spawn(move || writer_loop(file, rx, &writer_counters))
            .map_err(|e| format!("spawn eventlog writer: {e}"))?;
        let inner = Arc::new(LogInner {
            tx: Mutex::new(Some(tx.clone())),
            thread: Mutex::new(Some(handle)),
            closed: AtomicBool::new(false),
            counters,
            path,
        });
        Ok(EventLog { tx, inner })
    }

    /// Queue a record for the writer thread. Lock-free and never blocks:
    /// a full channel (or a closed log) drops the record and bumps
    /// [`dropped`](Self::dropped).
    pub fn emit(&self, ev: Event) {
        if self.inner.closed.load(Ordering::SeqCst)
            || self.tx.try_send(Msg::Record(ev)).is_err()
        {
            self.inner.counters.dropped.fetch_add(1, Ordering::SeqCst);
        }
    }

    /// Drain the backlog, fsync, truncate any torn tail, and stop the
    /// writer. Safe to call more than once; later [`emit`](Self::emit)s
    /// count as dropped.
    pub fn close(&self) {
        self.inner.close();
    }

    /// Records durably appended by the writer thread.
    pub fn appended(&self) -> u64 {
        self.inner.counters.appended.load(Ordering::SeqCst)
    }

    /// Records dropped (channel overflow or emission after close).
    pub fn dropped(&self) -> u64 {
        self.inner.counters.dropped.load(Ordering::SeqCst)
    }

    pub fn path(&self) -> &Path {
        &self.inner.path
    }
}

fn writer_loop(file: File, rx: Receiver<Msg>, counters: &Counters) {
    let mut w = std::io::BufWriter::new(file);
    let mut written: u64 = 0;
    let mut buf = [0u8; RECORD_BYTES];
    let mut append = |mut ev: Event, w: &mut std::io::BufWriter<File>| {
        ev.seq = written;
        ev.encode(&mut buf);
        if w.write_all(&buf).is_ok() {
            written += 1;
            counters.appended.fetch_add(1, Ordering::SeqCst);
        } else {
            counters.dropped.fetch_add(1, Ordering::SeqCst);
        }
    };
    loop {
        match rx.recv() {
            Ok(Msg::Record(ev)) => append(ev, &mut w),
            // Shutdown sentinel from close(), or (defensively) every
            // sender gone. Records queued ahead of the sentinel were
            // already drained by FIFO order; sweep any that raced in
            // behind it before finalizing the file.
            Ok(Msg::Shutdown) | Err(_) => break,
        }
    }
    while let Ok(Msg::Record(ev)) = rx.try_recv() {
        append(ev, &mut w);
    }
    // Clean shutdown: whatever actually reached the file, cut to a
    // whole-record boundary and make it durable.
    let file = match w.into_inner() {
        Ok(f) => f,
        Err(e) => e.into_inner(),
    };
    if let Ok(meta) = file.metadata() {
        let len = meta.len();
        let _ = file.set_len(len - len % RECORD_BYTES as u64);
    }
    let _ = file.sync_all();
}

/// Read every record from byte 0. See [`read_from`].
pub fn read_all<P: AsRef<Path>>(path: P) -> Result<Vec<Event>, String> {
    read_from(path, 0)
}

/// Read records starting at byte `offset` (must be a whole-record
/// boundary). A trailing partial record — a torn tail from a crash that
/// outran the clean shutdown — is detected by length and skipped;
/// mid-file corruption (bad magic / unknown kind) is an error.
pub fn read_from<P: AsRef<Path>>(path: P, offset: u64) -> Result<Vec<Event>, String> {
    let path = path.as_ref();
    let bytes =
        std::fs::read(path).map_err(|e| format!("read {}: {e}", path.display()))?;
    let usable = bytes.len() - bytes.len() % RECORD_BYTES;
    if offset % RECORD_BYTES as u64 != 0 {
        return Err(format!(
            "offset {offset} is not a multiple of the {RECORD_BYTES}-byte record size"
        ));
    }
    let offset = offset as usize;
    if offset > usable {
        return Err(format!(
            "offset {offset} past the last whole record (usable bytes: {usable})"
        ));
    }
    let mut events = Vec::with_capacity((usable - offset) / RECORD_BYTES);
    for (i, chunk) in bytes[offset..usable].chunks_exact(RECORD_BYTES).enumerate() {
        let ev = Event::decode(chunk).map_err(|e| {
            format!(
                "{} at byte {}: {e}",
                path.display(),
                offset + i * RECORD_BYTES
            )
        })?;
        events.push(ev);
    }
    Ok(events)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_path(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!(
            "swapless-eventlog-{tag}-{}.bin",
            std::process::id()
        ))
    }

    fn sample(kind: EventKind, seq: u64) -> Event {
        let mut ev = Event::new(kind, 1.5 + seq as f64, 3, 42, SloClass::Interactive);
        ev.seq = seq;
        ev.aux = 7;
        ev.entry = kind == EventKind::Admit;
        ev.value = 2.25;
        ev
    }

    #[test]
    fn encode_decode_round_trip_every_kind() {
        for (i, kind) in EventKind::ALL.into_iter().enumerate() {
            let mut ev = sample(kind, i as u64);
            ev.missed = i % 2 == 0;
            ev.marker = kind == EventKind::Failover;
            let mut buf = [0u8; RECORD_BYTES];
            ev.encode(&mut buf);
            assert_eq!(buf[3], MAGIC);
            let back = Event::decode(&buf).unwrap();
            assert_eq!(back, ev);
        }
        // NaN deadline round-trips to None.
        let ev = Event::new(EventKind::Admit, 0.0, 0, 0, SloClass::Standard);
        let mut buf = [0u8; RECORD_BYTES];
        ev.encode(&mut buf);
        assert_eq!(Event::decode(&buf).unwrap().deadline(), None);
    }

    #[test]
    fn span_records_pack_id_partition_and_duration() {
        let ev = Event::span(
            EventKind::SpanTpu,
            3.5,
            2,
            0xDEAD_BEEF_0000_0042, // high bits beyond 32 are truncated
            SloClass::Batch,
            7,
            5,
            0.012,
        );
        assert!(ev.kind.is_span());
        assert_eq!(ev.span_id(), 7);
        assert_eq!(ev.span_tenant(), 0x42);
        assert_eq!(ev.aux, 5);
        assert_eq!(ev.value, 0.012);
        let mut buf = [0u8; RECORD_BYTES];
        ev.encode(&mut buf);
        let back = Event::decode(&buf).unwrap();
        assert_eq!(back, ev);
        assert_eq!(back.span_id(), 7);
        assert_eq!(back.span_tenant(), 0x42);
    }

    #[test]
    fn decode_rejects_corruption() {
        let mut buf = [0u8; RECORD_BYTES];
        sample(EventKind::Admit, 0).encode(&mut buf);
        let mut bad_magic = buf;
        bad_magic[3] = 0x00;
        assert!(Event::decode(&bad_magic).is_err());
        let mut bad_kind = buf;
        bad_kind[0] = 99;
        assert!(Event::decode(&bad_kind).is_err());
        let mut bad_class = buf;
        bad_class[1] = 17;
        assert!(Event::decode(&bad_class).is_err());
        assert!(Event::decode(&buf[..10]).is_err());
    }

    #[test]
    fn write_close_read_round_trip_with_writer_assigned_seq() {
        let path = temp_path("roundtrip");
        let log = EventLog::create(&path).unwrap();
        for i in 0..100u64 {
            let mut ev = sample(EventKind::ALL[i as usize % EventKind::ALL.len()], 0);
            ev.tenant = i;
            log.emit(ev);
        }
        log.close();
        assert_eq!(log.appended(), 100);
        assert_eq!(log.dropped(), 0);
        let events = read_all(&path).unwrap();
        assert_eq!(events.len(), 100);
        for (i, ev) in events.iter().enumerate() {
            assert_eq!(ev.seq, i as u64, "writer assigns file-order seq");
            assert_eq!(ev.tenant, i as u64);
        }
        // Emission after close is drop-and-count, not an error.
        log.emit(sample(EventKind::Admit, 0));
        assert_eq!(log.dropped(), 1);
        // close() is idempotent.
        log.close();
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn reader_skips_a_torn_tail_and_replays_from_offsets() {
        let path = temp_path("torn");
        let log = EventLog::create(&path).unwrap();
        for i in 0..10u64 {
            let mut ev = sample(EventKind::Complete, 0);
            ev.tenant = i;
            log.emit(ev);
        }
        log.close();
        // Simulate a crash mid-append: a partial 17-byte record at the
        // tail. The reader must skip it, not fail.
        {
            let mut f = OpenOptions::new().append(true).open(&path).unwrap();
            f.write_all(&[0xAB; 17]).unwrap();
        }
        let events = read_all(&path).unwrap();
        assert_eq!(events.len(), 10);
        // Replay from a mid-file record boundary.
        let tail = read_from(&path, 4 * RECORD_BYTES as u64).unwrap();
        assert_eq!(tail.len(), 6);
        assert_eq!(tail[0].tenant, 4);
        // Misaligned or out-of-range offsets are errors.
        assert!(read_from(&path, 13).is_err());
        assert!(read_from(&path, 11 * RECORD_BYTES as u64).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn dropping_the_last_clone_closes_cleanly() {
        let path = temp_path("drop");
        let log = EventLog::create(&path).unwrap();
        let clone = log.clone();
        clone.emit(sample(EventKind::Admit, 0));
        drop(clone);
        drop(log);
        assert_eq!(read_all(&path).unwrap().len(), 1);
        let _ = std::fs::remove_file(&path);
    }
}
