//! Incremental materialized views over an event log.
//!
//! [`Rollup`] consumes records one at a time ([`Rollup::apply`]) and
//! maintains the same counters the live path keeps in memory: per-tenant
//! outcome counts, per-class counts plus latency histograms
//! ([`PerClassLatency`], the exact type `ServeStats` exposes), and
//! per-device totals. Because every counter is integral and `apply` is
//! a pure fold, replaying a log from offset 0 reproduces the live
//! counts bit-exactly, and a full replay equals a prefix rollup plus a
//! suffix rollup — the property the `audit` experiment and the parity
//! tests pin.
//!
//! Float aggregates (latency means) are intentionally *not* part of the
//! parity contract: emission order into the log is not the live
//! aggregation order, and Welford means are order-dependent. Counts and
//! histogram totals are order-free; means agree to float noise only.

use std::collections::BTreeMap;

use crate::metrics::PerClassLatency;

use super::{Event, EventKind};

/// Integral outcome counters for one tenant (or one device).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Counts {
    pub accepted: u64,
    pub rejected: u64,
    pub shed: u64,
    pub expired: u64,
    pub cancelled: u64,
    pub completed: u64,
}

impl Counts {
    /// Post-admission drops — the live path's combined `dropped` counter
    /// (shed + expired + cancelled).
    pub fn dropped(&self) -> u64 {
        self.shed + self.expired + self.cancelled
    }
}

/// `ServeStats`-shaped counters materialized incrementally from records.
#[derive(Debug, Clone, Default)]
pub struct Rollup {
    /// Keyed by `(device, tenant handle)`: each member server numbers
    /// its handles from 0, so the handle alone collides across devices.
    pub per_tenant: BTreeMap<(u16, u64), Counts>,
    /// Per-class counts, latency histograms, and deadline misses.
    pub per_class: PerClassLatency,
    /// Indexed by device; grown on demand.
    pub per_device: Vec<Counts>,
    /// `Start` records (station service starts).
    pub started: u64,
    /// Tenant migrations between devices.
    pub migrations: u64,
    /// Device outages handled (marker `Failover` records).
    pub failovers: u64,
    /// Requests served off their home device (non-marker `Failover`).
    pub failed_over: u64,
    /// Off-home requests per *fleet-level* tenant handle. A separate
    /// namespace from `per_tenant`'s member-server handles: the fleet
    /// assigns its own handles, and failover records carry those.
    pub per_tenant_failed_over: BTreeMap<u64, u64>,
    /// Sampled span stage records (`Span*` kinds). Outcome counters are
    /// untouched by spans, so audit parity with the live `ServeStats`
    /// holds whether or not a run sampled spans.
    pub spans: u64,
    /// Records consumed.
    pub records: u64,
}

impl Rollup {
    pub fn new() -> Rollup {
        Rollup::default()
    }

    /// Fold all of `events` into the rollup.
    pub fn replay(events: &[Event]) -> Rollup {
        let mut r = Rollup::new();
        for ev in events {
            r.apply(ev);
        }
        r
    }

    fn tenant_mut(&mut self, ev: &Event) -> &mut Counts {
        self.per_tenant.entry((ev.device, ev.tenant)).or_default()
    }

    fn device_mut(&mut self, device: u16) -> &mut Counts {
        let d = device as usize;
        if self.per_device.len() <= d {
            self.per_device.resize(d + 1, Counts::default());
        }
        &mut self.per_device[d]
    }

    /// Consume one record.
    pub fn apply(&mut self, ev: &Event) {
        self.records += 1;
        match ev.kind {
            EventKind::Admit => {
                self.tenant_mut(ev).accepted += 1;
                self.device_mut(ev.device).accepted += 1;
                self.per_class.record_accept(ev.class);
            }
            EventKind::Reject => {
                self.tenant_mut(ev).rejected += 1;
                self.device_mut(ev.device).rejected += 1;
                self.per_class.record_reject(ev.class);
            }
            EventKind::Shed => {
                self.tenant_mut(ev).shed += 1;
                self.device_mut(ev.device).shed += 1;
                self.per_class.record_shed(ev.class);
            }
            EventKind::Expire => {
                self.tenant_mut(ev).expired += 1;
                self.device_mut(ev.device).expired += 1;
                self.per_class.record_expired(ev.class);
            }
            EventKind::Start => {
                self.started += 1;
            }
            EventKind::Complete => {
                self.tenant_mut(ev).completed += 1;
                self.device_mut(ev.device).completed += 1;
                if ev.value.is_finite() {
                    self.per_class.record(ev.class, ev.value);
                }
                if ev.missed {
                    self.per_class.record_miss(ev.class);
                }
            }
            EventKind::Cancel => {
                self.tenant_mut(ev).cancelled += 1;
                self.device_mut(ev.device).cancelled += 1;
                self.per_class.record_cancelled(ev.class);
            }
            EventKind::Migrate => {
                self.migrations += 1;
            }
            EventKind::Failover => {
                if ev.marker {
                    self.failovers += 1;
                } else {
                    self.failed_over += 1;
                    *self.per_tenant_failed_over.entry(ev.tenant).or_insert(0) += 1;
                }
            }
            EventKind::SpanQueue
            | EventKind::SpanSwap
            | EventKind::SpanTpu
            | EventKind::SpanCpu => {
                self.spans += 1;
            }
        }
    }

    /// Totals across tenants — the shape of the live `overload:` line.
    pub fn totals(&self) -> Counts {
        let mut t = Counts::default();
        for c in self.per_tenant.values() {
            t.accepted += c.accepted;
            t.rejected += c.rejected;
            t.shed += c.shed;
            t.expired += c.expired;
            t.cancelled += c.cancelled;
            t.completed += c.completed;
        }
        t
    }

    /// Completions that met their deadline, per the class histograms.
    pub fn goodput(&self) -> u64 {
        self.per_class.goodput_total()
    }

    /// Merge another rollup (e.g. a suffix) into this one. Counts add;
    /// histogram merge requires identical geometry (always true for
    /// rollups, which use the default geometry).
    pub fn merge(&mut self, other: &Rollup) {
        for (k, c) in &other.per_tenant {
            let e = self.per_tenant.entry(*k).or_default();
            e.accepted += c.accepted;
            e.rejected += c.rejected;
            e.shed += c.shed;
            e.expired += c.expired;
            e.cancelled += c.cancelled;
            e.completed += c.completed;
        }
        if self.per_device.len() < other.per_device.len() {
            self.per_device
                .resize(other.per_device.len(), Counts::default());
        }
        for (d, c) in other.per_device.iter().enumerate() {
            let e = &mut self.per_device[d];
            e.accepted += c.accepted;
            e.rejected += c.rejected;
            e.shed += c.shed;
            e.expired += c.expired;
            e.cancelled += c.cancelled;
            e.completed += c.completed;
        }
        self.per_class.merge(&other.per_class);
        self.started += other.started;
        self.migrations += other.migrations;
        self.failovers += other.failovers;
        self.failed_over += other.failed_over;
        for (t, n) in &other.per_tenant_failed_over {
            *self.per_tenant_failed_over.entry(*t).or_insert(0) += n;
        }
        self.spans += other.spans;
        self.records += other.records;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::SloClass;

    fn ev(kind: EventKind, device: usize, tenant: u64, class: SloClass) -> Event {
        Event::new(kind, 1.0, device, tenant, class)
    }

    #[test]
    fn rollup_materializes_per_tenant_class_device_counters() {
        let mut events = vec![
            ev(EventKind::Admit, 0, 0, SloClass::Interactive),
            ev(EventKind::Start, 0, 0, SloClass::Interactive),
            ev(EventKind::Admit, 1, 0, SloClass::Standard),
            ev(EventKind::Reject, 0, 1, SloClass::Batch),
            ev(EventKind::Shed, 1, 0, SloClass::Standard),
            ev(EventKind::Expire, 0, 0, SloClass::Interactive),
            ev(EventKind::Cancel, 1, 2, SloClass::Batch),
            ev(EventKind::Migrate, 0, 0, SloClass::Standard),
        ];
        let mut done = ev(EventKind::Complete, 0, 0, SloClass::Interactive);
        done.value = 0.004;
        done.missed = true;
        events.push(done);
        let mut outage = ev(EventKind::Failover, 1, u64::MAX, SloClass::Standard);
        outage.marker = true;
        events.push(outage);
        events.push(ev(EventKind::Failover, 1, 3, SloClass::Standard));
        // Span records bump only `spans`/`records` — outcome counters
        // must be identical with or without sampling.
        events.push(Event::span(
            EventKind::SpanQueue,
            1.0,
            0,
            0,
            SloClass::Interactive,
            1,
            3,
            0.002,
        ));

        let r = Rollup::replay(&events);
        assert_eq!(r.records, events.len() as u64);
        let t00 = r.per_tenant[&(0, 0)];
        assert_eq!((t00.accepted, t00.expired, t00.completed), (1, 1, 1));
        // Same handle on another device is a different tenant.
        let t10 = r.per_tenant[&(1, 0)];
        assert_eq!((t10.accepted, t10.shed), (1, 1));
        assert_eq!(r.per_tenant[&(0, 1)].rejected, 1);
        assert_eq!(r.per_tenant[&(1, 2)].cancelled, 1);
        assert_eq!(r.per_device[0].completed, 1);
        assert_eq!(r.per_device[1].accepted, 1);
        assert_eq!(r.started, 1);
        assert_eq!(r.spans, 1);
        assert_eq!(r.migrations, 1);
        assert_eq!(r.failovers, 1);
        assert_eq!(r.failed_over, 1);
        assert_eq!(r.per_tenant_failed_over[&3], 1);
        assert_eq!(r.per_class.accepted(SloClass::Interactive), 1);
        assert_eq!(r.per_class.missed(SloClass::Interactive), 1);
        assert_eq!(r.per_class.get(SloClass::Interactive).count(), 1);
        let tot = r.totals();
        assert_eq!(tot.accepted, 2);
        assert_eq!(tot.dropped(), 3);
    }

    #[test]
    fn prefix_plus_suffix_merge_equals_full_replay() {
        let mut events = Vec::new();
        for i in 0..200u64 {
            let kind = EventKind::ALL[(i % 7) as usize]; // lifecycle kinds
            let class = SloClass::from_index((i % 3) as usize).unwrap();
            let mut e = ev(kind, (i % 2) as usize, i % 5, class);
            if kind == EventKind::Complete {
                e.value = 0.001 * (1 + i % 9) as f64;
            }
            events.push(e);
        }
        let full = Rollup::replay(&events);
        let mid = events.len() / 2;
        let mut merged = Rollup::replay(&events[..mid]);
        merged.merge(&Rollup::replay(&events[mid..]));
        assert_eq!(merged.per_tenant, full.per_tenant);
        assert_eq!(merged.per_device, full.per_device);
        assert_eq!(merged.records, full.records);
        assert_eq!(merged.started, full.started);
        for c in SloClass::ALL {
            assert_eq!(merged.per_class.accepted(c), full.per_class.accepted(c));
            assert_eq!(
                merged.per_class.get(c).count(),
                full.per_class.get(c).count()
            );
        }
    }
}
