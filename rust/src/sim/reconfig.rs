//! Online reconfiguration policies for dynamic workloads (Section IV +
//! Fig. 8): a sliding-window rate monitor feeding the resource allocator.
//!
//! A [`ReconfigPolicy`] is the single decision surface shared by the two
//! execution engines: the DES ([`crate::sim::Simulator`]) and the live
//! coordinator ([`crate::coordinator::Server`]) both feed arrivals in via
//! `observe_arrival`, invoke `decide` on the policy's period, and notify
//! tenant churn through the `on_attach`/`on_detach` hooks — there is no
//! second, hand-rolled re-planning loop anywhere.

use std::collections::VecDeque;

use crate::alloc;
use crate::analytic::{AnalyticModel, Config, Tenant};
use crate::tpu::PrefixTables;

/// Periodic decision hook the DES and the live coordinator invoke.
pub trait ReconfigPolicy {
    /// Seconds between periodic `decide` invocations; `None` means the
    /// policy never wants a periodic wake-up (static policies).
    fn period(&self) -> Option<f64>;
    /// Called on every arrival (the rate-monitor feed). `model` is the
    /// tenant's *current* positional index.
    fn observe_arrival(&mut self, t: f64, model: usize);
    /// Return `Some(new_config)` to reconfigure, `None` to keep current.
    /// `tenants` and `current` are positionally aligned snapshots.
    fn decide(&mut self, t: f64, tenants: &[Tenant], current: &Config) -> Option<Config>;
    /// A tenant was appended at positional `index` (== new tenant count−1).
    fn on_attach(&mut self, _t: f64, _index: usize) {}
    /// The tenant at positional `index` was removed; peers above shifted
    /// down by one.
    fn on_detach(&mut self, _t: f64, _index: usize) {}
    /// Fleet-level extension: propose a tenant→device reassignment for
    /// the given device registry (heterogeneous specs included —
    /// policies must plan against the *actual* fleet, not a clone of
    /// device 0). `current` maps tenant position → device index.
    /// Returning `Some(target)` asks the fleet router
    /// ([`crate::fleet::FleetServer::rebalance`]) to migrate every tenant
    /// whose device changed (drain-then-move). The default never
    /// migrates, so single-device policies are unaffected.
    fn decide_placement(
        &mut self,
        _t: f64,
        _tenants: &[Tenant],
        _fleet: &crate::fleet::Fleet,
        _current: &[usize],
    ) -> Option<Vec<usize>> {
        None
    }
}

/// Sliding-window per-model arrival-rate estimator.
///
/// Per-model event counts are maintained incrementally on observe/evict,
/// so [`rates`](RateMonitor::rates) is O(n_models) — it is called under
/// the coordinator's submit-path lock, where the old recount-the-window
/// implementation was O(events in window) per call.
#[derive(Debug, Clone)]
pub struct RateMonitor {
    window: f64,
    events: VecDeque<(f64, usize)>,
    counts: Vec<u64>,
}

impl RateMonitor {
    pub fn new(window: f64, n_models: usize) -> RateMonitor {
        assert!(window > 0.0);
        RateMonitor {
            window,
            events: VecDeque::new(),
            counts: vec![0; n_models],
        }
    }

    pub fn n_models(&self) -> usize {
        self.counts.len()
    }

    pub fn observe(&mut self, t: f64, model: usize) {
        // Out-of-range observations (a submit racing a detach) are dropped
        // rather than corrupting a peer's count.
        if model >= self.counts.len() {
            return;
        }
        self.events.push_back((t, model));
        self.counts[model] += 1;
        self.evict(t);
    }

    /// Track a newly attached model (appended at the end).
    pub fn insert_model(&mut self) {
        self.counts.push(0);
    }

    /// Forget the model at `index`; peers above shift down by one (their
    /// windowed events are preserved under the shifted indices).
    pub fn remove_model(&mut self, index: usize) {
        if index >= self.counts.len() {
            return;
        }
        self.counts.remove(index);
        let mut kept = VecDeque::with_capacity(self.events.len());
        for (t, m) in self.events.drain(..) {
            match m.cmp(&index) {
                std::cmp::Ordering::Less => kept.push_back((t, m)),
                std::cmp::Ordering::Equal => {}
                std::cmp::Ordering::Greater => kept.push_back((t, m - 1)),
            }
        }
        self.events = kept;
    }

    fn evict(&mut self, now: f64) {
        while let Some((t, m)) = self.events.front() {
            if now - t > self.window {
                self.counts[*m] -= 1;
                self.events.pop_front();
            } else {
                break;
            }
        }
    }

    /// Estimated per-model rates at time `now` (events / effective window).
    pub fn rates(&mut self, now: f64) -> Vec<f64> {
        self.evict(now);
        // Early in the run the window isn't full yet.
        let effective = self.window.min(now.max(1e-9));
        self.counts
            .iter()
            .map(|c| *c as f64 / effective)
            .collect()
    }
}

/// The SwapLess online policy: estimate rates over a sliding window, run
/// the hill-climbing allocator, and reconfigure when the predicted config
/// changes. Decision wall-clock times are recorded (the paper reports
/// < 2 ms per invocation). Tenant churn (`on_attach`/`on_detach`) resizes
/// the monitor in place and forces a re-plan on the next `decide`.
pub struct SwapLessPolicy {
    pub am: AnalyticModel,
    pub k_max: usize,
    pub monitor: RateMonitor,
    window: f64,
    period: f64,
    /// Relative rate change below which we skip re-planning.
    threshold: f64,
    last_rates: Vec<f64>,
    /// Rates the last `decide_placement` search ran with — the same
    /// threshold damping, applied independently to the (more expensive,
    /// migration-triggering) fleet-placement decision.
    last_placement_rates: Vec<f64>,
    /// Set by the churn hooks: the tenant set changed, so the next
    /// `decide` must re-plan regardless of the rate-change threshold.
    force_replan: bool,
    /// Like `force_replan`, for the next `decide_placement`.
    placement_dirty: bool,
    /// A previous `decide` saw a tenant count that disagreed with the
    /// monitor (stale snapshot racing churn, or a hookless driver).
    resync_pending: bool,
    pub decision_micros: Vec<f64>,
    /// Per-model prefix tables, built on the first decision and reused by
    /// every re-plan (rates change between decisions; the tables are
    /// rate-independent). Keyed by (model name, partition count) — names
    /// uniquely identify models under the manifest contract, and the
    /// partition count guards against a same-named model that was
    /// re-segmented — so a policy handed a different mix (including after
    /// churn) rebuilds instead of planning with stale tables.
    tables: Vec<PrefixTables>,
    table_models: Vec<(String, usize)>,
}

impl SwapLessPolicy {
    pub fn new(
        am: AnalyticModel,
        k_max: usize,
        n_models: usize,
        window: f64,
        period: f64,
        threshold: f64,
    ) -> SwapLessPolicy {
        SwapLessPolicy {
            am,
            k_max,
            monitor: RateMonitor::new(window, n_models),
            window,
            period,
            threshold,
            last_rates: vec![0.0; n_models],
            last_placement_rates: Vec::new(),
            force_replan: false,
            placement_dirty: true,
            resync_pending: false,
            decision_micros: Vec::new(),
            tables: Vec::new(),
            table_models: Vec::new(),
        }
    }

    fn rates_changed(&self, rates: &[f64]) -> bool {
        rates_differ(rates, &self.last_rates, self.threshold)
    }
}

/// True when any rate moved by more than `threshold` relative to `old`
/// (floored at 0.1 rps so idle tenants don't divide by ~zero).
fn rates_differ(new: &[f64], old: &[f64], threshold: f64) -> bool {
    for (n, o) in new.iter().zip(old) {
        let base = o.abs().max(0.1);
        if (n - o).abs() / base > threshold {
            return true;
        }
    }
    false
}

impl ReconfigPolicy for SwapLessPolicy {
    fn period(&self) -> Option<f64> {
        Some(self.period)
    }

    fn observe_arrival(&mut self, t: f64, model: usize) {
        self.monitor.observe(t, model);
    }

    fn on_attach(&mut self, _t: f64, _index: usize) {
        self.monitor.insert_model();
        self.last_rates.push(0.0);
        self.force_replan = true;
        self.placement_dirty = true;
    }

    fn on_detach(&mut self, _t: f64, index: usize) {
        self.monitor.remove_model(index);
        if index < self.last_rates.len() {
            self.last_rates.remove(index);
        }
        self.force_replan = true;
        self.placement_dirty = true;
    }

    fn decide(&mut self, t: f64, tenants: &[Tenant], current: &Config) -> Option<Config> {
        if self.monitor.n_models() != tenants.len() {
            // A single mismatch is almost always a stale snapshot racing a
            // churn hook (the caller's epoch guard discards the result
            // anyway) — skip rather than destroy the live rate window. A
            // PERSISTENT mismatch means the caller drives churn without
            // the hooks; resync defensively then.
            if !self.resync_pending {
                self.resync_pending = true;
                return None;
            }
            self.monitor = RateMonitor::new(self.window, tenants.len());
            self.last_rates = vec![0.0; tenants.len()];
            self.force_replan = true;
        }
        self.resync_pending = false;
        let rates = self.monitor.rates(t);
        if !self.force_replan && !self.rates_changed(&rates) {
            return None;
        }
        let stale = self.table_models.len() != tenants.len()
            || self.table_models.iter().zip(tenants).any(|((name, pp), t)| {
                *name != t.model.name || *pp != t.model.partition_points
            });
        if stale {
            self.tables = PrefixTables::for_tenants(&self.am.cost, tenants);
            self.table_models = tenants
                .iter()
                .map(|t| (t.model.name.clone(), t.model.partition_points))
                .collect();
        }
        let t0 = std::time::Instant::now();
        let estimated: Vec<Tenant> = tenants
            .iter()
            .zip(&rates)
            .map(|(tn, r)| Tenant {
                model: tn.model.clone(),
                rate: *r,
            })
            .collect();
        let alloc = alloc::hill_climb_with_tables(&self.am, &estimated, &self.tables, self.k_max);
        self.decision_micros
            .push(t0.elapsed().as_secs_f64() * 1e6);
        self.last_rates = rates;
        self.force_replan = false;
        if &alloc.config != current {
            Some(alloc.config)
        } else {
            None
        }
    }

    /// The SwapLess placement extension: estimate rates from the monitor
    /// and run the two-level fleet search ([`crate::fleet::place`]) over
    /// the actual device registry (per-device SRAM/bandwidth/core
    /// budgets respected). No observed traffic ⇒ no move.
    fn decide_placement(
        &mut self,
        t: f64,
        tenants: &[Tenant],
        fleet: &crate::fleet::Fleet,
        current: &[usize],
    ) -> Option<Vec<usize>> {
        if fleet.len() <= 1 || tenants.is_empty() || current.len() != tenants.len() {
            return None;
        }
        if self.monitor.n_models() != tenants.len() {
            return None;
        }
        let rates = self.monitor.rates(t);
        if rates.iter().sum::<f64>() <= 0.0 {
            return None;
        }
        // The same threshold damping `decide` applies: skip the (more
        // expensive, migration-triggering) two-level search while the
        // tenant set is unchanged and no rate moved beyond `threshold`
        // since the last placement decision — Poisson noise on a
        // near-tie placement must not flip tenants between devices.
        if !self.placement_dirty
            && self.last_placement_rates.len() == rates.len()
            && !rates_differ(&rates, &self.last_placement_rates, self.threshold)
        {
            return None;
        }
        let estimated: Vec<Tenant> = tenants
            .iter()
            .zip(&rates)
            .map(|(tn, r)| Tenant {
                model: tn.model.clone(),
                rate: *r,
            })
            .collect();
        let plan = crate::fleet::place(fleet, &estimated);
        self.last_placement_rates = rates;
        self.placement_dirty = false;
        let mut target = plan.assignment;
        // On a homogeneous fleet device labels are interchangeable:
        // relabel the plan's groups onto the current devices to minimize
        // migrations (a pure permutation of the current layout relabels
        // to the identity and proposes nothing). Heterogeneous fleets
        // keep the planner's labels — they carry real meaning there.
        if fleet.is_homogeneous() {
            relabel_to_minimize_moves(&mut target, current, fleet.len());
        }
        if target != current {
            Some(target)
        } else {
            None
        }
    }
}

/// Greedily map the target's device groups onto current device labels by
/// descending member overlap — valid only when devices are identical
/// (relabeling is cost-free), which `Fleet::uniform` guarantees.
///
/// `current` labels at or beyond `devices` (stale assignments surviving a
/// fleet shrink, e.g. after a crashed device was dropped from the
/// registry) contribute no overlap — those tenants migrate wherever the
/// planner put them instead of indexing out of bounds.
fn relabel_to_minimize_moves(target: &mut [usize], current: &[usize], devices: usize) {
    let mut overlap = vec![vec![0usize; devices]; devices];
    for (i, &pd) in target.iter().enumerate() {
        if current[i] < devices {
            overlap[pd][current[i]] += 1;
        }
    }
    let mut used = vec![false; devices];
    let mut map = vec![usize::MAX; devices];
    loop {
        let mut best: Option<(usize, usize, usize)> = None;
        for (pd, row) in overlap.iter().enumerate() {
            if map[pd] != usize::MAX {
                continue;
            }
            for (cd, &o) in row.iter().enumerate() {
                if used[cd] {
                    continue;
                }
                let better = match best {
                    None => true,
                    Some((bo, _, _)) => o > bo,
                };
                if better {
                    best = Some((o, pd, cd));
                }
            }
        }
        match best {
            Some((_, pd, cd)) => {
                map[pd] = cd;
                used[cd] = true;
            }
            None => break,
        }
    }
    for t in target.iter_mut() {
        *t = map[*t];
    }
}

/// A policy that never reconfigures (static baselines in Fig. 8). Its
/// period is honestly `None` — no periodic decision events are scheduled
/// at all, instead of the old `f64::MAX / 4.0` sentinel timestamp.
pub struct StaticPolicy;

impl ReconfigPolicy for StaticPolicy {
    fn period(&self) -> Option<f64> {
        None
    }

    fn observe_arrival(&mut self, _t: f64, _model: usize) {}

    fn decide(&mut self, _t: f64, _tenants: &[Tenant], _c: &Config) -> Option<Config> {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareSpec;
    use crate::model::synthetic_model;
    use crate::tpu::CostModel;

    #[test]
    fn rate_monitor_estimates_rate() {
        let mut m = RateMonitor::new(10.0, 2);
        // model 0 at 5 rps, model 1 at 1 rps for 20 seconds, observed in
        // chronological order (the monitor assumes a monotone clock).
        let mut t = 0.0f64;
        while t < 20.0 {
            m.observe(t, 0);
            if (t / 0.2).round() as u64 % 5 == 0 {
                m.observe(t, 1);
            }
            t += 0.2;
        }
        let rates = m.rates(20.0);
        assert!((rates[0] - 5.0).abs() < 0.5, "r0={}", rates[0]);
        assert!((rates[1] - 1.0).abs() < 0.3, "r1={}", rates[1]);
    }

    #[test]
    fn rate_monitor_forgets_old_events() {
        let mut m = RateMonitor::new(5.0, 1);
        for i in 0..50 {
            m.observe(i as f64 * 0.1, 0); // 10 rps for 5s
        }
        // silence until t=100
        let rates = m.rates(100.0);
        assert_eq!(rates[0], 0.0);
    }

    #[test]
    fn rate_monitor_incremental_counts_match_recount() {
        // The O(n_models) incremental counts must equal a full recount of
        // the live window at every step.
        let mut m = RateMonitor::new(7.0, 3);
        let mut rng = crate::util::rng::Rng::new(99);
        let mut t = 0.0;
        for _ in 0..500 {
            t += rng.range_f64(0.0, 0.3);
            let model = rng.below(3);
            m.observe(t, model);
            let rates = m.rates(t);
            let mut recount = vec![0u64; 3];
            for (et, em) in &m.events {
                assert!(t - et <= m.window + 1e-12);
                recount[*em] += 1;
            }
            let effective = m.window.min(t.max(1e-9));
            for i in 0..3 {
                assert!((rates[i] - recount[i] as f64 / effective).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn rate_monitor_churn_preserves_peer_counts() {
        let mut m = RateMonitor::new(100.0, 3);
        for i in 0..30 {
            m.observe(i as f64 * 0.1, i % 3);
        }
        m.remove_model(1); // old model 2 becomes index 1
        let rates = m.rates(3.0);
        assert_eq!(rates.len(), 2);
        assert!((rates[0] - 10.0 / 3.0).abs() < 1e-9, "r0={}", rates[0]);
        assert!((rates[1] - 10.0 / 3.0).abs() < 1e-9, "r1={}", rates[1]);
        m.insert_model();
        let rates = m.rates(3.0);
        assert_eq!(rates.len(), 3);
        assert_eq!(rates[2], 0.0);
        // Out-of-range observe is ignored, not a panic.
        m.observe(3.0, 9);
        assert_eq!(m.rates(3.0).len(), 3);
    }

    #[test]
    fn swapless_policy_reconfigures_on_rate_change() {
        let cost = CostModel::new(HardwareSpec::default());
        let am = AnalyticModel::new(cost);
        let tenants = vec![
            Tenant {
                model: synthetic_model("a", 6, 2_000_000, 800_000_000),
                rate: 0.0,
            },
            Tenant {
                model: synthetic_model("b", 6, 2_000_000, 800_000_000),
                rate: 0.0,
            },
        ];
        let mut pol = SwapLessPolicy::new(am, 4, 2, 10.0, 5.0, 0.05);
        assert_eq!(pol.period(), Some(5.0));
        // feed 3 rps of model a only
        let mut t = 0.0;
        while t < 10.0 {
            pol.observe_arrival(t, 0);
            t += 1.0 / 3.0;
        }
        let current = Config::all_cpu(2);
        let decision = pol.decide(10.0, &tenants, &current);
        assert!(decision.is_some(), "should reconfigure from cold state");
        assert!(!pol.decision_micros.is_empty());
        // Second decide with unchanged rates: no re-plan.
        let cfg = decision.unwrap();
        let again = pol.decide(10.1, &tenants, &cfg);
        assert!(again.is_none());
    }

    #[test]
    fn swapless_policy_replans_on_churn_hooks() {
        let cost = CostModel::new(HardwareSpec::default());
        let am = AnalyticModel::new(cost);
        let mut tenants = vec![Tenant {
            model: synthetic_model("a", 6, 2_000_000, 800_000_000),
            rate: 0.0,
        }];
        let mut pol = SwapLessPolicy::new(am, 4, 1, 10.0, 5.0, 0.05);
        for i in 0..30 {
            pol.observe_arrival(i as f64 / 3.0, 0);
        }
        let current = Config::all_cpu(1);
        let first = pol.decide(10.0, &tenants, &current).expect("cold replan");
        // Steady state: no decision.
        assert!(pol.decide(10.1, &tenants, &first).is_none());
        // Attach hook forces a re-plan sized for the new mix.
        tenants.push(Tenant {
            model: synthetic_model("b", 6, 2_000_000, 800_000_000),
            rate: 0.0,
        });
        pol.on_attach(10.2, 1);
        let grown = pol
            .decide(10.2, &tenants, &Config::all_cpu(2))
            .expect("attach forces re-plan");
        assert_eq!(grown.partitions.len(), 2);
        // Detach hook shrinks and forces another re-plan.
        tenants.remove(0);
        pol.on_detach(10.3, 0);
        let shrunk = pol.decide(10.3, &tenants, &Config::all_cpu(1));
        if let Some(cfg) = &shrunk {
            assert_eq!(cfg.partitions.len(), 1);
        }
        assert_eq!(pol.monitor.n_models(), 1);
    }

    #[test]
    fn swapless_policy_places_conflicting_tenants_apart() {
        // Two big-prefix tenants that cannot share one SRAM: once the
        // monitor has seen traffic for both, decide_placement on a
        // 2-device fleet must split them; with no traffic it must not
        // propose anything.
        let cost = CostModel::new(HardwareSpec::default());
        let am = AnalyticModel::new(cost);
        let tenants = vec![
            Tenant {
                model: synthetic_model("a", 6, 2_000_000, 800_000_000),
                rate: 0.0,
            },
            Tenant {
                model: synthetic_model("b", 6, 2_000_000, 800_000_000),
                rate: 0.0,
            },
        ];
        let mut pol = SwapLessPolicy::new(am, 4, 2, 10.0, 5.0, 0.05);
        let fleet = crate::fleet::Fleet::uniform(2, &HardwareSpec::default());
        assert_eq!(pol.decide_placement(0.0, &tenants, &fleet, &[0, 0]), None);
        let mut t = 0.0;
        while t < 10.0 {
            pol.observe_arrival(t, 0);
            pol.observe_arrival(t + 0.1, 1);
            t += 0.5;
        }
        let target = pol
            .decide_placement(10.0, &tenants, &fleet, &[0, 0])
            .expect("conflicting colocation should trigger a move");
        assert_ne!(target[0], target[1], "tenants not split: {target:?}");
        // Already balanced ⇒ no proposal.
        assert_eq!(pol.decide_placement(10.1, &tenants, &fleet, &target), None);
        // Default trait hook (StaticPolicy) never migrates.
        let mut stat = StaticPolicy;
        let four = crate::fleet::Fleet::uniform(4, &HardwareSpec::default());
        assert_eq!(stat.decide_placement(1.0, &tenants, &four, &[0, 0]), None);
    }

    #[test]
    fn relabel_ignores_stale_out_of_range_labels() {
        // Labels from a 4-device fleet, plan computed on 2 survivors:
        // out-of-range current labels contribute no overlap (no OOB
        // panic), and in-range overlap still anchors its group.
        let mut target = vec![0, 0, 1, 1];
        let current = vec![3, 2, 0, 0];
        relabel_to_minimize_moves(&mut target, &current, 2);
        assert!(target.iter().all(|&d| d < 2), "{target:?}");
        // The {2,3} group sits on current device 0 — it keeps label 0,
        // so those two tenants do not move.
        assert_eq!(&target[2..], &[0, 0]);
        assert_eq!(&target[..2], &[1, 1]);
    }

    #[test]
    fn decide_placement_survives_a_shrunken_fleet() {
        // A crash dropped the registry from 4 devices to 2 while the
        // tenants still carry their old device labels: decide_placement
        // must re-place them onto the survivors, not panic.
        let cost = CostModel::new(HardwareSpec::default());
        let am = AnalyticModel::new(cost);
        let tenants = vec![
            Tenant {
                model: synthetic_model("a", 6, 2_000_000, 800_000_000),
                rate: 0.0,
            },
            Tenant {
                model: synthetic_model("b", 6, 2_000_000, 800_000_000),
                rate: 0.0,
            },
        ];
        let mut pol = SwapLessPolicy::new(am, 4, 2, 10.0, 5.0, 0.05);
        let mut t = 0.0;
        while t < 10.0 {
            pol.observe_arrival(t, 0);
            pol.observe_arrival(t + 0.1, 1);
            t += 0.5;
        }
        let fleet = crate::fleet::Fleet::uniform(2, &HardwareSpec::default());
        let target = pol
            .decide_placement(10.0, &tenants, &fleet, &[2, 3])
            .expect("stale labels always differ from any in-range plan");
        assert!(target.iter().all(|&d| d < 2), "{target:?}");
        assert_ne!(target[0], target[1], "conflicting tenants not split");
    }

    #[test]
    fn static_policy_never_changes() {
        let mut p = StaticPolicy;
        let tenants: Vec<Tenant> = vec![];
        assert_eq!(p.period(), None);
        assert!(p.decide(1.0, &tenants, &Config::all_cpu(0)).is_none());
    }
}
