//! Online reconfiguration policies for dynamic workloads (Section IV +
//! Fig. 8): a sliding-window rate monitor feeding the resource allocator.

use std::collections::VecDeque;

use crate::alloc;
use crate::analytic::{AnalyticModel, Config, Tenant};
use crate::tpu::PrefixTables;

/// Periodic decision hook the DES (and the live coordinator) invokes.
pub trait ReconfigPolicy {
    /// Seconds between `decide` invocations.
    fn period(&self) -> f64;
    /// Called on every arrival (the rate-monitor feed).
    fn observe_arrival(&mut self, t: f64, model: usize);
    /// Return `Some(new_config)` to reconfigure, `None` to keep current.
    fn decide(&mut self, t: f64, tenants: &[Tenant], current: &Config) -> Option<Config>;
}

/// Sliding-window per-model arrival-rate estimator.
#[derive(Debug, Clone)]
pub struct RateMonitor {
    window: f64,
    events: VecDeque<(f64, usize)>,
    n_models: usize,
}

impl RateMonitor {
    pub fn new(window: f64, n_models: usize) -> RateMonitor {
        assert!(window > 0.0);
        RateMonitor {
            window,
            events: VecDeque::new(),
            n_models,
        }
    }

    pub fn observe(&mut self, t: f64, model: usize) {
        self.events.push_back((t, model));
        self.evict(t);
    }

    fn evict(&mut self, now: f64) {
        while let Some((t, _)) = self.events.front() {
            if now - t > self.window {
                self.events.pop_front();
            } else {
                break;
            }
        }
    }

    /// Estimated per-model rates at time `now` (events / effective window).
    pub fn rates(&mut self, now: f64) -> Vec<f64> {
        self.evict(now);
        let mut counts = vec![0usize; self.n_models];
        for (_, m) in &self.events {
            counts[*m] += 1;
        }
        // Early in the run the window isn't full yet.
        let effective = self.window.min(now.max(1e-9));
        counts
            .iter()
            .map(|c| *c as f64 / effective)
            .collect()
    }
}

/// The SwapLess online policy: estimate rates over a sliding window, run
/// the hill-climbing allocator, and reconfigure when the predicted config
/// changes. Decision wall-clock times are recorded (the paper reports
/// < 2 ms per invocation).
pub struct SwapLessPolicy {
    pub am: AnalyticModel,
    pub k_max: usize,
    pub monitor: RateMonitor,
    period: f64,
    /// Relative rate change below which we skip re-planning.
    threshold: f64,
    last_rates: Vec<f64>,
    pub decision_micros: Vec<f64>,
    /// Per-model prefix tables, built on the first decision and reused by
    /// every re-plan (rates change between decisions; the tables are
    /// rate-independent). Keyed by (model name, partition count) — names
    /// uniquely identify models under the manifest contract, and the
    /// partition count guards against a same-named model that was
    /// re-segmented — so a policy handed a different mix rebuilds instead
    /// of planning with stale tables.
    tables: Vec<PrefixTables>,
    table_models: Vec<(String, usize)>,
}

impl SwapLessPolicy {
    pub fn new(
        am: AnalyticModel,
        k_max: usize,
        n_models: usize,
        window: f64,
        period: f64,
        threshold: f64,
    ) -> SwapLessPolicy {
        SwapLessPolicy {
            am,
            k_max,
            monitor: RateMonitor::new(window, n_models),
            period,
            threshold,
            last_rates: vec![0.0; n_models],
            decision_micros: Vec::new(),
            tables: Vec::new(),
            table_models: Vec::new(),
        }
    }

    fn rates_changed(&self, rates: &[f64]) -> bool {
        for (new, old) in rates.iter().zip(&self.last_rates) {
            let base = old.abs().max(0.1);
            if (new - old).abs() / base > self.threshold {
                return true;
            }
        }
        false
    }
}

impl ReconfigPolicy for SwapLessPolicy {
    fn period(&self) -> f64 {
        self.period
    }

    fn observe_arrival(&mut self, t: f64, model: usize) {
        self.monitor.observe(t, model);
    }

    fn decide(&mut self, t: f64, tenants: &[Tenant], current: &Config) -> Option<Config> {
        let rates = self.monitor.rates(t);
        if !self.rates_changed(&rates) {
            return None;
        }
        let stale = self.table_models.len() != tenants.len()
            || self.table_models.iter().zip(tenants).any(|((name, pp), t)| {
                *name != t.model.name || *pp != t.model.partition_points
            });
        if stale {
            self.tables = PrefixTables::for_tenants(&self.am.cost, tenants);
            self.table_models = tenants
                .iter()
                .map(|t| (t.model.name.clone(), t.model.partition_points))
                .collect();
        }
        let t0 = std::time::Instant::now();
        let estimated: Vec<Tenant> = tenants
            .iter()
            .zip(&rates)
            .map(|(tn, r)| Tenant {
                model: tn.model.clone(),
                rate: *r,
            })
            .collect();
        let alloc = alloc::hill_climb_with_tables(&self.am, &estimated, &self.tables, self.k_max);
        self.decision_micros
            .push(t0.elapsed().as_secs_f64() * 1e6);
        self.last_rates = rates;
        if &alloc.config != current {
            Some(alloc.config)
        } else {
            None
        }
    }
}

/// A policy that never reconfigures (static baselines in Fig. 8).
pub struct StaticPolicy;

impl ReconfigPolicy for StaticPolicy {
    fn period(&self) -> f64 {
        f64::MAX / 4.0
    }

    fn observe_arrival(&mut self, _t: f64, _model: usize) {}

    fn decide(&mut self, _t: f64, _tenants: &[Tenant], _c: &Config) -> Option<Config> {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareSpec;
    use crate::model::synthetic_model;
    use crate::tpu::CostModel;

    #[test]
    fn rate_monitor_estimates_rate() {
        let mut m = RateMonitor::new(10.0, 2);
        // model 0 at 5 rps, model 1 at 1 rps for 20 seconds, observed in
        // chronological order (the monitor assumes a monotone clock).
        let mut t = 0.0f64;
        while t < 20.0 {
            m.observe(t, 0);
            if (t / 0.2).round() as u64 % 5 == 0 {
                m.observe(t, 1);
            }
            t += 0.2;
        }
        let rates = m.rates(20.0);
        assert!((rates[0] - 5.0).abs() < 0.5, "r0={}", rates[0]);
        assert!((rates[1] - 1.0).abs() < 0.3, "r1={}", rates[1]);
    }

    #[test]
    fn rate_monitor_forgets_old_events() {
        let mut m = RateMonitor::new(5.0, 1);
        for i in 0..50 {
            m.observe(i as f64 * 0.1, 0); // 10 rps for 5s
        }
        // silence until t=100
        let rates = m.rates(100.0);
        assert_eq!(rates[0], 0.0);
    }

    #[test]
    fn swapless_policy_reconfigures_on_rate_change() {
        let cost = CostModel::new(HardwareSpec::default());
        let am = AnalyticModel::new(cost);
        let tenants = vec![
            Tenant {
                model: synthetic_model("a", 6, 2_000_000, 800_000_000),
                rate: 0.0,
            },
            Tenant {
                model: synthetic_model("b", 6, 2_000_000, 800_000_000),
                rate: 0.0,
            },
        ];
        let mut pol = SwapLessPolicy::new(am, 4, 2, 10.0, 5.0, 0.05);
        // feed 3 rps of model a only
        let mut t = 0.0;
        while t < 10.0 {
            pol.observe_arrival(t, 0);
            t += 1.0 / 3.0;
        }
        let current = Config::all_cpu(2);
        let decision = pol.decide(10.0, &tenants, &current);
        assert!(decision.is_some(), "should reconfigure from cold state");
        assert!(!pol.decision_micros.is_empty());
        // Second decide with unchanged rates: no re-plan.
        let cfg = decision.unwrap();
        let again = pol.decide(10.1, &tenants, &cfg);
        assert!(again.is_none());
    }

    #[test]
    fn static_policy_never_changes() {
        let mut p = StaticPolicy;
        let tenants: Vec<Tenant> = vec![];
        assert!(p.decide(1.0, &tenants, &Config::all_cpu(0)).is_none());
    }
}
