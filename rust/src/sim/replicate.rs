//! Parallel independent replications of the DES.
//!
//! Experiments that want confidence intervals used to hand-roll seed
//! loops; [`simulate_replicated`] owns that pattern: it fans `n_reps`
//! seeds out across OS threads (`std::thread::scope` — replications
//! share nothing, so this is embarrassingly parallel), merges the
//! per-replication [`SimResult`]s through the exact parallel-merge
//! operators the metrics layer already provides
//! ([`crate::metrics::LatencyHistogram::merge`],
//! [`crate::metrics::Welford::merge`], counter addition), and reports
//! the across-replication mean latency with a 95% confidence interval.
//!
//! Replication `i` runs at seed [`replication_seed`]`(opts.seed, i)`;
//! replication 0 is *exactly* `opts.seed`, so a single-replication call
//! reproduces a plain [`simulate`] run bit-for-bit (pinned by
//! `tests/queue_parity.rs`).

use std::thread;

use crate::analytic::{Config, Tenant};
use crate::metrics::PerClassLatency;
use crate::tpu::CostModel;

use super::{simulate, ModelStats, SimOptions, SimResult};

/// Seed for replication `rep` of a run based at `base`: a golden-ratio
/// stride keeps the seeds well separated for the SplitMix64-seeded
/// generator, and `rep = 0` is the base seed itself.
pub fn replication_seed(base: u64, rep: usize) -> u64 {
    base.wrapping_add((rep as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

/// Merged statistics over `n` independent replications.
#[derive(Debug)]
pub struct ReplicatedResult {
    /// Per-replication results, in replication order (rep 0 first).
    pub reps: Vec<SimResult>,
    /// Per-tenant stats pooled across replications (counters summed,
    /// histograms merged).
    pub per_model: Vec<ModelStats>,
    /// Per-class latency + lifecycle counters pooled across replications.
    pub per_class: PerClassLatency,
    /// Mean of the per-replication request-weighted mean latencies.
    pub mean_latency: f64,
    /// 95% confidence half-width on `mean_latency` (Student-t over the
    /// replication means; 0 when `n < 2`).
    pub ci95: f64,
    /// Per-replication mean latencies (the CI's sample).
    pub rep_means: Vec<f64>,
    /// Mean TPU utilization across replications.
    pub tpu_utilization: f64,
    pub completed: u64,
    pub dropped: u64,
    pub attempted: u64,
    pub retried: u64,
    pub failed: u64,
}

/// Two-sided 95% Student-t critical value for `df` degrees of freedom.
fn t95(df: usize) -> f64 {
    const TABLE: [f64; 30] = [
        12.706, 4.303, 3.182, 2.776, 2.571, 2.447, 2.365, 2.306, 2.262, 2.228, 2.201, 2.179,
        2.160, 2.145, 2.131, 2.120, 2.110, 2.101, 2.093, 2.086, 2.080, 2.074, 2.069, 2.064,
        2.060, 2.056, 2.052, 2.048, 2.045, 2.042,
    ];
    if df == 0 {
        0.0
    } else if df <= TABLE.len() {
        TABLE[df - 1]
    } else {
        1.96
    }
}

/// Merge `b`'s per-tenant stats into `a` (positional — replications of
/// the same static run always agree on the tenant set).
fn merge_models(a: &mut [ModelStats], b: &[ModelStats]) {
    assert_eq!(a.len(), b.len(), "replications disagree on tenant count");
    for (x, y) in a.iter_mut().zip(b) {
        x.completed += y.completed;
        x.accepted += y.accepted;
        x.rejected += y.rejected;
        x.shed += y.shed;
        x.expired += y.expired;
        x.latency.merge(&y.latency);
        x.tpu_share.merge(&y.tpu_share);
    }
}

/// Pool replication results into a [`ReplicatedResult`]. Exposed so the
/// parity suite can compare a sequential loop against the threaded path.
pub fn merge_replications(results: Vec<SimResult>) -> ReplicatedResult {
    assert!(!results.is_empty(), "need at least one replication");
    let mut per_model: Vec<ModelStats> = results[0].per_model.clone();
    let mut per_class = results[0].per_class.clone();
    for r in &results[1..] {
        merge_models(&mut per_model, &r.per_model);
        per_class.merge(&r.per_class);
    }
    let rep_means: Vec<f64> = results.iter().map(|r| r.mean_latency).collect();
    let n = rep_means.len() as f64;
    let mean = rep_means.iter().sum::<f64>() / n;
    let ci95 = if rep_means.len() >= 2 {
        let var = rep_means.iter().map(|m| (m - mean).powi(2)).sum::<f64>() / (n - 1.0);
        t95(rep_means.len() - 1) * (var / n).sqrt()
    } else {
        0.0
    };
    ReplicatedResult {
        per_class,
        mean_latency: mean,
        ci95,
        tpu_utilization: results.iter().map(|r| r.tpu_utilization).sum::<f64>() / n,
        completed: per_model.iter().map(|m| m.completed).sum(),
        dropped: results.iter().map(|r| r.dropped).sum(),
        attempted: results.iter().map(|r| r.attempted).sum(),
        retried: results.iter().map(|r| r.retried).sum(),
        failed: results.iter().map(|r| r.failed).sum(),
        per_model,
        rep_means,
        reps: results,
    }
}

/// Run `n_reps` independent replications of the static-configuration DES
/// in parallel and pool the results. `opts.seed` seeds replication 0;
/// see [`replication_seed`] for the rest. The event log (if any) is
/// dropped per replication — replications must not interleave into one
/// trace.
pub fn simulate_replicated(
    cost: &CostModel,
    tenants: &[Tenant],
    cfg: &Config,
    opts: &SimOptions,
    n_reps: usize,
) -> ReplicatedResult {
    assert!(n_reps >= 1, "need at least one replication");
    let workers = thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(n_reps);
    let mut slots: Vec<Option<SimResult>> = (0..n_reps).map(|_| None).collect();
    thread::scope(|s| {
        let handles: Vec<_> = (0..workers)
            .map(|w| {
                s.spawn(move || {
                    let mut out: Vec<(usize, SimResult)> = Vec::new();
                    let mut rep = w;
                    while rep < n_reps {
                        let rep_opts = SimOptions {
                            seed: replication_seed(opts.seed, rep),
                            log: None,
                            timeline_window: None,
                            ..opts.clone()
                        };
                        out.push((rep, simulate(cost, tenants, cfg, rep_opts)));
                        rep += workers;
                    }
                    out
                })
            })
            .collect();
        for h in handles {
            for (rep, result) in h.join().expect("replication thread panicked") {
                slots[rep] = Some(result);
            }
        }
    });
    let results: Vec<SimResult> = slots
        .into_iter()
        .map(|s| s.expect("replication missing"))
        .collect();
    merge_replications(results)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytic::Config;
    use crate::config::HardwareSpec;
    use crate::model::synthetic_model;
    use crate::tpu::CostModel;

    fn setup() -> (CostModel, Vec<Tenant>, Config) {
        let cost = CostModel::new(HardwareSpec::default());
        let tenants = vec![
            Tenant {
                model: synthetic_model("a", 6, 1_000_000, 500_000_000),
                rate: 20.0,
            },
            Tenant {
                model: synthetic_model("b", 6, 1_000_000, 500_000_000),
                rate: 15.0,
            },
        ];
        let cfg = Config::all_tpu(&tenants);
        (cost, tenants, cfg)
    }

    fn opts() -> SimOptions {
        SimOptions {
            horizon: 60.0,
            warmup: 3.0,
            seed: 7,
            ..SimOptions::default()
        }
    }

    #[test]
    fn rep_zero_is_base_seed() {
        assert_eq!(replication_seed(42, 0), 42);
        assert_ne!(replication_seed(42, 1), replication_seed(42, 2));
    }

    #[test]
    fn replicated_is_deterministic() {
        let (cost, tenants, cfg) = setup();
        let a = simulate_replicated(&cost, &tenants, &cfg, &opts(), 4);
        let b = simulate_replicated(&cost, &tenants, &cfg, &opts(), 4);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.mean_latency.to_bits(), b.mean_latency.to_bits());
        assert_eq!(a.ci95.to_bits(), b.ci95.to_bits());
    }

    #[test]
    fn merged_counters_are_sums() {
        let (cost, tenants, cfg) = setup();
        let r = simulate_replicated(&cost, &tenants, &cfg, &opts(), 3);
        assert_eq!(r.reps.len(), 3);
        let total: u64 = r.reps.iter().flat_map(|rep| &rep.per_model).map(|m| m.completed).sum();
        assert_eq!(r.completed, total);
        assert!(r.completed > 0);
        assert!(r.ci95 >= 0.0);
        // Replications differ (different seeds) but not wildly.
        assert!(r.rep_means.iter().all(|m| *m > 0.0));
    }

    #[test]
    fn single_replication_matches_simulate() {
        let (cost, tenants, cfg) = setup();
        let r = simulate_replicated(&cost, &tenants, &cfg, &opts(), 1);
        let plain = simulate(&cost, &tenants, &cfg, opts());
        assert_eq!(r.completed, plain.per_model.iter().map(|m| m.completed).sum::<u64>());
        assert_eq!(r.mean_latency.to_bits(), plain.mean_latency.to_bits());
        assert_eq!(r.ci95, 0.0);
    }
}
