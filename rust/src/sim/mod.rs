//! Discrete-event simulator of the Edge-TPU serving testbed.
//!
//! This is the "observed" side of every validation figure: Poisson
//! arrivals flow through the TPU queue (with the SRAM cache deciding
//! inter-model reloads) and the per-model M/D/k CPU stations, under a
//! possibly time-varying configuration. The DES shares the `CostModel`
//! with the analytic side, so discrepancies between predicted and observed
//! latency are purely *queueing/caching dynamics* — exactly what the
//! paper's model-validation experiments measure against their testbed.
//!
//! Queueing order is delegated to the shared [`crate::sched`] core: the
//! TPU station and every CPU station run a [`SchedQueue`] built from
//! [`SimOptions::discipline`] — the *same* trait objects the live
//! `coordinator` server schedules with — so a discipline validated here
//! deploys unchanged (and vice versa; `tests/sched_parity.rs` pins the
//! FIFO equivalence). Requests carry an [`SloClass`], and completions are
//! accounted per class in [`SimResult::per_class`].
//!
//! The tenant set itself is dynamic: a [`ChurnEvent`] schedule replays
//! tenant arrivals and departures mid-run, driven through the same
//! [`ReconfigPolicy`] hooks (`on_attach`/`on_detach`) as the live
//! coordinator — Fig-8-style experiments can therefore include churn.
//! Requests are keyed by stable [`TenantHandle`]s, so statistics stay
//! attributed to the right tenant after a detach renumbers positions.
//!
//! Virtual-clock simulation: a 900 s Fig.-8 timeline runs in milliseconds.

use std::sync::Arc;

use crate::analytic::{Config, Tenant, TenantHandle};
use crate::eventlog::{Event as LogEvent, EventKind as LogKind, EventLog};
use crate::fault::{FaultPlan, RETRY_BACKOFF_S, RETRY_BUDGET};
use crate::metrics::{LatencyHistogram, PerClassLatency, TimeSeries, Welford};
use crate::sched::{
    DisciplineKind, JobMeta, Offer, OverloadPolicy, RejectReason, SchedQueue, SloClass,
    StationLoad,
};
use crate::telemetry::{emit_burst, SpanSampler, SpanTrace, DEFAULT_SPAN_SAMPLE};
use crate::tpu::{CostModel, PrefixTables, SramCache};
use crate::util::rng::Rng;
use crate::workload::{generate_arrivals, Arrival, RateSchedule};

mod events;
pub mod queue;
pub mod reconfig;
pub mod replicate;

pub use events::{Event, EventKind};
pub use queue::{CalendarQueue, EventQueue, HeapQueue, QueueKind};
pub use reconfig::ReconfigPolicy;
pub use replicate::{
    merge_replications, replication_seed, simulate_replicated, ReplicatedResult,
};

#[derive(Debug, Clone)]
pub struct SimOptions {
    pub horizon: f64,
    /// Discard samples completing before this time (cold-start transient).
    pub warmup: f64,
    pub seed: u64,
    /// Track a latency timeline with this window (None = off). Fig. 8.
    pub timeline_window: Option<f64>,
    /// Queueing discipline for the TPU station and every CPU station —
    /// built through the same `sched` factory the live server uses.
    pub discipline: DisciplineKind,
    /// Bound on each station's occupancy (queued + in-service) — the
    /// same admission layer the live server runs. `None` = unbounded.
    pub capacity: Option<usize>,
    /// What a full station does with new work (see
    /// [`OverloadPolicy`]); `Block` reproduces the legacy unbounded
    /// behavior exactly.
    pub overload: OverloadPolicy,
    /// Device index this simulator instance models (0 on a single-device
    /// run). The multi-device DES ([`crate::fleet::simulate_fleet`]) runs
    /// one station set per device and tags every queued job's
    /// [`JobMeta::device`] with it.
    pub device: usize,
    /// Injected fault schedule for this device (`None` = fault-free).
    /// Crash windows pause the TPU station (queued work stays queued),
    /// transient windows replay the live worker's bounded retry loop in
    /// virtual time, and slowdown windows stretch TPU service.
    pub faults: Option<FaultPlan>,
    /// Append-only event log (`None` = off). The DES emits the same
    /// binary records as the live server, timestamped in *virtual* time
    /// (entry records carry the request's arrival instant, so a logged
    /// run doubles as a replayable trace). The multi-device DES shares
    /// one log across its per-device simulators via `..opts.clone()`.
    pub log: Option<EventLog>,
    /// Pending-event structure for the DES hot loop. The calendar queue
    /// is the fast default; the heap is the reference implementation.
    /// Results are bit-exact across kinds (`tests/queue_parity.rs`).
    pub queue: QueueKind,
    /// Span sampling cadence: every N-th offered request carries a stage
    /// timeline, flushed at completion as the same `Span*` record burst
    /// the live server emits — timestamped in *virtual* time, so
    /// sim-vs-live stage-timing parity is directly testable. `0`
    /// disables. Spans are only sampled when a `log` is attached (they
    /// have nowhere to go otherwise), so the default-path hot loop is
    /// untouched.
    pub span_sample: usize,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions {
            horizon: 600.0,
            warmup: 30.0,
            seed: 1,
            timeline_window: None,
            discipline: DisciplineKind::Fifo,
            capacity: None,
            overload: OverloadPolicy::Block,
            device: 0,
            faults: None,
            log: None,
            queue: QueueKind::Calendar,
            span_sample: DEFAULT_SPAN_SAMPLE,
        }
    }
}

/// Per-tenant DES statistics. The lifecycle counters follow the shared
/// semantics documented on [`PerClassLatency`]: `accepted`/`rejected` at
/// the entry station, `shed`/`expired` post-acceptance drops.
#[derive(Debug, Clone)]
pub struct ModelStats {
    pub handle: TenantHandle,
    pub name: String,
    pub completed: u64,
    pub accepted: u64,
    pub rejected: u64,
    pub shed: u64,
    pub expired: u64,
    pub latency: LatencyHistogram,
    pub tpu_share: Welford,
}

impl ModelStats {
    fn new(handle: TenantHandle, name: String) -> ModelStats {
        ModelStats {
            handle,
            name,
            completed: 0,
            accepted: 0,
            rejected: 0,
            shed: 0,
            expired: 0,
            latency: LatencyHistogram::default(),
            tpu_share: Welford::new(),
        }
    }

    /// Requests dropped by the overload layer after or at admission.
    pub fn dropped(&self) -> u64 {
        self.rejected + self.shed + self.expired
    }
}

/// One tenant-lifecycle transition to replay mid-run.
#[derive(Debug, Clone)]
pub struct ChurnEvent {
    pub time: f64,
    pub kind: ChurnKind,
}

#[derive(Debug, Clone)]
pub enum ChurnKind {
    /// A tenant arrives: it joins the mix at `time` with partition 0 /
    /// zero cores (the policy re-plans immediately via its `on_attach`
    /// hook) and submits requests per `schedule` (time-shifted so step 0
    /// is the attach instant; the stream ends at the tenant's own
    /// scheduled detach, if one follows).
    Attach { tenant: Tenant, schedule: RateSchedule },
    /// The named tenant departs: queued work it owns is dropped (counted
    /// in [`SimResult::dropped`]), its stats move to
    /// [`SimResult::retired`], and the policy's `on_detach` hook fires.
    Detach { name: String },
}

#[derive(Debug)]
pub struct SimResult {
    /// Stats of the tenants still attached at the end of the run.
    pub per_model: Vec<ModelStats>,
    /// Stats of tenants detached mid-run (churn schedules).
    pub retired: Vec<ModelStats>,
    /// Requests abandoned because their tenant detached while they were
    /// queued or in flight.
    pub dropped: u64,
    /// Lifecycle transitions applied, as (time, description).
    pub churn_log: Vec<(f64, String)>,
    /// Request-weighted mean latency across models (the Fig. 7 metric).
    pub mean_latency: f64,
    /// Measured TPU busy fraction over the horizon.
    pub tpu_utilization: f64,
    /// SRAM cache hit rate over TPU executions.
    pub cache_hit_rate: f64,
    /// Mean-latency timeline (if requested).
    pub timeline: Option<TimeSeries>,
    /// Reconfiguration decisions taken (time, new config, decision µs).
    pub reconfigs: Vec<(f64, Config, f64)>,
    /// Latency + lifecycle counters per SLO class (live + retired
    /// tenants): accepted/rejected/shed/expired/goodput.
    pub per_class: PerClassLatency,
    /// Peak TPU-station occupancy (queued + in-service) over the run —
    /// bounded by `capacity` under `Reject`, divergent under `Block` at
    /// ρ ≥ 1.
    pub max_tpu_occupancy: usize,
    /// TPU execution attempts (retries included) — mirrors the live
    /// `ServeStats::attempted`.
    pub attempted: u64,
    /// Re-executions after an injected transient fault.
    pub retried: u64,
    /// Requests that exhausted the retry budget (or had their backoff
    /// clipped by the deadline) and failed terminally.
    pub failed: u64,
    /// Total events scheduled over the run (the event-queue traffic —
    /// `bench_des` reports wall-clock events/sec from this).
    pub events: u64,
}

impl SimResult {
    pub fn model_mean(&self, i: usize) -> f64 {
        self.per_model[i].latency.mean()
    }
}

#[derive(Debug, Clone, Copy)]
pub struct Request {
    /// Stable identity of the submitting tenant (NOT a positional index —
    /// positions shift under churn).
    pub tenant: TenantHandle,
    pub arrived: f64,
    /// SLO class the request arrived with (drives priority/WFQ decisions
    /// and the per-class accounting).
    pub class: SloClass,
    /// Absolute completion deadline (sim time). `DeadlineDrop` evicts
    /// requests that can no longer meet it; under every policy a late
    /// completion is excluded from goodput.
    pub deadline: Option<f64>,
    /// Sampled stage timeline (virtual-time spans). `Copy` like the rest
    /// of the request, so it rides through the shared `SchedQueue` and
    /// the event set unchanged; stations fill it exactly where the live
    /// workers do, and `record_completion` flushes the burst.
    pub trace: Option<SpanTrace>,
}

/// Per-model service-time memo for the current configuration — the DES
/// hot loop touches these on every execution, and they are pure functions
/// of (model, p), so they are precomputed here and rebuilt on reconfig.
/// The memo is filled from the per-model [`PrefixTables`] (built once per
/// tenant), so a rebuild is O(n) lookups, not O(n·L) segment sums —
/// this keeps high-frequency reconfiguration cheap (EXPERIMENTS.md §Perf).
#[derive(Debug, Clone, Default)]
struct ServiceMemo {
    resident_bytes: u64,
    tpu_service: f64,
    load_time: f64,
    cpu_service: f64,
    input_transfer: f64,
    output_transfer: f64,
}

/// In-flight simulator state for one run. Positional vectors (`tenants`,
/// `cfg`, `tables`, `memo`, queues, stats) are kept aligned; `handles`
/// maps positions to stable identities for requests already in flight.
pub struct Simulator {
    cost: CostModel,
    tenants: Vec<Tenant>,
    handles: Vec<TenantHandle>,
    /// O(1) handle → position map (indexed by `TenantHandle.0`); rebuilt
    /// on churn only, so the per-event lookup never scans.
    index_by_handle: Vec<Option<usize>>,
    next_handle: u64,
    cfg: Config,
    /// One prefix-sum cost table per tenant (immutable across reconfigs).
    tables: Vec<PrefixTables>,
    memo: Vec<ServiceMemo>,
    cache: SramCache,
    // TPU station (queue order owned by the shared sched core)
    tpu_queue: SchedQueue<Request>,
    tpu_busy: bool,
    tpu_busy_until: f64,
    tpu_busy_time: f64,
    // per-model CPU stations
    cpu_queues: Vec<SchedQueue<Request>>,
    cpu_busy: Vec<usize>,
    /// Station labels for typed rejections (precomputed — the enqueue
    /// hot path never allocates them).
    cpu_stations: Vec<String>,
    events: Box<dyn EventQueue>,
    /// Per-run event sequence counter (tie-break for equal times) —
    /// local to this simulator so runs are deterministic in isolation.
    next_seq: u64,
    /// The fault plan by `Arc` — the hot loop bumps a refcount instead of
    /// deep-cloning the window vectors on every service start.
    faults: Option<Arc<FaultPlan>>,
    /// True while the injected fault plan has this device crashed — the
    /// TPU station stops starting service (queued work stays queued).
    down: bool,
    /// Monotone attempt counter feeding the plan's deterministic
    /// transient sampling (one consumed per execution attempt, exactly
    /// like the live injector's sequence numbers).
    fault_seq: u64,
    attempted: u64,
    retried: u64,
    failed: u64,
    // stats
    stats: Vec<ModelStats>,
    retired: Vec<ModelStats>,
    dropped: u64,
    max_tpu_occupancy: usize,
    weighted_latency: Welford,
    class_latency: PerClassLatency,
    timeline: Option<TimeSeries>,
    /// 1-in-N span sampling — the same decision/allocation logic the live
    /// server runs (single-threaded here, the atomics are uncontended).
    sampler: SpanSampler,
    opts: SimOptions,
}

/// How a request left the system short of completing — mirrors the live
/// server's counting exactly (see [`PerClassLatency`]).
#[derive(Debug, Clone, Copy)]
enum DropKind {
    Rejected,
    Shed,
    Expired,
}

impl Simulator {
    pub fn new(
        cost: &CostModel,
        tenants: &[Tenant],
        cfg: Config,
        opts: SimOptions,
    ) -> Simulator {
        let n = tenants.len();
        let tables = PrefixTables::for_tenants(cost, tenants);
        let memo = build_memo(&tables, &cfg);
        Simulator {
            cost: cost.clone(),
            tenants: tenants.to_vec(),
            handles: (0..n as u64).map(TenantHandle).collect(),
            index_by_handle: (0..n).map(Some).collect(),
            next_handle: n as u64,
            cfg,
            tables,
            memo,
            cache: SramCache::new(cost.hw.sram_bytes),
            tpu_queue: SchedQueue::with_kind(opts.discipline),
            tpu_busy: false,
            tpu_busy_until: 0.0,
            tpu_busy_time: 0.0,
            cpu_queues: (0..n).map(|_| SchedQueue::with_kind(opts.discipline)).collect(),
            cpu_busy: vec![0; n],
            cpu_stations: (0..n)
                .map(|i| format!("cpu {}", TenantHandle(i as u64)))
                .collect(),
            events: opts.queue.build(),
            next_seq: 0,
            faults: opts.faults.clone().map(Arc::new),
            down: false,
            fault_seq: 0,
            attempted: 0,
            retried: 0,
            failed: 0,
            stats: tenants
                .iter()
                .enumerate()
                .map(|(i, t)| {
                    ModelStats::new(TenantHandle(i as u64), t.model.name.clone())
                })
                .collect(),
            retired: Vec::new(),
            dropped: 0,
            max_tpu_occupancy: 0,
            weighted_latency: Welford::new(),
            class_latency: PerClassLatency::new(),
            timeline: opts.timeline_window.map(TimeSeries::new),
            sampler: SpanSampler::new(if opts.log.is_some() {
                opts.span_sample
            } else {
                0
            }),
            opts,
        }
    }

    /// The scheduling discipline driving the TPU and CPU stations.
    pub fn discipline(&self) -> DisciplineKind {
        self.tpu_queue.kind()
    }

    /// Positional index of a handle, `None` if the tenant detached.
    #[inline]
    fn index_of(&self, h: TenantHandle) -> Option<usize> {
        self.index_by_handle.get(h.0 as usize).copied().flatten()
    }

    /// Rebuild the handle → position map after churn shifts positions.
    fn rebuild_handle_index(&mut self) {
        self.index_by_handle.clear();
        self.index_by_handle.resize(self.next_handle as usize, None);
        for (i, h) in self.handles.iter().enumerate() {
            self.index_by_handle[h.0 as usize] = Some(i);
        }
    }

    /// Schedule an event, stamping it with this run's next sequence
    /// number — the single entry point to the pending-event set.
    #[inline]
    fn schedule(&mut self, time: f64, kind: EventKind) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.events.push(Event::new(time, seq, kind));
    }

    /// Swap in a new configuration (online reconfiguration). Queued and
    /// in-flight requests finish under their admission-time partition; the
    /// cache entries of re-partitioned models are invalidated (their
    /// resident sets changed). The configuration must be positionally
    /// aligned with the current tenant set.
    pub fn set_config(&mut self, cfg: Config) {
        assert_eq!(cfg.partitions.len(), self.tenants.len());
        assert_eq!(cfg.cores.len(), self.tenants.len());
        for i in 0..self.tenants.len() {
            if cfg.partitions[i] != self.cfg.partitions[i] {
                self.cache.invalidate(self.handles[i].0 as usize);
            }
        }
        self.memo = build_memo(&self.tables, &cfg);
        self.cfg = cfg;
    }

    pub fn config(&self) -> &Config {
        &self.cfg
    }

    /// Append a tenant mid-run (churn): partition 0, zero cores until the
    /// policy re-plans. Returns the stable handle its requests carry.
    fn apply_attach(&mut self, tenant: Tenant) -> TenantHandle {
        let h = TenantHandle(self.next_handle);
        self.next_handle += 1;
        self.tables.push(PrefixTables::new(&self.cost, &tenant.model));
        self.stats
            .push(ModelStats::new(h, tenant.model.name.clone()));
        self.tenants.push(tenant);
        self.handles.push(h);
        self.cfg.partitions.push(0);
        self.cfg.cores.push(0);
        self.cpu_queues
            .push(SchedQueue::with_kind(self.opts.discipline));
        self.cpu_busy.push(0);
        self.cpu_stations.push(format!("cpu {h}"));
        self.memo = build_memo(&self.tables, &self.cfg);
        self.rebuild_handle_index();
        h
    }

    /// Remove the tenant at position `i` (churn): its queued requests are
    /// dropped, its stats retire, peers above shift down one position.
    fn apply_detach(&mut self, i: usize) -> TenantHandle {
        let h = self.handles.remove(i);
        self.tenants.remove(i);
        self.tables.remove(i);
        self.memo.remove(i);
        self.cfg.partitions.remove(i);
        self.cfg.cores.remove(i);
        self.retired.push(self.stats.remove(i));
        self.dropped += self.cpu_queues.remove(i).len() as u64;
        self.cpu_busy.remove(i);
        self.cpu_stations.remove(i);
        self.dropped += self.tpu_queue.drain_tenant(h).len() as u64;
        self.cache.invalidate(h.0 as usize);
        self.rebuild_handle_index();
        h
    }

    fn record_completion(&mut self, req: &Request, now: f64) {
        let Some(i) = self.index_of(req.tenant) else {
            // Tenant detached while this request was in flight.
            self.dropped += 1;
            return;
        };
        // Warmup is a per-REQUEST filter on the arrival time — the same
        // criterion the accept/drop counters use — so the conservation
        // identity (accepted == completed + shed + expired after drain)
        // holds exactly for any warmup, not just warmup = 0.
        if req.arrived < self.opts.warmup {
            return;
        }
        let latency = now - req.arrived;
        let missed = req.deadline.map(|d| now > d).unwrap_or(false);
        self.stats[i].completed += 1;
        self.stats[i].latency.record(latency);
        self.weighted_latency.add(latency);
        self.class_latency.record(req.class, latency);
        if missed {
            self.class_latency.record_miss(req.class);
        }
        if let Some(log) = &self.opts.log {
            let mut ev = LogEvent::new(
                LogKind::Complete,
                now,
                self.opts.device,
                req.tenant.0,
                req.class,
            );
            ev.value = latency;
            ev.missed = missed;
            log.emit(ev);
        }
        if let Some(tr) = &req.trace {
            // Same burst the live CPU pool / TPU worker flushes, in
            // virtual time. For a CPU-leg completion `now - mark` is the
            // CPU service exactly (mark was set at service start); a
            // full-TPU completion has `trace.p == P`, so `emit_burst`
            // skips the CPU record and the value is moot.
            emit_burst(
                self.opts.log.as_ref(),
                self.opts.device,
                req.tenant.0,
                req.class,
                tr,
                (now - tr.mark).max(0.0),
                now,
                self.tenants[i].model.partition_points,
                None,
            );
        }
        if let Some(ts) = &mut self.timeline {
            ts.record(now, latency);
        }
    }

    /// Count a request the overload layer resolved short of completion —
    /// identical bucket semantics to the live server's `count`. Warmup
    /// arrivals are excluded (same per-request filter as completions).
    /// `entry` marks a refusal at the request's entry station (the
    /// request never entered the system) — entry-marked records are what
    /// trace extraction replays as arrivals, so they are logged at the
    /// arrival instant; post-admission drops are logged at `now`, the
    /// virtual time the drop happens, matching the live server's stamps.
    fn count_drop(&mut self, req: &Request, kind: DropKind, entry: bool, now: f64) {
        if req.arrived < self.opts.warmup {
            return;
        }
        match self.index_of(req.tenant) {
            Some(i) => {
                let log_kind = match kind {
                    DropKind::Rejected => {
                        self.stats[i].rejected += 1;
                        self.class_latency.record_reject(req.class);
                        LogKind::Reject
                    }
                    DropKind::Shed => {
                        self.stats[i].shed += 1;
                        self.class_latency.record_shed(req.class);
                        LogKind::Shed
                    }
                    DropKind::Expired => {
                        self.stats[i].expired += 1;
                        self.class_latency.record_expired(req.class);
                        LogKind::Expire
                    }
                };
                if let Some(log) = &self.opts.log {
                    let mut ev = LogEvent::new(
                        log_kind,
                        if entry { req.arrived } else { now },
                        self.opts.device,
                        req.tenant.0,
                        req.class,
                    );
                    ev.entry = entry;
                    if let Some(d) = req.deadline {
                        ev.value = d;
                    }
                    log.emit(ev);
                }
            }
            // Detached while queued: the churn counter owns it.
            None => self.dropped += 1,
        }
    }

    fn count_accept(&mut self, i: usize, req: &Request) {
        if req.arrived < self.opts.warmup {
            return;
        }
        self.stats[i].accepted += 1;
        self.class_latency.record_accept(req.class);
        if let Some(log) = &self.opts.log {
            // Timestamped at the ARRIVAL instant: replaying the log's
            // entry records reconstructs this run's arrival process
            // exactly (trace format v4).
            let mut ev = LogEvent::new(
                LogKind::Admit,
                req.arrived,
                self.opts.device,
                req.tenant.0,
                req.class,
            );
            ev.entry = true;
            if let Some(d) = req.deadline {
                ev.value = d;
            }
            log.emit(ev);
        }
    }

    fn start_tpu_if_idle(&mut self, now: f64) {
        if self.tpu_busy || self.down {
            return;
        }
        // Before each service start, DeadlineDrop evicts jobs that can
        // no longer meet their deadline — same rule as the live workers.
        if self.opts.overload == OverloadPolicy::DeadlineDrop {
            for (_, req) in self.tpu_queue.drain_expired(now) {
                self.count_drop(&req, DropKind::Expired, false, now);
            }
        }
        let Some((_, mut req)) = self.tpu_queue.pop() else {
            return;
        };
        let Some(i) = self.index_of(req.tenant) else {
            self.dropped += 1;
            self.start_tpu_if_idle(now);
            return;
        };
        if let Some(tr) = &mut req.trace {
            // Same accumulation point as the live TPU worker: wait ends
            // when service starts (or when a p=0 reroute hands the
            // request to its CPU station, which re-marks on entry).
            tr.queued += (now - tr.mark).max(0.0);
            tr.mark = now;
        }
        let p = self.cfg.partitions[i];
        // Admission under a p=0 config (post-reconfig): route to CPU.
        if p == 0 {
            self.enqueue_cpu(req, now, false);
            self.start_tpu_if_idle(now);
            return;
        }
        if req.arrived >= self.opts.warmup {
            if let Some(log) = &self.opts.log {
                // Same service-start point as the live TPU worker (after
                // the eviction/liveness gates, before the cache access).
                log.emit(LogEvent::new(
                    LogKind::Start,
                    now,
                    self.opts.device,
                    req.tenant.0,
                    req.class,
                ));
            }
        }
        let memo = &self.memo[i];
        let hit = self
            .cache
            .access(req.tenant.0 as usize, memo.resident_bytes);
        let mut service = memo.tpu_service;
        // Swap share of the slept service (slowdown-stretched below) —
        // the exact split the live TPU worker computes, so a virtual
        // `SpanSwap` calibrates identically to a wall-clock one.
        let mut swap_part = if hit { 0.0 } else { memo.load_time };
        if !hit {
            service += memo.load_time;
        }
        // Injected fault envelope: slowdown windows stretch the service,
        // and the live worker's inline retry loop — an injected failed
        // attempt costs its backoff (not an execution) while holding the
        // station, bounded by the budget and clipped by the deadline —
        // is replayed in virtual time.
        // `Arc` clone: refcount bump only, no deep copy per service start.
        if let Some(plan) = self.faults.clone() {
            let slow = plan.slow_factor(self.opts.device, now);
            service *= slow;
            swap_part *= slow;
            let mut attempts: u32 = 0;
            let mut backoffs = 0.0;
            let exhausted = loop {
                attempts += 1;
                self.attempted += 1;
                let seq = self.fault_seq;
                self.fault_seq += 1;
                if !plan.transient_fails(self.opts.device, now, seq) {
                    break false;
                }
                if attempts >= RETRY_BUDGET {
                    break true;
                }
                let backoff = RETRY_BACKOFF_S * f64::from(1u32 << (attempts - 1));
                let hopeless = match req.deadline {
                    Some(d) => now + backoffs + backoff >= d,
                    None => false,
                };
                if hopeless {
                    break true;
                }
                self.retried += 1;
                self.class_latency.record_retried(req.class);
                backoffs += backoff;
            };
            if exhausted {
                self.tpu_busy = true;
                self.tpu_busy_until = now + backoffs;
                self.tpu_busy_time += backoffs;
                self.schedule(now + backoffs, EventKind::TpuFault { req });
                return;
            }
            service += backoffs;
        } else {
            self.attempted += 1;
        }
        self.tpu_busy = true;
        self.tpu_busy_until = now + service;
        self.tpu_busy_time += service;
        if let Some(tr) = &mut req.trace {
            // Stage split mirrors the live worker: the reload share is
            // the swap stage, everything else slept on the station —
            // compute, dispatch, retry backoffs — is the TPU stage.
            tr.swap = swap_part;
            tr.tpu = service - swap_part;
            tr.tpu_end = now + service;
            tr.mark = now + service;
        }
        self.schedule(now + service, EventKind::TpuDone { req });
    }

    /// Offer a request to its tenant's CPU station through the bounded
    /// admission layer. `entry` marks the CPU station as the request's
    /// entry point (p = 0 routes), which decides the counter an overload
    /// refusal lands in (`rejected` at entry, `shed` mid-pipeline).
    fn enqueue_cpu(&mut self, mut req: Request, now: f64, entry: bool) {
        let Some(i) = self.index_of(req.tenant) else {
            self.dropped += 1;
            return;
        };
        if let Some(tr) = &mut req.trace {
            // CPU-queue entry: the output transfer between the stations
            // is a transfer, not queue wait — re-mark so `queued` stays
            // pure (a no-op on the p=0 entry and reroute paths, where
            // `mark` is already `now`).
            tr.mark = now;
        }
        let meta = JobMeta {
            tenant: req.tenant,
            class: req.class,
            service_hint: self.memo[i].cpu_service,
            deadline: req.deadline,
            device: self.opts.device,
        };
        let load = StationLoad {
            in_service: self.cpu_busy[i],
            servers: self.cfg.cores[i].max(1),
        };
        match self.cpu_queues[i].offer(
            meta,
            req,
            now,
            &self.cpu_stations[i],
            self.opts.capacity,
            self.opts.overload,
            load,
        ) {
            Offer::Admitted { shed, expired } => {
                if entry {
                    self.count_accept(i, &req);
                }
                for (_, victim) in shed {
                    self.count_drop(&victim, DropKind::Shed, false, now);
                }
                for (_, victim) in expired {
                    self.count_drop(&victim, DropKind::Expired, false, now);
                }
            }
            Offer::Rejected {
                job: refused,
                reason,
                expired,
                ..
            } => {
                for (_, victim) in expired {
                    self.count_drop(&victim, DropKind::Expired, false, now);
                }
                match reason {
                    RejectReason::Overloaded(_) => self.count_drop(
                        &refused,
                        if entry { DropKind::Rejected } else { DropKind::Shed },
                        entry,
                        now,
                    ),
                    RejectReason::Expired => {
                        self.count_drop(&refused, DropKind::Expired, entry, now)
                    }
                }
            }
        }
        self.start_cpu_if_possible(i, now);
    }

    fn start_cpu_if_possible(&mut self, m: usize, now: f64) {
        if self.opts.overload == OverloadPolicy::DeadlineDrop {
            for (_, req) in self.cpu_queues[m].drain_expired(now) {
                self.count_drop(&req, DropKind::Expired, false, now);
            }
        }
        let k = self.cfg.cores[m];
        // k can legitimately be 0 right after a reconfig to full-TPU while
        // stragglers drain; serve them on a borrowed core rather than
        // deadlock (counts as best-effort cleanup, negligible in steady state).
        let k_eff = k.max(if self.cpu_queues[m].is_empty() { 0 } else { 1 });
        while self.cpu_busy[m] < k_eff {
            let Some((_, mut req)) = self.cpu_queues[m].pop() else {
                return;
            };
            if let Some(tr) = &mut req.trace {
                // Same accumulation point as the live CPU pool worker.
                tr.queued += (now - tr.mark).max(0.0);
                tr.mark = now;
            }
            if req.arrived >= self.opts.warmup {
                if let Some(log) = &self.opts.log {
                    log.emit(LogEvent::new(
                        LogKind::Start,
                        now,
                        self.opts.device,
                        req.tenant.0,
                        req.class,
                    ));
                }
            }
            let service = self.memo[m].cpu_service;
            self.cpu_busy[m] += 1;
            self.schedule(now + service, EventKind::CpuDone { req });
        }
    }

    /// Invoke the policy's decision path once, installing and logging any
    /// new configuration (shared by periodic ticks and churn transitions).
    fn policy_decide(
        &mut self,
        now: f64,
        policy: &mut dyn ReconfigPolicy,
        reconfigs: &mut Vec<(f64, Config, f64)>,
    ) {
        let t0 = std::time::Instant::now();
        if let Some(cfg) = policy.decide(now, &self.tenants, &self.cfg) {
            let micros = t0.elapsed().as_secs_f64() * 1e6;
            if cfg.partitions.len() == self.tenants.len()
                && cfg.cores.len() == self.tenants.len()
            {
                reconfigs.push((now, cfg.clone(), micros));
                self.set_config(cfg);
            }
        }
    }

    /// Run to completion over pre-generated arrivals, with an optional
    /// reconfiguration policy invoked on its period.
    pub fn run(
        &mut self,
        arrivals: &[Arrival],
        policy: Option<&mut dyn ReconfigPolicy>,
    ) -> SimResult {
        self.run_churn(arrivals, Vec::new(), policy)
    }

    /// Run with a tenant-churn schedule: `churn` entries are applied at
    /// their times (attaches generate their own Poisson arrivals from the
    /// attached schedule), and the policy's `on_attach`/`on_detach` hooks
    /// fire followed by an immediate decision — exactly the sequence the
    /// live coordinator performs.
    pub fn run_churn(
        &mut self,
        arrivals: &[Arrival],
        churn: Vec<ChurnEvent>,
        mut policy: Option<&mut dyn ReconfigPolicy>,
    ) -> SimResult {
        // Initial tenants hold handles 0..n in positional order.
        for a in arrivals {
            self.schedule(
                a.time,
                EventKind::Arrival {
                    req: Request {
                        tenant: TenantHandle(a.model as u64),
                        arrived: a.time,
                        class: a.class,
                        deadline: a.deadline,
                        trace: None,
                    },
                },
            );
        }

        // Sort churn by time; handles for attaches are pre-assigned in
        // that order (apply_attach allocates sequentially), so arrival
        // streams can be generated up front and tagged with the handle
        // the attach will receive. Equal-time ties resolve churn-first
        // because churn events are pushed before their arrivals.
        let mut churn: Vec<ChurnEvent> = churn;
        churn.sort_by(|a, b| a.time.partial_cmp(&b.time).unwrap());
        let mut churn_rng = Rng::new(self.opts.seed.wrapping_mul(0x9E37_79B9).wrapping_add(7));
        let mut planned = self.next_handle;
        for (idx, ev) in churn.iter().enumerate() {
            self.schedule(ev.time, EventKind::Churn { idx });
            if let ChurnKind::Attach { tenant, schedule } = &ev.kind {
                let h = TenantHandle(planned);
                planned += 1;
                // The stream ends at the tenant's own scheduled departure
                // (if any) — only requests already in the system when it
                // detaches count as dropped.
                let until = churn[idx + 1..]
                    .iter()
                    .find_map(|later| match &later.kind {
                        ChurnKind::Detach { name } if *name == tenant.model.name => {
                            Some(later.time)
                        }
                        _ => None,
                    })
                    .unwrap_or(self.opts.horizon);
                let span = (until.min(self.opts.horizon) - ev.time).max(0.0);
                let mut r = churn_rng.fork(idx as u64 + 1);
                for a in generate_arrivals(std::slice::from_ref(schedule), span, &mut r) {
                    let t = ev.time + a.time;
                    self.schedule(
                        t,
                        EventKind::Arrival {
                            req: Request {
                                tenant: h,
                                arrived: t,
                                class: a.class,
                                deadline: a.deadline.map(|d| ev.time + d),
                                trace: None,
                            },
                        },
                    );
                }
            }
        }
        let mut churn_kinds: Vec<Option<ChurnKind>> =
            churn.into_iter().map(|e| Some(e.kind)).collect();
        let mut churn_log: Vec<(f64, String)> = Vec::new();

        // Crash/recovery boundaries from the fault plan become station
        // pause/resume events (transient and slowdown windows are read
        // inline at service start).
        if let Some(plan) = self.faults.clone() {
            for (t, down) in plan.transitions(self.opts.device) {
                let kind = if down {
                    EventKind::DeviceDown
                } else {
                    EventKind::DeviceUp
                };
                self.schedule(t, kind);
            }
        }

        if let Some(p) = policy.as_deref_mut() {
            if let Some(first) = p.period() {
                self.schedule(first, EventKind::Reconfigure);
            }
        }
        let mut reconfigs: Vec<(f64, Config, f64)> = Vec::new();

        while let Some(ev) = self.events.pop() {
            let now = ev.time;
            if now > self.opts.horizon {
                break;
            }
            match ev.kind {
                EventKind::Arrival { mut req } => {
                    let Some(i) = self.index_of(req.tenant) else {
                        // Arrival for a tenant that already detached (or
                        // attaches later — cannot happen by construction).
                        self.dropped += 1;
                        continue;
                    };
                    if let Some(p) = policy.as_deref_mut() {
                        p.observe_arrival(now, i);
                    }
                    let part = self.cfg.partitions[i];
                    // Sampled BEFORE the admission offer — the same
                    // cadence contract as the live server (1-in-N of
                    // offered load; a refused request emits nothing).
                    req.trace = self.sampler.try_begin(part, now);
                    if part > 0 {
                        // d_in/B transfer precedes TPU queueing.
                        let delay = self.memo[i].input_transfer;
                        self.schedule(now + delay, EventKind::TpuEnqueue { req });
                    } else {
                        self.enqueue_cpu(req, now, true);
                    }
                }
                EventKind::TpuEnqueue { mut req } => {
                    // Hint = the deterministic prefix service under the
                    // *current* partition (stale after a reconfig only
                    // for already-queued jobs — advisory, not load-bearing).
                    let Some(i) = self.index_of(req.tenant) else {
                        // Detached between arrival and enqueue.
                        self.dropped += 1;
                        continue;
                    };
                    if let Some(tr) = &mut req.trace {
                        // Queue entry: the d_in/B transfer that preceded
                        // it is a transfer, not queue wait — `queued`
                        // stays pure so the stage-sum residual equals
                        // the boundary transfers exactly.
                        tr.mark = now;
                    }
                    let meta = JobMeta {
                        tenant: req.tenant,
                        class: req.class,
                        service_hint: self.memo[i].tpu_service,
                        deadline: req.deadline,
                        device: self.opts.device,
                    };
                    let load = StationLoad {
                        in_service: usize::from(self.tpu_busy),
                        servers: 1,
                    };
                    match self.tpu_queue.offer(
                        meta,
                        req,
                        now,
                        "tpu",
                        self.opts.capacity,
                        self.opts.overload,
                        load,
                    ) {
                        Offer::Admitted { shed, expired } => {
                            self.count_accept(i, &req);
                            for (_, victim) in shed {
                                self.count_drop(&victim, DropKind::Shed, false, now);
                            }
                            for (_, victim) in expired {
                                self.count_drop(&victim, DropKind::Expired, false, now);
                            }
                        }
                        Offer::Rejected {
                            job: refused,
                            reason,
                            expired,
                            ..
                        } => {
                            for (_, victim) in expired {
                                self.count_drop(&victim, DropKind::Expired, false, now);
                            }
                            match reason {
                                RejectReason::Overloaded(_) => {
                                    self.count_drop(&refused, DropKind::Rejected, true, now)
                                }
                                RejectReason::Expired => {
                                    self.count_drop(&refused, DropKind::Expired, true, now)
                                }
                            }
                        }
                    }
                    self.max_tpu_occupancy = self
                        .max_tpu_occupancy
                        .max(self.tpu_queue.len() + usize::from(self.tpu_busy));
                    self.start_tpu_if_idle(now);
                }
                EventKind::TpuDone { req } => {
                    self.tpu_busy = false;
                    if let Some(i) = self.index_of(req.tenant) {
                        let p = self.cfg.partitions[i];
                        let model = &self.tenants[i].model;
                        let d_out = self.memo[i].output_transfer;
                        if p >= model.partition_points {
                            // full-TPU: output returns to host, request done
                            self.schedule(now + d_out, EventKind::Complete { req });
                        } else {
                            self.schedule(now + d_out, EventKind::CpuEnqueue { req });
                        }
                    } else {
                        // Tenant detached while its request held the TPU:
                        // the service time was paid, the result is dropped.
                        self.dropped += 1;
                    }
                    self.start_tpu_if_idle(now);
                }
                EventKind::TpuFault { req } => {
                    self.tpu_busy = false;
                    self.failed += 1;
                    if self.index_of(req.tenant).is_none() {
                        self.dropped += 1;
                    }
                    self.start_tpu_if_idle(now);
                }
                EventKind::DeviceDown => {
                    // In-service work finishes (mirrors the live worker,
                    // which checks the plan before popping, not mid-run);
                    // nothing new starts until recovery.
                    self.down = true;
                }
                EventKind::DeviceUp => {
                    self.down = false;
                    self.start_tpu_if_idle(now);
                }
                EventKind::CpuEnqueue { req } => {
                    self.enqueue_cpu(req, now, false);
                }
                EventKind::CpuDone { req } => {
                    if let Some(i) = self.index_of(req.tenant) {
                        self.cpu_busy[i] -= 1;
                        self.record_completion(&req, now);
                        self.start_cpu_if_possible(i, now);
                    } else {
                        // The tenant's busy counter vanished with its slot.
                        self.dropped += 1;
                    }
                }
                EventKind::Complete { req } => {
                    self.record_completion(&req, now);
                }
                EventKind::Reconfigure => {
                    if let Some(p) = policy.as_deref_mut() {
                        self.policy_decide(now, p, &mut reconfigs);
                        if let Some(per) = p.period() {
                            let next = now + per;
                            if next <= self.opts.horizon {
                                self.schedule(next, EventKind::Reconfigure);
                            }
                        }
                    }
                }
                EventKind::Churn { idx } => {
                    match churn_kinds[idx].take() {
                        Some(ChurnKind::Attach { tenant, .. }) => {
                            let name = tenant.model.name.clone();
                            let h = self.apply_attach(tenant);
                            churn_log.push((now, format!("attach {name} as {h}")));
                            if let Some(p) = policy.as_deref_mut() {
                                p.on_attach(now, self.tenants.len() - 1);
                                self.policy_decide(now, p, &mut reconfigs);
                            }
                        }
                        Some(ChurnKind::Detach { name }) => {
                            if let Some(i) =
                                self.tenants.iter().position(|t| t.model.name == name)
                            {
                                let h = self.apply_detach(i);
                                churn_log.push((now, format!("detach {name} ({h})")));
                                if let Some(p) = policy.as_deref_mut() {
                                    p.on_detach(now, i);
                                    self.policy_decide(now, p, &mut reconfigs);
                                }
                            } else {
                                churn_log
                                    .push((now, format!("detach {name}: not attached")));
                            }
                        }
                        None => {}
                    }
                }
            }
        }

        let measured = self.opts.horizon.max(1e-9);
        // Move the accumulated stats out instead of cloning them — the
        // simulator is spent after `run` returns.
        SimResult {
            per_model: std::mem::take(&mut self.stats),
            retired: std::mem::take(&mut self.retired),
            dropped: self.dropped,
            churn_log,
            mean_latency: self.weighted_latency.mean(),
            tpu_utilization: self.tpu_busy_time / measured,
            cache_hit_rate: self.cache.hit_rate(),
            timeline: self.timeline.take(),
            reconfigs,
            per_class: std::mem::take(&mut self.class_latency),
            max_tpu_occupancy: self.max_tpu_occupancy,
            attempted: self.attempted,
            retried: self.retried,
            failed: self.failed,
            events: self.next_seq,
        }
    }
}

fn build_memo(tables: &[PrefixTables], cfg: &Config) -> Vec<ServiceMemo> {
    tables
        .iter()
        .enumerate()
        .map(|(i, tab)| {
            let p = cfg.partitions[i];
            ServiceMemo {
                resident_bytes: tab.resident_bytes(p),
                tpu_service: tab.tpu_service(p),
                load_time: tab.load_time(p),
                cpu_service: tab.cpu_service(p),
                input_transfer: tab.input_transfer(),
                output_transfer: tab.output_transfer(p),
            }
        })
        .collect()
}

/// One-call steady-state run under a static configuration.
pub fn simulate(
    cost: &CostModel,
    tenants: &[Tenant],
    cfg: &Config,
    opts: SimOptions,
) -> SimResult {
    let schedules: Vec<RateSchedule> = tenants
        .iter()
        .map(|t| RateSchedule::constant(t.rate))
        .collect();
    let mut rng = Rng::new(opts.seed);
    let arrivals = generate_arrivals(&schedules, opts.horizon, &mut rng);
    let mut sim = Simulator::new(cost, tenants, cfg.clone(), opts);
    sim.run(&arrivals, None)
}

/// Run with per-model rate schedules and a reconfiguration policy (Fig. 8).
pub fn simulate_dynamic(
    cost: &CostModel,
    tenants: &[Tenant],
    initial: &Config,
    schedules: &[RateSchedule],
    policy: &mut dyn ReconfigPolicy,
    opts: SimOptions,
) -> SimResult {
    let mut rng = Rng::new(opts.seed);
    let arrivals = generate_arrivals(schedules, opts.horizon, &mut rng);
    let mut sim = Simulator::new(cost, tenants, initial.clone(), opts);
    sim.run(&arrivals, Some(policy))
}

/// Run with rate schedules, a reconfiguration policy, AND a tenant-churn
/// schedule (dynamic experiments with arrivals/departures).
pub fn simulate_churn(
    cost: &CostModel,
    tenants: &[Tenant],
    initial: &Config,
    schedules: &[RateSchedule],
    churn: Vec<ChurnEvent>,
    policy: &mut dyn ReconfigPolicy,
    opts: SimOptions,
) -> SimResult {
    let mut rng = Rng::new(opts.seed);
    let arrivals = generate_arrivals(schedules, opts.horizon, &mut rng);
    let mut sim = Simulator::new(cost, tenants, initial.clone(), opts);
    sim.run_churn(&arrivals, churn, Some(policy))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytic::AnalyticModel;
    use crate::config::HardwareSpec;
    use crate::model::synthetic_model;
    use crate::sim::reconfig::SwapLessPolicy;

    fn setup(rate: f64) -> (CostModel, Vec<Tenant>) {
        let cost = CostModel::new(HardwareSpec::default());
        let tenants = vec![Tenant {
            model: synthetic_model("m", 6, 1_000_000, 500_000_000),
            rate,
        }];
        (cost, tenants)
    }

    fn opts(horizon: f64, seed: u64) -> SimOptions {
        SimOptions {
            horizon,
            warmup: horizon * 0.05,
            seed,
            ..SimOptions::default()
        }
    }

    #[test]
    fn all_tpu_single_tenant_matches_analytic() {
        // DES vs M/D/1: mean latency should agree within Monte-Carlo noise.
        let (cost, tenants) = setup(3.0);
        let cfg = Config {
            partitions: vec![6],
            cores: vec![0],
        };
        let am = AnalyticModel::new(cost.clone());
        let predicted = am.e2e_latency(&tenants, &cfg, 0);
        let res = simulate(&cost, &tenants, &cfg, opts(3000.0, 7));
        let observed = res.mean_latency;
        let err = (observed - predicted).abs() / predicted;
        assert!(
            err < 0.05,
            "observed={observed} predicted={predicted} err={err}"
        );
    }

    #[test]
    fn all_cpu_single_tenant_matches_analytic() {
        let (cost, tenants) = setup(2.0);
        let cfg = Config {
            partitions: vec![0],
            cores: vec![2],
        };
        let am = AnalyticModel::new(cost.clone());
        let predicted = am.e2e_latency(&tenants, &cfg, 0);
        let res = simulate(&cost, &tenants, &cfg, opts(3000.0, 11));
        let err = (res.mean_latency - predicted).abs() / predicted;
        assert!(err < 0.05, "err={err}");
    }

    #[test]
    fn split_config_uses_both_processors() {
        let (cost, tenants) = setup(2.0);
        let cfg = Config {
            partitions: vec![3],
            cores: vec![2],
        };
        let res = simulate(&cost, &tenants, &cfg, opts(500.0, 3));
        assert!(res.tpu_utilization > 0.0);
        assert!(res.per_model[0].completed > 500);
        assert!(res.mean_latency.is_finite());
    }

    #[test]
    fn single_tenant_no_misses_after_warmup() {
        let (cost, tenants) = setup(3.0);
        let cfg = Config {
            partitions: vec![6],
            cores: vec![0],
        };
        let res = simulate(&cost, &tenants, &cfg, opts(500.0, 5));
        // one cold miss over thousands of executions
        assert!(res.cache_hit_rate > 0.999, "hit={}", res.cache_hit_rate);
    }

    #[test]
    fn interleaved_oversized_models_miss_often() {
        let cost = CostModel::new(HardwareSpec::default());
        let tenants: Vec<Tenant> = (0..2)
            .map(|i| Tenant {
                model: synthetic_model(&format!("m{i}"), 6, 1_200_000, 300_000_000),
                rate: 2.0,
            })
            .collect();
        // prefixes 7.2 MB each: together 14.4 MB > 8 MB
        let cfg = Config {
            partitions: vec![6, 6],
            cores: vec![0, 0],
        };
        let res = simulate(&cost, &tenants, &cfg, opts(1000.0, 13));
        // 50:50 mix: analytic α = 0.5 each; hit rate should be near 0.5
        assert!(
            (res.cache_hit_rate - 0.5).abs() < 0.05,
            "hit={}",
            res.cache_hit_rate
        );
    }

    #[test]
    fn higher_load_higher_latency() {
        let (cost, tenants_lo) = setup(1.0);
        let (_, tenants_hi) = setup(5.0);
        let cfg = Config {
            partitions: vec![6],
            cores: vec![0],
        };
        let lo = simulate(&cost, &tenants_lo, &cfg, opts(1000.0, 17)).mean_latency;
        let hi = simulate(&cost, &tenants_hi, &cfg, opts(1000.0, 17)).mean_latency;
        assert!(hi > lo);
    }

    #[test]
    fn measured_utilization_tracks_analytic() {
        let (cost, tenants) = setup(4.0);
        let cfg = Config {
            partitions: vec![6],
            cores: vec![0],
        };
        let am = AnalyticModel::new(cost.clone());
        let rho = am.tpu_utilization(&tenants, &cfg);
        let res = simulate(&cost, &tenants, &cfg, opts(2000.0, 19));
        assert!((res.tpu_utilization - rho).abs() < 0.03);
    }

    #[test]
    fn deterministic_given_seed() {
        let (cost, tenants) = setup(3.0);
        let cfg = Config {
            partitions: vec![4],
            cores: vec![1],
        };
        let a = simulate(&cost, &tenants, &cfg, opts(300.0, 23)).mean_latency;
        let b = simulate(&cost, &tenants, &cfg, opts(300.0, 23)).mean_latency;
        assert_eq!(a, b);
    }

    #[test]
    fn per_class_latency_accounts_every_completion() {
        let (cost, tenants) = setup(3.0);
        let cfg = Config {
            partitions: vec![4],
            cores: vec![1],
        };
        let res = simulate(&cost, &tenants, &cfg, opts(300.0, 43));
        // Untagged workloads default to Standard; every recorded
        // completion lands in exactly one class histogram.
        assert_eq!(res.per_class.total_count(), res.per_model[0].completed);
        assert_eq!(
            res.per_class.get(SloClass::Standard).count(),
            res.per_model[0].completed
        );
        assert_eq!(res.per_class.get(SloClass::Interactive).count(), 0);
        let rows = res.per_class.non_empty();
        assert_eq!(rows.len(), 1);
        assert!(rows[0].1.mean().is_finite());
    }

    #[test]
    fn every_discipline_completes_and_is_deterministic() {
        let cost = CostModel::new(HardwareSpec::default());
        let tenants: Vec<Tenant> = (0..2)
            .map(|i| Tenant {
                model: synthetic_model(&format!("m{i}"), 5, 1_000_000, 400_000_000),
                rate: 3.0,
            })
            .collect();
        let cfg = Config {
            partitions: vec![5, 3],
            cores: vec![0, 2],
        };
        for kind in DisciplineKind::ALL {
            let run = || {
                let mut o = opts(300.0, 47);
                o.discipline = kind;
                simulate(&cost, &tenants, &cfg, o)
            };
            let a = run();
            let b = run();
            assert_eq!(a.mean_latency, b.mean_latency, "{kind}");
            for (x, y) in a.per_model.iter().zip(&b.per_model) {
                assert_eq!(x.completed, y.completed, "{kind}");
            }
            assert!(
                a.per_model.iter().all(|m| m.completed > 300),
                "{kind}: starved a tenant: {:?}",
                a.per_model.iter().map(|m| m.completed).collect::<Vec<_>>()
            );
            assert_eq!(a.dropped, 0, "{kind}");
            assert!(a.mean_latency.is_finite(), "{kind}");
        }
    }

    #[test]
    fn timeline_collects_windows() {
        let (cost, tenants) = setup(3.0);
        let cfg = Config {
            partitions: vec![6],
            cores: vec![0],
        };
        let mut o = opts(200.0, 29);
        o.timeline_window = Some(10.0);
        let res = simulate(&cost, &tenants, &cfg, o);
        let series = res.timeline.unwrap().series();
        assert!(series.len() >= 15);
    }

    fn churn_policy(cost: &CostModel, n: usize) -> SwapLessPolicy {
        SwapLessPolicy::new(AnalyticModel::new(cost.clone()), 4, n, 20.0, 5.0, 0.10)
    }

    #[test]
    fn churn_attach_detach_round_trip() {
        // One tenant serves throughout; a second attaches at t=100 and
        // detaches at t=300. Its stats retire, the survivor's stats stay
        // keyed to it, and the policy re-plans at both transitions.
        let (cost, tenants) = setup(3.0);
        let cfg = Config {
            partitions: vec![6],
            cores: vec![0],
        };
        let churn = vec![
            ChurnEvent {
                time: 100.0,
                kind: ChurnKind::Attach {
                    tenant: Tenant {
                        model: synthetic_model("guest", 5, 1_000_000, 400_000_000),
                        rate: 2.0,
                    },
                    schedule: RateSchedule::constant(2.0),
                },
            },
            ChurnEvent {
                time: 300.0,
                kind: ChurnKind::Detach {
                    name: "guest".into(),
                },
            },
        ];
        let mut policy = churn_policy(&cost, 1);
        let res = simulate_churn(
            &cost,
            &tenants,
            &cfg,
            &[RateSchedule::constant(3.0)],
            churn,
            &mut policy,
            opts(500.0, 31),
        );
        assert_eq!(res.per_model.len(), 1, "only the survivor remains");
        assert_eq!(res.per_model[0].name, "m");
        assert_eq!(res.per_model[0].handle, TenantHandle(0));
        assert_eq!(res.retired.len(), 1);
        assert_eq!(res.retired[0].name, "guest");
        assert!(
            res.retired[0].completed > 200,
            "guest served while attached: {}",
            res.retired[0].completed
        );
        // The survivor kept completing after the churn.
        assert!(res.per_model[0].completed > 1000);
        assert!(res.mean_latency.is_finite());
        assert_eq!(res.churn_log.len(), 2);
        // Attach + detach each force a policy decision; at least the
        // attach-time one must reconfigure (the guest needs resources).
        assert!(
            res.reconfigs.iter().any(|(t, _, _)| (*t - 100.0).abs() < 1e-9
                || (*t > 100.0 && *t < 300.0)),
            "no reconfiguration while the guest was attached: {:?}",
            res.reconfigs.iter().map(|(t, _, _)| *t).collect::<Vec<_>>()
        );
    }

    #[test]
    fn churn_detach_drops_inflight_cleanly() {
        // Detach under heavy load: queued requests of the departed tenant
        // are counted as dropped, never completed into its peers' stats.
        let cost = CostModel::new(HardwareSpec::default());
        let tenants = vec![
            Tenant {
                model: synthetic_model("stay", 6, 1_000_000, 500_000_000),
                rate: 2.0,
            },
            Tenant {
                model: synthetic_model("leave", 6, 1_000_000, 500_000_000),
                rate: 6.0,
            },
        ];
        let cfg = Config {
            partitions: vec![6, 6],
            cores: vec![0, 0],
        };
        let churn = vec![ChurnEvent {
            time: 200.0,
            kind: ChurnKind::Detach {
                name: "leave".into(),
            },
        }];
        let mut policy = churn_policy(&cost, 2);
        let res = simulate_churn(
            &cost,
            &tenants,
            &cfg,
            &[RateSchedule::constant(2.0), RateSchedule::constant(6.0)],
            churn,
            &mut policy,
            opts(400.0, 37),
        );
        assert_eq!(res.per_model.len(), 1);
        assert_eq!(res.per_model[0].name, "stay");
        assert_eq!(res.retired.len(), 1);
        assert_eq!(res.retired[0].name, "leave");
        // Arrivals generated for "leave" after t=200 all drop.
        assert!(res.dropped > 500, "dropped={}", res.dropped);
        // Totals stay consistent: stay's completions keep accruing.
        assert!(res.per_model[0].completed > 500);
    }

    #[test]
    fn crash_without_recovery_starves_the_tpu_station() {
        let (cost, tenants) = setup(3.0);
        let cfg = Config {
            partitions: vec![6],
            cores: vec![0],
        };
        let baseline = simulate(&cost, &tenants, &cfg, opts(400.0, 53));
        let mut o = opts(400.0, 53);
        o.faults = Some(FaultPlan::new(9).crash(0, 100.0, None));
        let crashed = simulate(&cost, &tenants, &cfg, o);
        // Only pre-crash arrivals complete; the rest stay queued forever.
        assert!(crashed.per_model[0].completed > 0);
        assert!(
            crashed.per_model[0].completed < baseline.per_model[0].completed / 2,
            "crash at 25% of the horizon should lose most completions: {} vs {}",
            crashed.per_model[0].completed,
            baseline.per_model[0].completed
        );
        assert!(crashed.tpu_utilization < baseline.tpu_utilization);

        // With recovery the station drains its backlog: completions come
        // back (the queue is unbounded under Block) at higher latency.
        let mut o = opts(400.0, 53);
        o.faults = Some(FaultPlan::new(9).crash(0, 100.0, Some(120.0)));
        let recovered = simulate(&cost, &tenants, &cfg, o);
        assert!(recovered.per_model[0].completed > crashed.per_model[0].completed);
        assert!(recovered.mean_latency > baseline.mean_latency);
    }

    #[test]
    fn transient_window_drives_retries_and_terminal_failures() {
        let (cost, tenants) = setup(3.0);
        let cfg = Config {
            partitions: vec![6],
            cores: vec![0],
        };
        let mut o = opts(600.0, 59);
        o.faults = Some(FaultPlan::new(11).transient(0, 0.0, 600.0, 0.3));
        let res = simulate(&cost, &tenants, &cfg, o);
        // 30% per-attempt failure: plenty of retries, and ~prob^3 of
        // requests exhaust the budget.
        assert!(res.retried > 0, "no retries under a 30% transient window");
        assert!(res.failed > 0, "no budget exhaustion under 30%^3");
        assert!(res.attempted > res.per_model[0].completed + res.retried / 2);
        assert_eq!(res.per_class.retried_total(), res.retried);
        assert!(res.per_model[0].completed > 0);

        // Fault-free runs still count attempts, one per execution.
        let clean = simulate(&cost, &tenants, &cfg, opts(600.0, 59));
        assert_eq!(clean.retried, 0);
        assert_eq!(clean.failed, 0);
        assert!(clean.attempted >= clean.per_model[0].completed);
    }

    #[test]
    fn slowdown_window_stretches_service_times() {
        let (cost, tenants) = setup(2.0);
        let cfg = Config {
            partitions: vec![6],
            cores: vec![0],
        };
        let baseline = simulate(&cost, &tenants, &cfg, opts(400.0, 61));
        let mut o = opts(400.0, 61);
        o.faults = Some(FaultPlan::new(13).slow_down(0, 0.0, 400.0, 2.0));
        let slowed = simulate(&cost, &tenants, &cfg, o);
        assert!(slowed.mean_latency > baseline.mean_latency);
        assert!(slowed.tpu_utilization > baseline.tpu_utilization * 1.5);
    }

    #[test]
    fn faulted_runs_are_deterministic_given_seed() {
        let (cost, tenants) = setup(3.0);
        let cfg = Config {
            partitions: vec![6],
            cores: vec![0],
        };
        let run = || {
            let mut o = opts(300.0, 67);
            o.faults = Some(
                FaultPlan::new(17)
                    .crash(0, 100.0, Some(120.0))
                    .transient(0, 150.0, 250.0, 0.2)
                    .slow_down(0, 50.0, 80.0, 1.5),
            );
            simulate(&cost, &tenants, &cfg, o)
        };
        let a = run();
        let b = run();
        assert_eq!(a.mean_latency, b.mean_latency);
        assert_eq!(a.per_model[0].completed, b.per_model[0].completed);
        assert_eq!(a.attempted, b.attempted);
        assert_eq!(a.retried, b.retried);
        assert_eq!(a.failed, b.failed);
    }

    #[test]
    fn churn_is_deterministic_given_seed() {
        let (cost, tenants) = setup(3.0);
        let cfg = Config {
            partitions: vec![6],
            cores: vec![0],
        };
        let churn = || {
            vec![ChurnEvent {
                time: 50.0,
                kind: ChurnKind::Attach {
                    tenant: Tenant {
                        model: synthetic_model("guest", 5, 1_000_000, 400_000_000),
                        rate: 2.0,
                    },
                    schedule: RateSchedule::constant(2.0),
                },
            }]
        };
        let mut p1 = churn_policy(&cost, 1);
        let mut p2 = churn_policy(&cost, 1);
        let sched = [RateSchedule::constant(3.0)];
        let a = simulate_churn(&cost, &tenants, &cfg, &sched, churn(), &mut p1, opts(200.0, 41));
        let b = simulate_churn(&cost, &tenants, &cfg, &sched, churn(), &mut p2, opts(200.0, 41));
        assert_eq!(a.mean_latency, b.mean_latency);
        assert_eq!(a.per_model[0].completed, b.per_model[0].completed);
        assert_eq!(a.per_model[1].completed, b.per_model[1].completed);
    }

    #[test]
    fn spans_conserve_one_timeline_per_completion_in_virtual_time() {
        // Sample-everything logged run on a split config: every completed
        // request must flush exactly one Queued/Tpu/Cpu triplet, and the
        // stage sums must account for the end-to-end latency up to the
        // boundary transfers (which spans deliberately exclude).
        let (cost, tenants) = setup(2.0);
        let p = 3usize;
        let cfg = Config {
            partitions: vec![p],
            cores: vec![2],
        };
        let path = std::env::temp_dir().join(format!(
            "swapless-sim-span-{}.log",
            std::process::id()
        ));
        let log = EventLog::create(&path).unwrap();
        let res = simulate(
            &cost,
            &tenants,
            &cfg,
            SimOptions {
                horizon: 50.0,
                warmup: 0.0,
                seed: 9,
                log: Some(log.clone()),
                span_sample: 1,
                ..SimOptions::default()
            },
        );
        log.close();
        assert_eq!(log.dropped(), 0);
        let events = crate::eventlog::read_all(&path).unwrap();
        let count = |k: LogKind| events.iter().filter(|e| e.kind == k).count() as u64;
        let completed = res.per_model[0].completed;
        assert!(completed > 50, "workload too small");
        assert_eq!(count(LogKind::SpanQueue), completed);
        assert_eq!(count(LogKind::SpanTpu), completed);
        assert_eq!(count(LogKind::SpanCpu), completed);
        // Single resident model: exactly one cold miss pays a swap.
        assert_eq!(count(LogKind::SpanSwap), 1);

        // Stage sums + boundary transfers == end-to-end, per timeline.
        let tables = PrefixTables::new(&cost, &tenants[0].model);
        let transfers = tables.input_transfer() + tables.output_transfer(p);
        let mut by_id: std::collections::BTreeMap<u32, (f64, f64, f64)> =
            std::collections::BTreeMap::new();
        for e in &events {
            if let Some(stage) = crate::telemetry::Stage::from_kind(e.kind) {
                assert_eq!(e.aux as usize, p, "span p mislabelled");
                assert_eq!(e.span_tenant(), 0);
                let slot = by_id.entry(e.span_id()).or_insert((f64::NAN, 0.0, 0.0));
                match stage {
                    crate::telemetry::Stage::Queued => slot.0 = e.t,
                    crate::telemetry::Stage::Cpu => slot.1 = e.t,
                    _ => {}
                }
                slot.2 += e.value;
            }
        }
        assert_eq!(by_id.len() as u64, completed);
        for (id, (start, end, stage_sum)) in &by_id {
            assert!(start.is_finite(), "span {id}: no SpanQueue anchor");
            let e2e = end - start;
            assert!(
                (stage_sum + transfers - e2e).abs() < 1e-9,
                "span {id}: stages {stage_sum} + transfers {transfers} != e2e {e2e}"
            );
        }
        let _ = std::fs::remove_file(&path);
    }
}
