//! Discrete-event simulator of the Edge-TPU serving testbed.
//!
//! This is the "observed" side of every validation figure: Poisson
//! arrivals flow through the FCFS TPU queue (with the SRAM cache deciding
//! inter-model reloads) and the per-model M/D/k CPU stations, under a
//! possibly time-varying configuration. The DES shares the `CostModel`
//! with the analytic side, so discrepancies between predicted and observed
//! latency are purely *queueing/caching dynamics* — exactly what the
//! paper's model-validation experiments measure against their testbed.
//!
//! Virtual-clock simulation: a 900 s Fig.-8 timeline runs in milliseconds.

use std::collections::{BinaryHeap, VecDeque};

use crate::analytic::{Config, Tenant};
use crate::metrics::{LatencyHistogram, TimeSeries, Welford};
use crate::tpu::{CostModel, PrefixTables, SramCache};
use crate::util::rng::Rng;
use crate::workload::{generate_arrivals, RateSchedule};

mod events;
pub mod reconfig;

pub use events::{Event, EventKind};
pub use reconfig::ReconfigPolicy;

#[derive(Debug, Clone)]
pub struct SimOptions {
    pub horizon: f64,
    /// Discard samples completing before this time (cold-start transient).
    pub warmup: f64,
    pub seed: u64,
    /// Track a latency timeline with this window (None = off). Fig. 8.
    pub timeline_window: Option<f64>,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions {
            horizon: 600.0,
            warmup: 30.0,
            seed: 1,
            timeline_window: None,
        }
    }
}

#[derive(Debug, Clone)]
pub struct ModelStats {
    pub name: String,
    pub completed: u64,
    pub latency: LatencyHistogram,
    pub tpu_share: Welford,
}

#[derive(Debug)]
pub struct SimResult {
    pub per_model: Vec<ModelStats>,
    /// Request-weighted mean latency across models (the Fig. 7 metric).
    pub mean_latency: f64,
    /// Measured TPU busy fraction over the horizon.
    pub tpu_utilization: f64,
    /// SRAM cache hit rate over TPU executions.
    pub cache_hit_rate: f64,
    /// Mean-latency timeline (if requested).
    pub timeline: Option<TimeSeries>,
    /// Reconfiguration decisions taken (time, new config, decision µs).
    pub reconfigs: Vec<(f64, Config, f64)>,
}

impl SimResult {
    pub fn model_mean(&self, i: usize) -> f64 {
        self.per_model[i].latency.mean()
    }
}

#[derive(Debug, Clone, Copy)]
pub struct Request {
    pub model: usize,
    pub arrived: f64,
}

/// Per-model service-time memo for the current configuration — the DES
/// hot loop touches these on every execution, and they are pure functions
/// of (model, p), so they are precomputed here and rebuilt on reconfig.
/// The memo is filled from the per-model [`PrefixTables`] (built once per
/// simulator), so a rebuild is O(n) lookups, not O(n·L) segment sums —
/// this keeps high-frequency reconfiguration cheap (EXPERIMENTS.md §Perf).
#[derive(Debug, Clone, Default)]
struct ServiceMemo {
    resident_bytes: u64,
    tpu_service: f64,
    load_time: f64,
    cpu_service: f64,
    input_transfer: f64,
    output_transfer: f64,
}

/// In-flight simulator state for one run.
pub struct Simulator<'a> {
    tenants: &'a [Tenant],
    cfg: Config,
    /// One prefix-sum cost table per tenant (immutable across reconfigs;
    /// the `CostModel` itself is only needed at construction).
    tables: Vec<PrefixTables>,
    memo: Vec<ServiceMemo>,
    cache: SramCache,
    // TPU station
    tpu_queue: VecDeque<Request>,
    tpu_busy: bool,
    tpu_busy_until: f64,
    tpu_busy_time: f64,
    // per-model CPU stations
    cpu_queues: Vec<VecDeque<Request>>,
    cpu_busy: Vec<usize>,
    heap: BinaryHeap<Event>,
    // stats
    stats: Vec<ModelStats>,
    weighted_latency: Welford,
    timeline: Option<TimeSeries>,
    opts: SimOptions,
}

impl<'a> Simulator<'a> {
    pub fn new(
        cost: &'a CostModel,
        tenants: &'a [Tenant],
        cfg: Config,
        opts: SimOptions,
    ) -> Simulator<'a> {
        let n = tenants.len();
        let tables = PrefixTables::for_tenants(cost, tenants);
        let memo = build_memo(&tables, &cfg);
        Simulator {
            tenants,
            cfg,
            tables,
            memo,
            cache: SramCache::new(cost.hw.sram_bytes),
            tpu_queue: VecDeque::new(),
            tpu_busy: false,
            tpu_busy_until: 0.0,
            tpu_busy_time: 0.0,
            cpu_queues: (0..n).map(|_| VecDeque::new()).collect(),
            cpu_busy: vec![0; n],
            heap: BinaryHeap::new(),
            stats: tenants
                .iter()
                .map(|t| ModelStats {
                    name: t.model.name.clone(),
                    completed: 0,
                    latency: LatencyHistogram::default(),
                    tpu_share: Welford::new(),
                })
                .collect(),
            weighted_latency: Welford::new(),
            timeline: opts.timeline_window.map(TimeSeries::new),
            opts,
        }
    }

    /// Swap in a new configuration (online reconfiguration). Queued and
    /// in-flight requests finish under their admission-time partition; the
    /// cache entries of re-partitioned models are invalidated (their
    /// resident sets changed).
    pub fn set_config(&mut self, cfg: Config) {
        for i in 0..self.tenants.len() {
            if cfg.partitions[i] != self.cfg.partitions[i] {
                self.cache.invalidate(i);
            }
        }
        self.memo = build_memo(&self.tables, &cfg);
        self.cfg = cfg;
    }

    pub fn config(&self) -> &Config {
        &self.cfg
    }

    fn record_completion(&mut self, req: &Request, now: f64) {
        if now < self.opts.warmup {
            return;
        }
        let latency = now - req.arrived;
        self.stats[req.model].completed += 1;
        self.stats[req.model].latency.record(latency);
        self.weighted_latency.add(latency);
        if let Some(ts) = &mut self.timeline {
            ts.record(now, latency);
        }
    }

    fn start_tpu_if_idle(&mut self, now: f64) {
        if self.tpu_busy {
            return;
        }
        let Some(req) = self.tpu_queue.pop_front() else {
            return;
        };
        let p = self.cfg.partitions[req.model];
        // Admission under a p=0 config (post-reconfig): route to CPU.
        if p == 0 {
            self.enqueue_cpu(req, now);
            self.start_tpu_if_idle(now);
            return;
        }
        let memo = &self.memo[req.model];
        let hit = self.cache.access(req.model, memo.resident_bytes);
        let mut service = memo.tpu_service;
        if !hit {
            service += memo.load_time;
        }
        self.tpu_busy = true;
        self.tpu_busy_until = now + service;
        self.tpu_busy_time += service;
        self.heap.push(Event::at(
            now + service,
            EventKind::TpuDone { req },
        ));
    }

    fn enqueue_cpu(&mut self, req: Request, now: f64) {
        let m = req.model;
        self.cpu_queues[m].push_back(req);
        self.start_cpu_if_possible(m, now);
    }

    fn start_cpu_if_possible(&mut self, m: usize, now: f64) {
        let k = self.cfg.cores[m];
        // k can legitimately be 0 right after a reconfig to full-TPU while
        // stragglers drain; serve them on a borrowed core rather than
        // deadlock (counts as best-effort cleanup, negligible in steady state).
        let k_eff = k.max(if self.cpu_queues[m].is_empty() { 0 } else { 1 });
        while self.cpu_busy[m] < k_eff {
            let Some(req) = self.cpu_queues[m].pop_front() else {
                return;
            };
            let service = self.memo[m].cpu_service;
            self.cpu_busy[m] += 1;
            self.heap.push(Event::at(
                now + service,
                EventKind::CpuDone { req },
            ));
        }
    }

    /// Run to completion over pre-generated arrivals, with an optional
    /// reconfiguration policy invoked on a fixed period.
    pub fn run(
        &mut self,
        arrivals: &[crate::workload::Arrival],
        mut policy: Option<&mut dyn ReconfigPolicy>,
    ) -> SimResult {
        for a in arrivals {
            self.heap.push(Event::at(
                a.time,
                EventKind::Arrival {
                    req: Request {
                        model: a.model,
                        arrived: a.time,
                    },
                },
            ));
        }
        if let Some(p) = policy.as_deref_mut() {
            let first = p.period();
            self.heap
                .push(Event::at(first, EventKind::Reconfigure));
        }
        let mut reconfigs: Vec<(f64, Config, f64)> = Vec::new();

        while let Some(ev) = self.heap.pop() {
            let now = ev.time;
            if now > self.opts.horizon {
                break;
            }
            match ev.kind {
                EventKind::Arrival { req } => {
                    if let Some(p) = policy.as_deref_mut() {
                        p.observe_arrival(now, req.model);
                    }
                    let part = self.cfg.partitions[req.model];
                    if part > 0 {
                        // d_in/B transfer precedes TPU queueing.
                        let delay = self.memo[req.model].input_transfer;
                        self.heap.push(Event::at(
                            now + delay,
                            EventKind::TpuEnqueue { req },
                        ));
                    } else {
                        self.enqueue_cpu(req, now);
                    }
                }
                EventKind::TpuEnqueue { req } => {
                    self.tpu_queue.push_back(req);
                    self.start_tpu_if_idle(now);
                }
                EventKind::TpuDone { req } => {
                    self.tpu_busy = false;
                    let p = self.cfg.partitions[req.model];
                    let model = &self.tenants[req.model].model;
                    let d_out = self.memo[req.model].output_transfer;
                    if p >= model.partition_points {
                        // full-TPU: output returns to host, request done
                        self.heap.push(Event::at(
                            now + d_out,
                            EventKind::Complete { req },
                        ));
                    } else {
                        self.heap.push(Event::at(
                            now + d_out,
                            EventKind::CpuEnqueue { req },
                        ));
                    }
                    self.start_tpu_if_idle(now);
                }
                EventKind::CpuEnqueue { req } => {
                    self.enqueue_cpu(req, now);
                }
                EventKind::CpuDone { req } => {
                    self.cpu_busy[req.model] -= 1;
                    self.record_completion(&req, now);
                    self.start_cpu_if_possible(req.model, now);
                }
                EventKind::Complete { req } => {
                    self.record_completion(&req, now);
                }
                EventKind::Reconfigure => {
                    if let Some(p) = policy.as_deref_mut() {
                        let t0 = std::time::Instant::now();
                        if let Some(cfg) = p.decide(now, self.tenants, &self.cfg) {
                            let micros = t0.elapsed().as_secs_f64() * 1e6;
                            reconfigs.push((now, cfg.clone(), micros));
                            self.set_config(cfg);
                        }
                        let next = now + p.period();
                        if next <= self.opts.horizon {
                            self.heap.push(Event::at(next, EventKind::Reconfigure));
                        }
                    }
                }
            }
        }

        let measured = self.opts.horizon.max(1e-9);
        SimResult {
            per_model: self.stats.clone(),
            mean_latency: self.weighted_latency.mean(),
            tpu_utilization: self.tpu_busy_time / measured,
            cache_hit_rate: self.cache.hit_rate(),
            timeline: self.timeline.take(),
            reconfigs,
        }
    }
}

fn build_memo(tables: &[PrefixTables], cfg: &Config) -> Vec<ServiceMemo> {
    tables
        .iter()
        .enumerate()
        .map(|(i, tab)| {
            let p = cfg.partitions[i];
            ServiceMemo {
                resident_bytes: tab.resident_bytes(p),
                tpu_service: tab.tpu_service(p),
                load_time: tab.load_time(p),
                cpu_service: tab.cpu_service(p),
                input_transfer: tab.input_transfer(),
                output_transfer: tab.output_transfer(p),
            }
        })
        .collect()
}

/// One-call steady-state run under a static configuration.
pub fn simulate(
    cost: &CostModel,
    tenants: &[Tenant],
    cfg: &Config,
    opts: SimOptions,
) -> SimResult {
    let schedules: Vec<RateSchedule> = tenants
        .iter()
        .map(|t| RateSchedule::constant(t.rate))
        .collect();
    let mut rng = Rng::new(opts.seed);
    let arrivals = generate_arrivals(&schedules, opts.horizon, &mut rng);
    let mut sim = Simulator::new(cost, tenants, cfg.clone(), opts);
    sim.run(&arrivals, None)
}

/// Run with per-model rate schedules and a reconfiguration policy (Fig. 8).
pub fn simulate_dynamic(
    cost: &CostModel,
    tenants: &[Tenant],
    initial: &Config,
    schedules: &[RateSchedule],
    policy: &mut dyn ReconfigPolicy,
    opts: SimOptions,
) -> SimResult {
    let mut rng = Rng::new(opts.seed);
    let arrivals = generate_arrivals(schedules, opts.horizon, &mut rng);
    let mut sim = Simulator::new(cost, tenants, initial.clone(), opts);
    sim.run(&arrivals, Some(policy))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytic::AnalyticModel;
    use crate::config::HardwareSpec;
    use crate::model::synthetic_model;

    fn setup(rate: f64) -> (CostModel, Vec<Tenant>) {
        let cost = CostModel::new(HardwareSpec::default());
        let tenants = vec![Tenant {
            model: synthetic_model("m", 6, 1_000_000, 500_000_000),
            rate,
        }];
        (cost, tenants)
    }

    fn opts(horizon: f64, seed: u64) -> SimOptions {
        SimOptions {
            horizon,
            warmup: horizon * 0.05,
            seed,
            timeline_window: None,
        }
    }

    #[test]
    fn all_tpu_single_tenant_matches_analytic() {
        // DES vs M/D/1: mean latency should agree within Monte-Carlo noise.
        let (cost, tenants) = setup(3.0);
        let cfg = Config {
            partitions: vec![6],
            cores: vec![0],
        };
        let am = AnalyticModel::new(cost.clone());
        let predicted = am.e2e_latency(&tenants, &cfg, 0);
        let res = simulate(&cost, &tenants, &cfg, opts(3000.0, 7));
        let observed = res.mean_latency;
        let err = (observed - predicted).abs() / predicted;
        assert!(
            err < 0.05,
            "observed={observed} predicted={predicted} err={err}"
        );
    }

    #[test]
    fn all_cpu_single_tenant_matches_analytic() {
        let (cost, tenants) = setup(2.0);
        let cfg = Config {
            partitions: vec![0],
            cores: vec![2],
        };
        let am = AnalyticModel::new(cost.clone());
        let predicted = am.e2e_latency(&tenants, &cfg, 0);
        let res = simulate(&cost, &tenants, &cfg, opts(3000.0, 11));
        let err = (res.mean_latency - predicted).abs() / predicted;
        assert!(err < 0.05, "err={err}");
    }

    #[test]
    fn split_config_uses_both_processors() {
        let (cost, tenants) = setup(2.0);
        let cfg = Config {
            partitions: vec![3],
            cores: vec![2],
        };
        let res = simulate(&cost, &tenants, &cfg, opts(500.0, 3));
        assert!(res.tpu_utilization > 0.0);
        assert!(res.per_model[0].completed > 500);
        assert!(res.mean_latency.is_finite());
    }

    #[test]
    fn single_tenant_no_misses_after_warmup() {
        let (cost, tenants) = setup(3.0);
        let cfg = Config {
            partitions: vec![6],
            cores: vec![0],
        };
        let res = simulate(&cost, &tenants, &cfg, opts(500.0, 5));
        // one cold miss over thousands of executions
        assert!(res.cache_hit_rate > 0.999, "hit={}", res.cache_hit_rate);
    }

    #[test]
    fn interleaved_oversized_models_miss_often() {
        let cost = CostModel::new(HardwareSpec::default());
        let tenants: Vec<Tenant> = (0..2)
            .map(|i| Tenant {
                model: synthetic_model(&format!("m{i}"), 6, 1_200_000, 300_000_000),
                rate: 2.0,
            })
            .collect();
        // prefixes 7.2 MB each: together 14.4 MB > 8 MB
        let cfg = Config {
            partitions: vec![6, 6],
            cores: vec![0, 0],
        };
        let res = simulate(&cost, &tenants, &cfg, opts(1000.0, 13));
        // 50:50 mix: analytic α = 0.5 each; hit rate should be near 0.5
        assert!(
            (res.cache_hit_rate - 0.5).abs() < 0.05,
            "hit={}",
            res.cache_hit_rate
        );
    }

    #[test]
    fn higher_load_higher_latency() {
        let (cost, tenants_lo) = setup(1.0);
        let (_, tenants_hi) = setup(5.0);
        let cfg = Config {
            partitions: vec![6],
            cores: vec![0],
        };
        let lo = simulate(&cost, &tenants_lo, &cfg, opts(1000.0, 17)).mean_latency;
        let hi = simulate(&cost, &tenants_hi, &cfg, opts(1000.0, 17)).mean_latency;
        assert!(hi > lo);
    }

    #[test]
    fn measured_utilization_tracks_analytic() {
        let (cost, tenants) = setup(4.0);
        let cfg = Config {
            partitions: vec![6],
            cores: vec![0],
        };
        let am = AnalyticModel::new(cost.clone());
        let rho = am.tpu_utilization(&tenants, &cfg);
        let res = simulate(&cost, &tenants, &cfg, opts(2000.0, 19));
        assert!((res.tpu_utilization - rho).abs() < 0.03);
    }

    #[test]
    fn deterministic_given_seed() {
        let (cost, tenants) = setup(3.0);
        let cfg = Config {
            partitions: vec![4],
            cores: vec![1],
        };
        let a = simulate(&cost, &tenants, &cfg, opts(300.0, 23)).mean_latency;
        let b = simulate(&cost, &tenants, &cfg, opts(300.0, 23)).mean_latency;
        assert_eq!(a, b);
    }

    #[test]
    fn timeline_collects_windows() {
        let (cost, tenants) = setup(3.0);
        let cfg = Config {
            partitions: vec![6],
            cores: vec![0],
        };
        let mut o = opts(200.0, 29);
        o.timeline_window = Some(10.0);
        let res = simulate(&cost, &tenants, &cfg, o);
        let series = res.timeline.unwrap().series();
        assert!(series.len() >= 15);
    }
}
