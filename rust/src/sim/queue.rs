//! Pluggable pending-event set for the DES hot loop.
//!
//! Two implementations sit behind [`EventQueue`]:
//!
//! * [`HeapQueue`] — the original `BinaryHeap<Event>`, kept as the
//!   reference implementation;
//! * [`CalendarQueue`] — a classic calendar queue (Brown 1988): events
//!   hash into bucket "days" of width `w` by `floor(time / w)`, each day
//!   holds a short sorted list, and `pop` scans forward from the current
//!   day. With the width adapted to the pending-event density, both push
//!   and pop are O(1) amortized versus the heap's O(log n) — the win
//!   that matters when a million pre-generated arrivals sit in the queue.
//!
//! Both orderings are the *same strict total order* — ascending
//! `(time, seq)`, `seq` being the per-run scheduling sequence — so any
//! simulation result is bit-exact across implementations
//! (`tests/queue_parity.rs` pins this across workloads, disciplines,
//! overload policies, and fault plans).
//!
//! Calendar correctness does not depend on the bucket geometry: the scan
//! compares integer day indices (`floor(time / width)`, computed the same
//! way on push and pop — no accumulated float drift), and a full fruitless
//! lap falls back to a direct search for the globally minimal bucket tail,
//! so a degenerate width only costs speed, never order.

use std::collections::BinaryHeap;

use super::events::Event;

/// Which pending-event structure the simulator runs on
/// ([`crate::sim::SimOptions::queue`], `--queue` on the CLI).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueueKind {
    /// `BinaryHeap<Event>` — the reference implementation.
    Heap,
    /// Calendar queue — the fast default.
    Calendar,
}

impl QueueKind {
    pub const ALL: [QueueKind; 2] = [QueueKind::Heap, QueueKind::Calendar];

    pub fn parse(s: &str) -> Result<QueueKind, String> {
        match s {
            "heap" => Ok(QueueKind::Heap),
            "calendar" => Ok(QueueKind::Calendar),
            other => Err(format!("unknown --queue {other} (heap|calendar)")),
        }
    }

    pub fn build(self) -> Box<dyn EventQueue> {
        match self {
            QueueKind::Heap => Box::new(HeapQueue::new()),
            QueueKind::Calendar => Box::new(CalendarQueue::new()),
        }
    }
}

impl std::fmt::Display for QueueKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            QueueKind::Heap => write!(f, "heap"),
            QueueKind::Calendar => write!(f, "calendar"),
        }
    }
}

/// The pending-event set: `pop` must return events in strictly ascending
/// `(time, seq)` order regardless of push order. Times are finite and
/// non-negative (the simulator's `schedule` asserts this).
pub trait EventQueue: Send {
    fn push(&mut self, ev: Event);
    fn pop(&mut self) -> Option<Event>;
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Reference implementation: the original max-heap over the inverted
/// [`Event`] ordering.
#[derive(Debug, Default)]
pub struct HeapQueue {
    heap: BinaryHeap<Event>,
}

impl HeapQueue {
    pub fn new() -> HeapQueue {
        HeapQueue::default()
    }
}

impl EventQueue for HeapQueue {
    fn push(&mut self, ev: Event) {
        self.heap.push(ev);
    }

    fn pop(&mut self) -> Option<Event> {
        self.heap.pop()
    }

    fn len(&self) -> usize {
        self.heap.len()
    }
}

/// Strictly-before in the queue's total order: ascending `(time, seq)`.
#[inline]
fn before(a: &Event, b: &Event) -> bool {
    a.time < b.time || (a.time == b.time && a.seq < b.seq)
}

const MIN_BUCKETS: usize = 64;

/// Calendar queue: `buckets[day % n]` holds day `day`'s events sorted
/// *descending* by `(time, seq)`, so the bucket minimum pops from the
/// tail in O(1).
#[derive(Debug)]
pub struct CalendarQueue {
    buckets: Vec<Vec<Event>>,
    len: usize,
    /// Bucket-day width in simulated seconds.
    width: f64,
    /// Absolute day index (`floor(time / width)`) the scan cursor is on.
    cur_day: u64,
}

impl Default for CalendarQueue {
    fn default() -> Self {
        CalendarQueue::new()
    }
}

impl CalendarQueue {
    pub fn new() -> CalendarQueue {
        CalendarQueue {
            buckets: vec![Vec::new(); MIN_BUCKETS],
            len: 0,
            width: 1.0,
            cur_day: 0,
        }
    }

    #[inline]
    fn day_of(width: f64, t: f64) -> u64 {
        // `as` saturates, so far-future times all land on the last day —
        // they merely scan slower, order is still exact.
        (t / width) as u64
    }

    fn insert(buckets: &mut [Vec<Event>], width: f64, ev: Event) {
        let day = Self::day_of(width, ev.time);
        let b = &mut buckets[(day % buckets.len() as u64) as usize];
        // Keep the bucket descending by (time, seq): everything greater
        // than `ev` forms the prefix, so this binary search is valid.
        let pos = b.partition_point(|e| before(&ev, e));
        b.insert(pos, ev);
    }

    /// Rehash into `n_new` buckets, re-estimating the day width from the
    /// pending span (targeting a few events per day so the scan stays
    /// O(1) per pop).
    fn resize(&mut self, n_new: usize) {
        let mut events: Vec<Event> = Vec::with_capacity(self.len);
        for b in &mut self.buckets {
            events.append(b);
        }
        let mut min_t = f64::INFINITY;
        let mut max_t = f64::NEG_INFINITY;
        for e in &events {
            min_t = min_t.min(e.time);
            max_t = max_t.max(e.time);
        }
        if events.len() >= 2 && max_t > min_t {
            let w = 4.0 * (max_t - min_t) / events.len() as f64;
            if w.is_finite() && w > 0.0 {
                self.width = w;
            }
        }
        self.buckets = vec![Vec::new(); n_new];
        if !events.is_empty() {
            // The cursor must not start past the earliest pending event.
            self.cur_day = Self::day_of(self.width, min_t);
        }
        for ev in events {
            Self::insert(&mut self.buckets, self.width, ev);
        }
    }

    fn maybe_shrink(&mut self) {
        if self.buckets.len() > MIN_BUCKETS && self.len < self.buckets.len() / 2 {
            let n = (self.buckets.len() / 2).max(MIN_BUCKETS);
            self.resize(n);
        }
    }
}

impl EventQueue for CalendarQueue {
    fn push(&mut self, ev: Event) {
        // A push earlier than the cursor (never happens in the DES, which
        // only schedules at or after `now`) rewinds the scan — always
        // safe, it only costs extra scanning.
        let day = Self::day_of(self.width, ev.time);
        if day < self.cur_day {
            self.cur_day = day;
        }
        Self::insert(&mut self.buckets, self.width, ev);
        self.len += 1;
        if self.len > 2 * self.buckets.len() {
            let n = self.buckets.len() * 2;
            self.resize(n);
        }
    }

    fn pop(&mut self) -> Option<Event> {
        if self.len == 0 {
            return None;
        }
        let n = self.buckets.len() as u64;
        for _ in 0..self.buckets.len() {
            let b = &mut self.buckets[(self.cur_day % n) as usize];
            if let Some(tail) = b.last() {
                // Only the bucket's current-day events are eligible:
                // events of day `d` live in bucket `d % n`, and all
                // pending events have day >= the last popped day, so the
                // minimal tail of the cursor's day is the global minimum.
                if Self::day_of(self.width, tail.time) <= self.cur_day {
                    let ev = b.pop().unwrap();
                    self.len -= 1;
                    self.maybe_shrink();
                    return Some(ev);
                }
            }
            self.cur_day = self.cur_day.saturating_add(1);
        }
        // A full fruitless lap (sparse far-future events): direct-search
        // the globally minimal bucket tail and jump the cursor to it.
        // This also guarantees progress for any bucket geometry.
        let bi = self
            .buckets
            .iter()
            .enumerate()
            .filter_map(|(i, b)| b.last().map(|e| (i, e)))
            .min_by(|a, b| {
                if before(a.1, b.1) {
                    std::cmp::Ordering::Less
                } else {
                    std::cmp::Ordering::Greater
                }
            })
            .map(|(i, _)| i)
            .expect("calendar len > 0 with every bucket empty");
        let ev = self.buckets[bi].pop().unwrap();
        self.len -= 1;
        self.cur_day = Self::day_of(self.width, ev.time);
        self.maybe_shrink();
        Some(ev)
    }

    fn len(&self) -> usize {
        self.len
    }
}

#[cfg(test)]
mod tests {
    use super::super::events::EventKind;
    use super::*;
    use crate::util::rng::Rng;

    fn ev(time: f64, seq: u64) -> Event {
        Event::new(time, seq, EventKind::Reconfigure)
    }

    fn drain(q: &mut dyn EventQueue) -> Vec<(f64, u64)> {
        let mut out = Vec::new();
        while let Some(e) = q.pop() {
            out.push((e.time, e.seq));
        }
        out
    }

    #[test]
    fn kinds_parse_and_display() {
        for k in QueueKind::ALL {
            assert_eq!(QueueKind::parse(&k.to_string()).unwrap(), k);
        }
        assert!(QueueKind::parse("splay").is_err());
    }

    #[test]
    fn calendar_matches_heap_on_random_streams() {
        let mut rng = Rng::new(99);
        for case in 0..20 {
            let mut heap = HeapQueue::new();
            let mut cal = CalendarQueue::new();
            // Random pre-load, then interleaved pop/push with the DES
            // invariant (pushes never before the last popped time).
            let mut seq = 0u64;
            for _ in 0..rng.below(400) + 1 {
                let t = rng.f64() * 1000.0;
                heap.push(ev(t, seq));
                cal.push(ev(t, seq));
                seq += 1;
            }
            let mut now = 0.0;
            while heap.len() > 0 {
                let a = heap.pop().unwrap();
                let b = cal.pop().unwrap();
                assert_eq!(
                    (a.time, a.seq),
                    (b.time, b.seq),
                    "case {case}: divergence at seq {seq}"
                );
                now = a.time;
                if rng.f64() < 0.3 {
                    // Schedule ahead, sometimes at exactly `now` (the
                    // zero-delay events the DES emits constantly).
                    let t = now + if rng.f64() < 0.2 { 0.0 } else { rng.f64() * 50.0 };
                    heap.push(ev(t, seq));
                    cal.push(ev(t, seq));
                    seq += 1;
                }
            }
            assert_eq!(cal.len(), 0);
        }
    }

    #[test]
    fn equal_times_pop_in_seq_order() {
        for kind in QueueKind::ALL {
            let mut q = kind.build();
            for seq in [3u64, 1, 0, 2] {
                q.push(ev(5.0, seq));
            }
            let order: Vec<u64> = drain(q.as_mut()).iter().map(|(_, s)| *s).collect();
            assert_eq!(order, vec![0, 1, 2, 3], "{kind}");
        }
    }

    #[test]
    fn calendar_survives_resize_cycles() {
        let mut cal = CalendarQueue::new();
        // Far more events than MIN_BUCKETS forces growth; draining
        // forces shrink. Order must stay exact throughout.
        let n = 10_000u64;
        for seq in 0..n {
            // Insertion order deliberately scrambled vs time order.
            let t = ((seq * 7919) % n) as f64 * 0.01;
            cal.push(ev(t, seq));
        }
        assert!(cal.buckets.len() > MIN_BUCKETS);
        let popped = drain(&mut cal);
        assert_eq!(popped.len(), n as usize);
        for w in popped.windows(2) {
            assert!(
                w[0].0 < w[1].0 || (w[0].0 == w[1].0 && w[0].1 < w[1].1),
                "order violated: {w:?}"
            );
        }
    }

    #[test]
    fn calendar_handles_sparse_far_future_events() {
        let mut cal = CalendarQueue::new();
        // Events separated by many empty "days" exercise the lap +
        // direct-search fallback.
        cal.push(ev(1e6, 0));
        cal.push(ev(3.0, 1));
        cal.push(ev(5e8, 2));
        assert_eq!(cal.pop().unwrap().time, 3.0);
        assert_eq!(cal.pop().unwrap().time, 1e6);
        cal.push(ev(1e6 + 1.0, 3));
        assert_eq!(cal.pop().unwrap().time, 1e6 + 1.0);
        assert_eq!(cal.pop().unwrap().time, 5e8);
        assert!(cal.pop().is_none());
    }
}
