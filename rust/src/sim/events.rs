//! Event types + the time-ordered heap ordering for the DES.

use super::Request;

#[derive(Debug, Clone, Copy)]
pub enum EventKind {
    /// External arrival of a request.
    Arrival { req: Request },
    /// Request finished its d_in/B transfer and joins the TPU FCFS queue.
    TpuEnqueue { req: Request },
    /// TPU finished serving (compute + swaps) — release the server.
    TpuDone { req: Request },
    /// Boundary tensor arrived at the host — join the model's CPU queue.
    CpuEnqueue { req: Request },
    /// A CPU core finished the suffix — request complete.
    CpuDone { req: Request },
    /// Full-TPU request finished its output transfer.
    Complete { req: Request },
    /// The TPU station's in-service request exhausted its transient-fault
    /// retry budget (or its deadline clipped the backoff) — release the
    /// server, count a failure.
    TpuFault { req: Request },
    /// The injected fault plan crashes this station set's device: the TPU
    /// station stops starting service (queued work stays queued).
    DeviceDown,
    /// The device recovers: the TPU station resumes.
    DeviceUp,
    /// Periodic invocation of the online reconfiguration policy.
    Reconfigure,
    /// Tenant lifecycle transition: apply the churn-schedule entry at
    /// `idx` (attach or detach) — see [`crate::sim::ChurnEvent`].
    Churn { idx: usize },
}

#[derive(Debug, Clone, Copy)]
pub struct Event {
    pub time: f64,
    /// Tie-break sequence: equal-time events keep their scheduling order,
    /// making runs fully deterministic.
    pub seq: u64,
    pub kind: EventKind,
}

impl Event {
    /// `seq` is the per-`Simulator` scheduling counter (see
    /// `Simulator::schedule`) — keeping it per-run makes event order
    /// independent of whatever other simulators the process has run,
    /// and contention-free across parallel replications.
    pub fn new(time: f64, seq: u64, kind: EventKind) -> Event {
        assert!(time.is_finite(), "event scheduled at non-finite time");
        Event { time, seq, kind }
    }
}

// BinaryHeap is a max-heap; invert the ordering for earliest-first.
impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        other
            .time
            .partial_cmp(&self.time)
            .unwrap()
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BinaryHeap;

    #[test]
    fn heap_pops_earliest_first() {
        let mut h = BinaryHeap::new();
        h.push(Event::new(3.0, 0, EventKind::Reconfigure));
        h.push(Event::new(1.0, 1, EventKind::Reconfigure));
        h.push(Event::new(2.0, 2, EventKind::Reconfigure));
        assert_eq!(h.pop().unwrap().time, 1.0);
        assert_eq!(h.pop().unwrap().time, 2.0);
        assert_eq!(h.pop().unwrap().time, 3.0);
    }

    #[test]
    fn equal_times_preserve_fifo() {
        let mut h = BinaryHeap::new();
        h.push(Event::new(1.0, 1, EventKind::Reconfigure));
        h.push(Event::new(1.0, 0, EventKind::Reconfigure));
        let first = h.pop().unwrap();
        let second = h.pop().unwrap();
        assert!(first.seq < second.seq);
    }

    #[test]
    #[should_panic]
    fn non_finite_time_panics() {
        Event::new(f64::NAN, 0, EventKind::Reconfigure);
    }
}
