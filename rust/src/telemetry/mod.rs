//! Request-stage tracing, the /metrics telemetry plane, and the
//! span-calibrated profiled cost model.
//!
//! Three cooperating pieces:
//!
//! * **Stage spans** — a sampled (1-in-N, [`SpanSampler`]) request carries
//!   a [`SpanTrace`] through the pipeline; at completion the producer
//!   emits one burst of `Span*` records ([`emit_burst`]) through the
//!   existing [`EventLog`] writer: `SpanQueue` (total cross-station
//!   queue wait, stamped at the *admission* instant), `SpanSwap`
//!   (prefix swap-in, misses only), `SpanTpu` (pure TPU service) and
//!   `SpanCpu` (CPU suffix execution). Dropped requests emit nothing, so
//!   "exactly one complete timeline per sampled completed request" is a
//!   testable conservation property. The DES emits the identical burst
//!   in virtual time, which makes sim-vs-live stage-timing comparable
//!   record-for-record.
//! * **[`SpanCollector`]** — a fixed-size, lock-free (atomics-only)
//!   open-addressing table folding span durations into per-(device,
//!   tenant, partition, stage) running estimates, fed inline at emission
//!   on the live path and foldable offline from a log
//!   ([`SpanCollector::fold_event`]). Estimates surface as
//!   predicted-vs-observed drift gauges on `GET /metrics`.
//! * **[`ProfiledCostModel`]** — the measured alternative to the analytic
//!   [`CostModel`]: collector estimates override per-prefix entries of
//!   [`PrefixTables`] via [`PrefixTables::with_measured`] (values are
//!   copied, never re-accumulated), so a model calibrated from spans the
//!   analytic model itself generated reproduces the analytic tables
//!   **bit-for-bit** — the closing-the-loop parity the acceptance tests
//!   pin.
//!
//! [`PromWriter`] renders everything in Prometheus text exposition
//! format (HELP/TYPE headers deduplicated, label values escaped), reusing
//! [`LatencyHistogram`](crate::metrics::LatencyHistogram) quantiles as
//! summary series rather than dumping 1024 raw buckets.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use crate::eventlog::{Event, EventKind, EventLog};
use crate::metrics::LatencyHistogram;
use crate::model::ModelMeta;
use crate::sched::SloClass;
use crate::tpu::{CostModel, PrefixTables};

/// Default sampling cadence: one request in 16.
pub const DEFAULT_SPAN_SAMPLE: usize = 16;

/// The pipeline stage a span duration belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Stage {
    /// Total queue wait accumulated across every station.
    Queued,
    /// Prefix swap-in (SRAM cache miss) time.
    Swap,
    /// Pure TPU prefix service time.
    Tpu,
    /// CPU suffix execution time.
    Cpu,
}

impl Stage {
    pub const COUNT: usize = 4;
    pub const ALL: [Stage; 4] = [Stage::Queued, Stage::Swap, Stage::Tpu, Stage::Cpu];

    pub fn index(self) -> usize {
        match self {
            Stage::Queued => 0,
            Stage::Swap => 1,
            Stage::Tpu => 2,
            Stage::Cpu => 3,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Stage::Queued => "queued",
            Stage::Swap => "swap",
            Stage::Tpu => "tpu",
            Stage::Cpu => "cpu",
        }
    }

    /// The stage a `Span*` record kind carries; `None` for lifecycle kinds.
    pub fn from_kind(kind: EventKind) -> Option<Stage> {
        match kind {
            EventKind::SpanQueue => Some(Stage::Queued),
            EventKind::SpanSwap => Some(Stage::Swap),
            EventKind::SpanTpu => Some(Stage::Tpu),
            EventKind::SpanCpu => Some(Stage::Cpu),
            _ => None,
        }
    }

    fn kind(self) -> EventKind {
        match self {
            Stage::Queued => EventKind::SpanQueue,
            Stage::Swap => EventKind::SpanSwap,
            Stage::Tpu => EventKind::SpanTpu,
            Stage::Cpu => EventKind::SpanCpu,
        }
    }
}

/// Per-request stage timeline under construction. `Copy` and fixed-size
/// so it rides inside job structs and the DES request without allocating;
/// everything is filled in by the stations and flushed in one burst at
/// completion.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SpanTrace {
    /// Producer-local span id (regroups interleaved records; unique per
    /// `(device, id)`).
    pub id: u32,
    /// The partition point the request executed under.
    pub p: u16,
    /// Admission time (producer clock) — `SpanQueue.t`, the timeline
    /// anchor end-to-end latency is derived from.
    pub start: f64,
    /// Scratch: when the request last entered a queue; stations turn it
    /// into `queued` increments at pop time.
    pub mark: f64,
    /// Accumulated cross-station queue wait.
    pub queued: f64,
    /// Swap-in duration (0.0 = cache hit or no TPU prefix).
    pub swap: f64,
    /// Pure TPU stage duration.
    pub tpu: f64,
    /// When the TPU stage finished — the stamp `SpanSwap`/`SpanTpu`
    /// records carry. Stays `start` until a TPU stage completes, so the
    /// trace can ride through the CPU leg without extra plumbing.
    pub tpu_end: f64,
}

impl SpanTrace {
    pub fn new(id: u32, p: usize, now: f64) -> SpanTrace {
        SpanTrace {
            id,
            p: p.min(u16::MAX as usize) as u16,
            start: now,
            mark: now,
            queued: 0.0,
            swap: 0.0,
            tpu: 0.0,
            tpu_end: now,
        }
    }
}

/// Lock-free 1-in-N sampling decision + span-id allocation. `every == 0`
/// disables sampling entirely.
#[derive(Debug)]
pub struct SpanSampler {
    every: u64,
    counter: AtomicU64,
    next_id: AtomicU64,
}

impl SpanSampler {
    pub fn new(every: usize) -> SpanSampler {
        SpanSampler {
            every: every as u64,
            counter: AtomicU64::new(0),
            next_id: AtomicU64::new(0),
        }
    }

    pub fn every(&self) -> usize {
        self.every as usize
    }

    /// Admission counter — total requests offered to the sampler.
    pub fn offered(&self) -> u64 {
        self.counter.load(Ordering::Relaxed)
    }

    /// Spans started (sampled admissions).
    pub fn sampled(&self) -> u64 {
        self.next_id.load(Ordering::Relaxed)
    }

    /// Decide at admission: every N-th offer starts a trace.
    pub fn try_begin(&self, p: usize, now: f64) -> Option<SpanTrace> {
        if self.every == 0 {
            return None;
        }
        let n = self.counter.fetch_add(1, Ordering::Relaxed);
        if n % self.every != 0 {
            return None;
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed) as u32;
        Some(SpanTrace::new(id, p, now))
    }
}

/// Flush a completed trace as one burst of `Span*` records (when a log
/// is attached) and fold the durations into the live estimates (when a
/// collector is attached). Either sink may be absent — `/metrics` drift
/// works without a log file, and offline replay works without a
/// collector.
///
/// Emission rules (what the conservation property pins):
/// * exactly one `SpanQueue`, stamped at the admission instant with the
///   *total* cross-station queue wait;
/// * one `SpanTpu` iff the partition has a TPU prefix (`p > 0`), stamped
///   at `trace.tpu_end`;
/// * at most one `SpanSwap` (misses only — hit-path zeros would corrupt
///   swap-time calibration), same stamp;
/// * one `SpanCpu` iff a CPU suffix ran (`p < p_max`), stamped at
///   completion.
#[allow(clippy::too_many_arguments)]
pub fn emit_burst(
    log: Option<&EventLog>,
    device: usize,
    tenant: u64,
    class: SloClass,
    trace: &SpanTrace,
    cpu: f64,
    end: f64,
    p_max: usize,
    collector: Option<&SpanCollector>,
) {
    let p = trace.p as usize;
    let mut emit = |stage: Stage, t: f64, v: f64| {
        if let Some(log) = log {
            log.emit(Event::span(
                stage.kind(),
                t,
                device,
                tenant,
                class,
                trace.id,
                p,
                v,
            ));
        }
        if let Some(c) = collector {
            c.observe(device, tenant, p, stage, v);
        }
    };
    emit(Stage::Queued, trace.start, trace.queued);
    if p > 0 {
        if trace.swap > 0.0 {
            emit(Stage::Swap, trace.tpu_end, trace.swap);
        }
        emit(Stage::Tpu, trace.tpu_end, trace.tpu);
    }
    if p < p_max {
        emit(Stage::Cpu, end, cpu);
    }
}

/// Lock-free f64 accumulator: CAS loops over bit-cast atomics. Reads are
/// monitoring-grade (sum and count may be one observation apart under
/// concurrency), which is exactly what a scrape needs.
struct StageAcc {
    count: AtomicU64,
    sum_bits: AtomicU64,
    min_bits: AtomicU64,
    max_bits: AtomicU64,
}

impl StageAcc {
    fn new() -> StageAcc {
        StageAcc {
            count: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0.0f64.to_bits()),
            min_bits: AtomicU64::new(f64::INFINITY.to_bits()),
            max_bits: AtomicU64::new(f64::NEG_INFINITY.to_bits()),
        }
    }

    fn add(&self, v: f64) {
        let mut cur = self.sum_bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match self
                .sum_bits
                .compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => break,
                Err(c) => cur = c,
            }
        }
        let mut cur = self.min_bits.load(Ordering::Relaxed);
        while v < f64::from_bits(cur) {
            match self
                .min_bits
                .compare_exchange_weak(cur, v.to_bits(), Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => break,
                Err(c) => cur = c,
            }
        }
        let mut cur = self.max_bits.load(Ordering::Relaxed);
        while v > f64::from_bits(cur) {
            match self
                .max_bits
                .compare_exchange_weak(cur, v.to_bits(), Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => break,
                Err(c) => cur = c,
            }
        }
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    fn stats(&self) -> Option<StageStats> {
        let count = self.count.load(Ordering::Relaxed);
        if count == 0 {
            return None;
        }
        Some(StageStats {
            count,
            mean: f64::from_bits(self.sum_bits.load(Ordering::Relaxed)) / count as f64,
            min: f64::from_bits(self.min_bits.load(Ordering::Relaxed)),
            max: f64::from_bits(self.max_bits.load(Ordering::Relaxed)),
        })
    }
}

/// Snapshot of one (device, tenant, partition, stage) accumulator.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StageStats {
    pub count: u64,
    pub mean: f64,
    pub min: f64,
    pub max: f64,
}

impl StageStats {
    /// The calibration value: when every observation was identical
    /// (`min == max` — e.g. the DES's deterministic virtual times) the
    /// exact observed f64 is returned, preserving bit-identity through
    /// the mean division; otherwise the mean.
    pub fn estimate(&self) -> f64 {
        if self.min == self.max {
            self.min
        } else {
            self.mean
        }
    }
}

/// Per-(device, tenant, partition) stage snapshots keyed for the
/// profiled cost model: `(device, tenant-low-32, p)`.
pub type EstimateMap = BTreeMap<(u16, u64, u16), SpanEstimate>;

/// All four stage snapshots of one key.
#[derive(Debug, Clone, Copy, Default)]
pub struct SpanEstimate {
    stages: [Option<StageStats>; Stage::COUNT],
}

impl SpanEstimate {
    pub fn stage(&self, s: Stage) -> Option<StageStats> {
        self.stages[s.index()]
    }
}

const COLLECTOR_SLOTS: usize = 1024;

/// Fixed-size, allocation-free, lock-free fold of span durations into
/// per-(device, tenant, partition, stage) running estimates.
///
/// Open addressing over [`COLLECTOR_SLOTS`] slots: the key packs
/// `(device, tenant-low-32, p)` into a u64 (stored +1 so 0 means empty),
/// placed by Fibonacci hashing with linear probing. A full table drops
/// the observation and counts it ([`overflowed`](Self::overflowed)) —
/// the span path never blocks and never allocates.
pub struct SpanCollector {
    slots: Vec<Slot>,
    overflow: AtomicUsize,
}

struct Slot {
    /// `packed_key + 1`; 0 = empty.
    key: AtomicU64,
    accs: [StageAcc; Stage::COUNT],
}

fn pack_key(device: usize, tenant: u64, p: usize) -> u64 {
    ((device as u64 & 0xFFFF) << 48) | ((tenant & 0xFFFF_FFFF) << 16) | (p as u64 & 0xFFFF)
}

impl Default for SpanCollector {
    fn default() -> Self {
        SpanCollector::new()
    }
}

impl SpanCollector {
    pub fn new() -> SpanCollector {
        SpanCollector {
            slots: (0..COLLECTOR_SLOTS)
                .map(|_| Slot {
                    key: AtomicU64::new(0),
                    accs: std::array::from_fn(|_| StageAcc::new()),
                })
                .collect(),
            overflow: AtomicUsize::new(0),
        }
    }

    /// Observations dropped because every slot was taken by other keys.
    pub fn overflowed(&self) -> usize {
        self.overflow.load(Ordering::Relaxed)
    }

    /// Fold one stage duration. Lock-free; drops (and counts) on table
    /// overflow instead of blocking or allocating.
    pub fn observe(&self, device: usize, tenant: u64, p: usize, stage: Stage, v: f64) {
        if !v.is_finite() {
            return;
        }
        let key = pack_key(device, tenant, p) + 1;
        // Fibonacci hashing spreads the low-entropy packed keys.
        let start = (key.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 54) as usize % COLLECTOR_SLOTS;
        for i in 0..COLLECTOR_SLOTS {
            let slot = &self.slots[(start + i) % COLLECTOR_SLOTS];
            let cur = slot.key.load(Ordering::Acquire);
            let owned = if cur == key {
                true
            } else if cur == 0 {
                match slot.key.compare_exchange(
                    0,
                    key,
                    Ordering::AcqRel,
                    Ordering::Acquire,
                ) {
                    Ok(_) => true,
                    Err(won) => won == key,
                }
            } else {
                false
            };
            if owned {
                slot.accs[stage.index()].add(v);
                return;
            }
        }
        self.overflow.fetch_add(1, Ordering::Relaxed);
    }

    /// Fold one decoded log record (offline counterpart of the inline
    /// feed). Non-span records are ignored.
    pub fn fold_event(&self, ev: &Event) {
        if let Some(stage) = Stage::from_kind(ev.kind) {
            self.observe(
                ev.device as usize,
                ev.span_tenant(),
                ev.aux as usize,
                stage,
                ev.value,
            );
        }
    }

    /// Snapshot every populated key.
    pub fn estimates(&self) -> EstimateMap {
        let mut out = EstimateMap::new();
        for slot in &self.slots {
            let key = slot.key.load(Ordering::Acquire);
            if key == 0 {
                continue;
            }
            let packed = key - 1;
            let device = (packed >> 48) as u16;
            let tenant = (packed >> 16) & 0xFFFF_FFFF;
            let p = (packed & 0xFFFF) as u16;
            let mut est = SpanEstimate::default();
            let mut any = false;
            for stage in Stage::ALL {
                est.stages[stage.index()] = slot.accs[stage.index()].stats();
                any |= est.stages[stage.index()].is_some();
            }
            if any {
                out.insert((device, tenant, p), est);
            }
        }
        out
    }
}

/// Measured alternative to the analytic [`CostModel`]: per-prefix span
/// estimates override the analytic [`PrefixTables`] entries wherever a
/// calibration point exists; every uncalibrated entry stays analytic.
#[derive(Debug, Clone)]
pub struct ProfiledCostModel {
    analytic: CostModel,
    estimates: BTreeMap<(u16, u64, u16), [Option<f64>; Stage::COUNT]>,
}

impl ProfiledCostModel {
    /// No calibration points: behaves exactly like the analytic model.
    pub fn new(analytic: CostModel) -> ProfiledCostModel {
        ProfiledCostModel {
            analytic,
            estimates: BTreeMap::new(),
        }
    }

    /// Calibrate from a live collector snapshot.
    pub fn from_collector(analytic: CostModel, collector: &SpanCollector) -> ProfiledCostModel {
        Self::from_estimates(analytic, &collector.estimates())
    }

    /// Calibrate from decoded log records (the offline path `--profile`
    /// uses: replay a span-sampled log, fold, calibrate).
    pub fn from_events(analytic: CostModel, events: &[Event]) -> ProfiledCostModel {
        let c = SpanCollector::new();
        for ev in events {
            c.fold_event(ev);
        }
        Self::from_collector(analytic, &c)
    }

    pub fn from_estimates(analytic: CostModel, est: &EstimateMap) -> ProfiledCostModel {
        let estimates = est
            .iter()
            .map(|(k, e)| {
                let mut vals = [None; Stage::COUNT];
                for stage in Stage::ALL {
                    vals[stage.index()] = e.stage(stage).map(|s| s.estimate());
                }
                (*k, vals)
            })
            .collect();
        ProfiledCostModel {
            analytic,
            estimates,
        }
    }

    pub fn analytic(&self) -> &CostModel {
        &self.analytic
    }

    /// Calibrated (device, tenant, partition) points.
    pub fn calibrated_points(&self) -> usize {
        self.estimates.len()
    }

    /// Build prefix tables for `(device, tenant)`: analytic base, then
    /// measured overrides copied in verbatim. `SpanTpu` calibrates
    /// `tpu_service(p)` (p > 0), `SpanCpu` calibrates `cpu_service(p)`
    /// (p < P), `SpanSwap` calibrates `load_time(p)` (p > 0). Transfer
    /// and residency columns stay analytic (spans do not measure bus
    /// occupancy).
    pub fn tables(&self, device: usize, tenant: u64, meta: &ModelMeta) -> PrefixTables {
        let base = PrefixTables::new(&self.analytic, meta);
        let pp = meta.partition_points;
        let mut tpu = vec![None; pp + 1];
        let mut cpu = vec![None; pp + 1];
        let mut load = vec![None; pp + 1];
        for (p, ((t, c), l)) in tpu.iter_mut().zip(cpu.iter_mut()).zip(load.iter_mut()).enumerate()
        {
            let key = (
                device.min(u16::MAX as usize) as u16,
                tenant & 0xFFFF_FFFF,
                p as u16,
            );
            if let Some(vals) = self.estimates.get(&key) {
                if p > 0 {
                    *t = vals[Stage::Tpu.index()];
                    *l = vals[Stage::Swap.index()];
                }
                if p < pp {
                    *c = vals[Stage::Cpu.index()];
                }
            }
        }
        base.with_measured(&tpu, &cpu, &load)
    }
}

/// `observed / predicted` drift ratio; `None` when the prediction is
/// degenerate (zero/non-finite) or the observation is non-finite.
pub fn drift_ratio(observed: f64, predicted: f64) -> Option<f64> {
    if predicted > 0.0 && predicted.is_finite() && observed.is_finite() {
        Some(observed / predicted)
    } else {
        None
    }
}

/// Prometheus text-exposition writer: HELP/TYPE headers deduplicated by
/// metric name (scrapers reject repeated headers), label values escaped
/// per the spec, histograms rendered as quantile summaries.
#[derive(Debug, Default)]
pub struct PromWriter {
    out: String,
    seen: std::collections::BTreeSet<String>,
}

fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for ch in v.chars() {
        match ch {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

impl PromWriter {
    pub fn new() -> PromWriter {
        PromWriter::default()
    }

    /// Emit `# HELP` / `# TYPE` once per metric name.
    pub fn header(&mut self, name: &str, help: &str, kind: &str) {
        if self.seen.insert(name.to_string()) {
            self.out.push_str(&format!("# HELP {name} {help}\n"));
            self.out.push_str(&format!("# TYPE {name} {kind}\n"));
        }
    }

    fn labels(pairs: &[(&str, &str)]) -> String {
        if pairs.is_empty() {
            return String::new();
        }
        let inner: Vec<String> = pairs
            .iter()
            .map(|(k, v)| format!("{k}=\"{}\"", escape_label(v)))
            .collect();
        format!("{{{}}}", inner.join(","))
    }

    /// One integer-valued sample line.
    pub fn counter(&mut self, name: &str, pairs: &[(&str, &str)], v: u64) {
        self.out
            .push_str(&format!("{name}{} {v}\n", Self::labels(pairs)));
    }

    /// One float-valued sample line (Rust's shortest-roundtrip `Display`).
    pub fn gauge(&mut self, name: &str, pairs: &[(&str, &str)], v: f64) {
        self.out
            .push_str(&format!("{name}{} {v}\n", Self::labels(pairs)));
    }

    /// Render a latency histogram as a Prometheus summary: p50/p90/p99
    /// quantile series plus `_sum`/`_count`. Empty histograms emit only
    /// the zero `_count` (NaN quantiles are not useful series).
    pub fn summary(&mut self, name: &str, pairs: &[(&str, &str)], hist: &LatencyHistogram) {
        let count = hist.count();
        if count > 0 {
            for (q, pct) in [("0.5", 50.0), ("0.9", 90.0), ("0.99", 99.0)] {
                let mut with_q: Vec<(&str, &str)> = pairs.to_vec();
                with_q.push(("quantile", q));
                self.gauge(name, &with_q, hist.percentile(pct));
            }
            self.gauge(
                &format!("{name}_sum"),
                pairs,
                hist.mean() * count as f64,
            );
        }
        self.counter(&format!("{name}_count"), pairs, count);
    }

    pub fn finish(self) -> String {
        self.out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareSpec;
    use crate::model::synthetic_model;

    #[test]
    fn sampler_samples_one_in_n_and_allocates_ids() {
        let s = SpanSampler::new(4);
        let traces: Vec<SpanTrace> =
            (0..16).filter_map(|i| s.try_begin(3, i as f64)).collect();
        assert_eq!(traces.len(), 4);
        for (i, t) in traces.iter().enumerate() {
            assert_eq!(t.id, i as u32);
            assert_eq!(t.p, 3);
            assert_eq!(t.queued, 0.0);
            assert_eq!(t.mark, t.start);
        }
        assert_eq!(s.offered(), 16);
        assert_eq!(s.sampled(), 4);
    }

    #[test]
    fn sampler_zero_disables() {
        let s = SpanSampler::new(0);
        assert!(s.try_begin(1, 0.0).is_none());
        assert_eq!(s.offered(), 0);
    }

    #[test]
    fn collector_exact_for_constant_observations_mean_otherwise() {
        let c = SpanCollector::new();
        // The awkward f64 0.1 must round-trip exactly when constant.
        for _ in 0..3 {
            c.observe(1, 7, 2, Stage::Tpu, 0.1);
        }
        c.observe(1, 7, 2, Stage::Cpu, 1.0);
        c.observe(1, 7, 2, Stage::Cpu, 3.0);
        let est = c.estimates();
        let e = est[&(1, 7, 2)];
        let tpu = e.stage(Stage::Tpu).unwrap();
        assert_eq!(tpu.count, 3);
        assert_eq!(tpu.estimate(), 0.1, "constant observations are bit-exact");
        let cpu = e.stage(Stage::Cpu).unwrap();
        assert_eq!(cpu.estimate(), 2.0);
        assert_eq!(cpu.min, 1.0);
        assert_eq!(cpu.max, 3.0);
        assert!(e.stage(Stage::Swap).is_none());
        assert_eq!(c.overflowed(), 0);
    }

    #[test]
    fn collector_overflow_drops_and_counts() {
        let c = SpanCollector::new();
        for i in 0..(COLLECTOR_SLOTS + 10) as u64 {
            c.observe(0, i, 1, Stage::Queued, 0.5);
        }
        assert_eq!(c.overflowed(), 10);
        assert_eq!(c.estimates().len(), COLLECTOR_SLOTS);
    }

    #[test]
    fn collector_folds_log_records() {
        let c = SpanCollector::new();
        let ev = Event::span(
            EventKind::SpanTpu,
            5.0,
            2,
            9,
            SloClass::Standard,
            0,
            4,
            0.25,
        );
        c.fold_event(&ev);
        // Lifecycle records are ignored.
        c.fold_event(&Event::new(EventKind::Complete, 1.0, 2, 9, SloClass::Standard));
        let est = c.estimates();
        assert_eq!(est.len(), 1);
        assert_eq!(est[&(2, 9, 4)].stage(Stage::Tpu).unwrap().estimate(), 0.25);
    }

    #[test]
    fn emit_burst_produces_one_ordered_timeline() {
        let path = std::env::temp_dir().join(format!(
            "swapless-telemetry-burst-{}.log",
            std::process::id()
        ));
        let log = EventLog::create(&path).unwrap();
        let mut tr = SpanTrace::new(5, 3, 10.0);
        tr.queued = 0.004;
        tr.swap = 0.002;
        tr.tpu = 0.006;
        tr.tpu_end = 10.012;
        let c = SpanCollector::new();
        emit_burst(
            Some(&log),
            1,
            2,
            SloClass::Interactive,
            &tr,
            0.008,
            10.020,
            6,
            Some(&c),
        );
        log.close();
        let events = crate::eventlog::read_all(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        let kinds: Vec<EventKind> = events.iter().map(|e| e.kind).collect();
        assert_eq!(
            kinds,
            vec![
                EventKind::SpanQueue,
                EventKind::SpanSwap,
                EventKind::SpanTpu,
                EventKind::SpanCpu
            ]
        );
        for ev in &events {
            assert_eq!(ev.span_id(), 5);
            assert_eq!(ev.span_tenant(), 2);
            assert_eq!(ev.aux, 3);
        }
        // Monotone stamps, anchored at admission.
        assert_eq!(events[0].t, 10.0);
        assert!(events.windows(2).all(|w| w[0].t <= w[1].t));
        // Stage sum vs e2e: the residual is the transfer time.
        let e2e = events.last().unwrap().t - events[0].t;
        let sum: f64 = events.iter().map(|e| e.value).sum();
        assert!((e2e - sum).abs() < 0.05);
        // Inline fold observed all four stages.
        assert_eq!(c.estimates()[&(1, 2, 3)].stage(Stage::Swap).unwrap().count, 1);
    }

    #[test]
    fn emit_burst_edge_partitions_skip_absent_stages() {
        let path = std::env::temp_dir().join(format!(
            "swapless-telemetry-edge-{}.log",
            std::process::id()
        ));
        let log = EventLog::create(&path).unwrap();
        // p = 0: no TPU stage, no swap.
        let tr0 = SpanTrace::new(0, 0, 1.0);
        emit_burst(Some(&log), 0, 0, SloClass::Batch, &tr0, 0.5, 1.5, 4, None);
        // p = P on a cache hit: no CPU stage, no swap record.
        let mut trp = SpanTrace::new(1, 4, 2.0);
        trp.tpu = 0.25;
        trp.tpu_end = 2.3;
        emit_burst(Some(&log), 0, 0, SloClass::Batch, &trp, 0.0, 2.3, 4, None);
        log.close();
        let events = crate::eventlog::read_all(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        let kinds: Vec<EventKind> = events.iter().map(|e| e.kind).collect();
        assert_eq!(
            kinds,
            vec![
                EventKind::SpanQueue,
                EventKind::SpanCpu,
                EventKind::SpanQueue,
                EventKind::SpanTpu
            ]
        );
    }

    #[test]
    fn profiled_model_identity_without_calibration_and_verbatim_with() {
        let cost = CostModel::new(HardwareSpec::default());
        let m = synthetic_model("m", 4, 1_000_000, 100_000_000);
        let base = PrefixTables::new(&cost, &m);
        let pm = ProfiledCostModel::new(cost.clone());
        assert_eq!(pm.calibrated_points(), 0);
        let t = pm.tables(0, 0, &m);
        for p in 0..=4 {
            assert_eq!(t.tpu_service(p), base.tpu_service(p));
            assert_eq!(t.cpu_service(p), base.cpu_service(p));
            assert_eq!(t.load_time(p), base.load_time(p));
        }
        // One measured point lands verbatim; other keys unaffected.
        let c = SpanCollector::new();
        c.observe(0, 0, 2, Stage::Tpu, 0.125);
        let pm = ProfiledCostModel::from_collector(cost, &c);
        assert_eq!(pm.calibrated_points(), 1);
        let t = pm.tables(0, 0, &m);
        assert_eq!(t.tpu_service(2), 0.125);
        assert_eq!(t.tpu_service(1), base.tpu_service(1));
        // A different tenant/device sees pure analytic tables.
        let other = pm.tables(1, 0, &m);
        assert_eq!(other.tpu_service(2), base.tpu_service(2));
    }

    #[test]
    fn closing_the_loop_parity_from_analytic_spans() {
        // Spans whose durations are the analytic model's own table
        // values must calibrate a ProfiledCostModel whose tables are
        // bit-identical to the analytic ones — for every prefix.
        let cost = CostModel::new(HardwareSpec::default());
        let m = synthetic_model("loop", 6, 2_000_000, 400_000_000);
        let base = PrefixTables::new(&cost, &m);
        let mut events = Vec::new();
        for p in 0..=6usize {
            for rep in 0..3u32 {
                // Two spans per p with identical (analytic) durations —
                // min == max keeps the estimate bit-exact.
                if p > 0 {
                    events.push(Event::span(
                        EventKind::SpanTpu,
                        rep as f64,
                        0,
                        0,
                        SloClass::Standard,
                        rep,
                        p,
                        base.tpu_service(p),
                    ));
                    events.push(Event::span(
                        EventKind::SpanSwap,
                        rep as f64,
                        0,
                        0,
                        SloClass::Standard,
                        rep,
                        p,
                        base.load_time(p),
                    ));
                }
                if p < 6 {
                    events.push(Event::span(
                        EventKind::SpanCpu,
                        rep as f64,
                        0,
                        0,
                        SloClass::Standard,
                        rep,
                        p,
                        base.cpu_service(p),
                    ));
                }
            }
        }
        let pm = ProfiledCostModel::from_events(cost, &events);
        let t = pm.tables(0, 0, &m);
        for p in 0..=6 {
            assert_eq!(t.tpu_service(p), base.tpu_service(p), "tpu p={p}");
            assert_eq!(t.cpu_service(p), base.cpu_service(p), "cpu p={p}");
            assert_eq!(t.load_time(p), base.load_time(p), "load p={p}");
            assert_eq!(t.output_transfer(p), base.output_transfer(p));
        }
        assert_eq!(t.input_transfer(), base.input_transfer());
    }

    #[test]
    fn drift_ratio_guards_degenerate_predictions() {
        assert_eq!(drift_ratio(0.2, 0.1), Some(2.0));
        assert_eq!(drift_ratio(0.2, 0.0), None);
        assert_eq!(drift_ratio(f64::NAN, 0.1), None);
        assert_eq!(drift_ratio(0.2, f64::INFINITY), None);
    }

    #[test]
    fn prom_writer_escapes_labels_and_dedupes_headers() {
        let mut w = PromWriter::new();
        w.header("m_total", "a counter", "counter");
        w.header("m_total", "a counter", "counter"); // deduped
        w.counter("m_total", &[("name", "we\"ird\\mo\ndel")], 3);
        w.gauge("g", &[], 0.5);
        let mut h = LatencyHistogram::default();
        h.record(0.010);
        h.record(0.020);
        w.summary("lat_seconds", &[("class", "interactive")], &h);
        let empty = LatencyHistogram::default();
        w.summary("lat_seconds", &[("class", "batch")], &empty);
        let text = w.finish();
        assert_eq!(text.matches("# HELP m_total").count(), 1);
        assert!(text.contains("m_total{name=\"we\\\"ird\\\\mo\\ndel\"} 3"));
        assert!(text.contains("g 0.5"));
        assert!(text.contains("lat_seconds{class=\"interactive\",quantile=\"0.5\"}"));
        assert!(text.contains("lat_seconds_count{class=\"interactive\"} 2"));
        // Empty histogram: count line only, no NaN quantiles.
        assert!(text.contains("lat_seconds_count{class=\"batch\"} 0"));
        assert!(!text.contains("quantile=\"0.5\"} NaN"));
        // Every non-comment line is `name{labels} value`.
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            assert!(line.rsplit_once(' ').is_some(), "malformed line: {line}");
        }
    }
}
