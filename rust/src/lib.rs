//! # SwapLess
//!
//! Reproduction of *"Collaborative Processing for Multi-Tenant Inference on
//! Memory-Constrained Edge TPUs"* — an adaptive system that splits CNN
//! inference between a memory-constrained (Edge-TPU-like) accelerator and
//! host CPU cores, driven by an analytic queueing model and a greedy
//! hill-climbing resource allocator.
//!
//! Architecture (three layers, python never on the request path):
//! * L1 — Pallas kernels (`python/compile/kernels/`), AOT-lowered;
//! * L2 — JAX model zoo (`python/compile/`), one HLO artifact per segment;
//! * L3 — this crate: runtime (PJRT), device model, queueing model,
//!   allocator, discrete-event simulator, online coordinator, experiment
//!   harness regenerating every figure/table of the paper.
//!
//! See DESIGN.md for the full system inventory and experiment index.

pub mod alloc;
pub mod analytic;
pub mod config;
pub mod coordinator;
pub mod eventlog;
pub mod experiments;
pub mod fault;
pub mod fleet;
pub mod metrics;
pub mod model;
pub mod net;
pub mod profiler;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod telemetry;
pub mod tpu;
pub mod util;
pub mod workload;
