//! Precomputed per-model prefix-sum cost tables.
//!
//! Every [`CostModel`] query about a prefix `[1:p]` — TPU compute, CPU
//! suffix time, resident bytes, reload time, intra-model swap time,
//! boundary transfer — is a pure function of `(model, p)` that the naive
//! path recomputes by iterating the segment list (O(L) per call). The
//! allocator's hill climb issues O(n·P) such queries per decision, so the
//! segment iteration dominates decision latency (EXPERIMENTS.md §Perf).
//!
//! [`PrefixTables`] evaluates all of them once per model — O(P²) trivial
//! work at construction, reused across every candidate — and answers each
//! query in O(1). All sums are accumulated in the exact same left-to-right
//! order as the naive `CostModel` loops, so the table entries are
//! **bit-for-bit identical** to the values `CostModel` returns (asserted
//! by `prop_prefix_tables_bitexact` in `tests/property_tests.rs`).

use crate::model::ModelMeta;
use crate::tpu::CostModel;

/// O(1) per-prefix cost answers for one model under one [`CostModel`].
///
/// Invalidation: tables depend only on the model metadata and the
/// hardware spec, both immutable for the life of a tenant mix — rates and
/// core allocations do NOT enter, so one build serves every allocator
/// decision for that mix.
#[derive(Debug, Clone)]
pub struct PrefixTables {
    /// `P_i` — number of partition points (tables are indexed `0..=P`).
    pub partition_points: usize,
    /// `s^TPU(p)` — matches [`CostModel::tpu_service`].
    tpu_service: Vec<f64>,
    /// `s^CPU(p)` — matches [`CostModel::cpu_service`].
    cpu_service: Vec<f64>,
    /// Resident SRAM bytes — matches [`CostModel::resident_bytes`].
    resident_bytes: Vec<u64>,
    /// `T_load(p)` — matches [`CostModel::load_time`].
    load_time: Vec<f64>,
    /// Per-inference intra-model swap — matches [`CostModel::intra_swap_time`].
    intra_swap: Vec<f64>,
    /// `d_out(p)/B` — matches [`CostModel::output_transfer`].
    output_transfer: Vec<f64>,
    /// `d_in/B` — matches [`CostModel::input_transfer`].
    input_transfer: f64,
}

impl PrefixTables {
    pub fn new(cost: &CostModel, model: &ModelMeta) -> PrefixTables {
        let pp = model.partition_points;
        let mut tpu_service = vec![0.0; pp + 1];
        let mut cpu_service = vec![0.0; pp + 1];
        let mut resident_bytes = vec![0u64; pp + 1];
        let mut load_time = vec![0.0; pp + 1];
        let mut intra_swap = vec![0.0; pp + 1];
        let mut output_transfer = vec![0.0; pp + 1];

        // Prefix pass: weight bytes and TPU compute, accumulated in the
        // same order as the naive per-call loops.
        let mut weight_acc = 0u64;
        let mut compute_acc = 0.0f64;
        for p in 0..=pp {
            if p > 0 {
                let seg = &model.segments[p - 1];
                weight_acc += seg.sim_weight_bytes;
                compute_acc += cost.tpu_segment_time(model, seg);
            }
            let excess = weight_acc.saturating_sub(cost.hw.sram_bytes);
            intra_swap[p] = excess as f64 / cost.hw.bus_bytes_per_sec;
            resident_bytes[p] = weight_acc.min(cost.hw.sram_bytes);
            load_time[p] = resident_bytes[p] as f64 / cost.hw.bus_bytes_per_sec;
            tpu_service[p] = if p == 0 {
                0.0
            } else {
                cost.hw.tpu_dispatch_s + compute_acc + intra_swap[p]
            };
            output_transfer[p] = model.boundary_bytes(p) as f64 / cost.hw.bus_bytes_per_sec;
            // Suffix sums re-fold forward from p so rounding matches the
            // naive left-to-right accumulation exactly (a backward
            // running sum would differ in the last ulps). O(P²) once.
            cpu_service[p] = if p >= pp {
                0.0
            } else {
                let t1: f64 = model.segments[p..]
                    .iter()
                    .map(|s| cost.cpu_segment_time(s))
                    .sum();
                cost.hw.cpu_dispatch_s + t1
            };
        }

        PrefixTables {
            partition_points: pp,
            tpu_service,
            cpu_service,
            resident_bytes,
            load_time,
            intra_swap,
            output_transfer,
            input_transfer: model.input_bytes() as f64 / cost.hw.bus_bytes_per_sec,
        }
    }

    /// Clone with per-prefix *measured* overrides (from the telemetry
    /// span collector). Each slice is indexed `0..=P`; `None` keeps the
    /// analytic entry. Values are **copied verbatim, never
    /// re-accumulated**, so a table calibrated with the analytic model's
    /// own values is bit-for-bit identical to the uncalibrated one — the
    /// parity contract `ProfiledCostModel` relies on. Transfer and
    /// residency columns stay analytic: spans measure service stages,
    /// not bus occupancy.
    pub fn with_measured(
        &self,
        tpu_service: &[Option<f64>],
        cpu_service: &[Option<f64>],
        load_time: &[Option<f64>],
    ) -> PrefixTables {
        let mut t = self.clone();
        let apply = |col: &mut [f64], over: &[Option<f64>]| {
            for (slot, o) in col.iter_mut().zip(over) {
                if let Some(v) = o {
                    *slot = *v;
                }
            }
        };
        apply(&mut t.tpu_service, tpu_service);
        apply(&mut t.cpu_service, cpu_service);
        apply(&mut t.load_time, load_time);
        t
    }

    /// Build one table per tenant model (the common call site).
    pub fn for_tenants(cost: &CostModel, tenants: &[crate::analytic::Tenant]) -> Vec<PrefixTables> {
        tenants
            .iter()
            .map(|t| PrefixTables::new(cost, &t.model))
            .collect()
    }

    #[inline]
    pub fn tpu_service(&self, p: usize) -> f64 {
        self.tpu_service[p]
    }

    #[inline]
    pub fn cpu_service(&self, p: usize) -> f64 {
        self.cpu_service[p]
    }

    #[inline]
    pub fn resident_bytes(&self, p: usize) -> u64 {
        self.resident_bytes[p]
    }

    #[inline]
    pub fn load_time(&self, p: usize) -> f64 {
        self.load_time[p]
    }

    #[inline]
    pub fn intra_swap_time(&self, p: usize) -> f64 {
        self.intra_swap[p]
    }

    #[inline]
    pub fn output_transfer(&self, p: usize) -> f64 {
        self.output_transfer[p]
    }

    #[inline]
    pub fn input_transfer(&self) -> f64 {
        self.input_transfer
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareSpec;
    use crate::model::synthetic_model;

    fn check_model(name: &str, segs: usize, bytes: u64, flops: u64) {
        let cost = CostModel::new(HardwareSpec::default());
        let m = synthetic_model(name, segs, bytes, flops);
        let t = PrefixTables::new(&cost, &m);
        assert_eq!(t.partition_points, segs);
        for p in 0..=segs {
            assert_eq!(t.tpu_service(p), cost.tpu_service(&m, p), "tpu p={p}");
            assert_eq!(t.cpu_service(p), cost.cpu_service(&m, p), "cpu p={p}");
            assert_eq!(t.resident_bytes(p), cost.resident_bytes(&m, p), "res p={p}");
            assert_eq!(t.load_time(p), cost.load_time(&m, p), "load p={p}");
            assert_eq!(
                t.intra_swap_time(p),
                cost.intra_swap_time(&m, p),
                "swap p={p}"
            );
            assert_eq!(
                t.output_transfer(p),
                cost.output_transfer(&m, p),
                "out p={p}"
            );
        }
        assert_eq!(t.input_transfer(), cost.input_transfer(&m));
    }

    #[test]
    fn bitexact_small_model() {
        check_model("small", 4, 1_000_000, 100_000_000);
    }

    #[test]
    fn bitexact_oversized_model() {
        // 40 MB > 8 MB SRAM: exercises the intra-swap and capped-resident
        // branches.
        check_model("big", 8, 5_000_000, 1_000_000_000);
    }

    #[test]
    fn bitexact_single_segment() {
        check_model("tiny", 1, 500_000, 10_000_000);
    }

    #[test]
    fn with_measured_copies_overrides_and_keeps_the_rest() {
        let cost = CostModel::new(HardwareSpec::default());
        let m = synthetic_model("m", 4, 1_000_000, 100_000_000);
        let t = PrefixTables::new(&cost, &m);
        let none = vec![None; 5];
        // All-None calibration is the identity (bit-exact clone).
        let same = t.with_measured(&none, &none, &none);
        for p in 0..=4 {
            assert_eq!(same.tpu_service(p), t.tpu_service(p));
            assert_eq!(same.cpu_service(p), t.cpu_service(p));
            assert_eq!(same.load_time(p), t.load_time(p));
        }
        // A single override lands verbatim; neighbors untouched.
        let mut tpu = none.clone();
        tpu[2] = Some(0.125);
        let cal = t.with_measured(&tpu, &none, &none);
        assert_eq!(cal.tpu_service(2), 0.125);
        assert_eq!(cal.tpu_service(1), t.tpu_service(1));
        assert_eq!(cal.tpu_service(3), t.tpu_service(3));
        assert_eq!(cal.cpu_service(2), t.cpu_service(2));
        assert_eq!(cal.output_transfer(2), t.output_transfer(2));
    }

    #[test]
    fn endpoints() {
        let cost = CostModel::new(HardwareSpec::default());
        let m = synthetic_model("m", 6, 1_000_000, 500_000_000);
        let t = PrefixTables::new(&cost, &m);
        assert_eq!(t.tpu_service(0), 0.0);
        assert_eq!(t.cpu_service(6), 0.0);
        assert_eq!(t.resident_bytes(0), 0);
    }
}
