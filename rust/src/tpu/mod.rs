//! Edge-TPU device model: service-time cost model + SRAM weight cache.
//!
//! The paper's testbed phenomena (DESIGN.md §3) are functions of segment
//! metadata, reproduced here:
//!
//! * **Compute**: a segment's TPU time is its (paper-scale) FLOPs divided
//!   by the throughput the segment can extract from the systolic array.
//!   The TPU/CPU speedup of a segment follows the Fig. 3 shape: segments
//!   whose Pallas tiling fills the MXU get `tpu_speedup_max` over one CPU
//!   core; array-starved (late / depthwise / dense) segments decay toward
//!   `tpu_speedup_min` (≈ parity — the collaborative opportunity).
//! * **Intra-model swapping** (Fig. 1): a prefix larger than SRAM streams
//!   its excess weights from host memory on *every* inference.
//! * **Inter-model swapping** (Fig. 2): an LRU-approximated SRAM cache;
//!   a miss reloads the prefix's resident set over the bus (`T_load`).

pub mod cache;
pub mod prefix;

pub use cache::SramCache;
pub use prefix::PrefixTables;

use crate::config::HardwareSpec;
use crate::model::{ModelMeta, SegmentMeta};

/// Deterministic service-time model shared by the analytic queueing model,
/// the discrete-event simulator, and the online coordinator.
#[derive(Debug, Clone)]
pub struct CostModel {
    pub hw: HardwareSpec,
}

impl CostModel {
    pub fn new(hw: HardwareSpec) -> CostModel {
        CostModel { hw }
    }

    /// TPU-over-1-CPU-core speedup of one segment (Fig. 3 shape).
    ///
    /// The global `mxu_util_anchor` maps the Pallas kernels' array-fill
    /// estimates to speedups: segments at/above the anchor earn the full
    /// `tpu_speedup_max`; array-starved segments (late layers, depthwise,
    /// DenseNet-style small convs) decay toward `tpu_speedup_min`
    /// (DESIGN.md §3).
    pub fn segment_speedup(&self, model: &ModelMeta, seg: &SegmentMeta) -> f64 {
        let _ = model;
        let rel = seg.mxu_util / self.hw.mxu_util_anchor;
        (self.hw.tpu_speedup_max * rel).clamp(self.hw.tpu_speedup_min, self.hw.tpu_speedup_max)
    }

    /// One CPU core's time for a segment (no dispatch overhead).
    pub fn cpu_segment_time(&self, seg: &SegmentMeta) -> f64 {
        seg.sim_flops as f64 / self.hw.cpu_core_flops
    }

    /// TPU compute time for a segment (no dispatch, no swap).
    pub fn tpu_segment_time(&self, model: &ModelMeta, seg: &SegmentMeta) -> f64 {
        self.cpu_segment_time(seg) / self.segment_speedup(model, seg)
    }

    /// Pure compute time of the TPU prefix `[1:p]`, excluding dispatch/swap.
    pub fn tpu_prefix_compute(&self, model: &ModelMeta, p: usize) -> f64 {
        model.segments[..p]
            .iter()
            .map(|s| self.tpu_segment_time(model, s))
            .sum()
    }

    /// Per-inference intra-model swap time: the prefix bytes beyond SRAM
    /// capacity stream from host memory every execution (Fig. 1).
    pub fn intra_swap_time(&self, model: &ModelMeta, p: usize) -> f64 {
        let excess = model
            .prefix_weight_bytes(p)
            .saturating_sub(self.hw.sram_bytes);
        excess as f64 / self.hw.bus_bytes_per_sec
    }

    /// SRAM bytes the prefix keeps resident (the cacheable set).
    pub fn resident_bytes(&self, model: &ModelMeta, p: usize) -> u64 {
        model.prefix_weight_bytes(p).min(self.hw.sram_bytes)
    }

    /// `T_load` — inter-model swap latency: reload the prefix's resident
    /// weight set after eviction (Eq. 4 / Table I).
    pub fn load_time(&self, model: &ModelMeta, p: usize) -> f64 {
        self.resident_bytes(model, p) as f64 / self.hw.bus_bytes_per_sec
    }

    /// `s^TPU` — deterministic TPU service time of the prefix, including
    /// dispatch and intra-model swapping (but NOT the α·T_load reload,
    /// which is a per-request Bernoulli handled by the queueing model).
    pub fn tpu_service(&self, model: &ModelMeta, p: usize) -> f64 {
        if p == 0 {
            return 0.0;
        }
        self.hw.tpu_dispatch_s
            + self.tpu_prefix_compute(model, p)
            + self.intra_swap_time(model, p)
    }

    /// `s^CPU` — deterministic per-request CPU service time of the suffix
    /// `[p+1:P]`. One request executes single-threaded on one of the
    /// model's `k_i` dedicated cores; the cores act as the `k` parallel
    /// servers of the paper's M/D/k model (Eq. 3), so per-request service
    /// time does not depend on `k`.
    pub fn cpu_service(&self, model: &ModelMeta, p: usize) -> f64 {
        if p >= model.partition_points {
            return 0.0;
        }
        let t1: f64 = model.segments[p..]
            .iter()
            .map(|s| self.cpu_segment_time(s))
            .sum();
        self.hw.cpu_dispatch_s + t1
    }

    /// `d_in / B` — host→TPU input transfer (only when a prefix exists).
    pub fn input_transfer(&self, model: &ModelMeta) -> f64 {
        model.input_bytes() as f64 / self.hw.bus_bytes_per_sec
    }

    /// `d_out / B` — TPU→host transfer of the boundary tensor at p.
    pub fn output_transfer(&self, model: &ModelMeta, p: usize) -> f64 {
        model.boundary_bytes(p) as f64 / self.hw.bus_bytes_per_sec
    }

    /// Fraction of a full-TPU execution spent swapping (the Fig. 1 metric).
    pub fn intra_swap_fraction(&self, model: &ModelMeta) -> f64 {
        let p = model.partition_points;
        let swap = self.intra_swap_time(model, p);
        let total = self.tpu_service(model, p);
        if total == 0.0 {
            0.0
        } else {
            swap / total
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synthetic_model;

    fn cm() -> CostModel {
        CostModel::new(HardwareSpec::default())
    }

    #[test]
    fn speedup_respects_bounds_and_shape() {
        let m = synthetic_model("m", 6, 1_000_000, 500_000_000);
        let cm = cm();
        let first = cm.segment_speedup(&m, &m.segments[0]);
        let last = cm.segment_speedup(&m, &m.segments[5]);
        assert!(first > last, "early segments must be faster on TPU");
        assert!(first <= cm.hw.tpu_speedup_max + 1e-12);
        assert!(last >= cm.hw.tpu_speedup_min - 1e-12);
        // best segment of the model gets the max speedup (normalization)
        assert!((first - cm.hw.tpu_speedup_max).abs() < 1e-9);
    }

    #[test]
    fn small_model_no_intra_swap() {
        let m = synthetic_model("small", 4, 1_000_000, 100_000_000); // 4 MB < 8 MB
        assert_eq!(cm().intra_swap_time(&m, 4), 0.0);
        assert_eq!(cm().intra_swap_fraction(&m), 0.0);
    }

    #[test]
    fn big_model_intra_swap_positive_and_monotone() {
        let m = synthetic_model("big", 8, 5_000_000, 1_000_000_000); // 40 MB
        let cm = cm();
        assert_eq!(cm.intra_swap_time(&m, 1), 0.0); // 5 MB fits
        let s4 = cm.intra_swap_time(&m, 4); // 20 MB -> 12 MB excess
        let s8 = cm.intra_swap_time(&m, 8); // 40 MB -> 32 MB excess
        assert!(s4 > 0.0 && s8 > s4);
        let expected = (40_000_000u64 - 8 * 1024 * 1024) as f64 / cm.hw.bus_bytes_per_sec;
        assert!((s8 - expected).abs() < 1e-9);
    }

    #[test]
    fn load_time_caps_at_sram() {
        let m = synthetic_model("big", 8, 5_000_000, 1_000_000_000);
        let cm = cm();
        let full = cm.load_time(&m, 8);
        let cap = cm.hw.sram_bytes as f64 / cm.hw.bus_bytes_per_sec;
        assert!((full - cap).abs() < 1e-9);
        assert!(cm.load_time(&m, 1) < full);
    }

    #[test]
    fn service_time_zero_cases() {
        let m = synthetic_model("m", 4, 1_000_000, 100_000_000);
        let cm = cm();
        assert_eq!(cm.tpu_service(&m, 0), 0.0);
        assert_eq!(cm.cpu_service(&m, 4), 0.0);
    }

    #[test]
    fn cpu_service_shrinks_with_larger_prefix() {
        let m = synthetic_model("m", 4, 1_000_000, 1_000_000_000);
        let cm = cm();
        let t0 = cm.cpu_service(&m, 0);
        let t3 = cm.cpu_service(&m, 3);
        assert!(t3 < t0);
        let expect = 1_000_000_000.0 / cm.hw.cpu_core_flops + cm.hw.cpu_dispatch_s;
        assert!((t3 - expect).abs() < 1e-12);
    }

    #[test]
    fn fig1_shape_swap_fraction_grows_with_model_size() {
        let cm = cm();
        let small = synthetic_model("s", 5, 1_400_000 / 5, 200_000_000);
        let large = synthetic_model("l", 10, 4_320_000, 1_227_000_000); // 43.2 MB
        assert_eq!(cm.intra_swap_fraction(&small), 0.0);
        let f = cm.intra_swap_fraction(&large);
        assert!(f > 0.2 && f < 0.9, "fraction={f}");
    }
}
