//! SRAM weight cache: the shared-occupancy behaviour behind inter-model
//! swapping (Fig. 2) and the weight-miss probability α (Eq. 10).
//!
//! The real Edge TPU's eviction policy is proprietary; the paper
//! conservatively assumes any intervening request for a different model
//! evicts yours. This cache implements LRU over per-model resident sets,
//! which realizes exactly that behaviour whenever the aggregate footprint
//! exceeds capacity and requests interleave — and keeps everything
//! resident when the mix fits (the α = 0 regime).

use std::collections::HashMap;

#[derive(Debug, Clone)]
struct Entry {
    bytes: u64,
    last_use: u64,
}

#[derive(Debug, Clone)]
pub struct SramCache {
    capacity: u64,
    used: u64,
    clock: u64,
    entries: HashMap<usize, Entry>,
    hits: u64,
    misses: u64,
}

impl SramCache {
    pub fn new(capacity: u64) -> SramCache {
        SramCache {
            capacity,
            used: 0,
            clock: 0,
            entries: HashMap::new(),
            hits: 0,
            misses: 0,
        }
    }

    /// Execute model `id` with a resident weight set of `bytes`.
    /// Returns `true` on a hit (weights already resident), `false` on a
    /// miss (the caller pays `T_load`). Either way the model ends resident,
    /// evicting least-recently-used peers as needed.
    pub fn access(&mut self, id: usize, bytes: u64) -> bool {
        assert!(
            bytes <= self.capacity,
            "resident set {bytes} exceeds SRAM capacity {}",
            self.capacity
        );
        self.clock += 1;
        if bytes == 0 {
            // No TPU prefix — does not touch the cache.
            return true;
        }
        if let Some(e) = self.entries.get_mut(&id) {
            if e.bytes == bytes {
                e.last_use = self.clock;
                self.hits += 1;
                return true;
            }
            // Partition point changed — resident set must be rebuilt.
            self.used -= e.bytes;
            self.entries.remove(&id);
        }
        self.misses += 1;
        // Evict LRU entries until the new set fits.
        while self.used + bytes > self.capacity {
            let lru = self
                .entries
                .iter()
                .min_by_key(|(_, e)| e.last_use)
                .map(|(k, _)| *k)
                .expect("over capacity with no entries");
            let e = self.entries.remove(&lru).unwrap();
            self.used -= e.bytes;
        }
        self.used += bytes;
        self.entries.insert(
            id,
            Entry {
                bytes,
                last_use: self.clock,
            },
        );
        false
    }

    /// Drop a model's weights (model removed / partition reconfigured).
    pub fn invalidate(&mut self, id: usize) {
        if let Some(e) = self.entries.remove(&id) {
            self.used -= e.bytes;
        }
    }

    pub fn clear(&mut self) {
        self.entries.clear();
        self.used = 0;
    }

    pub fn resident(&self, id: usize) -> bool {
        self.entries.contains_key(&id)
    }

    pub fn used_bytes(&self) -> u64 {
        self.used
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            return f64::NAN;
        }
        self.hits as f64 / total as f64
    }

    pub fn counts(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fits_together_all_hits_after_warmup() {
        let mut c = SramCache::new(100);
        assert!(!c.access(1, 40)); // cold
        assert!(!c.access(2, 50)); // cold
        for _ in 0..10 {
            assert!(c.access(1, 40));
            assert!(c.access(2, 50));
        }
        assert_eq!(c.counts(), (20, 2));
    }

    #[test]
    fn over_capacity_interleaving_always_misses() {
        let mut c = SramCache::new(100);
        c.access(1, 80);
        c.access(2, 80);
        // 1 was evicted by 2; 2 will be evicted by 1; etc.
        for _ in 0..5 {
            assert!(!c.access(1, 80));
            assert!(!c.access(2, 80));
        }
    }

    #[test]
    fn single_tenant_over_capacity_stays_resident() {
        // Mirrors the paper's single-tenant observation: the resident set
        // (≤ C) persists across inferences of the same model.
        let mut c = SramCache::new(100);
        assert!(!c.access(1, 100));
        for _ in 0..10 {
            assert!(c.access(1, 100));
        }
    }

    #[test]
    fn lru_evicts_least_recent() {
        let mut c = SramCache::new(100);
        c.access(1, 40);
        c.access(2, 40);
        c.access(1, 40); // 2 is now LRU
        c.access(3, 40); // evicts 2
        assert!(c.resident(1));
        assert!(!c.resident(2));
        assert!(c.resident(3));
    }

    #[test]
    fn partition_change_invalidates() {
        let mut c = SramCache::new(100);
        c.access(1, 40);
        assert!(!c.access(1, 60)); // resident set size changed -> rebuild
        assert!(c.access(1, 60));
        assert_eq!(c.used_bytes(), 60);
    }

    #[test]
    fn zero_byte_access_is_noop_hit() {
        let mut c = SramCache::new(100);
        assert!(c.access(7, 0));
        assert_eq!(c.used_bytes(), 0);
    }

    #[test]
    fn invalidate_frees_space() {
        let mut c = SramCache::new(100);
        c.access(1, 100);
        c.invalidate(1);
        assert_eq!(c.used_bytes(), 0);
        assert!(!c.resident(1));
    }

    #[test]
    #[should_panic]
    fn oversized_resident_set_panics() {
        let mut c = SramCache::new(100);
        c.access(1, 101);
    }
}
