//! Per-tenant CPU executor pools with dynamically adjustable core gates.
//!
//! Each tenant owns an independent queue ordered by the shared
//! [`crate::sched`] core (the paper's performance-isolation design ran
//! FCFS; any [`DisciplineKind`] plugs in, and it is the *same* discipline
//! implementation the DES's CPU stations run). A fixed set of `K_max`
//! worker threads per tenant is spawned at [`CpuPools::add_pool`]; at any
//! moment only `k_i` of them may be *active* — the core gate — so
//! reallocation is a single atomic store, not a thread spawn/join (this
//! is what makes <2 ms reconfiguration possible). Pools are keyed by
//! stable [`TenantHandle`]s and created / destroyed at tenant attach /
//! detach: removing a pool fails its queued jobs cleanly ("tenant
//! detached") while in-flight jobs finish; the worker threads are reaped
//! when the pools object drops.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use anyhow::anyhow;

use crate::analytic::TenantHandle;
use crate::model::ModelMeta;
use crate::sched::{DisciplineKind, JobMeta, SchedQueue};

/// A unit of CPU suffix work.
pub struct CpuJob {
    /// The model whose suffix to run (resolved at submit time, so workers
    /// never need the tenant registry).
    pub meta: Arc<ModelMeta>,
    /// Partition point at admission time (suffix = segments [p, P)).
    pub p: usize,
    pub input: Vec<f32>,
    /// Called with the final output on completion (or the failure).
    pub done: Box<dyn FnOnce(anyhow::Result<Vec<f32>>) + Send>,
}

struct PoolShared {
    queue: Mutex<SchedQueue<CpuJob>>,
    cv: Condvar,
    /// Allowed concurrency (k_i) — the core gate.
    allowed: AtomicUsize,
    /// Currently executing workers.
    active: AtomicUsize,
    shutdown: AtomicBool,
}

struct PoolEntry {
    shared: Arc<PoolShared>,
    workers: Vec<JoinHandle<()>>,
}

type ExecFn = dyn Fn(&ModelMeta, usize, Vec<f32>) -> anyhow::Result<Vec<f32>> + Send + Sync;

pub struct CpuPools {
    k_max: usize,
    discipline: DisciplineKind,
    exec: Arc<ExecFn>,
    pools: Mutex<HashMap<TenantHandle, PoolEntry>>,
    /// Worker threads of removed pools, joined on drop.
    retired: Mutex<Vec<JoinHandle<()>>>,
}

impl CpuPools {
    /// Create an empty pool set. `exec` runs a suffix (it submits to the
    /// executor-service thread); `k_max` workers are spawned per attached
    /// tenant, each pool's queue ordered by `discipline`.
    pub fn new<F>(k_max: usize, discipline: DisciplineKind, exec: F) -> CpuPools
    where
        F: Fn(&ModelMeta, usize, Vec<f32>) -> anyhow::Result<Vec<f32>> + Send + Sync + 'static,
    {
        CpuPools {
            k_max,
            discipline,
            exec: Arc::new(exec),
            pools: Mutex::new(HashMap::new()),
            retired: Mutex::new(Vec::new()),
        }
    }

    /// Spawn a tenant's pool (k_max gated workers, initially 0 allowed).
    pub fn add_pool(&self, h: TenantHandle) {
        let shared = Arc::new(PoolShared {
            queue: Mutex::new(SchedQueue::with_kind(self.discipline)),
            cv: Condvar::new(),
            allowed: AtomicUsize::new(0),
            active: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
        });
        let mut workers = Vec::new();
        for w in 0..self.k_max.max(1) {
            let s = shared.clone();
            let exec = self.exec.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("cpu-pool-{}-{w}", h.0))
                    .spawn(move || worker_loop(s, exec))
                    .expect("spawn cpu pool worker"),
            );
        }
        self.pools
            .lock()
            .unwrap()
            .insert(h, PoolEntry { shared, workers });
    }

    /// Tear down a tenant's pool: queued jobs fail cleanly with a
    /// "detached" error, in-flight jobs finish, and the workers wind down
    /// (their join handles are reaped when the pools object drops).
    pub fn remove_pool(&self, h: TenantHandle) {
        let entry = self.pools.lock().unwrap().remove(&h);
        let Some(mut entry) = entry else { return };
        entry.shared.shutdown.store(true, Ordering::SeqCst);
        let drained: Vec<CpuJob> = entry
            .shared
            .queue
            .lock()
            .unwrap()
            .drain_all()
            .into_iter()
            .map(|(_, job)| job)
            .collect();
        entry.shared.cv.notify_all();
        self.retired.lock().unwrap().append(&mut entry.workers);
        for job in drained {
            (job.done)(Err(anyhow!("{h} detached before its job ran")));
        }
    }

    /// Enqueue a suffix job for `h` with its scheduling metadata (SLO
    /// class + predicted suffix service time). If the tenant has no pool
    /// (detached, or detaching concurrently), the job fails cleanly
    /// through its completion callback — submitters racing a detach never
    /// panic and never hang: the shutdown flag is re-checked under the
    /// queue lock, so a job can never land in a queue whose workers
    /// already exited (remove_pool stores the flag before draining).
    pub fn submit(&self, h: TenantHandle, meta: JobMeta, job: CpuJob) {
        let shared = self
            .pools
            .lock()
            .unwrap()
            .get(&h)
            .map(|e| e.shared.clone());
        match shared {
            Some(s) => {
                let rejected = {
                    let mut q = s.queue.lock().unwrap();
                    if s.shutdown.load(Ordering::SeqCst) {
                        Some(job)
                    } else {
                        q.push(meta, job);
                        None
                    }
                };
                match rejected {
                    None => s.cv.notify_one(),
                    Some(job) => {
                        (job.done)(Err(anyhow!("{h} detached before its job ran")))
                    }
                }
            }
            None => (job.done)(Err(anyhow!("{h} is not attached"))),
        }
    }

    /// Apply a new core allocation. O(1) per tenant; handles without a
    /// pool are skipped (they raced a detach).
    pub fn set_cores(&self, cores: &[(TenantHandle, usize)]) {
        let pools = self.pools.lock().unwrap();
        for (h, k) in cores {
            if let Some(e) = pools.get(h) {
                e.shared.allowed.store(*k, Ordering::SeqCst);
                e.shared.cv.notify_all();
            }
        }
    }

    pub fn queue_len(&self, h: TenantHandle) -> usize {
        self.pools
            .lock()
            .unwrap()
            .get(&h)
            .map(|e| e.shared.queue.lock().unwrap().len())
            .unwrap_or(0)
    }

    pub fn active(&self, h: TenantHandle) -> usize {
        self.pools
            .lock()
            .unwrap()
            .get(&h)
            .map(|e| e.shared.active.load(Ordering::SeqCst))
            .unwrap_or(0)
    }
}

fn worker_loop(s: Arc<PoolShared>, exec: Arc<ExecFn>) {
    loop {
        let job = {
            let mut q = s.queue.lock().unwrap();
            loop {
                if s.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                // Straggler drain: if k dropped to 0 with queued work, one
                // borrowed slot keeps requests from deadlocking (matches
                // the DES's drain rule).
                let allowed = s.allowed.load(Ordering::SeqCst).max(usize::from(!q.is_empty()));
                if !q.is_empty() && s.active.load(Ordering::SeqCst) < allowed {
                    s.active.fetch_add(1, Ordering::SeqCst);
                    break q.pop().unwrap().1;
                }
                q = s.cv.wait(q).unwrap();
            }
        };
        let CpuJob {
            meta,
            p,
            input,
            done,
        } = job;
        let result = exec(&meta, p, input);
        done(result);
        s.active.fetch_sub(1, Ordering::SeqCst);
        s.cv.notify_one();
    }
}

impl Drop for CpuPools {
    fn drop(&mut self) {
        let mut pools = self.pools.lock().unwrap();
        for entry in pools.values() {
            entry.shared.shutdown.store(true, Ordering::SeqCst);
            entry.shared.cv.notify_all();
        }
        for (_, entry) in pools.drain() {
            for w in entry.workers {
                let _ = w.join();
            }
        }
        drop(pools);
        for w in self.retired.lock().unwrap().drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synthetic_model;
    use std::sync::mpsc;

    fn meta() -> Arc<ModelMeta> {
        Arc::new(synthetic_model("m", 4, 1_000_000, 100_000_000))
    }

    fn job_meta(h: TenantHandle, class: crate::sched::SloClass) -> JobMeta {
        JobMeta {
            tenant: h,
            class,
            service_hint: 1e-3,
        }
    }

    fn std_meta(h: TenantHandle) -> JobMeta {
        job_meta(h, crate::sched::SloClass::Standard)
    }

    fn echo_pools(handles: &[TenantHandle], k: usize) -> CpuPools {
        let pools = CpuPools::new(k, DisciplineKind::Fifo, |_meta, _p, input| Ok(input));
        for h in handles {
            pools.add_pool(*h);
        }
        pools
    }

    #[test]
    fn jobs_complete() {
        let h0 = TenantHandle(0);
        let h1 = TenantHandle(1);
        let pools = echo_pools(&[h0, h1], 2);
        pools.set_cores(&[(h0, 1), (h1, 1)]);
        let (tx, rx) = mpsc::channel();
        let m = meta();
        for i in 0..10 {
            let tx = tx.clone();
            let h = if i % 2 == 0 { h0 } else { h1 };
            pools.submit(
                h,
                std_meta(h),
                CpuJob {
                    meta: m.clone(),
                    p: 0,
                    input: vec![i as f32],
                    done: Box::new(move |r| tx.send(r.unwrap()[0]).unwrap()),
                },
            );
        }
        let mut got: Vec<f32> = (0..10).map(|_| rx.recv().unwrap()).collect();
        got.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(got, (0..10).map(|i| i as f32).collect::<Vec<_>>());
    }

    #[test]
    fn concurrency_is_gated() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        static PEAK: AtomicUsize = AtomicUsize::new(0);
        static CUR: AtomicUsize = AtomicUsize::new(0);
        let h = TenantHandle(7);
        let pools = CpuPools::new(4, DisciplineKind::Fifo, |_meta, _p, input| {
            let c = CUR.fetch_add(1, Ordering::SeqCst) + 1;
            PEAK.fetch_max(c, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_millis(20));
            CUR.fetch_sub(1, Ordering::SeqCst);
            Ok(input)
        });
        pools.add_pool(h);
        pools.set_cores(&[(h, 2)]);
        let (tx, rx) = mpsc::channel();
        let m = meta();
        for _ in 0..8 {
            let tx = tx.clone();
            pools.submit(
                h,
                std_meta(h),
                CpuJob {
                    meta: m.clone(),
                    p: 0,
                    input: vec![0.0],
                    done: Box::new(move |_| tx.send(()).unwrap()),
                },
            );
        }
        for _ in 0..8 {
            rx.recv().unwrap();
        }
        assert!(PEAK.load(Ordering::SeqCst) <= 2, "peak={}", PEAK.load(Ordering::SeqCst));
    }

    #[test]
    fn zero_cores_still_drains() {
        let h = TenantHandle(3);
        let pools = echo_pools(&[h], 2);
        pools.set_cores(&[(h, 0)]);
        let (tx, rx) = mpsc::channel();
        pools.submit(
            h,
            std_meta(h),
            CpuJob {
                meta: meta(),
                p: 0,
                input: vec![7.0],
                done: Box::new(move |r| tx.send(r.unwrap()[0]).unwrap()),
            },
        );
        assert_eq!(rx.recv_timeout(std::time::Duration::from_secs(2)).unwrap(), 7.0);
    }

    #[test]
    fn submit_to_missing_pool_fails_cleanly() {
        let pools = echo_pools(&[], 2);
        let (tx, rx) = mpsc::channel();
        pools.submit(
            TenantHandle(9),
            std_meta(TenantHandle(9)),
            CpuJob {
                meta: meta(),
                p: 0,
                input: vec![1.0],
                done: Box::new(move |r| tx.send(r.is_err()).unwrap()),
            },
        );
        assert!(rx.recv().unwrap(), "job against missing pool must error");
    }

    #[test]
    fn priority_discipline_reorders_queued_jobs() {
        use crate::sched::SloClass;
        // One gated worker; the first job blocks on `gate` while the rest
        // queue up, so the pop order is the discipline's to choose:
        // strict priority must serve the interactive job before the batch
        // job even though batch was submitted first. `started` confirms
        // the blocker is executing (not merely queued) before the others
        // are submitted — no sleep-based races.
        let gate = Arc::new(AtomicBool::new(false));
        let started = Arc::new(AtomicBool::new(false));
        let g = gate.clone();
        let s = started.clone();
        let h = TenantHandle(5);
        let pools = CpuPools::new(1, DisciplineKind::Priority, move |_meta, _p, input| {
            if input[0] < 0.0 {
                s.store(true, Ordering::SeqCst);
                while !g.load(Ordering::SeqCst) {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                }
            }
            Ok(input)
        });
        pools.add_pool(h);
        pools.set_cores(&[(h, 1)]);
        let order = Arc::new(Mutex::new(Vec::<f32>::new()));
        let (tx, rx) = mpsc::channel();
        let m = meta();
        let submit = |class: SloClass, v: f32| {
            let order = order.clone();
            let tx = tx.clone();
            pools.submit(
                h,
                job_meta(h, class),
                CpuJob {
                    meta: m.clone(),
                    p: 0,
                    input: vec![v],
                    done: Box::new(move |r| {
                        order.lock().unwrap().push(r.unwrap()[0]);
                        tx.send(()).unwrap();
                    }),
                },
            );
        };
        submit(SloClass::Standard, -1.0); // blocker
        while !started.load(Ordering::SeqCst) {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        submit(SloClass::Batch, 1.0);
        submit(SloClass::Interactive, 2.0);
        gate.store(true, Ordering::SeqCst);
        for _ in 0..3 {
            rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        }
        assert_eq!(*order.lock().unwrap(), vec![-1.0, 2.0, 1.0]);
    }

    #[test]
    fn remove_pool_fails_queued_jobs_and_keeps_peers() {
        let ha = TenantHandle(1);
        let hb = TenantHandle(2);
        let pools = CpuPools::new(2, DisciplineKind::Fifo, |_meta, _p, input| {
            std::thread::sleep(std::time::Duration::from_millis(5));
            Ok(input)
        });
        pools.add_pool(ha);
        pools.add_pool(hb);
        // a gets no cores, so its queue holds everything we submit.
        pools.set_cores(&[(ha, 0), (hb, 1)]);
        // (the borrowed-slot drain rule serves one at a time anyway, so
        // queue several to guarantee some are still queued at removal)
        let (tx, rx) = mpsc::channel();
        let m = meta();
        for _ in 0..16 {
            let tx = tx.clone();
            pools.submit(
                ha,
                std_meta(ha),
                CpuJob {
                    meta: m.clone(),
                    p: 0,
                    input: vec![1.0],
                    done: Box::new(move |r| tx.send(r.is_ok()).unwrap()),
                },
            );
        }
        pools.remove_pool(ha);
        let results: Vec<bool> = (0..16).map(|_| rx.recv().unwrap()).collect();
        assert!(results.iter().any(|ok| !ok), "queued jobs must fail cleanly");
        // Peer pool is unaffected.
        let (tx2, rx2) = mpsc::channel();
        pools.submit(
            hb,
            std_meta(hb),
            CpuJob {
                meta: m,
                p: 0,
                input: vec![5.0],
                done: Box::new(move |r| tx2.send(r.unwrap()[0]).unwrap()),
            },
        );
        assert_eq!(rx2.recv_timeout(std::time::Duration::from_secs(2)).unwrap(), 5.0);
        // Double-remove is a no-op.
        pools.remove_pool(ha);
    }
}
