//! Per-tenant CPU executor pools with dynamically adjustable core gates
//! and a bounded admission layer.
//!
//! Each tenant owns an independent queue ordered by the shared
//! [`crate::sched`] core (the paper's performance-isolation design ran
//! FCFS; any [`DisciplineKind`] plugs in, and it is the *same* discipline
//! implementation the DES's CPU stations run). A fixed set of `K_max`
//! worker threads per tenant is spawned at [`CpuPools::add_pool`]; at any
//! moment only `k_i` of them may be *active* — the core gate — so
//! reallocation is a single atomic store, not a thread spawn/join (this
//! is what makes <2 ms reconfiguration possible). Pools are keyed by
//! stable [`TenantHandle`]s and created / destroyed at tenant attach /
//! detach: removing a pool fails its queued jobs with the typed
//! [`RequestError::Detached`] while in-flight jobs finish; the worker
//! threads are reaped when the pools object drops.
//!
//! Admission is bounded per station: [`CpuPools::submit`] offers the job
//! through [`SchedQueue::offer`] against the pool's capacity and
//! [`OverloadPolicy`] — the *same* admission code the DES's CPU stations
//! run — and every refused or evicted job resolves its completion
//! callback with a typed [`RequestError`], never a silent drop. Workers
//! additionally drain deadline-hopeless jobs before each service start
//! under `DeadlineDrop`, and honor request cancellation tokens before
//! execution.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::analytic::TenantHandle;
use crate::eventlog::{Event as LogEvent, EventKind as LogKind, EventLog};
use crate::model::ModelMeta;
use crate::sched::{
    DisciplineKind, JobMeta, Offer, OverloadPolicy, RejectReason, SchedQueue, StationLoad,
};
use crate::telemetry::{emit_burst, SpanCollector, SpanTrace};

use super::request::{CancelToken, RequestError};

/// A unit of CPU suffix work.
pub struct CpuJob {
    /// The model whose suffix to run (resolved at submit time, so workers
    /// never need the tenant registry).
    pub meta: Arc<ModelMeta>,
    /// Partition point at admission time (suffix = segments [p, P)).
    pub p: usize,
    pub input: Vec<f32>,
    /// Cancellation token of the originating request; checked before
    /// execution starts.
    pub cancel: CancelToken,
    /// Sampled stage timeline riding the request (None = unsampled);
    /// the worker flushes it as one `Span*` burst on success.
    pub trace: Option<SpanTrace>,
    /// Called with the final output on completion (or the typed failure).
    pub done: Box<dyn FnOnce(Result<Vec<f32>, RequestError>) + Send>,
}

struct PoolShared {
    queue: Mutex<SchedQueue<CpuJob>>,
    cv: Condvar,
    /// Allowed concurrency (k_i) — the core gate.
    allowed: AtomicUsize,
    /// Currently executing workers.
    active: AtomicUsize,
    shutdown: AtomicBool,
    /// Station clock origin (shared with the server), for deadlines.
    started: Instant,
    policy: OverloadPolicy,
    /// Station label for typed rejections (computed once per pool — the
    /// submit hot path never allocates it).
    station: String,
    /// Event log shared with the server (service-start records).
    log: Option<EventLog>,
    /// Fleet device index stamped on emitted records.
    device: usize,
    /// Span-duration sink shared with the server (`None` = standalone
    /// pools, e.g. in unit tests).
    collector: Option<Arc<SpanCollector>>,
}

struct PoolEntry {
    shared: Arc<PoolShared>,
    workers: Vec<JoinHandle<()>>,
}

type ExecFn = dyn Fn(&ModelMeta, usize, Vec<f32>) -> anyhow::Result<Vec<f32>> + Send + Sync;

pub struct CpuPools {
    k_max: usize,
    discipline: DisciplineKind,
    /// Bounded-admission settings applied to every tenant's queue.
    capacity: Option<usize>,
    policy: OverloadPolicy,
    started: Instant,
    /// Event log shared with the server (`None` = logging off).
    log: Option<EventLog>,
    /// Fleet device index stamped on emitted records.
    device: usize,
    /// Span-duration sink shared with the server's collector.
    collector: Option<Arc<SpanCollector>>,
    exec: Arc<ExecFn>,
    pools: Mutex<HashMap<TenantHandle, PoolEntry>>,
    /// Worker threads of removed pools, joined on drop.
    retired: Mutex<Vec<JoinHandle<()>>>,
}

impl CpuPools {
    /// Create an empty pool set. `exec` runs a suffix (it submits to the
    /// executor-service thread); `k_max` workers are spawned per attached
    /// tenant, each pool's queue ordered by `discipline` and admission
    /// bounded by `capacity`/`policy`. `started` is the clock origin that
    /// absolute job deadlines are measured against (the server's);
    /// `log`/`device` mirror the server's event-log attachment (workers
    /// emit service-start records); `collector` is the server's span
    /// sink — workers flush each sampled request's stage timeline there
    /// (and to `log`) at completion.
    #[allow(clippy::too_many_arguments)]
    pub fn new<F>(
        k_max: usize,
        discipline: DisciplineKind,
        capacity: Option<usize>,
        policy: OverloadPolicy,
        started: Instant,
        log: Option<EventLog>,
        device: usize,
        collector: Option<Arc<SpanCollector>>,
        exec: F,
    ) -> CpuPools
    where
        F: Fn(&ModelMeta, usize, Vec<f32>) -> anyhow::Result<Vec<f32>> + Send + Sync + 'static,
    {
        CpuPools {
            k_max,
            discipline,
            capacity,
            policy,
            started,
            log,
            device,
            collector,
            exec: Arc::new(exec),
            pools: Mutex::new(HashMap::new()),
            retired: Mutex::new(Vec::new()),
        }
    }

    /// Spawn a tenant's pool (k_max gated workers, initially 0 allowed).
    pub fn add_pool(&self, h: TenantHandle) {
        let shared = Arc::new(PoolShared {
            queue: Mutex::new(SchedQueue::with_kind(self.discipline)),
            cv: Condvar::new(),
            allowed: AtomicUsize::new(0),
            active: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
            started: self.started,
            policy: self.policy,
            station: format!("cpu {h}"),
            log: self.log.clone(),
            device: self.device,
            collector: self.collector.clone(),
        });
        let mut workers = Vec::new();
        for w in 0..self.k_max.max(1) {
            let s = shared.clone();
            let exec = self.exec.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("cpu-pool-{}-{w}", h.0))
                    .spawn(move || worker_loop(s, exec))
                    .expect("spawn cpu pool worker"),
            );
        }
        self.pools
            .lock()
            .unwrap()
            .insert(h, PoolEntry { shared, workers });
    }

    /// Tear down a tenant's pool: queued jobs fail with the typed
    /// `Detached` error, in-flight jobs finish, and the workers wind down
    /// (their join handles are reaped when the pools object drops).
    pub fn remove_pool(&self, h: TenantHandle) {
        let entry = self.pools.lock().unwrap().remove(&h);
        let Some(mut entry) = entry else { return };
        entry.shared.shutdown.store(true, Ordering::SeqCst);
        let drained: Vec<CpuJob> = entry
            .shared
            .queue
            .lock()
            .unwrap()
            .drain_all()
            .into_iter()
            .map(|(_, job)| job)
            .collect();
        entry.shared.cv.notify_all();
        self.retired.lock().unwrap().append(&mut entry.workers);
        for job in drained {
            (job.done)(Err(RequestError::Detached(h)));
        }
    }

    /// Offer a suffix job for `h` through the bounded admission layer.
    /// Returns `true` when the job was enqueued. Every other outcome —
    /// no pool (detached), full queue (`Reject`), no sheddable victim,
    /// hopeless deadline — resolves the job's completion callback with
    /// the typed [`RequestError`] before returning; evicted victims are
    /// resolved the same way. Submitters racing a detach never panic and
    /// never hang: the shutdown flag is re-checked under the queue lock,
    /// so a job can never land in a queue whose workers already exited
    /// (remove_pool stores the flag before draining).
    pub fn submit(&self, h: TenantHandle, meta: JobMeta, job: CpuJob) -> bool {
        let shared = self
            .pools
            .lock()
            .unwrap()
            .get(&h)
            .map(|e| e.shared.clone());
        let Some(s) = shared else {
            (job.done)(Err(RequestError::NotAttached(h)));
            return false;
        };
        let now = self.started.elapsed().as_secs_f64();
        let outcome = {
            let mut q = s.queue.lock().unwrap();
            if s.shutdown.load(Ordering::SeqCst) {
                Err(job)
            } else {
                let load = StationLoad {
                    in_service: s.active.load(Ordering::SeqCst),
                    servers: s.allowed.load(Ordering::SeqCst).max(1),
                };
                Ok(q.offer(meta, job, now, &s.station, self.capacity, self.policy, load))
            }
        };
        match outcome {
            Err(job) => {
                // Raced a detach between the map lookup and the lock.
                (job.done)(Err(RequestError::Detached(h)));
                false
            }
            Ok(Offer::Admitted { shed, expired }) => {
                s.cv.notify_one();
                resolve_evictions(now, &s.station, shed, expired);
                true
            }
            Ok(Offer::Rejected {
                meta,
                job,
                reason,
                expired,
            }) => {
                resolve_evictions(now, &s.station, Vec::new(), expired);
                match reason {
                    RejectReason::Overloaded(o) => (job.done)(Err(RequestError::Overloaded(o))),
                    RejectReason::Expired => (job.done)(Err(RequestError::DeadlineExceeded {
                        deadline_s: meta.deadline.unwrap_or(now),
                        now_s: now,
                    })),
                }
                false
            }
        }
    }

    /// Apply a new core allocation. O(1) per tenant; handles without a
    /// pool are skipped (they raced a detach).
    pub fn set_cores(&self, cores: &[(TenantHandle, usize)]) {
        let pools = self.pools.lock().unwrap();
        for (h, k) in cores {
            if let Some(e) = pools.get(h) {
                e.shared.allowed.store(*k, Ordering::SeqCst);
                e.shared.cv.notify_all();
            }
        }
    }

    pub fn queue_len(&self, h: TenantHandle) -> usize {
        self.pools
            .lock()
            .unwrap()
            .get(&h)
            .map(|e| e.shared.queue.lock().unwrap().len())
            .unwrap_or(0)
    }

    pub fn active(&self, h: TenantHandle) -> usize {
        self.pools
            .lock()
            .unwrap()
            .get(&h)
            .map(|e| e.shared.active.load(Ordering::SeqCst))
            .unwrap_or(0)
    }
}

/// Fail evicted jobs with their typed reasons (outside any queue lock).
fn resolve_evictions(
    now: f64,
    station: &str,
    shed: Vec<(JobMeta, CpuJob)>,
    expired: Vec<(JobMeta, CpuJob)>,
) {
    for (_, job) in shed {
        (job.done)(Err(RequestError::Shed {
            station: station.to_string(),
        }));
    }
    for (meta, job) in expired {
        (job.done)(Err(RequestError::DeadlineExceeded {
            deadline_s: meta.deadline.unwrap_or(now),
            now_s: now,
        }));
    }
}

fn worker_loop(s: Arc<PoolShared>, exec: Arc<ExecFn>) {
    loop {
        let (job, expired) = {
            let mut q = s.queue.lock().unwrap();
            loop {
                if s.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                // Deadline-hopeless jobs never reach execution: drained
                // here (and failed below, outside the lock) before the
                // pop decision — the DES's CPU stations apply the same
                // rule at service start.
                let mut expired_jobs = Vec::new();
                if s.policy == OverloadPolicy::DeadlineDrop && !q.is_empty() {
                    let now = s.started.elapsed().as_secs_f64();
                    expired_jobs = q.drain_expired(now);
                }
                // Straggler drain: if k dropped to 0 with queued work, one
                // borrowed slot keeps requests from deadlocking (matches
                // the DES's drain rule).
                let allowed = s.allowed.load(Ordering::SeqCst).max(usize::from(!q.is_empty()));
                if !q.is_empty() && s.active.load(Ordering::SeqCst) < allowed {
                    s.active.fetch_add(1, Ordering::SeqCst);
                    break (q.pop(), expired_jobs);
                }
                if !expired_jobs.is_empty() {
                    break (None, expired_jobs);
                }
                q = s.cv.wait(q).unwrap();
            }
        };
        if !expired.is_empty() {
            let now = s.started.elapsed().as_secs_f64();
            for (meta, j) in expired {
                (j.done)(Err(RequestError::DeadlineExceeded {
                    deadline_s: meta.deadline.unwrap_or(now),
                    now_s: now,
                }));
            }
        }
        let Some((jmeta, job)) = job else { continue };
        let CpuJob {
            meta,
            p,
            input,
            cancel,
            mut trace,
            done,
        } = job;
        if cancel.is_cancelled() {
            done(Err(RequestError::Cancelled));
        } else {
            let start = s.started.elapsed().as_secs_f64();
            if let Some(tr) = &mut trace {
                // The CPU-queue wait ends here: service is starting.
                tr.queued += (start - tr.mark).max(0.0);
                tr.mark = start;
            }
            if let Some(log) = &s.log {
                log.emit(LogEvent::new(
                    LogKind::Start,
                    start,
                    s.device,
                    jmeta.tenant.0,
                    jmeta.class,
                ));
            }
            let result = exec(&meta, p, input)
                .map_err(|e| RequestError::Execution(e.to_string()));
            if result.is_ok() {
                if let Some(tr) = &trace {
                    // Completion: flush the whole stage timeline in one
                    // burst (failed requests emit nothing — span
                    // conservation counts completed timelines only).
                    let end = s.started.elapsed().as_secs_f64();
                    emit_burst(
                        s.log.as_ref(),
                        s.device,
                        jmeta.tenant.0,
                        jmeta.class,
                        tr,
                        end - tr.mark,
                        end,
                        meta.partition_points,
                        s.collector.as_deref(),
                    );
                }
            }
            done(result);
        }
        s.active.fetch_sub(1, Ordering::SeqCst);
        s.cv.notify_one();
    }
}

impl Drop for CpuPools {
    fn drop(&mut self) {
        let mut pools = self.pools.lock().unwrap();
        for entry in pools.values() {
            entry.shared.shutdown.store(true, Ordering::SeqCst);
            entry.shared.cv.notify_all();
        }
        for (_, entry) in pools.drain() {
            for w in entry.workers {
                let _ = w.join();
            }
            // Deliver the typed shutdown error on every still-queued job
            // before its sender drops (workers are gone; no lock races).
            for (_, job) in entry.shared.queue.lock().unwrap().drain_all() {
                (job.done)(Err(RequestError::Shutdown));
            }
        }
        drop(pools);
        for w in self.retired.lock().unwrap().drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synthetic_model;
    use crate::sched::SloClass;
    use std::sync::mpsc;
    use std::time::Duration;

    fn meta() -> Arc<ModelMeta> {
        Arc::new(synthetic_model("m", 4, 1_000_000, 100_000_000))
    }

    fn job_meta(h: TenantHandle, class: SloClass) -> JobMeta {
        JobMeta {
            tenant: h,
            class,
            service_hint: 1e-3,
            deadline: None,
            device: 0,
        }
    }

    fn std_meta(h: TenantHandle) -> JobMeta {
        job_meta(h, SloClass::Standard)
    }

    fn echo_pools(handles: &[TenantHandle], k: usize) -> CpuPools {
        let pools = CpuPools::new(
            k,
            DisciplineKind::Fifo,
            None,
            OverloadPolicy::Block,
            Instant::now(),
            None,
            0,
            None,
            |_meta, _p, input| Ok(input),
        );
        for h in handles {
            pools.add_pool(*h);
        }
        pools
    }

    fn echo_job(input: Vec<f32>, done: Box<dyn FnOnce(Result<Vec<f32>, RequestError>) + Send>) -> CpuJob {
        CpuJob {
            meta: meta(),
            p: 0,
            input,
            cancel: CancelToken::new(),
            trace: None,
            done,
        }
    }

    #[test]
    fn jobs_complete() {
        let h0 = TenantHandle(0);
        let h1 = TenantHandle(1);
        let pools = echo_pools(&[h0, h1], 2);
        pools.set_cores(&[(h0, 1), (h1, 1)]);
        let (tx, rx) = mpsc::channel();
        for i in 0..10 {
            let tx = tx.clone();
            let h = if i % 2 == 0 { h0 } else { h1 };
            pools.submit(
                h,
                std_meta(h),
                echo_job(
                    vec![i as f32],
                    Box::new(move |r| tx.send(r.unwrap()[0]).unwrap()),
                ),
            );
        }
        let mut got: Vec<f32> = (0..10).map(|_| rx.recv().unwrap()).collect();
        got.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(got, (0..10).map(|i| i as f32).collect::<Vec<_>>());
    }

    #[test]
    fn concurrency_is_gated() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        static PEAK: AtomicUsize = AtomicUsize::new(0);
        static CUR: AtomicUsize = AtomicUsize::new(0);
        let h = TenantHandle(7);
        let pools = CpuPools::new(
            4,
            DisciplineKind::Fifo,
            None,
            OverloadPolicy::Block,
            Instant::now(),
            None,
            0,
            None,
            |_meta, _p, input| {
                let c = CUR.fetch_add(1, Ordering::SeqCst) + 1;
                PEAK.fetch_max(c, Ordering::SeqCst);
                std::thread::sleep(Duration::from_millis(20));
                CUR.fetch_sub(1, Ordering::SeqCst);
                Ok(input)
            },
        );
        pools.add_pool(h);
        pools.set_cores(&[(h, 2)]);
        let (tx, rx) = mpsc::channel();
        for _ in 0..8 {
            let tx = tx.clone();
            pools.submit(
                h,
                std_meta(h),
                echo_job(vec![0.0], Box::new(move |_| tx.send(()).unwrap())),
            );
        }
        for _ in 0..8 {
            rx.recv().unwrap();
        }
        assert!(PEAK.load(Ordering::SeqCst) <= 2, "peak={}", PEAK.load(Ordering::SeqCst));
    }

    #[test]
    fn zero_cores_still_drains() {
        let h = TenantHandle(3);
        let pools = echo_pools(&[h], 2);
        pools.set_cores(&[(h, 0)]);
        let (tx, rx) = mpsc::channel();
        pools.submit(
            h,
            std_meta(h),
            echo_job(vec![7.0], Box::new(move |r| tx.send(r.unwrap()[0]).unwrap())),
        );
        assert_eq!(rx.recv_timeout(Duration::from_secs(2)).unwrap(), 7.0);
    }

    #[test]
    fn submit_to_missing_pool_fails_cleanly() {
        let pools = echo_pools(&[], 2);
        let (tx, rx) = mpsc::channel();
        let admitted = pools.submit(
            TenantHandle(9),
            std_meta(TenantHandle(9)),
            echo_job(
                vec![1.0],
                Box::new(move |r| {
                    tx.send(matches!(r, Err(RequestError::NotAttached(_)))).unwrap()
                }),
            ),
        );
        assert!(!admitted);
        assert!(rx.recv().unwrap(), "job against missing pool must error typed");
    }

    #[test]
    fn reject_policy_bounds_queue_and_types_error() {
        // One gated worker blocks on the first job; capacity 2 with
        // Reject: beyond queued+in-flight = 2 every submit is refused
        // with a typed Overloaded carrying depth and the wait estimate.
        let gate = Arc::new(AtomicBool::new(false));
        let g = gate.clone();
        let h = TenantHandle(4);
        let pools = CpuPools::new(
            1,
            DisciplineKind::Fifo,
            Some(2),
            OverloadPolicy::Reject,
            Instant::now(),
            None,
            0,
            None,
            move |_meta, _p, input| {
                while !g.load(Ordering::SeqCst) {
                    std::thread::sleep(Duration::from_millis(1));
                }
                Ok(input)
            },
        );
        pools.add_pool(h);
        pools.set_cores(&[(h, 1)]);
        let (tx, rx) = mpsc::channel();
        let mut admitted = 0;
        for i in 0..6 {
            let tx = tx.clone();
            if pools.submit(
                h,
                std_meta(h),
                echo_job(
                    vec![i as f32],
                    Box::new(move |r| tx.send(r.map_err(|e| format!("{e}"))).unwrap()),
                ),
            ) {
                admitted += 1;
            }
            // Let the worker pick up the first job so in-service counts.
            if i == 0 {
                let deadline = Instant::now() + Duration::from_secs(2);
                while pools.active(h) == 0 && Instant::now() < deadline {
                    std::thread::sleep(Duration::from_millis(1));
                }
            }
        }
        // In-flight blocker + at most 2 occupancy: 2 admitted, 4 refused
        // (the refusals resolved synchronously through their callbacks).
        assert_eq!(admitted, 2, "cap 2 must admit exactly 2");
        let mut rejected = 0;
        for _ in 0..4 {
            let r = rx.recv_timeout(Duration::from_secs(2)).unwrap();
            let e = r.expect_err("refused job must error");
            assert!(e.contains("overloaded"), "unexpected error: {e}");
            rejected += 1;
        }
        assert_eq!(rejected, 4);
        gate.store(true, Ordering::SeqCst);
        for _ in 0..2 {
            rx.recv_timeout(Duration::from_secs(2)).unwrap().unwrap();
        }
    }

    #[test]
    fn cancelled_job_skips_execution() {
        use std::sync::atomic::AtomicUsize;
        let h = TenantHandle(6);
        let ran = Arc::new(AtomicUsize::new(0));
        let gate = Arc::new(AtomicBool::new(false));
        let ran2 = ran.clone();
        let g = gate.clone();
        let pools = CpuPools::new(
            1,
            DisciplineKind::Fifo,
            None,
            OverloadPolicy::Block,
            Instant::now(),
            None,
            0,
            None,
            move |_meta, _p, input| {
                ran2.fetch_add(1, Ordering::SeqCst);
                while !g.load(Ordering::SeqCst) {
                    std::thread::sleep(Duration::from_millis(1));
                }
                Ok(input)
            },
        );
        pools.add_pool(h);
        pools.set_cores(&[(h, 1)]);
        let (tx, rx) = mpsc::channel();
        // First job occupies the single worker (blocked on the gate); the
        // second is cancelled while still queued, so it must resolve with
        // Cancelled without ever reaching the exec closure.
        let tx1 = tx.clone();
        pools.submit(
            h,
            std_meta(h),
            echo_job(vec![1.0], Box::new(move |r| tx1.send(r.is_ok()).unwrap())),
        );
        let deadline = Instant::now() + Duration::from_secs(2);
        while ran.load(Ordering::SeqCst) == 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(1));
        }
        let cancel = CancelToken::new();
        let tx2 = tx.clone();
        pools.submit(
            h,
            std_meta(h),
            CpuJob {
                meta: meta(),
                p: 0,
                input: vec![2.0],
                cancel: cancel.clone(),
                trace: None,
                done: Box::new(move |r| {
                    tx2.send(matches!(r, Err(RequestError::Cancelled))).unwrap()
                }),
            },
        );
        cancel.cancel();
        gate.store(true, Ordering::SeqCst);
        assert!(rx.recv_timeout(Duration::from_secs(2)).unwrap());
        assert!(rx.recv_timeout(Duration::from_secs(2)).unwrap());
        assert_eq!(ran.load(Ordering::SeqCst), 1, "cancelled job must not execute");
    }

    #[test]
    fn priority_discipline_reorders_queued_jobs() {
        // One gated worker; the first job blocks on `gate` while the rest
        // queue up, so the pop order is the discipline's to choose:
        // strict priority must serve the interactive job before the batch
        // job even though batch was submitted first. `started` confirms
        // the blocker is executing (not merely queued) before the others
        // are submitted — no sleep-based races.
        let gate = Arc::new(AtomicBool::new(false));
        let started = Arc::new(AtomicBool::new(false));
        let g = gate.clone();
        let s = started.clone();
        let h = TenantHandle(5);
        let pools = CpuPools::new(
            1,
            DisciplineKind::Priority,
            None,
            OverloadPolicy::Block,
            Instant::now(),
            None,
            0,
            None,
            move |_meta, _p, input| {
                if input[0] < 0.0 {
                    s.store(true, Ordering::SeqCst);
                    while !g.load(Ordering::SeqCst) {
                        std::thread::sleep(Duration::from_millis(1));
                    }
                }
                Ok(input)
            },
        );
        pools.add_pool(h);
        pools.set_cores(&[(h, 1)]);
        let order = Arc::new(Mutex::new(Vec::<f32>::new()));
        let (tx, rx) = mpsc::channel();
        let submit = |class: SloClass, v: f32| {
            let order = order.clone();
            let tx = tx.clone();
            pools.submit(
                h,
                job_meta(h, class),
                echo_job(
                    vec![v],
                    Box::new(move |r| {
                        order.lock().unwrap().push(r.unwrap()[0]);
                        tx.send(()).unwrap();
                    }),
                ),
            );
        };
        submit(SloClass::Standard, -1.0); // blocker
        while !started.load(Ordering::SeqCst) {
            std::thread::sleep(Duration::from_millis(1));
        }
        submit(SloClass::Batch, 1.0);
        submit(SloClass::Interactive, 2.0);
        gate.store(true, Ordering::SeqCst);
        for _ in 0..3 {
            rx.recv_timeout(Duration::from_secs(5)).unwrap();
        }
        assert_eq!(*order.lock().unwrap(), vec![-1.0, 2.0, 1.0]);
    }

    #[test]
    fn remove_pool_fails_queued_jobs_and_keeps_peers() {
        let ha = TenantHandle(1);
        let hb = TenantHandle(2);
        let pools = CpuPools::new(
            2,
            DisciplineKind::Fifo,
            None,
            OverloadPolicy::Block,
            Instant::now(),
            None,
            0,
            None,
            |_meta, _p, input| {
                std::thread::sleep(Duration::from_millis(5));
                Ok(input)
            },
        );
        pools.add_pool(ha);
        pools.add_pool(hb);
        // a gets no cores, so its queue holds everything we submit.
        pools.set_cores(&[(ha, 0), (hb, 1)]);
        // (the borrowed-slot drain rule serves one at a time anyway, so
        // queue several to guarantee some are still queued at removal)
        let (tx, rx) = mpsc::channel();
        for _ in 0..16 {
            let tx = tx.clone();
            pools.submit(
                ha,
                std_meta(ha),
                echo_job(
                    vec![1.0],
                    Box::new(move |r| {
                        let detached = matches!(&r, Err(RequestError::Detached(_)));
                        tx.send((r.is_ok(), detached)).unwrap()
                    }),
                ),
            );
        }
        pools.remove_pool(ha);
        let results: Vec<(bool, bool)> = (0..16).map(|_| rx.recv().unwrap()).collect();
        assert!(
            results.iter().any(|(ok, detached)| !ok && *detached),
            "queued jobs must fail with the typed Detached error"
        );
        // Peer pool is unaffected.
        let (tx2, rx2) = mpsc::channel();
        pools.submit(
            hb,
            std_meta(hb),
            echo_job(vec![5.0], Box::new(move |r| tx2.send(r.unwrap()[0]).unwrap())),
        );
        assert_eq!(rx2.recv_timeout(Duration::from_secs(2)).unwrap(), 5.0);
        // Double-remove is a no-op.
        pools.remove_pool(ha);
    }
}
